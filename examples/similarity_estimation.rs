//! Similarity estimation across the whole ρ range, comparing all four
//! schemes against the paper's asymptotic theory (Theorems 2–4), plus
//! the contingency-table MLE extension (paper Section 7).
//!
//! ```bash
//! cargo run --release --example similarity_estimation
//! ```

use crp::coding::{CodingParams, Scheme};
use crp::data::pairs::bivariate_normal_batch;
use crp::estimator::{CollisionEstimator, TwoBitMle};

fn main() {
    let k = 1024;
    let w = 0.75;
    let reps = 200u64;
    println!("k = {k}, w = {w}, {reps} repetitions per cell\n");
    println!(
        "{:>5} {:>10} | {:>21} {:>21} {:>21} {:>21} {:>21}",
        "rho",
        "",
        "h_w",
        "h_wq",
        "h_w2",
        "h_1",
        "h_w2 MLE"
    );

    let mle = TwoBitMle::new_default(w);
    for &rho in &[0.1, 0.3, 0.5, 0.7, 0.9, 0.95] {
        let mut line = format!("{rho:>5.2} {:>10} |", "k*Var/V");
        for scheme in [
            Scheme::Uniform,
            Scheme::WindowOffset,
            Scheme::TwoBit,
            Scheme::OneBit,
        ] {
            let wv = if scheme == Scheme::OneBit { 0.0 } else { w };
            let params = CodingParams::new(scheme, wv);
            let est = CollisionEstimator::new(params.clone());
            let mut sum = 0.0;
            let mut sumsq = 0.0;
            for r in 0..reps {
                let (x, y) = bivariate_normal_batch(k, rho, 1000 + r * 13);
                let e = est.estimate(&params.encode(&x), &params.encode(&y));
                sum += e;
                sumsq += e * e;
            }
            let mean = sum / reps as f64;
            let var = (sumsq / reps as f64 - mean * mean).max(0.0);
            let theory = scheme.variance_factor(rho, wv) / k as f64;
            line.push_str(&format!(
                " {:>8.4}±{:<5.4} r={:<4.2}",
                mean,
                var.sqrt(),
                var / theory
            ));
        }
        // MLE on the 2-bit codes.
        {
            let params = CodingParams::new(Scheme::TwoBit, w);
            let mut sum = 0.0;
            let mut sumsq = 0.0;
            for r in 0..reps {
                let (x, y) = bivariate_normal_batch(k, rho, 1000 + r * 13);
                let e = mle.estimate(&params.encode(&x), &params.encode(&y));
                sum += e;
                sumsq += e * e;
            }
            let mean = sum / reps as f64;
            let var = (sumsq / reps as f64 - mean * mean).max(0.0);
            line.push_str(&format!(" {:>8.4}±{:<5.4}      ", mean, var.sqrt()));
        }
        println!("{line}");
    }
    println!(
        "\nr = empirical variance / asymptotic theory (Theorems 2-4): ≈1 everywhere"
    );
    println!("confirms the delta-method analysis; the h_wq column shows the");
    println!("baseline's larger errors at this w, matching Figure 4.");
}
