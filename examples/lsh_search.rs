//! Near-neighbor search with coded-projection LSH (Section 1.1's
//! motivating application): build an index per coding scheme, plant
//! near-duplicates, and compare recall vs candidate cost.
//!
//! ```bash
//! cargo run --release --example lsh_search
//! ```

use crp::coding::{CodingParams, Scheme};
use crp::lsh::eval::evaluate_lsh_noise;
use crp::lsh::LshParams;

fn main() {
    let corpus = 3000;
    let dim = 64;
    let queries = 150;
    println!(
        "LSH duplicate-retrieval: corpus={corpus}, dim={dim}, {queries} queries"
    );
    println!("query = corpus item + per-coord noise (rho ≈ 0.93)\n");
    println!(
        "{:<14} {:>5} {:>11} {:>9} {:>13} {:>16}",
        "scheme", "w", "k/table", "tables", "recall@10", "candidate_frac"
    );
    for (scheme, w) in [
        (Scheme::Uniform, 1.0),
        (Scheme::WindowOffset, 1.0),
        (Scheme::TwoBit, 0.75),
        (Scheme::OneBit, 0.0),
    ] {
        for &(kpt, tables) in &[(4usize, 8usize), (6, 16)] {
            let params = LshParams {
                coding: CodingParams::new(scheme, w),
                k_per_table: kpt,
                n_tables: tables,
                seed: 7,
            };
            let r = evaluate_lsh_noise(params, corpus, dim, queries, 99, 0.05);
            println!(
                "{:<14} {:>5.2} {:>11} {:>9} {:>13.3} {:>16.4}",
                r.scheme, r.w, r.k_per_table, r.n_tables, r.recall_at_10, r.candidate_frac
            );
        }
    }
    println!("\nHigher recall at equal candidate cost = better hash family.");
    println!("h_w / h_{{w,2}} buckets separate dissimilar points that the");
    println!("offset scheme h_{{w,q}} merges at large w (paper Figure 1).");
}
