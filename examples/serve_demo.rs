//! Serving demo: start the coordinator in-process, register a corpus of
//! vectors over TCP, then run similarity and kNN queries — the full L3
//! request path (router → dynamic batcher → projector → packed store).
//!
//! ```bash
//! cargo run --release --example serve_demo
//! ```

use std::sync::Arc;

use crp::coordinator::server::{serve, ServerConfig};
use crp::coordinator::SketchClient;
use crp::projection::{ProjectionConfig, Projector};

fn main() -> crp::Result<()> {
    // Start the service on an ephemeral port.
    let projector = Arc::new(Projector::new_cpu(ProjectionConfig {
        k: 512,
        seed: 0,
        ..Default::default()
    }));
    let cfg = ServerConfig {
        addr: "127.0.0.1:0".into(),
        ..Default::default()
    };
    let (tx, rx) = std::sync::mpsc::channel();
    std::thread::spawn(move || {
        let _ = serve(projector, cfg, Some(tx));
    });
    let addr = rx.recv()?.to_string();
    println!("sketch service listening on {addr}");

    // Register a corpus with planted similarity structure.
    let mut client = SketchClient::connect(&addr)?;
    let dim = 256;
    let (anchor, near) = crp::data::pairs::unit_pair_with_rho(dim, 0.92, 5);
    let (_, mid) = crp::data::pairs::unit_pair_with_rho(dim, 0.5, 5);
    client.register("anchor", anchor.clone())?;
    client.register("near", near)?;
    client.register("mid", mid)?;
    for i in 0..200 {
        let (r, _) = crp::data::pairs::unit_pair_with_rho(dim, 0.0, 100 + i);
        client.register(&format!("noise-{i}"), r)?;
    }
    println!("registered 203 vectors (codes only are stored)\n");

    // Pairwise similarity estimates from the packed sketches.
    for other in ["near", "mid", "noise-0"] {
        let (rho, err) = client.estimate("anchor", other)?;
        println!("rho(anchor, {other:<8}) = {rho:>6.3} ± {err:.3}");
    }

    // kNN over the sketch store.
    let hits = client.knn(anchor, 5)?;
    println!("\ntop-5 neighbors of anchor:");
    for h in &hits {
        println!("  {:<10} rho ≈ {:.3}", h.id, h.rho);
    }
    assert_eq!(hits[0].id, "anchor");
    assert_eq!(hits[1].id, "near");

    let stats = client.stats()?;
    println!(
        "\nstats: {} registered, {} estimates, {} knn, mean batch {:.1}, p50 register {}us",
        stats.registered,
        stats.estimates,
        stats.knn_queries,
        stats.mean_batch_size,
        stats.p50_register_us
    );
    Ok(())
}
