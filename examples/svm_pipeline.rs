//! End-to-end driver (the headline E2E validation): generate the
//! URL-like corpus, random-project every example, code with all four
//! schemes, expand to sparse binary features (Section 6), train the
//! linear SVM with dual coordinate descent, and report test accuracy —
//! reproducing the shape of the paper's Figures 11, 12 and 14.
//!
//! ```bash
//! cargo run --release --example svm_pipeline            # quick scale
//! CRP_SCALE=1.0 cargo run --release --example svm_pipeline  # paper scale
//! ```

use crp::coding::{CodingParams, Scheme};
use crp::data::synth::{SynthKind, SynthSpec};
use crp::projection::{ProjectionConfig, Projector};
use crp::svm::sweep::{project_dataset, run_coded_svm, SvmTask};

fn main() {
    let scale: f64 = std::env::var("CRP_SCALE")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(0.15);
    let mut spec = SynthSpec::paper(SynthKind::UrlLike);
    if scale < 1.0 {
        spec.train_n = ((spec.train_n as f64 * scale) as usize).max(200);
        spec.test_n = ((spec.test_n as f64 * scale) as usize).max(200);
        spec.dim = ((spec.dim as f64 * scale.max(0.1)) as usize).max(2000);
        spec.n_informative = (spec.n_informative as f64 * scale.max(0.1)) as usize + 50;
    }
    println!(
        "URL-like corpus: {} train / {} test, D = {}, ~{} nnz/row",
        spec.train_n, spec.test_n, spec.dim, spec.avg_nnz
    );
    let t0 = std::time::Instant::now();
    let (train, test) = spec.generate();
    println!("generated in {:.2}s", t0.elapsed().as_secs_f64());

    let k_max = 256;
    let projector = Projector::new_cpu(ProjectionConfig {
        k: k_max,
        seed: 11,
        ..Default::default()
    });
    let t0 = std::time::Instant::now();
    let ptr = project_dataset(&train, &projector);
    let pte = project_dataset(&test, &projector);
    println!(
        "projected {} rows to k = {k_max} in {:.2}s\n",
        train.len() + test.len(),
        t0.elapsed().as_secs_f64()
    );

    println!(
        "{:>5} {:>6} {:<10} {:>9} {:>9} {:>8}",
        "k", "w", "scheme", "train", "test", "sec"
    );
    for &k in &[16usize, 64, 256] {
        // Slice the k-prefix out of the shared k_max projection.
        let slice = |buf: &[f32], n: usize| -> Vec<f32> {
            let mut out = vec![0.0f32; n * k];
            for r in 0..n {
                out[r * k..(r + 1) * k]
                    .copy_from_slice(&buf[r * k_max..r * k_max + k]);
            }
            out
        };
        let (str_, ste) = (slice(&ptr, train.len()), slice(&pte, test.len()));
        let tasks: Vec<(String, SvmTask)> = vec![
            ("orig".into(), SvmTask::Orig),
            ("h_w".into(), SvmTask::Coded(CodingParams::new(Scheme::Uniform, 0.75))),
            ("h_wq".into(), SvmTask::Coded(CodingParams::new(Scheme::WindowOffset, 0.75))),
            ("h_w2".into(), SvmTask::Coded(CodingParams::new(Scheme::TwoBit, 0.75))),
            ("h_1".into(), SvmTask::Coded(CodingParams::new(Scheme::OneBit, 0.0))),
            // Large-w contrast: the regime where the offset scheme breaks.
            ("h_w(w=4)".into(), SvmTask::Coded(CodingParams::new(Scheme::Uniform, 4.0))),
            ("h_wq(w=4)".into(), SvmTask::Coded(CodingParams::new(Scheme::WindowOffset, 4.0))),
        ];
        for (name, task) in &tasks {
            let r = run_coded_svm(&str_, &train.y, &ste, &test.y, k, task, 1.0);
            println!(
                "{:>5} {:>6.2} {:<10} {:>9.4} {:>9.4} {:>8.2}",
                k, r.w, name, r.train_acc, r.test_acc, r.train_seconds
            );
        }
        println!();
    }
    println!("Expected shape (paper Figs 11/12/14): h_w ≈ h_w2 ≈ orig at");
    println!("w ≈ 0.75 and k = 256; h_1 trails; h_wq collapses at w = 4");
    println!("while h_w holds — the random offset is what hurts.");
}
