//! Quickstart: project, code, estimate — the paper in 60 lines.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```

use crp::coding::{CodingParams, Scheme};
use crp::estimator::CollisionEstimator;
use crp::projection::{ProjectionConfig, Projector};

fn main() {
    // Two unit vectors with known similarity ρ = 0.8 (Eq. 2 setup).
    let rho = 0.8;
    let (u, v) = crp::data::pairs::unit_pair_with_rho(512, rho, 42);

    // k = 2048 shared Gaussian projections (Eq. 1). The projection
    // matrix is virtual — regenerated row-by-row from the seed.
    let projector = Projector::new_cpu(ProjectionConfig {
        k: 2048,
        seed: 7,
        ..Default::default()
    });
    let xu = projector.project_dense(&u);
    let xv = projector.project_dense(&v);

    println!("true rho = {rho}\n");
    println!(
        "{:<22} {:>9} {:>9} {:>7} {:>12}",
        "scheme", "rho_hat", "std_err", "bits", "sketch bytes"
    );

    // Code with each of the paper's four schemes and estimate ρ from
    // collision rates (Section 3's table inversion).
    for (scheme, w) in [
        (Scheme::Uniform, 0.75),     // h_w      — proposed, Sec 1.1
        (Scheme::WindowOffset, 0.75),// h_{w,q}  — Datar et al. baseline
        (Scheme::TwoBit, 0.75),      // h_{w,2}  — proposed 2-bit, Sec 4
        (Scheme::OneBit, 0.0),       // h_1      — sign / SimHash
    ] {
        let params = CodingParams::new(scheme, w);
        let cu = params.encode(&xu);
        let cv = params.encode(&xv);
        let est = CollisionEstimator::new(params.clone());
        let e = est.estimate_with_error(&cu, &cv);
        let packed = crp::coding::pack_codes(&cu, params.bits_per_code());
        println!(
            "{:<22} {:>9.4} {:>9.4} {:>7} {:>12}",
            format!("{} (w={w})", scheme.label()),
            e.rho,
            e.std_err,
            params.bits_per_code(),
            packed.storage_bytes(),
        );
    }

    println!(
        "\nRaw f32 storage of the projections would be {} bytes;",
        4 * 2048
    );
    println!("the recommended 2-bit scheme stores the same sketch in 512.");
}
