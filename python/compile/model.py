"""Layer-2 JAX graphs: the exported computations, composed from the
Layer-1 Pallas kernels.

Each function here is AOT-lowered by :mod:`compile.aot` at a fixed shape
and shipped to the Rust runtime as HLO text. Python never runs at serve
time — these exist only to define the dataflow the coordinator executes.

Exported graphs (shapes baked at AOT time, names in
``rust/src/runtime/artifact.rs``):

* ``proj_acc``     — ``(u[B,D], r[D,K], acc[B,K]) → (acc + u·r,)``
  The D-tiled projection step; Rust loops it over tiles of the virtual
  projection matrix, so any data dimensionality runs through one shape.
* ``quantize_all`` — ``(x[B,K], w, offs[K]) → (hw, hwq, hw2, h1)``
  All four codings of a projected block in one dispatch.
* ``collision``    — ``(a[B,K], b[B,K]) → (counts[B],)``
* ``proj_code``    — ``(u[B,D], r[D,K], w) → (codes2bit[B,K],)``
  Fused project + 2-bit code: the recommended-scheme fast path.
"""

from .kernels import collision as kcollision
from .kernels import project as kproject
from .kernels import quantize as kquantize


def proj_acc(u, r, acc):
    return (kproject.project_acc(u, r, acc),)


def quantize_all(x, w, offs):
    return tuple(kquantize.quantize_all(x, w, offs))


def collision(a, b):
    return (kcollision.collision_counts(a, b),)


def proj_code(u, r, w):
    return (kproject.project_code_two_bit(u, r, w),)
