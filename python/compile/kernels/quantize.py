"""Layer-1 Pallas kernels: the four coding schemes of the paper, fused
into one element-wise pass over the projected block.

Quantization is pure VPU work (compares, floor, clip) on a block already
resident in VMEM — on TPU it fuses behind the projection matmul; here it
is also exported standalone (`quantize_all_*`) so the Rust runtime can
re-code a cached projection under a new bin width without reprojecting.

All kernels take the bin width ``w`` as a runtime (1,1) f32 block, so a
single compiled artifact serves every w — the bin count ``B = ceil(6/w)``
is computed inside the kernel.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

CUTOFF = 6.0


def _quantize_all_kernel(x_ref, w_ref, q_ref, hw_ref, hwq_ref, hw2_ref, h1_ref):
    x = x_ref[...]
    w = w_ref[0, 0]
    q = q_ref[...]  # (1, K) offsets, broadcast over rows
    b = jnp.ceil(CUTOFF / w)
    clamped = jnp.clip(x, -CUTOFF, CUTOFF)
    # h_w: floor + clamp to [-B, B-1], shift to start at 0.
    hw = jnp.clip(jnp.floor(clamped / w), -b, b - 1.0) + b
    hw_ref[...] = hw.astype(jnp.int32)
    # h_{w,q}: random offset shifts the lattice; one extra bin.
    hwq = jnp.clip(jnp.floor((clamped + q) / w), -b, b) + b
    hwq_ref[...] = hwq.astype(jnp.int32)
    # h_{w,2}: four fixed regions.
    hw2_ref[...] = jnp.where(
        x < -w, 0, jnp.where(x < 0.0, 1, jnp.where(x < w, 2, 3))
    ).astype(jnp.int32)
    # h_1: sign.
    h1_ref[...] = (x >= 0.0).astype(jnp.int32)


@jax.jit
def quantize_all(x, w, q):
    """All four codings of a projected block.

    Args:
      x: f32[B, K] projected values.
      w: f32 scalar (bin width).
      q: f32[K] per-coordinate offsets for ``h_{w,q}``.

    Returns:
      (hw, hwq, hw2, h1), each i32[B, K].
    """
    b, k = x.shape
    w2d = jnp.asarray(w, jnp.float32).reshape(1, 1)
    q2d = jnp.asarray(q, jnp.float32).reshape(1, k)
    out = jax.ShapeDtypeStruct((b, k), jnp.int32)
    return pl.pallas_call(
        _quantize_all_kernel,
        in_specs=[
            pl.BlockSpec((b, k), lambda: (0, 0)),
            pl.BlockSpec((1, 1), lambda: (0, 0)),
            pl.BlockSpec((1, k), lambda: (0, 0)),
        ],
        out_specs=[pl.BlockSpec((b, k), lambda: (0, 0))] * 4,
        out_shape=[out, out, out, out],
        interpret=True,
    )(x, w2d, q2d)
