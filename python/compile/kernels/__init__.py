"""Layer-1 Pallas kernels (build-time only; never on the request path).

* :mod:`project`   -- D-tiled projection matmul-accumulate + fused
  project-and-code.
* :mod:`quantize`  -- the four coding schemes in one fused pass.
* :mod:`collision` -- per-pair collision counting.
* :mod:`ref`       -- pure-jnp oracle for all of the above.
"""

from . import collision, project, quantize, ref  # noqa: F401
