"""Layer-1 Pallas kernel: the D-tiled projection matmul-accumulate.

The paper's compute hot-spot (Eq. 1) is the contraction
``x[B,K] = u[B,D] @ R[D,K]``. On TPU this tiles as a 3-level loop with
the MXU doing ``(bm, bd) x (bd, bn)`` block products and VMEM holding
one tile of ``u``, one tile of ``R``, and the f32 accumulator. Here the
grid iterates the contraction dimension; the output block is revisited
every step (its index map ignores the grid axis), which expresses the
accumulation the way a TPU pipeline would keep the accumulator resident
in VMEM while streaming ``u``/``R`` tiles from HBM.

``interpret=True`` everywhere: the CPU PJRT plugin cannot execute Mosaic
custom-calls; interpret mode lowers to plain HLO, which is what the AOT
bridge ships to the Rust runtime. The BlockSpec structure is still the
real TPU schedule — DESIGN.md §Perf derives the VMEM/MXU occupancy
estimate from these shapes.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Contraction tile. 256 divides every artifact D (1024) and keeps the
# interpret-mode working set small; on real TPU this would be the bd of
# the MXU pipeline (128-multiple).
BD = 256


def _proj_acc_kernel(u_ref, r_ref, acc_ref, o_ref):
    """One grid step: o += u_tile @ r_tile (init from acc on step 0)."""

    @pl.when(pl.program_id(0) == 0)
    def _init():
        o_ref[...] = acc_ref[...]

    o_ref[...] += jnp.dot(
        u_ref[...], r_ref[...], preferred_element_type=jnp.float32
    )


@functools.partial(jax.jit, static_argnames=("bd",))
def project_acc(u, r, acc, *, bd=BD):
    """``acc + u @ r`` via the Pallas kernel.

    Args:
      u:   f32[B, D] data tile (D must be a multiple of ``bd``).
      r:   f32[D, K] projection tile.
      acc: f32[B, K] running accumulator.
    """
    b, d = u.shape
    d2, k = r.shape
    assert d == d2 and acc.shape == (b, k)
    assert d % bd == 0, f"D={d} not a multiple of bd={bd}"
    grid = (d // bd,)
    return pl.pallas_call(
        _proj_acc_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((b, bd), lambda i: (0, i)),
            pl.BlockSpec((bd, k), lambda i: (i, 0)),
            pl.BlockSpec((b, k), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((b, k), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((b, k), jnp.float32),
        interpret=True,
    )(u, r, acc)


def _proj_code2_kernel(u_ref, r_ref, w_ref, acc_ref, o_ref):
    """Fused projection + 2-bit coding epilogue.

    The accumulator lives in the (revisited) ``acc_ref`` output-scratch
    block; on the final contraction step the epilogue quantizes it into
    the i32 code block — codes never round-trip through HBM as floats.
    """
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(
        u_ref[...], r_ref[...], preferred_element_type=jnp.float32
    )

    @pl.when(i == pl.num_programs(0) - 1)
    def _epilogue():
        x = acc_ref[...]
        w = w_ref[0, 0]
        o_ref[...] = jnp.where(
            x < -w, 0, jnp.where(x < 0.0, 1, jnp.where(x < w, 2, 3))
        ).astype(jnp.int32)


@functools.partial(jax.jit, static_argnames=("bd",))
def project_code_two_bit(u, r, w, *, bd=BD):
    """2-bit codes of ``u @ r`` with bin width ``w`` (f32 scalar array).

    Returns i32[B, K] codes in {0,1,2,3}.
    """
    b, d = u.shape
    d2, k = r.shape
    assert d == d2
    assert d % bd == 0
    w2d = jnp.asarray(w, jnp.float32).reshape(1, 1)
    grid = (d // bd,)
    _, codes = pl.pallas_call(
        _proj_code2_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((b, bd), lambda i: (0, i)),
            pl.BlockSpec((bd, k), lambda i: (i, 0)),
            pl.BlockSpec((1, 1), lambda i: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((b, k), lambda i: (0, 0)),
            pl.BlockSpec((b, k), lambda i: (0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, k), jnp.float32),  # accumulator
            jax.ShapeDtypeStruct((b, k), jnp.int32),  # codes
        ],
        interpret=True,
    )(u, r, w2d)
    return codes
