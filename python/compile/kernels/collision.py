"""Layer-1 Pallas kernel: per-pair collision counting.

Given two coded blocks (i32[B, K]), count per row how many coordinates
agree — the sufficient statistic of the paper's linear estimator
(`P̂ = collisions / k`). Row-parallel VPU reduction.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _collision_kernel(a_ref, b_ref, o_ref):
    eq = (a_ref[...] == b_ref[...]).astype(jnp.int32)
    # Keep the reduced axis as a (B, 1) block: TPU-friendly 2-D layout.
    o_ref[...] = jnp.sum(eq, axis=1, keepdims=True)


@jax.jit
def collision_counts(a, b):
    """Per-row collision counts: i32[B, K] × i32[B, K] → i32[B]."""
    bb, k = a.shape
    assert a.shape == b.shape
    out = pl.pallas_call(
        _collision_kernel,
        in_specs=[
            pl.BlockSpec((bb, k), lambda: (0, 0)),
            pl.BlockSpec((bb, k), lambda: (0, 0)),
        ],
        out_specs=pl.BlockSpec((bb, 1), lambda: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((bb, 1), jnp.int32),
        interpret=True,
    )(a, b)
    return out[:, 0]
