"""Pure-jnp correctness oracle for the Pallas kernels.

Every kernel in this package has an exact reference here; pytest asserts
``allclose`` (exact equality for the integer coding outputs). The coding
semantics mirror ``rust/src/coding/schemes.rs`` at the level of code
*values* (bins are shifted to start at 0):

* uniform  ``h_w``     : clamp(x, ±cutoff) → floor(x/w) → clamp to
                         [-B, B-1] → +B,  B = ceil(cutoff/w)
* offset   ``h_{w,q}`` : clamp(x, ±cutoff) → floor((x+q)/w) → clamp to
                         [-B, B] → +B
* two-bit  ``h_{w,2}`` : regions (-inf,-w), [-w,0), [0,w), [w,inf) → 0..3
* one-bit  ``h_1``     : x >= 0
"""

import jax.numpy as jnp

CUTOFF = 6.0


def project_acc(u, r, acc):
    """acc + u @ r, f32 accumulate (matches the proj_acc kernel)."""
    return acc + jnp.dot(u, r, preferred_element_type=jnp.float32)


def encode_uniform(x, w):
    b = jnp.ceil(CUTOFF / w)
    clamped = jnp.clip(x, -CUTOFF, CUTOFF)
    code = jnp.floor(clamped / w)
    return (jnp.clip(code, -b, b - 1.0) + b).astype(jnp.int32)


def encode_offset(x, w, q):
    """q has shape (k,) and broadcasts over the batch dimension of x."""
    b = jnp.ceil(CUTOFF / w)
    clamped = jnp.clip(x, -CUTOFF, CUTOFF)
    code = jnp.floor((clamped + q) / w)
    return (jnp.clip(code, -b, b) + b).astype(jnp.int32)


def encode_two_bit(x, w):
    return jnp.where(
        x < -w, 0, jnp.where(x < 0.0, 1, jnp.where(x < w, 2, 3))
    ).astype(jnp.int32)


def encode_one_bit(x):
    return (x >= 0.0).astype(jnp.int32)


def quantize_all(x, w, q):
    return (
        encode_uniform(x, w),
        encode_offset(x, w, q),
        encode_two_bit(x, w),
        encode_one_bit(x),
    )


def collision_counts(a, b):
    """Per-row count of equal codes: (B, K) i32 pairs → (B,) i32."""
    return jnp.sum((a == b).astype(jnp.int32), axis=1)


def project_code_two_bit(u, r, w):
    """Fused projection + 2-bit coding (matches the proj_code kernel)."""
    x = jnp.dot(u, r, preferred_element_type=jnp.float32)
    return encode_two_bit(x, w)
