"""AOT driver: lower the Layer-2 graphs to HLO **text** artifacts.

HLO text (not ``.serialize()``) is the interchange format: jax >= 0.5
emits HloModuleProtos with 64-bit instruction ids which the Rust side's
xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the text parser
reassigns ids and round-trips cleanly. See /opt/xla-example/README.md.

Usage (from ``make artifacts``)::

    cd python && python -m compile.aot --out ../artifacts

Re-running is cheap and idempotent; a manifest records shapes + content
hashes so the Makefile can skip rebuilds.
"""

import argparse
import hashlib
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model

# Artifact shape table. Names must match rust/src/runtime/artifact.rs.
BATCHES = (64, 256)
D_TILE = 1024
K = 256


def to_hlo_text(lowered) -> str:
    """StableHLO → XlaComputation → HLO text (return_tuple=True)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def spec(shape, dtype=jnp.float32):
    return jax.ShapeDtypeStruct(shape, dtype)


def artifact_plan():
    """(name, function, example-arg specs) for every exported graph."""
    plan = []
    for b in BATCHES:
        plan.append(
            (
                f"proj_acc_b{b}_d{D_TILE}_k{K}",
                model.proj_acc,
                (spec((b, D_TILE)), spec((D_TILE, K)), spec((b, K))),
            )
        )
    plan.append(
        (
            f"quantize_all_b{BATCHES[0]}_k{K}",
            model.quantize_all,
            (spec((BATCHES[0], K)), spec(()), spec((K,))),
        )
    )
    plan.append(
        (
            f"collision_b{BATCHES[0]}_k{K}",
            model.collision,
            (
                spec((BATCHES[0], K), jnp.int32),
                spec((BATCHES[0], K), jnp.int32),
            ),
        )
    )
    plan.append(
        (
            f"proj_code_b{BATCHES[0]}_d{D_TILE}_k{K}",
            model.proj_code,
            (spec((BATCHES[0], D_TILE)), spec((D_TILE, K)), spec(())),
        )
    )
    return plan


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts", help="artifact directory")
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)
    manifest = {}
    for name, fn, specs in artifact_plan():
        lowered = jax.jit(fn).lower(*specs)
        text = to_hlo_text(lowered)
        path = os.path.join(args.out, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        manifest[name] = {
            "bytes": len(text),
            "sha256": hashlib.sha256(text.encode()).hexdigest()[:16],
            "inputs": [list(map(int, s.shape)) for s in specs],
        }
        print(f"wrote {path} ({len(text)} chars)")
    with open(os.path.join(args.out, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2, sort_keys=True)
    print(f"manifest: {len(manifest)} artifacts")


if __name__ == "__main__":
    main()
