"""Kernel-vs-reference correctness: the core L1 signal.

Every Pallas kernel is checked against the pure-jnp oracle in
``compile.kernels.ref`` — exact equality for integer codes, allclose for
float accumulations — over fixed shapes and hypothesis-driven sweeps of
shapes, widths, and value ranges.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import collision, project, quantize, ref

jax.config.update("jax_enable_x64", False)


def rand(key, shape, scale=1.0):
    return jax.random.normal(jax.random.PRNGKey(key), shape, jnp.float32) * scale


# ---------------------------------------------------------------- project


class TestProjectAcc:
    def test_matches_ref_basic(self):
        u = rand(0, (8, 512))
        r = rand(1, (512, 32))
        acc = rand(2, (8, 32))
        got = project.project_acc(u, r, acc)
        want = ref.project_acc(u, r, acc)
        np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)

    def test_zero_acc_is_plain_matmul(self):
        u = rand(3, (4, 256))
        r = rand(4, (256, 16))
        got = project.project_acc(u, r, jnp.zeros((4, 16), jnp.float32))
        np.testing.assert_allclose(got, u @ r, rtol=1e-4, atol=1e-4)

    def test_accumulation_chains_over_tiles(self):
        # Chaining two D-tiles == projecting the concatenated input.
        u1, u2 = rand(5, (4, 256)), rand(6, (4, 256))
        r1, r2 = rand(7, (256, 16)), rand(8, (256, 16))
        acc = jnp.zeros((4, 16), jnp.float32)
        acc = project.project_acc(u1, r1, acc)
        acc = project.project_acc(u2, r2, acc)
        full = jnp.concatenate([u1, u2], axis=1) @ jnp.concatenate([r1, r2], axis=0)
        np.testing.assert_allclose(acc, full, rtol=1e-4, atol=1e-4)

    @settings(max_examples=20, deadline=None)
    @given(
        b=st.integers(1, 16),
        d_tiles=st.integers(1, 4),
        k=st.integers(1, 64),
        seed=st.integers(0, 2**30),
    )
    def test_matches_ref_hypothesis(self, b, d_tiles, k, seed):
        d = d_tiles * 256
        u = rand(seed, (b, d))
        r = rand(seed + 1, (d, k))
        acc = rand(seed + 2, (b, k))
        got = project.project_acc(u, r, acc)
        want = ref.project_acc(u, r, acc)
        np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-4)

    def test_rejects_non_multiple_d(self):
        with pytest.raises(AssertionError):
            project.project_acc(
                rand(0, (2, 100)), rand(1, (100, 8)), jnp.zeros((2, 8))
            )


class TestProjectCode:
    def test_matches_ref(self):
        u = rand(10, (8, 512))
        r = rand(11, (512, 32))
        for w in (0.25, 0.75, 1.5):
            got = project.project_code_two_bit(u, r, jnp.float32(w))
            want = ref.project_code_two_bit(u, r, jnp.float32(w))
            np.testing.assert_array_equal(got, want)

    @settings(max_examples=15, deadline=None)
    @given(
        b=st.integers(1, 8),
        k=st.integers(1, 32),
        w=st.floats(0.05, 4.0),
        seed=st.integers(0, 2**30),
    )
    def test_hypothesis(self, b, k, w, seed):
        u = rand(seed, (b, 512))
        r = rand(seed + 9, (512, k))
        got = project.project_code_two_bit(u, r, jnp.float32(w))
        want = ref.project_code_two_bit(u, r, jnp.float32(w))
        # Codes are integers; matmul rounding can flip values that sit
        # exactly on a bin boundary — allow a vanishing fraction.
        mismatch = np.mean(np.asarray(got) != np.asarray(want))
        assert mismatch < 1e-3, f"mismatch fraction {mismatch}"

    def test_codes_in_range(self):
        u = rand(12, (4, 256), scale=3.0)
        r = rand(13, (256, 16))
        codes = np.asarray(project.project_code_two_bit(u, r, jnp.float32(0.75)))
        assert codes.min() >= 0 and codes.max() <= 3


# --------------------------------------------------------------- quantize


class TestQuantizeAll:
    def encode_all(self, x, w, q):
        return quantize.quantize_all(x, jnp.float32(w), q)

    def test_matches_ref_fixed(self):
        x = rand(20, (16, 64), scale=2.0)
        q = jax.random.uniform(jax.random.PRNGKey(21), (64,), jnp.float32) * 0.75
        got = self.encode_all(x, 0.75, q)
        want = ref.quantize_all(x, jnp.float32(0.75), q)
        for g, wv, name in zip(got, want, ["hw", "hwq", "hw2", "h1"]):
            np.testing.assert_array_equal(g, wv, err_msg=name)

    @settings(max_examples=25, deadline=None)
    @given(
        b=st.integers(1, 16),
        k=st.integers(1, 96),
        w=st.floats(0.1, 8.0),
        scale=st.floats(0.1, 4.0),
        seed=st.integers(0, 2**30),
    )
    def test_matches_ref_hypothesis(self, b, k, w, scale, seed):
        x = rand(seed, (b, k), scale=scale)
        q = (
            jax.random.uniform(jax.random.PRNGKey(seed + 1), (k,), jnp.float32)
            * w
        )
        got = self.encode_all(x, w, q)
        want = ref.quantize_all(x, jnp.float32(w), q)
        for g, wv in zip(got, want):
            np.testing.assert_array_equal(g, wv)

    def test_uniform_code_range(self):
        # w = 2 ⇒ cardinality 6 (paper Section 1.1 example).
        x = jnp.linspace(-10, 10, 101).reshape(1, -1)
        q = jnp.zeros((101,), jnp.float32)
        hw, hwq, hw2, h1 = self.encode_all(x, 2.0, q)
        assert int(jnp.min(hw)) == 0
        assert int(jnp.max(hw)) == 5
        assert int(jnp.max(hwq)) <= 6
        assert set(np.unique(np.asarray(hw2))) <= {0, 1, 2, 3}
        assert set(np.unique(np.asarray(h1))) <= {0, 1}

    def test_one_bit_is_sign(self):
        x = jnp.array([[-1.0, -0.0, 0.0, 2.0]])
        q = jnp.zeros((4,), jnp.float32)
        _, _, _, h1 = self.encode_all(x, 1.0, q)
        np.testing.assert_array_equal(np.asarray(h1)[0], [0, 1, 1, 1])

    def test_offsets_shift_lattice(self):
        x = jnp.full((1, 8), 0.9, jnp.float32)
        q0 = jnp.zeros((8,), jnp.float32)
        q1 = jnp.full((8,), 0.2, jnp.float32)
        _, a, _, _ = self.encode_all(x, 1.0, q0)
        _, b, _, _ = self.encode_all(x, 1.0, q1)
        assert int(np.asarray(b)[0, 0]) == int(np.asarray(a)[0, 0]) + 1


# --------------------------------------------------------------- collision


class TestCollision:
    def test_matches_ref(self):
        key = jax.random.PRNGKey(30)
        a = jax.random.randint(key, (8, 128), 0, 4, jnp.int32)
        b = jax.random.randint(jax.random.PRNGKey(31), (8, 128), 0, 4, jnp.int32)
        got = collision.collision_counts(a, b)
        want = ref.collision_counts(a, b)
        np.testing.assert_array_equal(got, want)

    def test_identical_rows_full_count(self):
        a = jax.random.randint(jax.random.PRNGKey(32), (4, 64), 0, 12, jnp.int32)
        got = np.asarray(collision.collision_counts(a, a))
        np.testing.assert_array_equal(got, np.full(4, 64))

    @settings(max_examples=20, deadline=None)
    @given(
        b=st.integers(1, 12),
        k=st.integers(1, 200),
        card=st.integers(2, 24),
        seed=st.integers(0, 2**30),
    )
    def test_hypothesis(self, b, k, card, seed):
        a = jax.random.randint(jax.random.PRNGKey(seed), (b, k), 0, card, jnp.int32)
        c = jax.random.randint(
            jax.random.PRNGKey(seed + 1), (b, k), 0, card, jnp.int32
        )
        np.testing.assert_array_equal(
            collision.collision_counts(a, c), ref.collision_counts(a, c)
        )


# --------------------------------------------- statistical (end-to-end L1)


class TestCollisionStatistics:
    """Monte-Carlo check that kernel codes reproduce the paper's P(ρ)."""

    def p1(self, rho):
        return 1.0 - np.arccos(rho) / np.pi

    def test_one_bit_collision_probability(self):
        rho = 0.6
        k = 200_000
        key1, key2 = jax.random.split(jax.random.PRNGKey(40))
        z1 = jax.random.normal(key1, (1, k), jnp.float32)
        z2 = jax.random.normal(key2, (1, k), jnp.float32)
        x = z1
        y = rho * z1 + np.sqrt(1 - rho * rho) * z2
        q = jnp.zeros((k,), jnp.float32)
        _, _, _, h1x = quantize.quantize_all(x, jnp.float32(1.0), q)
        _, _, _, h1y = quantize.quantize_all(y, jnp.float32(1.0), q)
        rate = float(collision.collision_counts(h1x, h1y)[0]) / k
        want = self.p1(rho)
        assert abs(rate - want) < 5e-3, f"{rate} vs {want}"
