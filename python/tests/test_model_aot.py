"""Layer-2 / AOT tests: exported graphs lower to HLO text that the
xla_extension text parser accepts, with the right shapes and tuple arity.
"""

import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np

from compile import aot, model


class TestModelGraphs:
    def test_proj_acc_shape_and_value(self):
        u = jnp.ones((4, 512), jnp.float32)
        r = jnp.ones((512, 8), jnp.float32) * 0.5
        acc = jnp.ones((4, 8), jnp.float32)
        (out,) = model.proj_acc(u, r, acc)
        assert out.shape == (4, 8)
        np.testing.assert_allclose(out, 1.0 + 512 * 0.5, rtol=1e-5)

    def test_quantize_all_arity(self):
        x = jnp.zeros((4, 16), jnp.float32)
        outs = model.quantize_all(x, jnp.float32(0.75), jnp.zeros((16,)))
        assert len(outs) == 4
        assert all(o.shape == (4, 16) and o.dtype == jnp.int32 for o in outs)

    def test_collision_counts(self):
        a = jnp.zeros((4, 16), jnp.int32)
        (c,) = model.collision(a, a)
        np.testing.assert_array_equal(c, np.full(4, 16))

    def test_proj_code_shape(self):
        u = jnp.zeros((4, 512), jnp.float32)
        r = jnp.zeros((512, 8), jnp.float32)
        (codes,) = model.proj_code(u, r, jnp.float32(0.75))
        assert codes.shape == (4, 8)
        # x = 0 falls in region [0, w) → code 2.
        assert int(codes[0, 0]) == 2


class TestAotLowering:
    def test_plan_covers_runtime_names(self):
        names = {name for name, _, _ in aot.artifact_plan()}
        assert f"proj_acc_b64_d{aot.D_TILE}_k{aot.K}" in names
        assert f"proj_acc_b256_d{aot.D_TILE}_k{aot.K}" in names
        assert f"quantize_all_b64_k{aot.K}" in names
        assert f"collision_b64_k{aot.K}" in names
        assert f"proj_code_b64_d{aot.D_TILE}_k{aot.K}" in names

    def test_hlo_text_emits_and_parses_structurally(self):
        # Small synthetic lowering (full-size artifacts are exercised by
        # `make artifacts` + the Rust pjrt_roundtrip test).
        def fn(x, y):
            return (jnp.matmul(x, y) + 1.0,)

        spec = jax.ShapeDtypeStruct((4, 4), jnp.float32)
        lowered = jax.jit(fn).lower(spec, spec)
        text = aot.to_hlo_text(lowered)
        assert text.startswith("HloModule")
        assert "ENTRY" in text
        assert "f32[4,4]" in text
        # return_tuple=True → root is a tuple.
        assert "tuple(" in text or "(f32[4,4]" in text

    def test_quantize_graph_lowers_with_scalar_w(self):
        lowered = jax.jit(model.quantize_all).lower(
            jax.ShapeDtypeStruct((8, 16), jnp.float32),
            jax.ShapeDtypeStruct((), jnp.float32),
            jax.ShapeDtypeStruct((16,), jnp.float32),
        )
        text = aot.to_hlo_text(lowered)
        assert text.startswith("HloModule")
        assert "s32[8,16]" in text

    def test_main_writes_artifacts_and_manifest(self, monkeypatch):
        with tempfile.TemporaryDirectory() as tmp:
            monkeypatch.setattr(
                "sys.argv", ["aot", "--out", tmp]
            )
            # Shrink the plan for test speed: patch shape table.
            monkeypatch.setattr(aot, "BATCHES", (8,))
            monkeypatch.setattr(aot, "D_TILE", 256)
            monkeypatch.setattr(aot, "K", 16)
            aot.main()
            files = os.listdir(tmp)
            assert "manifest.json" in files
            hlos = [f for f in files if f.endswith(".hlo.txt")]
            assert len(hlos) == 4  # 1 proj_acc + quantize + collision + proj_code
            for f in hlos:
                text = open(os.path.join(tmp, f)).read()
                assert text.startswith("HloModule"), f
