//! Coding of projected values — the paper's contribution, operational side.
//!
//! * [`schemes`] — the four encoders (`h_w`, `h_{w,q}`, `h_{w,2}`, `h_1`)
//!   over slices of projected values, with the Section-1.1 cutoff
//!   convention (values beyond ±cutoff are clamped; cutoff = 6 loses
//!   `1 − Φ(6) ≈ 1e-9` of mass).
//! * [`packing`] — dense bit-packing of codes into `u64` words and fast
//!   per-coordinate collision counting (the estimator hot path).
//! * [`expand`] — the Section-6 one-hot expansion that turns `k` codes
//!   into a sparse binary feature vector of length `k · cardinality` with
//!   exactly `k` ones, unit-normalized for the linear SVM.
//! * [`encoder`] — [`BatchEncoder`]: the fused encode+pack stage with
//!   cached `h_{w,q}` offsets and reusable scratch, feeding packed rows
//!   straight into the scan arena's bulk-ingest path.

pub mod encoder;
pub mod schemes;
pub mod packing;
pub mod expand;

pub use encoder::BatchEncoder;
pub use expand::{expand_to_sparse, expanded_dim};
pub use packing::{
    collision_count, collision_count_packed, pack_codes, pack_codes_into, supported_width,
    unpack_codes, PackedCodes,
};
pub use schemes::{CodingParams, Scheme};
