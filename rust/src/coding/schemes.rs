//! The four coding schemes as concrete encoders.
//!
//! All encoders map a projected value `x` (marginally `N(0,1)` for
//! unit-norm inputs) to a small non-negative integer code suitable for
//! bit-packing and one-hot expansion. Bin numbering is shifted so codes
//! start at 0; the *collision structure* (which pairs of values share a
//! code) is exactly the paper's.

use crate::mathx::Pcg64;
use crate::theory::SchemeKind;

/// Re-export under the operational name used by the serving layer.
pub type Scheme = SchemeKind;

/// Parameters of a concrete coder: scheme, bin width `w`, tail cutoff,
/// and the seed for the `h_{w,q}` offsets `q_j ~ U(0, w)`.
#[derive(Clone, Debug)]
pub struct CodingParams {
    pub scheme: Scheme,
    /// Bin width `w` (ignored by `OneBit`).
    pub w: f64,
    /// Tail cutoff (paper uses 6: `1 − Φ(6) = 9.9e-10`). Values beyond
    /// `±cutoff` clamp to the extreme bins.
    pub cutoff: f64,
    /// Seed for the per-coordinate random offsets of `h_{w,q}`. The same
    /// seed must be used for every vector in a dataset (offsets are part
    /// of the hash function, shared across vectors).
    pub offset_seed: u64,
}

impl CodingParams {
    /// Standard construction with the paper's cutoff of 6.
    pub fn new(scheme: Scheme, w: f64) -> Self {
        CodingParams {
            scheme,
            w,
            cutoff: 6.0,
            offset_seed: 0x0FF5E7,
        }
    }

    pub fn with_cutoff(mut self, cutoff: f64) -> Self {
        self.cutoff = cutoff;
        self
    }

    pub fn with_offset_seed(mut self, seed: u64) -> Self {
        self.offset_seed = seed;
        self
    }

    /// Number of distinct code values (the one-hot expansion width).
    ///
    /// * `h_w`: `2·ceil(cutoff/w)` bins cover `[-cutoff, cutoff)` —
    ///   Section 1.1's `1 + log2(ceil(6/w))` bits.
    /// * `h_{w,q}`: the offset shifts the lattice by up to `w`, adding
    ///   one more bin: `2·ceil(cutoff/w) + 1`.
    /// * `h_{w,2}`: 4. `h_1`: 2.
    pub fn cardinality(&self) -> usize {
        match self.scheme {
            Scheme::Uniform => 2 * (self.cutoff / self.w).ceil() as usize,
            Scheme::WindowOffset => 2 * (self.cutoff / self.w).ceil() as usize + 1,
            Scheme::TwoBit => 4,
            Scheme::OneBit => 2,
        }
    }

    /// Bits needed per code (`ceil(log2(cardinality))`).
    pub fn bits_per_code(&self) -> u32 {
        let m = self.cardinality();
        (usize::BITS - (m - 1).leading_zeros()).max(1)
    }

    /// The `h_{w,q}` offsets `q_j ~ U(0, w)` for coordinates `0..k`,
    /// deterministic in `(offset_seed, k)` — part of the hash function.
    pub fn offsets(&self, k: usize) -> Vec<f64> {
        let mut rng = Pcg64::new(self.offset_seed, Q_STREAM);
        (0..k).map(|_| rng.next_f64() * self.w).collect()
    }

    /// Bins per side for the lattice schemes: `B = ceil(cutoff/w)`.
    #[inline]
    pub fn bins_per_side(&self) -> i64 {
        (self.cutoff / self.w).ceil() as i64
    }

    /// Encode one projected coordinate `x` at position `j`.
    ///
    /// `offset` is the precomputed `q_j` (only read by `WindowOffset`).
    /// Convenience wrapper — the batch paths precompute the lattice
    /// constants once (see `encode_into`).
    #[inline]
    pub fn encode_one(&self, x: f64, offset: f64) -> u16 {
        self.encode_one_with(x, offset, self.bins_per_side(), 1.0 / self.w)
    }

    /// Core encoder with hoisted per-vector constants (`b`, `1/w`).
    #[inline(always)]
    fn encode_one_with(&self, x: f64, offset: f64, b: i64, inv_w: f64) -> u16 {
        match self.scheme {
            Scheme::Uniform => {
                let clamped = x.clamp(-self.cutoff, self.cutoff);
                let code = (clamped * inv_w).floor() as i64;
                (code.clamp(-b, b - 1) + b) as u16
            }
            Scheme::WindowOffset => {
                let clamped = x.clamp(-self.cutoff, self.cutoff);
                let code = ((clamped + offset) * inv_w).floor() as i64;
                (code.clamp(-b, b) + b) as u16
            }
            Scheme::TwoBit => {
                // Regions (-∞,-w), [-w,0), [0,w), [w,∞) → 0,1,2,3.
                if x < -self.w {
                    0
                } else if x < 0.0 {
                    1
                } else if x < self.w {
                    2
                } else {
                    3
                }
            }
            Scheme::OneBit => u16::from(x >= 0.0),
        }
    }

    /// Encode a whole projected vector.
    pub fn encode(&self, x: &[f32]) -> Vec<u16> {
        let mut out = vec![0u16; x.len()];
        match self.scheme {
            Scheme::WindowOffset => {
                let q = self.offsets(x.len());
                self.encode_into(x, Some(&q), &mut out);
            }
            _ => self.encode_into(x, None, &mut out),
        }
        out
    }

    /// Encode into a caller-provided buffer (allocation-free hot path;
    /// lattice constants hoisted out of the element loop).
    pub fn encode_into(&self, x: &[f32], offsets: Option<&[f64]>, out: &mut [u16]) {
        assert_eq!(x.len(), out.len());
        let b = self.bins_per_side();
        let inv_w = 1.0 / self.w;
        match self.scheme {
            Scheme::WindowOffset => {
                let q = offsets.expect("WindowOffset requires precomputed offsets");
                assert_eq!(q.len(), x.len());
                for ((o, &xi), &qi) in out.iter_mut().zip(x).zip(q) {
                    *o = self.encode_one_with(xi as f64, qi, b, inv_w);
                }
            }
            _ => {
                for (o, &xi) in out.iter_mut().zip(x) {
                    *o = self.encode_one_with(xi as f64, 0.0, b, inv_w);
                }
            }
        }
    }
}

/// PRNG stream id reserved for the `h_{w,q}` offsets.
const Q_STREAM: u64 = 0x71;

#[cfg(test)]
mod tests {
    use super::*;

    fn params(s: Scheme, w: f64) -> CodingParams {
        CodingParams::new(s, w)
    }

    #[test]
    fn uniform_floor_semantics() {
        // Paper Section 1.1: floor(3.1)=3, floor(4.99)=4, floor(-3.1)=-4.
        let p = params(Scheme::Uniform, 1.0);
        let b = 6; // ceil(6/1)
        assert_eq!(p.encode_one(3.1, 0.0) as i64 - b, 3);
        assert_eq!(p.encode_one(4.99, 0.0) as i64 - b, 4);
        assert_eq!(p.encode_one(-3.1, 0.0) as i64 - b, -4);
    }

    #[test]
    fn uniform_cardinality_matches_bit_count() {
        // w = 2 ⇒ codes in {-3..2}, 6 values (paper's Section 1.1 example).
        let p = params(Scheme::Uniform, 2.0);
        assert_eq!(p.cardinality(), 6);
        let p = params(Scheme::Uniform, 6.0);
        assert_eq!(p.cardinality(), 2); // one-bit regime
        let p = params(Scheme::Uniform, 0.5);
        assert_eq!(p.cardinality(), 24);
        assert_eq!(p.bits_per_code(), 5);
    }

    #[test]
    fn uniform_clamps_tails() {
        let p = params(Scheme::Uniform, 1.0);
        let lo = p.encode_one(-100.0, 0.0);
        let hi = p.encode_one(100.0, 0.0);
        assert_eq!(lo, 0);
        assert_eq!(hi as usize, p.cardinality() - 1);
    }

    #[test]
    fn two_bit_regions() {
        let p = params(Scheme::TwoBit, 0.75);
        assert_eq!(p.encode_one(-2.0, 0.0), 0);
        assert_eq!(p.encode_one(-0.5, 0.0), 1);
        assert_eq!(p.encode_one(0.0, 0.0), 2);
        assert_eq!(p.encode_one(0.5, 0.0), 2);
        assert_eq!(p.encode_one(0.75, 0.0), 3);
        assert_eq!(p.cardinality(), 4);
        assert_eq!(p.bits_per_code(), 2);
    }

    #[test]
    fn one_bit_signs() {
        let p = params(Scheme::OneBit, 0.0);
        assert_eq!(p.encode_one(-0.001, 0.0), 0);
        assert_eq!(p.encode_one(0.0, 0.0), 1);
        assert_eq!(p.encode_one(3.0, 0.0), 1);
        assert_eq!(p.bits_per_code(), 1);
    }

    #[test]
    fn offset_scheme_shares_offsets_across_vectors() {
        let p = params(Scheme::WindowOffset, 1.0);
        let x = vec![0.4f32; 8];
        let y = vec![0.4f32; 8];
        assert_eq!(p.encode(&x), p.encode(&y));
        // Different seed ⇒ (almost surely) different codes somewhere.
        let p2 = p.clone().with_offset_seed(99);
        let mut varied = false;
        let xs: Vec<f32> = (0..64).map(|i| (i as f32) * 0.09 - 3.0).collect();
        if p.encode(&xs) != p2.encode(&xs) {
            varied = true;
        }
        assert!(varied, "offset seed had no effect");
    }

    #[test]
    fn offset_collision_rate_matches_theory() {
        // Monte-Carlo: encode correlated normal pairs, compare collision
        // rate with P_{w,q}(ρ).
        use crate::mathx::NormalSampler;
        use crate::theory::p_wq;
        let rho: f64 = 0.5;
        let w = 1.0;
        let p = params(Scheme::WindowOffset, w);
        let k = 200_000;
        let mut ns = NormalSampler::new(2024, 1);
        let mut x = vec![0f32; k];
        let mut y = vec![0f32; k];
        let c = (1.0 - rho * rho).sqrt();
        for i in 0..k {
            let z1 = ns.next();
            let z2 = ns.next();
            x[i] = z1 as f32;
            y[i] = (rho * z1 + c * z2) as f32;
        }
        let cx = p.encode(&x);
        let cy = p.encode(&y);
        let rate =
            cx.iter().zip(&cy).filter(|(a, b)| a == b).count() as f64 / k as f64;
        let want = p_wq(rho, w);
        assert!((rate - want).abs() < 5e-3, "rate={rate} want={want}");
    }

    #[test]
    fn uniform_collision_rate_matches_theory() {
        use crate::mathx::NormalSampler;
        use crate::theory::p_w;
        let rho: f64 = 0.75;
        let w = 0.75;
        let p = params(Scheme::Uniform, w);
        let k = 200_000;
        let mut ns = NormalSampler::new(7, 3);
        let c = (1.0 - rho * rho).sqrt();
        let mut hits = 0usize;
        for _ in 0..k {
            let z1 = ns.next();
            let z2 = ns.next();
            let a = p.encode_one(z1, 0.0);
            let b = p.encode_one(rho * z1 + c * z2, 0.0);
            hits += usize::from(a == b);
        }
        let rate = hits as f64 / k as f64;
        let want = p_w(rho, w);
        assert!((rate - want).abs() < 5e-3, "rate={rate} want={want}");
    }

    #[test]
    fn two_bit_collision_rate_matches_theory() {
        use crate::mathx::NormalSampler;
        use crate::theory::p_w2;
        let rho: f64 = 0.6;
        let w = 0.75;
        let p = params(Scheme::TwoBit, w);
        let k = 200_000;
        let mut ns = NormalSampler::new(11, 4);
        let c = (1.0 - rho * rho).sqrt();
        let mut hits = 0usize;
        for _ in 0..k {
            let z1 = ns.next();
            let z2 = ns.next();
            hits += usize::from(
                p.encode_one(z1, 0.0) == p.encode_one(rho * z1 + c * z2, 0.0),
            );
        }
        let rate = hits as f64 / k as f64;
        let want = p_w2(rho, w);
        assert!((rate - want).abs() < 5e-3, "rate={rate} want={want}");
    }

    #[test]
    fn encode_into_matches_encode() {
        let p = params(Scheme::Uniform, 0.5);
        let xs: Vec<f32> = (0..100).map(|i| (i as f32 - 50.0) * 0.13).collect();
        let a = p.encode(&xs);
        let mut b = vec![0u16; xs.len()];
        p.encode_into(&xs, None, &mut b);
        assert_eq!(a, b);
        let pq = params(Scheme::WindowOffset, 0.5);
        let a = pq.encode(&xs);
        let q = pq.offsets(xs.len());
        let mut b = vec![0u16; xs.len()];
        pq.encode_into(&xs, Some(&q), &mut b);
        assert_eq!(a, b);
    }
}
