//! Fused encode stage: projected `f32` rows → packed code words in one
//! pass, with cached `h_{w,q}` offsets and a reusable scratch buffer.
//!
//! Before this module the serving path recomputed the `h_{w,q}` offset
//! vector (`CodingParams::offsets`, a fresh `Vec<f64>`) on every flush
//! and packed every vector through its own allocation. [`BatchEncoder`]
//! hoists everything that is per-configuration out of the per-vector
//! loop: offsets are computed once at construction (they are part of the
//! hash function and never change), the `u16` code scratch is reused
//! across calls, and [`BatchEncoder::encode_pack_batch_into`] lands a
//! whole projected batch in one contiguous word buffer — rows in
//! [`crate::scan::CodeArena`] layout, ready for
//! `SketchStore::put_rows` with zero per-vector allocation.

use super::packing::{pack_codes_into, supported_width, PackedCodes};
use super::schemes::{CodingParams, Scheme};
use crate::data::sparse::CsrMatrix;
use crate::projection::Projector;

/// Reusable project→quantize→pack state for one coding configuration at
/// a fixed sketch width `k`.
#[derive(Clone, Debug)]
pub struct BatchEncoder {
    params: CodingParams,
    k: usize,
    bits: u32,
    stride: usize,
    /// `h_{w,q}` offsets, computed once (`None` for offset-free schemes).
    offsets: Option<Vec<f64>>,
    /// Per-vector code scratch, reused across calls.
    scratch: Vec<u16>,
    /// Projected-row scratch for the sparse path, reused across calls.
    xrow: Vec<f32>,
    /// Gathered R-row scratch for the sparse path, reused across calls.
    gather: Vec<f32>,
}

impl BatchEncoder {
    pub fn new(params: CodingParams, k: usize) -> Self {
        let bits = supported_width(params.bits_per_code());
        let offsets = match params.scheme {
            Scheme::WindowOffset => Some(params.offsets(k)),
            _ => None,
        };
        BatchEncoder {
            stride: k.div_ceil((64 / bits) as usize),
            scratch: vec![0u16; k],
            xrow: vec![0.0f32; k],
            gather: Vec::new(),
            params,
            k,
            bits,
            offsets,
        }
    }

    /// Codes per sketch.
    pub fn k(&self) -> usize {
        self.k
    }

    /// Packed width per code (a supported packing width).
    pub fn bits(&self) -> u32 {
        self.bits
    }

    /// `u64` words per packed row.
    pub fn stride(&self) -> usize {
        self.stride
    }

    pub fn params(&self) -> &CodingParams {
        &self.params
    }

    /// Encode and pack one projected vector of length `k`. The only
    /// allocation is the returned sketch's own word buffer.
    pub fn encode_pack(&mut self, x: &[f32]) -> PackedCodes {
        assert_eq!(x.len(), self.k, "projected width mismatch");
        self.params
            .encode_into(x, self.offsets.as_deref(), &mut self.scratch);
        let mut words = vec![0u64; self.stride];
        pack_codes_into(&self.scratch, self.bits, &mut words);
        PackedCodes::from_words(self.bits, self.k, words)
    }

    /// Fused batch pass: encode and pack `b` projected rows (`b·k`
    /// floats, row-major) into one contiguous buffer of `b·stride()`
    /// words — one buffer resize per batch, zero per-vector allocation.
    /// Row `i` of `out` is the packed sketch of `x[i·k..(i+1)·k]`,
    /// byte-identical to [`BatchEncoder::encode_pack`] on that row.
    pub fn encode_pack_batch_into(&mut self, x: &[f32], b: usize, out: &mut Vec<u64>) {
        assert_eq!(x.len(), b * self.k, "batch shape mismatch");
        out.clear();
        out.resize(b * self.stride, 0);
        for row in 0..b {
            self.params.encode_into(
                &x[row * self.k..(row + 1) * self.k],
                self.offsets.as_deref(),
                &mut self.scratch,
            );
            pack_codes_into(
                &self.scratch,
                self.bits,
                &mut out[row * self.stride..(row + 1) * self.stride],
            );
        }
    }

    /// Fused sparse batch pass: project each CSR row at O(nnz·k)
    /// through the projector's gather kernel, quantize, and pack into
    /// one contiguous buffer of `rows·stride()` words. Byte-identical
    /// to densifying the batch and running
    /// [`BatchEncoder::encode_pack_batch_into`] on it — the projection
    /// replays the dense GEMM's exact operation sequence (see
    /// `projection::sparse`). Zero per-row allocation at steady state.
    pub fn encode_csr(&mut self, projector: &Projector, csr: &CsrMatrix, out: &mut Vec<u64>) {
        assert_eq!(projector.cfg.k, self.k, "projector width mismatch");
        let b = csr.rows();
        out.clear();
        out.resize(b * self.stride, 0);
        for row in 0..b {
            let (idx, val) = csr.row(row);
            self.xrow.fill(0.0);
            projector.project_csr_row_into(idx, val, &mut self.gather, &mut self.xrow);
            self.params
                .encode_into(&self.xrow, self.offsets.as_deref(), &mut self.scratch);
            pack_codes_into(
                &self.scratch,
                self.bits,
                &mut out[row * self.stride..(row + 1) * self.stride],
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coding::pack_codes;
    use crate::mathx::Pcg64;
    use crate::theory::SchemeKind;

    fn rand_x(n: usize, seed: u64) -> Vec<f32> {
        let mut g = Pcg64::new(seed, 0);
        (0..n)
            .map(|_| (g.next_f64() as f32 - 0.5) * 6.0)
            .collect()
    }

    #[test]
    fn encode_pack_matches_unfused_path_all_schemes() {
        for (scheme, w) in [
            (SchemeKind::Uniform, 0.75),
            (SchemeKind::WindowOffset, 1.0),
            (SchemeKind::TwoBit, 0.75),
            (SchemeKind::OneBit, 0.0),
        ] {
            let params = CodingParams::new(scheme, w);
            let k = 131; // ragged: partial last word at every width
            let mut enc = BatchEncoder::new(params.clone(), k);
            let x = rand_x(k, 7);
            let got = enc.encode_pack(&x);
            let want = pack_codes(&params.encode(&x), params.bits_per_code());
            assert_eq!(got, want, "{scheme:?}");
            // Scratch reuse must not leak state between calls.
            let y = rand_x(k, 8);
            let got2 = enc.encode_pack(&y);
            assert_eq!(got2, pack_codes(&params.encode(&y), params.bits_per_code()));
        }
    }

    #[test]
    fn batch_rows_match_per_vector_encoding() {
        let params = CodingParams::new(SchemeKind::WindowOffset, 1.0);
        let k = 100;
        let b = 9;
        let mut enc = BatchEncoder::new(params.clone(), k);
        let x = rand_x(b * k, 21);
        let mut words = Vec::new();
        enc.encode_pack_batch_into(&x, b, &mut words);
        assert_eq!(words.len(), b * enc.stride());
        for row in 0..b {
            let want = pack_codes(
                &params.encode(&x[row * k..(row + 1) * k]),
                params.bits_per_code(),
            );
            assert_eq!(
                &words[row * enc.stride()..(row + 1) * enc.stride()],
                want.words(),
                "row {row}"
            );
        }
        // The buffer is reusable: a second (smaller) batch overwrites it.
        let x2 = rand_x(2 * k, 22);
        enc.encode_pack_batch_into(&x2, 2, &mut words);
        assert_eq!(words.len(), 2 * enc.stride());
    }

    #[test]
    fn cached_offsets_equal_fresh_offsets() {
        let params = CodingParams::new(SchemeKind::WindowOffset, 0.5);
        let k = 64;
        let mut enc = BatchEncoder::new(params.clone(), k);
        let x = rand_x(k, 3);
        // Two encoders and the raw path all agree — the offsets are a
        // pure function of (seed, k), cached rather than recomputed.
        let mut enc2 = BatchEncoder::new(params.clone(), k);
        assert_eq!(enc.encode_pack(&x), enc2.encode_pack(&x));
        assert_eq!(
            enc.encode_pack(&x),
            pack_codes(&params.encode(&x), params.bits_per_code())
        );
    }

    #[test]
    fn empty_batch_is_fine() {
        let mut enc = BatchEncoder::new(CodingParams::new(SchemeKind::TwoBit, 0.75), 32);
        let mut words = vec![99u64; 4];
        enc.encode_pack_batch_into(&[], 0, &mut words);
        assert!(words.is_empty());
    }

    #[test]
    fn encode_csr_matches_densified_batch_all_schemes_and_kinds() {
        use crate::data::sparse::CsrMatrix;
        use crate::projection::{MatrixKind, ProjectionConfig, Projector};

        let (k, d, b) = (77usize, 400usize, 6usize);
        let mut g = Pcg64::new(17, 0);
        let mut csr = CsrMatrix::with_capacity(b, b * 10, d);
        let mut dense = vec![0.0f32; b * d];
        for row in 0..b {
            let nnz = 1 + g.next_below(14) as usize;
            let mut cols: Vec<u32> = Vec::new();
            while cols.len() < nnz {
                let c = g.next_below(d as u64) as u32;
                if !cols.contains(&c) {
                    cols.push(c);
                }
            }
            cols.sort_unstable();
            let vals: Vec<f32> = cols
                .iter()
                .map(|_| (g.next_f64() as f32 - 0.5) * 5.0)
                .collect();
            for (&c, &v) in cols.iter().zip(&vals) {
                dense[row * d + c as usize] = v;
            }
            csr.push_row(&cols, &vals);
        }
        for kind in [MatrixKind::Gaussian, MatrixKind::SignSparse { s: 4 }] {
            let p = Projector::new_cpu(ProjectionConfig {
                k,
                seed: 23,
                kind,
                ..Default::default()
            });
            for (scheme, w) in [
                (SchemeKind::OneBit, 0.0),
                (SchemeKind::TwoBit, 0.75),
                (SchemeKind::Uniform, 0.75),
                (SchemeKind::WindowOffset, 1.0),
            ] {
                let params = CodingParams::new(scheme, w);
                let mut enc = BatchEncoder::new(params.clone(), k);
                let mut sparse_words = Vec::new();
                enc.encode_csr(&p, &csr, &mut sparse_words);
                let x = p.project_batch(&dense, b, d);
                let mut dense_words = Vec::new();
                enc.encode_pack_batch_into(&x, b, &mut dense_words);
                assert_eq!(sparse_words, dense_words, "{kind:?} {scheme:?}");
            }
        }
    }
}
