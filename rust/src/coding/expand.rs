//! The Section-6 one-hot expansion: turn `k` codes into a sparse binary
//! feature vector for the linear SVM.
//!
//! With cardinality `m` codes, projected coordinate `j` with code `c_j`
//! contributes a single 1 at index `j·m + c_j`, giving a vector of length
//! `k·m` with exactly `k` ones. The paper normalizes inputs to unit norm
//! before LIBLINEAR, so values are `1/√k`.
//!
//! The expansion makes the linear kernel equal (up to scale) to the
//! collision count: `⟨x̃_u, x̃_v⟩ = (1/k) Σ_j 1{c_u[j] = c_v[j]} = P̂`,
//! which is why an inner-product machine can exploit the coded data.

/// Dimensionality of the expanded feature space.
pub fn expanded_dim(k: usize, cardinality: usize) -> usize {
    k * cardinality
}

/// Expand codes to sorted sparse (index, value) pairs with unit norm.
pub fn expand_to_sparse(codes: &[u16], cardinality: usize) -> (Vec<u32>, Vec<f32>) {
    let k = codes.len();
    let val = if k == 0 { 0.0 } else { 1.0 / (k as f32).sqrt() };
    let mut idx = Vec::with_capacity(k);
    for (j, &c) in codes.iter().enumerate() {
        debug_assert!((c as usize) < cardinality, "code out of range");
        idx.push((j * cardinality + c as usize) as u32);
    }
    (idx, vec![val; k])
}

/// Expand into caller-provided buffers (allocation-free hot path).
/// Buffers must have length `codes.len()`.
pub fn expand_into(codes: &[u16], cardinality: usize, idx: &mut [u32], val: &mut [f32]) {
    let k = codes.len();
    assert_eq!(idx.len(), k);
    assert_eq!(val.len(), k);
    let v = if k == 0 { 0.0 } else { 1.0 / (k as f32).sqrt() };
    for (j, &c) in codes.iter().enumerate() {
        idx[j] = (j * cardinality + c as usize) as u32;
        val[j] = v;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coding::{CodingParams, Scheme};

    #[test]
    fn paper_section6_example() {
        // h_{w,2}, w = 0.75: x = -2 ⇒ [1 0 0 0]; x = -0.3 ⇒ [0 1 0 0];
        // x = 0.1 ⇒ [0 0 1 0]; x = 1.0 ⇒ [0 0 0 1].
        let p = CodingParams::new(Scheme::TwoBit, 0.75);
        let codes = p.encode(&[-2.0, -0.3, 0.1, 1.0]);
        let (idx, val) = expand_to_sparse(&codes, 4);
        assert_eq!(idx, vec![0, 4 + 1, 8 + 2, 12 + 3]);
        let v = 1.0 / 2.0; // 1/√4
        assert!(val.iter().all(|&x| (x - v).abs() < 1e-7));
    }

    #[test]
    fn exactly_k_ones_unit_norm() {
        let p = CodingParams::new(Scheme::Uniform, 0.5);
        let xs: Vec<f32> = (0..64).map(|i| (i as f32 - 32.0) * 0.1).collect();
        let codes = p.encode(&xs);
        let (idx, val) = expand_to_sparse(&codes, p.cardinality());
        assert_eq!(idx.len(), 64);
        let norm: f32 = val.iter().map(|v| v * v).sum();
        assert!((norm - 1.0).abs() < 1e-5);
        // Indices strictly increasing (one per block).
        for w in idx.windows(2) {
            assert!(w[1] > w[0]);
        }
        assert!(*idx.last().unwrap() < expanded_dim(64, p.cardinality()) as u32);
    }

    #[test]
    fn inner_product_equals_collision_rate() {
        // ⟨expand(u), expand(v)⟩ = collision_rate — the linear-estimator
        // identity the whole Section 6 construction rests on.
        let p = CodingParams::new(Scheme::TwoBit, 0.75);
        let xu: Vec<f32> = (0..128).map(|i| ((i * 37) % 64) as f32 * 0.05 - 1.6).collect();
        let xv: Vec<f32> = (0..128).map(|i| ((i * 53) % 64) as f32 * 0.05 - 1.6).collect();
        let cu = p.encode(&xu);
        let cv = p.encode(&xv);
        let (iu, vu) = expand_to_sparse(&cu, 4);
        let (iv, vv) = expand_to_sparse(&cv, 4);
        // Sparse dot product (both sorted).
        let mut dot = 0.0f64;
        let (mut a, mut b) = (0usize, 0usize);
        while a < iu.len() && b < iv.len() {
            match iu[a].cmp(&iv[b]) {
                std::cmp::Ordering::Less => a += 1,
                std::cmp::Ordering::Greater => b += 1,
                std::cmp::Ordering::Equal => {
                    dot += (vu[a] * vv[b]) as f64;
                    a += 1;
                    b += 1;
                }
            }
        }
        let collisions = crate::coding::collision_count(&cu, &cv);
        assert!(
            (dot - collisions as f64 / 128.0).abs() < 1e-6,
            "dot={dot} rate={}",
            collisions as f64 / 128.0
        );
    }

    #[test]
    fn expand_into_matches_alloc() {
        let p = CodingParams::new(Scheme::OneBit, 0.0);
        let xs: Vec<f32> = (0..33).map(|i| (i as f32) - 16.0).collect();
        let codes = p.encode(&xs);
        let (i1, v1) = expand_to_sparse(&codes, 2);
        let mut i2 = vec![0u32; 33];
        let mut v2 = vec![0f32; 33];
        expand_into(&codes, 2, &mut i2, &mut v2);
        assert_eq!(i1, i2);
        assert_eq!(v1, v2);
    }

    #[test]
    fn empty_input() {
        let (i, v) = expand_to_sparse(&[], 4);
        assert!(i.is_empty() && v.is_empty());
    }
}
