//! Bit-packing of codes and fast collision counting.
//!
//! The whole point of the paper is that a projected value needs only a
//! few bits. This module stores `k` codes of `b` bits densely in `u64`
//! words and counts per-coordinate collisions between two packed vectors
//! — the estimator's hot inner loop (`Σ_j 1{c_u[j] = c_v[j]}`).
//!
//! Specialized SWAR paths exist for `b = 1` (XOR + popcount) and `b = 2`
//! (nibble-wise equality), which cover the paper's recommended schemes.

/// Codes packed at a fixed bit width. Codes never straddle word
/// boundaries (we only allow widths dividing 64), keeping extraction
/// branch-free.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct PackedCodes {
    /// Bit width per code; one of 1, 2, 4, 8, 16.
    pub bits: u32,
    /// Number of codes.
    pub len: usize,
    /// Codes per word (`64 / bits`), hoisted so `get` stays division-free.
    per_word: usize,
    /// Per-code mask (`(1 << bits) - 1`), hoisted likewise.
    mask: u64,
    words: Vec<u64>,
}

/// Round a requested width up to a supported divisor of 64.
pub fn supported_width(bits: u32) -> u32 {
    match bits {
        0 | 1 => 1,
        2 => 2,
        3 | 4 => 4,
        5..=8 => 8,
        _ => 16,
    }
}

/// Pack `codes` at `bits` per code (rounded up to a supported width).
pub fn pack_codes(codes: &[u16], bits: u32) -> PackedCodes {
    let bits = supported_width(bits);
    let per_word = (64 / bits) as usize;
    let mut words = vec![0u64; codes.len().div_ceil(per_word)];
    pack_codes_into(codes, bits, &mut words);
    PackedCodes {
        bits,
        len: codes.len(),
        per_word,
        // `supported_width` caps widths at 16, so the shift never overflows.
        mask: (1u64 << bits) - 1,
        words,
    }
}

/// Pack `codes` at a supported width into a caller-provided word buffer
/// of exactly `codes.len().div_ceil(64 / bits)` words. The buffer is
/// fully overwritten with padding bits zeroed — the allocation-free core
/// of [`pack_codes`], used by the fused batch-encode pipeline.
pub fn pack_codes_into(codes: &[u16], bits: u32, out: &mut [u64]) {
    assert_eq!(bits, supported_width(bits), "unsupported width {bits}");
    let per_word = (64 / bits) as usize;
    assert_eq!(
        out.len(),
        codes.len().div_ceil(per_word),
        "word buffer does not match {} codes at {bits} bits",
        codes.len()
    );
    let mask = (1u64 << bits) - 1;
    out.fill(0);
    for (i, &c) in codes.iter().enumerate() {
        debug_assert!(
            (c as u64) <= mask,
            "code {c} does not fit in {bits} bits"
        );
        out[i / per_word] |= ((c as u64) & mask) << ((i % per_word) as u32 * bits);
    }
}

/// Unpack back to a `u16` vector.
pub fn unpack_codes(p: &PackedCodes) -> Vec<u16> {
    (0..p.len).map(|i| p.get(i)).collect()
}

impl PackedCodes {
    /// Reassemble packed codes from raw storage words (e.g. rows of a
    /// [`crate::scan::CodeArena`] or a snapshot). `bits` must already be
    /// a supported width and `words` must hold exactly
    /// `len.div_ceil(64 / bits)` words with all padding bits zero (as
    /// produced by [`pack_codes`]).
    pub fn from_words(bits: u32, len: usize, words: Vec<u64>) -> PackedCodes {
        assert_eq!(bits, supported_width(bits), "unsupported width {bits}");
        let per_word = (64 / bits) as usize;
        assert_eq!(
            words.len(),
            len.div_ceil(per_word),
            "word count does not match len={len} at {bits} bits"
        );
        PackedCodes {
            bits,
            len,
            per_word,
            mask: (1u64 << bits) - 1,
            words,
        }
    }

    /// Raw words (e.g. for hashing into LSH buckets).
    pub fn words(&self) -> &[u64] {
        &self.words
    }

    /// Extract the code at position `i`.
    #[inline]
    pub fn get(&self, i: usize) -> u16 {
        ((self.words[i / self.per_word] >> ((i % self.per_word) as u32 * self.bits)) & self.mask)
            as u16
    }

    /// Storage bytes used.
    pub fn storage_bytes(&self) -> usize {
        self.words.len() * 8
    }
}

/// Count positions where two unpacked code slices agree.
pub fn collision_count(a: &[u16], b: &[u16]) -> usize {
    assert_eq!(a.len(), b.len());
    a.iter().zip(b).filter(|(x, y)| x == y).count()
}

/// Count positions where two packed code vectors agree. Requires equal
/// length and bit width.
pub fn collision_count_packed(a: &PackedCodes, b: &PackedCodes) -> usize {
    assert_eq!(a.bits, b.bits, "bit width mismatch");
    assert_eq!(a.len, b.len, "length mismatch");
    match a.bits {
        1 => collisions_b1(a, b),
        2 => collisions_b2(a, b),
        4 => collisions_swar(a, b, 4, 0x1111_1111_1111_1111),
        8 => collisions_swar(a, b, 8, 0x0101_0101_0101_0101),
        16 => collisions_swar(a, b, 16, 0x0001_0001_0001_0001),
        _ => unreachable!("unsupported width"),
    }
}

/// 1-bit: agreement = NOT(XOR); popcount, with tail masking.
fn collisions_b1(a: &PackedCodes, b: &PackedCodes) -> usize {
    let mut total = 0usize;
    let full = a.len / 64;
    for i in 0..full {
        total += (!(a.words[i] ^ b.words[i])).count_ones() as usize;
    }
    let rem = a.len % 64;
    if rem > 0 {
        let mask = (1u64 << rem) - 1;
        total += ((!(a.words[full] ^ b.words[full])) & mask).count_ones() as usize;
    }
    total
}

/// 2-bit SWAR: a 2-bit lane is equal iff both of its bits match.
fn collisions_b2(a: &PackedCodes, b: &PackedCodes) -> usize {
    const LO: u64 = 0x5555_5555_5555_5555; // low bit of each 2-bit lane
    let mut total = 0usize;
    let per_word = 32;
    let full = a.len / per_word;
    for i in 0..full {
        let eq = !(a.words[i] ^ b.words[i]);
        // lane equal iff both bits equal: AND the two bits of each lane.
        let lanes = eq & (eq >> 1) & LO;
        total += lanes.count_ones() as usize;
    }
    let rem = a.len % per_word;
    if rem > 0 {
        let eq = !(a.words[full] ^ b.words[full]);
        let lanes = eq & (eq >> 1) & LO & ((1u64 << (2 * rem)) - 1);
        total += lanes.count_ones() as usize;
    }
    total
}

/// Generic SWAR equality count for lane widths 4/8/16: a lane is equal
/// iff `xor` restricted to the lane is zero. Zero lanes are detected by
/// OR-collapsing each lane onto its low bit (no cross-lane borrows,
/// unlike the subtract-based trick).
fn collisions_swar(a: &PackedCodes, b: &PackedCodes, bits: u32, lo_mask: u64) -> usize {
    let per_word = (64 / bits) as usize;
    let mut total = 0usize;
    let full = a.len / per_word;
    for i in 0..full {
        let x = a.words[i] ^ b.words[i];
        // Collapse every bit of a lane onto the lane's low bit.
        let mut y = x;
        let mut shift = bits / 2;
        while shift > 0 {
            y |= y >> shift;
            shift /= 2;
        }
        let nonzero = (y & lo_mask).count_ones() as usize;
        total += per_word - nonzero;
    }
    let rem = a.len % per_word;
    if rem > 0 {
        for j in 0..rem {
            total += usize::from(a.get(full * per_word + j) == b.get(full * per_word + j));
        }
    }
    total
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mathx::Pcg64;

    fn random_codes(n: usize, card: u16, seed: u64) -> Vec<u16> {
        let mut rng = Pcg64::new(seed, 0);
        (0..n).map(|_| rng.next_below(card as u64) as u16).collect()
    }

    #[test]
    fn pack_unpack_roundtrip_all_widths() {
        for &(bits, card) in &[(1u32, 2u16), (2, 4), (4, 16), (8, 200), (16, 5000)] {
            for &n in &[0usize, 1, 7, 63, 64, 65, 257] {
                let codes = random_codes(n, card, 42 + bits as u64);
                let packed = pack_codes(&codes, bits);
                assert_eq!(unpack_codes(&packed), codes, "bits={bits} n={n}");
            }
        }
    }

    #[test]
    fn width_rounding() {
        assert_eq!(supported_width(3), 4);
        assert_eq!(supported_width(5), 8);
        assert_eq!(supported_width(9), 16);
        assert_eq!(supported_width(1), 1);
    }

    #[test]
    fn packed_collision_matches_scalar_all_widths() {
        for &(bits, card) in &[(1u32, 2u16), (2, 4), (4, 16), (8, 200), (16, 1000)] {
            for &n in &[1usize, 31, 64, 100, 513] {
                let a = random_codes(n, card, 1000 + bits as u64);
                let b = random_codes(n, card, 2000 + bits as u64);
                let pa = pack_codes(&a, bits);
                let pb = pack_codes(&b, bits);
                assert_eq!(
                    collision_count_packed(&pa, &pb),
                    collision_count(&a, &b),
                    "bits={bits} n={n}"
                );
            }
        }
    }

    #[test]
    fn identical_vectors_collide_everywhere() {
        let a = random_codes(777, 4, 5);
        let pa = pack_codes(&a, 2);
        assert_eq!(collision_count_packed(&pa, &pa), 777);
    }

    #[test]
    fn storage_is_compact() {
        // 256 2-bit codes = 64 bytes — the paper's economy argument.
        let a = random_codes(256, 4, 6);
        let p = pack_codes(&a, 2);
        assert_eq!(p.storage_bytes(), 64);
        // vs 1 KiB for f32 storage of the raw projections.
    }

    #[test]
    fn from_words_rebuilds_exactly() {
        for &(bits, card) in &[(1u32, 2u16), (2, 4), (4, 16), (16, 5000)] {
            let codes = random_codes(130, card, 11 + bits as u64);
            let p = pack_codes(&codes, bits);
            let q = PackedCodes::from_words(bits, p.len, p.words().to_vec());
            assert_eq!(p, q);
            assert_eq!(unpack_codes(&q), codes);
        }
    }

    #[test]
    #[should_panic(expected = "word count")]
    fn from_words_rejects_bad_word_count() {
        PackedCodes::from_words(2, 100, vec![0u64; 1]);
    }

    #[test]
    fn get_matches_unpack() {
        let a = random_codes(130, 16, 9);
        let p = pack_codes(&a, 4);
        for (i, &c) in a.iter().enumerate() {
            assert_eq!(p.get(i), c);
        }
    }

    #[test]
    #[should_panic(expected = "length mismatch")]
    fn mismatched_lengths_panic() {
        let a = pack_codes(&random_codes(10, 4, 1), 2);
        let b = pack_codes(&random_codes(11, 4, 2), 2);
        collision_count_packed(&a, &b);
    }
}
