//! # `crp` — Coding for Random Projections
//!
//! A production-grade reproduction of *Coding for Random Projections*
//! (Ping Li, Michael Mitzenmacher, Anshumali Shrivastava; ICML 2014) as a
//! three-layer Rust + JAX + Pallas system.
//!
//! The paper studies **coding schemes** for Gaussian random projections:
//! project unit-norm vectors `u, v ∈ R^D` with `R ∈ R^{D×k}`,
//! `r_ij ~ N(0,1)`, then quantize each projected coordinate to a small
//! number of bits. Four schemes are analyzed and implemented here:
//!
//! * [`coding::Scheme::Uniform`] — `h_w(x) = floor(x/w)`, the paper's
//!   proposed uniform quantization (Section 1.1, Theorem 1/3).
//! * [`coding::Scheme::WindowOffset`] — `h_{w,q}(x) = floor((x+q)/w)`,
//!   `q ~ U(0,w)`, the prior scheme of Datar et al. (SCG 2004) used as the
//!   baseline throughout the paper (Theorem 2).
//! * [`coding::Scheme::TwoBit`] — the paper's non-uniform 2-bit scheme
//!   `h_{w,2}` over the regions `(-∞,-w), [-w,0), [0,w), [w,∞)`
//!   (Section 4, Theorem 4).
//! * [`coding::Scheme::OneBit`] — `h_1(x) = sign(x)`, SimHash-style
//!   (Section 5).
//!
//! ## Layer map
//!
//! * **Layer 1/2 (build-time Python, `python/compile/`)** — Pallas kernels
//!   for the blocked projection matmul and fused quantization, composed
//!   into JAX graphs and AOT-lowered to HLO text in `artifacts/`.
//! * **Layer 3 (this crate)** — the runtime system. [`runtime`] loads the
//!   AOT artifacts via PJRT; [`projection`] tiles arbitrary workloads onto
//!   the fixed artifact shapes; [`coordinator`] serves sketch/similarity
//!   requests over TCP with dynamic batching and a fused
//!   project→quantize→pack bulk-ingest path ([`coding::BatchEncoder`]).
//!   Sparse inputs ingest at O(nnz) ([`projection::sparse`]): CSR
//!   batches travel the wire as `RegisterSparse` frames
//!   ([`data::CsrMatrix`], validated at every decode boundary), are
//!   coalesced by the reactor like dense registers, and are projected by
//!   a gather kernel that touches only the stored-row tiles named by
//!   each row's nonzeros — replaying the dense kernel's accumulation
//!   order exactly, so the packed codes are **byte-identical** to
//!   densify-then-project. Collections can opt into a seeded
//!   sign-sparse matrix ([`projection::MatrixKind::SignSparse`],
//!   Achlioptas-style ±1 entries, add/sub only, recorded in the
//!   MANIFEST) to drop the Gaussian row generation too; `crp register
//!   --libsvm FILE` bulk-loads standard sparse datasets through this
//!   path.
//!   [`scan`] answers `Knn` and batched `TopK` queries with a columnar
//!   code arena swept by runtime-dispatched collision kernels (AVX-512
//!   `vpopcntq` → AVX2 → SSE2 → portable SWAR, all byte-identical;
//!   `CRP_SCAN_KERNEL=swar|sse2|avx2|avx512` forces a tier) into an
//!   exact top-k selection, sharded across threads; [`lsh`] turns the
//!   same packed words into sub-linear retrieval — a banded multi-probe
//!   [`lsh::CodeIndex`] over the sealed arena, maintained at every
//!   epoch drain, serving `ApproxTopK` (bucket candidates reranked
//!   through the same kernels, pending rows swept exactly, the exact
//!   scan kept as the oracle and small-store fallback). The
//!   coordinator is multi-collection
//!   ([`coordinator::registry`]): one process serves many named
//!   collections, each bundling its own projector, batcher, coding
//!   scheme, arena-backed store, and durability — the paper's point
//!   that the coding choice is per-workload, made operational
//!   (`CreateCollection`/`DropCollection`/`ListCollections` at runtime,
//!   legacy no-namespace frames routed to `default` byte-identically).
//!   Registration is epoch-buffered ([`scan::EpochArena`]): writers
//!   land in a pending buffer beside the sealed arena and never take
//!   the write lock scans read behind, with bulk drains and
//!   tombstone-aware compaction per epoch — owned by one background
//!   maintenance thread ([`coordinator::maintenance`]) multiplexing
//!   every collection, not the threshold-crossing writer. The serving
//!   state is durable ([`coordinator::durability`]): acknowledged
//!   mutations append to a checksummed epoch WAL (`CRPWAL1`, fsync
//!   policy `always|os|group:<ms>`) before the store mutates, and
//!   checkpoints serialize the sealed arena verbatim (`CRPSNAP2`
//!   arena-image snapshots, written with no store lock held) then
//!   truncate the WAL; a CRC-checked `MANIFEST` under `--data-dir`
//!   records every collection's coding config **and serving options**
//!   (per-collection checkpoint cadence + banded-index shape) so
//!   restart rebuilds the whole registry byte-identically to the
//!   pre-crash server — the index itself is derived state, rebuilt
//!   from the restored arena at the first drain (`crp serve
//!   --data-dir`, `crp collection create|drop|list`, `crp recover`,
//!   `crp topk --approx --probes`, `crp stats`). The whole serving
//!   stack is observable ([`coordinator::obs`]): every request is
//!   timed end to end into per-kind power-of-two latency histograms,
//!   the engine keeps per-collection histograms for drain/fold,
//!   compaction, WAL appends (labeled by fsync policy), snapshot
//!   writes, and ApproxTopK candidate/probe counts, and all of it is
//!   exported as Prometheus text (`--metrics-addr`, `crp metrics`,
//!   the `MetricsText` frame) next to structured key=value logging
//!   with a slow-query log — mirrored into an in-memory ring served
//!   over the wire (`crp slow`) — and sampled request traces
//!   (`CRP_LOG`/`--log-level`, `--slow-query-us`, `--trace-sample`,
//!   `crp stats --watch`), plus `/healthz` + `/readyz` probes on the
//!   metrics listener. The stack replicates
//!   ([`coordinator::replication`]): read-only replicas bootstrap from
//!   a wire-shipped snapshot then tail the primary's CRC-framed WAL
//!   over the same protocol (`crp serve --replicate-from`), reconnect
//!   with jittered exponential backoff, re-bootstrap automatically
//!   past the primary's segment-retention lag cap, expose their lag as
//!   gauges, and fail over via `crp promote`. The TCP front-end is
//!   selectable (`--server-mode`): the default blocking
//!   thread-per-connection loop, or the sharded epoll reactor
//!   ([`coordinator::reactor`]) — `--reactor-threads N` event loops,
//!   each with its own SO_REUSEPORT listener so the kernel spreads
//!   connections across them with nothing shared on the hot path, each
//!   loop holding 10k+ connections (nonblocking accept, frames parsed
//!   in place from per-connection buffers, pipelined dispatch,
//!   concurrent Register/RegisterSparse/TopK coalesced into the bulk
//!   engine paths, gathered writes with per-connection backpressure,
//!   coarse idle sweep honoring `--conn-timeout-ms`), with
//!   `--reactor-workers` optionally running fused bulk work off-loop
//!   through SPSC rings + eventfd wakeups while program and ack order
//!   hold — answering byte-identically to the blocking oracle with no
//!   per-request allocation at steady state. Python never runs on the
//!   request path.
//!
//! ## Analysis stack
//!
//! [`theory`] implements every closed form in the paper — collision
//! probabilities `P_w, P_{w,q}, P_{w,2}, P_1` and asymptotic variance
//! factors `V_w, V_{w,q}, V_{w,2}, V_1` (Theorems 1–4) — on top of the
//! self-contained numerics in [`mathx`]. [`estimator`] inverts empirical
//! collision rates into similarity estimates (plus the contingency-table
//! MLE the paper leaves as future work), and [`figures`] regenerates every
//! figure of the paper's evaluation.
//!
//! ## Quickstart
//!
//! ```no_run
//! use crp::coding::{CodingParams, Scheme};
//! use crp::projection::{ProjectionConfig, Projector};
//! use crp::estimator::CollisionEstimator;
//!
//! // Project two unit vectors with k = 1024 shared Gaussian projections
//! // and estimate their inner-product similarity from 2-bit codes.
//! let cfg = ProjectionConfig { k: 1024, seed: 7, ..Default::default() };
//! let projector = Projector::new_cpu(cfg);
//! let (u, v) = crp::data::pairs::unit_pair_with_rho(256, 0.8, 42);
//! let xu = projector.project_dense(&u);
//! let xv = projector.project_dense(&v);
//! let params = CodingParams::new(Scheme::TwoBit, 0.75);
//! let cu = params.encode(&xu);
//! let cv = params.encode(&xv);
//! let est = CollisionEstimator::new(params);
//! let rho_hat = est.estimate(&cu, &cv);
//! assert!((rho_hat - 0.8).abs() < 0.1);
//! ```

pub mod mathx;
pub mod theory;
pub mod coding;
pub mod projection;
pub mod runtime;
pub mod estimator;
pub mod data;
pub mod svm;
pub mod lsh;
pub mod scan;
pub mod coordinator;
pub mod figures;

/// Crate-wide result alias.
pub type Result<T> = anyhow::Result<T>;
