//! Epoch-buffered ingest: writes land beside the sealed arena instead of
//! behind it.
//!
//! The seed design had `SketchStore::put` take the [`CodeArena`] write
//! lock *outer* to the shard locks, so every register serialized against
//! in-flight scans holding the read side. [`EpochArena`] splits the
//! columnar state in two:
//!
//! * a **sealed** arena behind an `RwLock` that scans share read-side
//!   and only [`EpochArena::drain`] ever write-locks, and
//! * a small **pending** epoch buffer behind a plain `Mutex` — an arena
//!   of rows written since the last drain plus a sorted list of sealed
//!   rows *masked* (overridden or removed) this epoch.
//!
//! Writers touch only the pending mutex plus a sealed *read* lock (to
//! resolve which sealed row an overwrite masks), so ingest never waits
//! on a scan. Scans sweep the pending rows under the mutex — bounded by
//! the drain threshold — and the sealed arena under the read lock with
//! the masked rows skipped; results are byte-identical to scanning one
//! fully drained arena because ranking orders by
//! `(collisions desc, id asc)`, independent of row placement.
//!
//! A **drain** folds the pending buffer into the sealed arena in bulk —
//! one short write-lock hold per epoch, amortized over
//! [`EpochConfig::drain_threshold`] writes — and runs the
//! tombstone-aware compaction policy behind the same write lock. The
//! ingest path uses the non-blocking [`EpochArena::try_drain`], so even
//! the fold never makes a register wait behind a scan: under read
//! pressure the pending buffer just keeps absorbing writes and a later
//! write retries the fold.
//!
//! ## The banded index rides the drain
//!
//! With [`EpochArena::with_index_config`] the sealed arena carries a
//! [`CodeIndex`] — the banded multi-probe candidate index
//! ([`crate::lsh::index`]) — kept in lock-step *incrementally*: every
//! fold un-indexes the masked sealed rows (their old words are still in
//! place at that point), indexes the epoch's rows as they land, and
//! rebuilds wholesale only when compaction remaps row ids. Pending rows
//! are never indexed; [`EpochArena::scan_topk_approx`] sweeps them
//! exactly, so an approximate query is always as fresh as an exact one.
//!
//! Lock order is `sealed` before `pending` everywhere (put, remove,
//! scan, drain), so those two can never deadlock. The index lock sits
//! *outside* that pair's ordering — scans acquire it before the
//! pending mutex, the fold after — and is deadlock-free by a different
//! invariant: **the index is only ever write-locked while the sealed
//! write lock is held** (the fold). Every index reader also holds the
//! sealed *read* lock, which excludes the fold entirely, so no reader
//! can wait behind an index writer that in turn waits on a lock the
//! reader holds — and every reader sees an index exactly consistent
//! with the sealed rows. Adding an index write on any path that does
//! not hold the sealed write lock would break this — don't.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Mutex, RwLock};
use std::time::Instant;

use super::arena::{CodeArena, RowsSnapshot};
use super::scanner::{self, ScanHit};
use super::simd::{CollisionKernel, KernelKind};
use super::topk::TopK;
use crate::coding::PackedCodes;
use crate::lsh::index::{CodeIndex, IndexConfig, APPROX_MIN_ROWS};

/// Drain and compaction policy knobs.
#[derive(Clone, Debug)]
pub struct EpochConfig {
    /// Pending load (inserted rows + masked sealed rows) that arms an
    /// automatic drain; [`EpochArena::put`] reports it so the caller can
    /// fold outside its own critical section.
    pub drain_threshold: usize,
    /// Compact the sealed arena during a drain when tombstones exceed
    /// this fraction of its allocated rows…
    pub compact_ratio: f64,
    /// …and this absolute floor (avoids thrashing small arenas).
    pub compact_min: usize,
}

impl Default for EpochConfig {
    fn default() -> Self {
        EpochConfig {
            drain_threshold: 4096,
            compact_ratio: 0.25,
            compact_min: 1024,
        }
    }
}

/// Pending load (as a multiple of the drain threshold) beyond which
/// [`EpochArena::relieve`] stops deferring to scans and folds with a
/// blocking write-lock acquisition — the hard bound on pending growth.
pub const RELIEF_FACTOR: usize = 8;

/// Engine-side histogram: 32 power-of-two buckets (`[2^i, 2^(i+1))`,
/// the final bucket unbounded) plus count and sum, all relaxed
/// atomics. Same shape as the coordinator's `LatencyHistogram`,
/// duplicated here because the scan layer must not depend on
/// `crate::coordinator` — the exposition layer reads raw bucket
/// counts from either through the same rendering helper.
#[derive(Debug, Default)]
pub struct EngineHist {
    buckets: [AtomicU64; 32],
    count: AtomicU64,
    sum: AtomicU64,
}

impl EngineHist {
    /// Record one sample (0 clamps into the first bucket).
    pub fn record(&self, value: u64) {
        let b = (64 - value.max(1).leading_zeros() as usize - 1).min(31);
        self.buckets[b].fetch_add(1, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
        self.sum.fetch_add(value, Ordering::Relaxed);
    }

    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    pub fn sum(&self) -> u64 {
        self.sum.load(Ordering::Relaxed)
    }

    /// Raw per-bucket counts (bucket `i` covers `[2^i, 2^(i+1))`; the
    /// last is unbounded).
    pub fn bucket_counts(&self) -> [u64; 32] {
        std::array::from_fn(|i| self.buckets[i].load(Ordering::Relaxed))
    }
}

/// Engine-side observability for one arena: drain/fold and compaction
/// durations (µs) plus `ApproxTopK` candidate-set sizes and probe
/// counts. Recording is a few relaxed atomic adds on paths that
/// already hold the relevant lock — it adds no lock traffic.
#[derive(Debug, Default)]
pub struct ArenaObs {
    /// Whole-fold duration per [`EpochArena::drain`] (µs); empty folds
    /// are not recorded.
    pub fold_us: EngineHist,
    /// Compaction (+ index rebuild) duration when the tombstone policy
    /// fires (µs).
    pub compact_us: EngineHist,
    /// Candidate rows the banded index returned per approx query.
    pub approx_candidates: EngineHist,
    /// Probes used per approx query (post defaulting/clamping).
    pub approx_probes: EngineHist,
}

/// One epoch's write set.
#[derive(Debug)]
struct Pending {
    /// Rows written this epoch (same shape as the sealed arena; deletes
    /// of same-epoch rows tombstone here as usual).
    inserts: CodeArena,
    /// Sealed rows hidden this epoch (removed or overridden), sorted
    /// ascending so sweeps skip them with a pointer walk.
    masked: Vec<u32>,
    /// Bumped on every mutation; keys the scan-side snapshot cache.
    generation: u64,
}

/// Cached pending snapshot shared by scans between writes.
#[derive(Debug)]
struct SnapCache {
    generation: u64,
    rows: std::sync::Arc<RowsSnapshot>,
    masked: std::sync::Arc<Vec<u32>>,
}

impl Pending {
    /// Mask `row`; returns whether it was newly masked.
    fn mask(&mut self, row: u32) -> bool {
        match self.masked.binary_search(&row) {
            Err(pos) => {
                self.masked.insert(pos, row);
                true
            }
            Ok(_) => false,
        }
    }

    /// Write load counted against the drain threshold.
    fn load(&self) -> usize {
        self.inserts.rows_allocated() + self.masked.len()
    }
}

/// Columnar sketch storage with epoch-buffered writes and a cached,
/// runtime-dispatched collision kernel (selected once at construction).
#[derive(Debug)]
pub struct EpochArena {
    k: usize,
    bits: u32,
    stride: usize,
    kernel: CollisionKernel,
    cfg: EpochConfig,
    sealed: RwLock<CodeArena>,
    /// Banded multi-probe candidate index over the sealed rows, kept in
    /// lock-step by the fold (see the module docs). `None` = exact
    /// scans only.
    index: Option<RwLock<CodeIndex>>,
    pending: Mutex<Pending>,
    /// Scan-side snapshot of the pending buffer, reused until the next
    /// write bumps the pending generation.
    snap: Mutex<Option<SnapCache>>,
    /// Epochs completed (bumps at every drain).
    epoch: AtomicU64,
    drains: AtomicU64,
    /// Single-row [`EpochArena::put`] calls — each is one pending-buffer
    /// round trip. Bulk paths (restore, `put_rows`) must keep this flat.
    single_puts: AtomicU64,
    /// Engine-side histograms (fold/compaction durations, approx
    /// candidate/probe distributions).
    obs: ArenaObs,
}

impl EpochArena {
    /// An epoch arena for sketches of `k` codes at `bits` per code
    /// (rounded up to a supported packing width), with default policy.
    pub fn new(k: usize, bits: u32) -> Self {
        Self::with_config(k, bits, EpochConfig::default())
    }

    pub fn with_config(k: usize, bits: u32, cfg: EpochConfig) -> Self {
        Self::build(k, bits, cfg, None)
    }

    /// As [`EpochArena::with_config`], additionally maintaining a
    /// banded multi-probe [`CodeIndex`] over the sealed rows so
    /// [`EpochArena::scan_topk_approx`] answers in bucket-bounded work.
    /// Panics on an index config [`IndexConfig::validate`] rejects for
    /// this sketch shape (the serving layer validates first).
    pub fn with_index_config(k: usize, bits: u32, cfg: EpochConfig, icfg: IndexConfig) -> Self {
        Self::build(k, bits, cfg, Some(icfg))
    }

    fn build(k: usize, bits: u32, cfg: EpochConfig, icfg: Option<IndexConfig>) -> Self {
        let sealed = CodeArena::new(k, bits);
        let (k, bits, stride) = (sealed.k(), sealed.bits(), sealed.stride());
        EpochArena {
            k,
            bits,
            stride,
            kernel: CollisionKernel::select(bits),
            cfg,
            index: icfg.map(|ic| RwLock::new(CodeIndex::new(k, bits, ic))),
            pending: Mutex::new(Pending {
                inserts: CodeArena::new(k, bits),
                masked: Vec::new(),
                generation: 0,
            }),
            snap: Mutex::new(None),
            sealed: RwLock::new(sealed),
            epoch: AtomicU64::new(0),
            drains: AtomicU64::new(0),
            single_puts: AtomicU64::new(0),
            obs: ArenaObs::default(),
        }
    }

    /// Engine-side observability histograms for this arena.
    pub fn obs(&self) -> &ArenaObs {
        &self.obs
    }

    /// Whether a banded candidate index is maintained.
    pub fn has_index(&self) -> bool {
        self.index.is_some()
    }

    /// The index shape, when one is maintained.
    pub fn index_config(&self) -> Option<IndexConfig> {
        self.index.as_ref().map(|l| l.read().unwrap().config())
    }

    /// Occupied index buckets across all bands (0 without an index) —
    /// the stats gauge.
    pub fn index_buckets(&self) -> usize {
        self.index
            .as_ref()
            .map(|l| l.read().unwrap().buckets())
            .unwrap_or(0)
    }

    /// Largest single index bucket across all bands (0 without an
    /// index) — the bucket-skew diagnostic gauge: a bucket far above
    /// `rows / buckets` means one band value is degenerate and approx
    /// candidate sets will balloon.
    pub fn index_max_bucket(&self) -> usize {
        self.index
            .as_ref()
            .map(|l| l.read().unwrap().max_bucket_len())
            .unwrap_or(0)
    }

    /// Codes per sketch.
    pub fn k(&self) -> usize {
        self.k
    }

    /// Bit width per code (a supported packing width).
    pub fn bits(&self) -> u32 {
        self.bits
    }

    /// `u64` words per row.
    pub fn stride(&self) -> usize {
        self.stride
    }

    /// Instruction tier of the collision kernel selected at construction.
    pub fn kernel_kind(&self) -> KernelKind {
        self.kernel.kind()
    }

    /// Insert or replace the sketch for `id`. Never takes the sealed
    /// write lock, so it completes while scans hold the read side.
    /// Returns `true` when the pending load reached the drain threshold
    /// — the caller should invoke [`EpochArena::try_drain`] (ingest
    /// paths) or [`EpochArena::drain`] (maintenance) soon; until a fold
    /// succeeds the pending buffer simply keeps absorbing writes.
    #[must_use]
    pub fn put(&self, id: &str, codes: &PackedCodes) -> bool {
        assert_eq!(codes.len, self.k, "sketch length mismatch");
        assert_eq!(codes.bits, self.bits, "sketch bit width mismatch");
        self.single_puts.fetch_add(1, Ordering::Relaxed);
        let sealed = self.sealed.read().unwrap();
        let mut p = self.pending.lock().unwrap();
        p.inserts.insert(id, codes);
        if let Some(row) = sealed.row_of(id) {
            p.mask(row);
        }
        p.generation += 1;
        p.load() >= self.cfg.drain_threshold
    }

    /// Bulk insert `ids` with their packed rows laid out contiguously in
    /// `words` ([`EpochArena::stride`] words per row, padding bits zero)
    /// — the fused encode pipeline lands a whole batch with one lock
    /// round-trip and no per-vector allocation. Returns `true` when a
    /// drain is due.
    #[must_use]
    pub fn put_rows(&self, ids: &[String], words: &[u64]) -> bool {
        assert_eq!(
            words.len(),
            ids.len() * self.stride,
            "bulk row buffer shape mismatch"
        );
        let sealed = self.sealed.read().unwrap();
        let mut p = self.pending.lock().unwrap();
        for (i, id) in ids.iter().enumerate() {
            p.inserts
                .insert_row_words(id, &words[i * self.stride..(i + 1) * self.stride]);
            if let Some(row) = sealed.row_of(id) {
                p.mask(row);
            }
        }
        p.generation += 1;
        p.load() >= self.cfg.drain_threshold
    }

    /// Remove the sketch for `id`. Returns whether it was present
    /// (pending or sealed).
    pub fn remove(&self, id: &str) -> bool {
        let sealed = self.sealed.read().unwrap();
        let mut p = self.pending.lock().unwrap();
        let in_pending = p.inserts.remove(id);
        let newly_masked = match sealed.row_of(id) {
            Some(row) => p.mask(row),
            None => false,
        };
        if in_pending || newly_masked {
            p.generation += 1;
        }
        in_pending || newly_masked
    }

    /// Clone out the sketch for `id`; pending writes override sealed
    /// rows, masked-but-not-rewritten rows read as absent.
    pub fn get(&self, id: &str) -> Option<PackedCodes> {
        let sealed = self.sealed.read().unwrap();
        let p = self.pending.lock().unwrap();
        if let Some(codes) = p.inserts.get(id) {
            return Some(codes);
        }
        match sealed.row_of(id) {
            Some(row) if p.masked.binary_search(&row).is_ok() => None,
            Some(_) => sealed.get(id),
            None => None,
        }
    }

    /// Live sketches across the sealed arena and the pending epoch.
    pub fn len(&self) -> usize {
        let sealed = self.sealed.read().unwrap();
        let p = self.pending.lock().unwrap();
        sealed.len() + p.inserts.len() - p.masked.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Rows in the pending buffer (including same-epoch tombstones).
    pub fn pending_rows(&self) -> usize {
        self.pending.lock().unwrap().inserts.rows_allocated()
    }

    /// Pending write load (inserted rows + masked sealed rows) — the
    /// quantity compared against [`EpochConfig::drain_threshold`].
    pub fn pending_load(&self) -> usize {
        self.pending.lock().unwrap().load()
    }

    /// Whether the pending load has reached the drain threshold. Lets
    /// delete-heavy callers (whose `remove` does not report it) trigger
    /// [`EpochArena::relieve`] too, so masks and tombstones fold and
    /// compact without waiting for a later put.
    pub fn drain_due(&self) -> bool {
        self.pending_load() >= self.cfg.drain_threshold
    }

    /// Rows a scan currently skips: sealed tombstones plus this epoch's
    /// masked rows.
    pub fn tombstones(&self) -> usize {
        let sealed = self.sealed.read().unwrap();
        let p = self.pending.lock().unwrap();
        sealed.tombstones() + p.masked.len()
    }

    /// Bytes of packed storage across both halves.
    pub fn storage_bytes(&self) -> usize {
        let sealed = self.sealed.read().unwrap();
        let p = self.pending.lock().unwrap();
        sealed.storage_bytes() + p.inserts.storage_bytes()
    }

    /// Epochs completed so far.
    pub fn epoch(&self) -> u64 {
        self.epoch.load(Ordering::Relaxed)
    }

    /// Drains executed so far (equals [`EpochArena::epoch`]).
    pub fn drains(&self) -> u64 {
        self.drains.load(Ordering::Relaxed)
    }

    /// Single-row `put` calls so far — the per-sketch epoch-buffer
    /// trips a bulk restore is required to avoid.
    pub fn single_puts(&self) -> u64 {
        self.single_puts.load(Ordering::Relaxed)
    }

    /// Run `f` against the sealed arena under the read lock (snapshots,
    /// tests, persistence). Writes keep flowing into the pending buffer
    /// while `f` runs — that is the whole point of the epoch split.
    pub fn with_sealed<R>(&self, f: impl FnOnce(&CodeArena) -> R) -> R {
        f(&self.sealed.read().unwrap())
    }

    /// Consistent owned image of the sealed arena — words, id table, and
    /// tombstones as of one instant — taken under a single short
    /// read-lock hold (one flat clone, no per-row work). This is the
    /// checkpoint unit: callers serialize it to disk with **no** arena
    /// or shard lock held, so puts and scans flow freely for the whole
    /// file write. Pending-epoch rows are not included; drain first if
    /// the image must cover everything acknowledged so far.
    pub fn sealed_image(&self) -> super::arena::ArenaImage {
        self.sealed.read().unwrap().image()
    }

    /// Whether the pending load has reached [`RELIEF_FACTOR`]× the drain
    /// threshold — the point past which even an ingest path that has
    /// handed fold duty to a maintenance thread must fold inline
    /// (blocking) to bound pending memory.
    pub fn overloaded(&self) -> bool {
        self.pending_load() >= self.cfg.drain_threshold.saturating_mul(RELIEF_FACTOR)
    }

    /// Fold the pending epoch into the sealed arena in one bulk step:
    /// tombstone removed rows, rewrite overridden rows in place, append
    /// fresh rows in write order, then compact if the tombstone policy
    /// says so. Blocks until the sealed write lock is free; the ingest
    /// path uses [`EpochArena::try_drain`] instead so it never waits
    /// behind scans. Returns the number of live rows folded in.
    pub fn drain(&self) -> usize {
        let mut sealed = self.sealed.write().unwrap();
        self.fold_into(&mut sealed)
    }

    /// Non-blocking [`EpochArena::drain`]: folds only when no scan holds
    /// the sealed side, so the writer that crossed the drain threshold
    /// skips the fold under read pressure and a later write retries.
    /// Returns `None` when the sealed lock was contended.
    pub fn try_drain(&self) -> Option<usize> {
        let mut sealed = self.sealed.try_write().ok()?;
        Some(self.fold_into(&mut sealed))
    }

    /// The ingest path's fold policy: try-lock normally, but once the
    /// pending load exceeds [`RELIEF_FACTOR`]× the drain threshold —
    /// sustained scans can starve `try_drain` indefinitely — fall back
    /// to a blocking fold so pending memory (and the pending sweep every
    /// scan pays) stays bounded. Returns rows folded (0 when skipped).
    pub fn relieve(&self) -> usize {
        if let Some(folded) = self.try_drain() {
            return folded;
        }
        if self.pending_load()
            >= self.cfg.drain_threshold.saturating_mul(RELIEF_FACTOR)
        {
            return self.drain();
        }
        0
    }

    fn fold_into(&self, sealed: &mut CodeArena) -> usize {
        let mut p = self.pending.lock().unwrap();
        if p.inserts.rows_allocated() == 0 && p.masked.is_empty() {
            // Empty folds are free and constant; recording them would
            // only drown the histogram in maintenance-tick noise.
            return 0;
        }
        let t0 = Instant::now();
        let folded = p.inserts.len();
        // The caller holds the sealed write lock, so the index can be
        // updated in lock-step with the arena (innermost lock).
        let mut index = self.index.as_ref().map(|l| l.write().unwrap());
        // Un-index every masked sealed row while its *old* words are
        // still in place — whether it is about to be removed or
        // rewritten, its current band entries are stale either way.
        if let Some(idx) = index.as_deref_mut() {
            for &row in &p.masked {
                if sealed.id_of(row).is_some() {
                    idx.remove(row, sealed.row_words(row));
                }
            }
        }
        // Pure removals first. Overridden ids (masked but re-written
        // this epoch) keep their sealed row: the insert below rewrites
        // it in place, so steady-state overwrites create no tombstones
        // and no arena growth.
        for &row in &p.masked {
            let dead = sealed.id_of(row).map(str::to_string);
            if let Some(id) = dead {
                if p.inserts.row_of(&id).is_none() {
                    sealed.remove(&id);
                }
            }
        }
        // Then this epoch's rows, preserving their write order; each
        // lands in the index under its sealed row id.
        for row in 0..p.inserts.rows_allocated() as u32 {
            if let Some(id) = p.inserts.id_of(row) {
                let words = p.inserts.row_words(row);
                let srow = sealed.insert_row_words(id, words);
                if let Some(idx) = index.as_deref_mut() {
                    idx.insert(srow, words);
                }
            }
        }
        p.inserts.clear();
        p.masked.clear();
        p.generation += 1;
        let tomb = sealed.tombstones();
        if tomb >= self.cfg.compact_min
            && tomb as f64 >= self.cfg.compact_ratio * sealed.rows_allocated() as f64
        {
            let c0 = Instant::now();
            sealed.compact();
            // Compaction remaps every surviving row downward; the
            // bucket row ids are wholesale stale. Rebuild.
            if let Some(idx) = index.as_deref_mut() {
                idx.rebuild(sealed);
            }
            self.obs.compact_us.record(c0.elapsed().as_micros() as u64);
        }
        self.epoch.fetch_add(1, Ordering::Relaxed);
        self.drains.fetch_add(1, Ordering::Relaxed);
        self.obs.fold_us.record(t0.elapsed().as_micros() as u64);
        folded
    }

    /// Exact top-`n` by collision count over both halves, ordered
    /// `(collisions desc, id asc)` — byte-identical to scanning one
    /// fully drained arena. Pending rows report their row index offset
    /// by the sealed row count (rows are transient across drains; ids
    /// are the stable key).
    pub fn scan_topk(&self, query: &PackedCodes, n: usize, threads: usize) -> Vec<ScanHit> {
        assert_eq!(query.len, self.k, "query length mismatch");
        assert_eq!(query.bits, self.bits, "query bit width mismatch");
        let sealed = self.sealed.read().unwrap();
        let (pend, masked) = self.snapshot_pending();
        let base = sealed.rows_allocated() as u32;
        let mut top = self.sweep_pending(&pend, base, query, n);
        top.merge(scanner::scan_arena(
            &sealed,
            self.kernel,
            query,
            &masked,
            n,
            threads,
        ));
        top.into_sorted().into_iter().map(ScanHit::from).collect()
    }

    /// Batched [`EpochArena::scan_topk`]: one pending snapshot serves
    /// every query's pending sweep lock-free, then the sealed sweeps fan
    /// out across threads. Result `i` equals `scan_topk(&queries[i], n, 1)`.
    pub fn scan_topk_batch(
        &self,
        queries: &[PackedCodes],
        n: usize,
        threads: usize,
    ) -> Vec<Vec<ScanHit>> {
        for q in queries {
            assert_eq!(q.len, self.k, "query length mismatch");
            assert_eq!(q.bits, self.bits, "query bit width mismatch");
        }
        let sealed = self.sealed.read().unwrap();
        let (pend, masked) = self.snapshot_pending();
        let base = sealed.rows_allocated() as u32;
        let pending_tops: Vec<TopK> = queries
            .iter()
            .map(|q| self.sweep_pending(&pend, base, q, n))
            .collect();
        let swept =
            scanner::scan_arena_batch(&sealed, self.kernel, queries, &masked, n, threads);
        pending_tops
            .into_iter()
            .zip(swept)
            .map(|(mut top, sealed_top)| {
                top.merge(sealed_top);
                top.into_sorted().into_iter().map(ScanHit::from).collect()
            })
            .collect()
    }

    /// Approximate top-`n` through the banded index: bucket candidates
    /// from the sealed rows (multi-probe expanded by `probes` low-order
    /// band-bit flips) reranked through the exact collision kernel,
    /// merged with an **exact** sweep of the pending epoch — so results
    /// are as fresh as [`EpochArena::scan_topk`] and every reported
    /// collision count (hence ρ̂) is exact for its row. Recall against
    /// the exact scan is governed by the index shape
    /// ([`IndexConfig::for_shape`]) and `probes`; ordering is the same
    /// `(collisions desc, id asc)`. Falls back to the exact sweep when
    /// no index is maintained or the sealed arena is still below
    /// [`APPROX_MIN_ROWS`] (probing cannot beat a tiny sequential
    /// pass, and the exact scan is the oracle anyway).
    pub fn scan_topk_approx(&self, query: &PackedCodes, n: usize, probes: usize) -> Vec<ScanHit> {
        self.scan_topk_approx_batch(std::slice::from_ref(query), n, probes)
            .pop()
            .unwrap_or_default()
    }

    /// Batched [`EpochArena::scan_topk_approx`]: one sealed-lock hold
    /// and one pending snapshot serve every query. Result `i` equals
    /// `scan_topk_approx(&queries[i], n, probes)`.
    pub fn scan_topk_approx_batch(
        &self,
        queries: &[PackedCodes],
        n: usize,
        probes: usize,
    ) -> Vec<Vec<ScanHit>> {
        self.scan_topk_approx_batch_counted(queries, n, probes).0
    }

    /// As [`EpochArena::scan_topk_approx_batch`], also reporting the
    /// total candidate rows the index returned across the batch (0 when
    /// the exact fallback served it) — the slow-query log attributes a
    /// slow approx request to its candidate volume through this.
    pub fn scan_topk_approx_batch_counted(
        &self,
        queries: &[PackedCodes],
        n: usize,
        probes: usize,
    ) -> (Vec<Vec<ScanHit>>, u64) {
        for q in queries {
            assert_eq!(q.len, self.k, "query length mismatch");
            assert_eq!(q.bits, self.bits, "query bit width mismatch");
        }
        let sealed = self.sealed.read().unwrap();
        // Index reads are consistent with the sealed rows because the
        // index is only ever written under the sealed write lock.
        let index = match &self.index {
            Some(l) if sealed.rows_allocated() >= APPROX_MIN_ROWS => Some(l.read().unwrap()),
            _ => None,
        };
        let (pend, masked) = self.snapshot_pending();
        let base = sealed.rows_allocated() as u32;
        let mut total_candidates = 0u64;
        let results = queries
            .iter()
            .map(|q| {
                let mut top = self.sweep_pending(&pend, base, q, n);
                match index.as_deref() {
                    Some(idx) => {
                        let cands = idx.candidates(q.words(), probes);
                        self.obs.approx_candidates.record(cands.len() as u64);
                        self.obs.approx_probes.record(probes as u64);
                        total_candidates += cands.len() as u64;
                        top.merge(scanner::scan_candidates(
                            &sealed,
                            self.kernel,
                            q,
                            &cands,
                            &masked,
                            n,
                        ));
                    }
                    None => top.merge(scanner::scan_arena(
                        &sealed,
                        self.kernel,
                        q,
                        &masked,
                        n,
                        0,
                    )),
                }
                top.into_sorted().into_iter().map(ScanHit::from).collect()
            })
            .collect();
        (results, total_candidates)
    }

    /// The pending rows as a shared snapshot, copied out under one short
    /// mutex hold — words and ids only, no id-index rebuild — so
    /// query-time sweeps never stall writers. Consecutive scans between
    /// writes share one copy (the cache is keyed by the pending
    /// generation); snapshot size is bounded by [`RELIEF_FACTOR`]× the
    /// drain threshold, the relief policy's cap on pending growth.
    fn snapshot_pending(&self) -> (std::sync::Arc<RowsSnapshot>, std::sync::Arc<Vec<u32>>) {
        let p = self.pending.lock().unwrap();
        let mut cache = self.snap.lock().unwrap();
        if let Some(c) = cache.as_ref() {
            if c.generation == p.generation {
                return (c.rows.clone(), c.masked.clone());
            }
        }
        let rows = std::sync::Arc::new(p.inserts.rows_snapshot());
        let masked = std::sync::Arc::new(p.masked.clone());
        *cache = Some(SnapCache {
            generation: p.generation,
            rows: rows.clone(),
            masked: masked.clone(),
        });
        (rows, masked)
    }

    /// Serial sweep of a pending snapshot (runs without any lock held).
    fn sweep_pending(
        &self,
        pend: &RowsSnapshot,
        base: u32,
        query: &PackedCodes,
        n: usize,
    ) -> TopK {
        let mut top = TopK::new(n);
        let qwords = query.words();
        for row in 0..pend.rows_allocated() as u32 {
            if let Some(id) = pend.id_of(row) {
                let c = self.kernel.count(self.k, qwords, pend.row_words(row));
                top.offer(base + row, id, c);
            }
        }
        top
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coding::pack_codes;
    use crate::mathx::Pcg64;

    fn sketch(k: usize, seed: u64) -> PackedCodes {
        let mut g = Pcg64::new(seed, 0);
        let codes: Vec<u16> = (0..k).map(|_| g.next_below(4) as u16).collect();
        pack_codes(&codes, 2)
    }

    fn small_cfg() -> EpochConfig {
        EpochConfig {
            drain_threshold: 8,
            compact_ratio: 0.5,
            compact_min: 4,
        }
    }

    #[test]
    fn put_get_remove_across_the_epoch_split() {
        let e = EpochArena::with_config(64, 2, small_cfg());
        assert!(e.is_empty());
        assert!(!e.put("a", &sketch(64, 1)));
        assert!(!e.put("b", &sketch(64, 2)));
        assert_eq!(e.len(), 2);
        assert_eq!(e.get("a"), Some(sketch(64, 1)));
        assert_eq!(e.get("zzz"), None);
        e.drain();
        assert_eq!(e.epoch(), 1);
        assert_eq!(e.len(), 2);
        assert_eq!(e.get("a"), Some(sketch(64, 1)));
        // Override a sealed row from the new epoch.
        assert!(!e.put("a", &sketch(64, 9)));
        assert_eq!(e.get("a"), Some(sketch(64, 9)));
        assert_eq!(e.len(), 2);
        // Remove a sealed row without draining.
        assert!(e.remove("b"));
        assert!(!e.remove("b"));
        assert_eq!(e.get("b"), None);
        assert_eq!(e.len(), 1);
        e.drain();
        assert_eq!(e.get("a"), Some(sketch(64, 9)));
        assert_eq!(e.get("b"), None);
        assert_eq!(e.len(), 1);
    }

    #[test]
    fn put_reports_drain_due_at_threshold() {
        let e = EpochArena::with_config(32, 2, small_cfg());
        let mut due = false;
        for i in 0..8 {
            due = e.put(&format!("id{i}"), &sketch(32, i));
        }
        assert!(due, "8th put must cross the threshold of 8");
        assert_eq!(e.pending_load(), 8);
        assert_eq!(e.drain(), 8);
        assert_eq!(e.pending_load(), 0);
        assert_eq!(e.len(), 8);
    }

    #[test]
    fn scan_sees_sealed_pending_and_masks_consistently() {
        let e = EpochArena::with_config(64, 2, small_cfg());
        for i in 0..6 {
            let _ = e.put(&format!("s{i}"), &sketch(64, i));
        }
        e.drain();
        // New epoch: one fresh row, one override, one removal.
        let _ = e.put("p0", &sketch(64, 100));
        let _ = e.put("s1", &sketch(64, 101));
        e.remove("s2");
        let q = sketch(64, 100);
        let hits = e.scan_topk(&q, 10, 1);
        assert_eq!(hits.len(), 6); // 6 sealed + 1 pending − 1 removed… s1 counted once
        assert_eq!(hits[0].id, "p0");
        assert_eq!(hits[0].collisions, 64);
        assert!(hits.iter().all(|h| h.id != "s2"));
        assert_eq!(hits.iter().filter(|h| h.id == "s1").count(), 1);
        // Draining must not change the ranking.
        let want: Vec<(String, usize)> =
            hits.into_iter().map(|h| (h.id, h.collisions)).collect();
        e.drain();
        let got: Vec<(String, usize)> = e
            .scan_topk(&q, 10, 1)
            .into_iter()
            .map(|h| (h.id, h.collisions))
            .collect();
        assert_eq!(got, want);
    }

    #[test]
    fn drain_compacts_when_policy_fires() {
        let e = EpochArena::with_config(32, 2, small_cfg());
        for i in 0..8 {
            let _ = e.put(&format!("id{i}"), &sketch(32, i));
        }
        e.drain();
        for i in 0..6 {
            e.remove(&format!("id{i}"));
        }
        e.drain();
        // 6 of 8 rows tombstoned ≥ max(4, 0.5·8) → compacted away.
        e.with_sealed(|sealed| {
            assert_eq!(sealed.tombstones(), 0);
            assert_eq!(sealed.rows_allocated(), 2);
        });
        assert_eq!(e.len(), 2);
        assert_eq!(e.obs().compact_us.count(), 1, "compaction was timed");
        assert_eq!(e.obs().fold_us.count(), 2, "both non-empty folds timed");
    }

    #[test]
    fn same_epoch_insert_then_remove_leaves_nothing() {
        let e = EpochArena::with_config(32, 2, small_cfg());
        let _ = e.put("x", &sketch(32, 5));
        assert!(e.remove("x"));
        assert_eq!(e.len(), 0);
        assert_eq!(e.get("x"), None);
        e.drain();
        assert_eq!(e.len(), 0);
        assert!(e.scan_topk(&sketch(32, 5), 5, 1).is_empty());
    }

    #[test]
    fn batch_scan_matches_single_scans() {
        let e = EpochArena::with_config(96, 1, small_cfg());
        let mut g = Pcg64::new(9, 1);
        for i in 0..40 {
            let codes: Vec<u16> = (0..96).map(|_| g.next_below(2) as u16).collect();
            if e.put(&format!("r{i:03}"), &pack_codes(&codes, 1)) {
                e.drain();
            }
        }
        let queries: Vec<PackedCodes> = (0..5)
            .map(|_| {
                let codes: Vec<u16> = (0..96).map(|_| g.next_below(2) as u16).collect();
                pack_codes(&codes, 1)
            })
            .collect();
        let batched = e.scan_topk_batch(&queries, 7, 3);
        assert_eq!(batched.len(), queries.len());
        for (i, q) in queries.iter().enumerate() {
            assert_eq!(batched[i], e.scan_topk(q, 7, 1), "query {i}");
        }
    }

    #[test]
    fn sealed_image_excludes_pending_until_drain() {
        let e = EpochArena::with_config(64, 2, small_cfg());
        let _ = e.put("a", &sketch(64, 1));
        assert_eq!(e.sealed_image().rows(), 0, "pending rows are not sealed");
        e.drain();
        let _ = e.put("b", &sketch(64, 2));
        let img = e.sealed_image();
        assert_eq!(img.rows(), 1);
        assert_eq!(img.ids[0].as_deref(), Some("a"));
        assert_eq!(img.row_words(0), sketch(64, 1).words());
        // Writes keep landing while an image is held — it is a copy.
        let _ = e.put("c", &sketch(64, 3));
        assert_eq!(e.len(), 3);
        assert_eq!(img.rows(), 1);
    }

    #[test]
    fn empty_drain_is_a_noop() {
        let e = EpochArena::new(64, 2);
        assert_eq!(e.drain(), 0);
        assert_eq!(e.epoch(), 0);
        assert_eq!(e.obs().fold_us.count(), 0, "empty folds are not recorded");
    }

    #[test]
    fn engine_hist_buckets_count_and_sum() {
        let h = EngineHist::default();
        h.record(0); // clamps into the first bucket
        h.record(1);
        h.record(2);
        h.record(3);
        h.record(1u64 << 40); // clamps into the unbounded final bucket
        let b = h.bucket_counts();
        assert_eq!(b[0], 2, "0 and 1 land in [1, 2)");
        assert_eq!(b[1], 2, "2 and 3 land in [2, 4)");
        assert_eq!(b[31], 1, "2^40 clamps into the final bucket");
        assert_eq!(b.iter().sum::<u64>(), h.count());
        assert_eq!(h.count(), 5);
        assert_eq!(h.sum(), 6 + (1u64 << 40));
        // The unbounded final bucket absorbs everything ≥ 2^31.
        h.record(u64::MAX);
        assert_eq!(h.bucket_counts()[31], 2);
    }

    #[test]
    fn arena_obs_records_folds_and_approx_queries() {
        let e =
            EpochArena::with_index_config(64, 2, small_cfg(), IndexConfig::for_shape(64, 2));
        for i in 0..(APPROX_MIN_ROWS as u64 + 16) {
            let _ = e.put(&format!("r{i:05}"), &sketch(64, i));
        }
        e.drain();
        assert_eq!(e.obs().fold_us.count(), 1);
        let q = sketch(64, 3);
        let (hits, cands) = e.scan_topk_approx_batch_counted(std::slice::from_ref(&q), 2, 1);
        assert_eq!(hits.len(), 1);
        assert_eq!(hits[0][0].id, "r00003");
        assert!(cands >= 1, "an exact duplicate is always a candidate");
        assert_eq!(e.obs().approx_candidates.count(), 1);
        assert_eq!(e.obs().approx_probes.count(), 1);
        assert!(e.index_max_bucket() >= 1);

        // Below the fallback floor the exact sweep serves the query:
        // no candidate set exists and nothing is recorded.
        let small =
            EpochArena::with_index_config(64, 2, small_cfg(), IndexConfig::for_shape(64, 2));
        let _ = small.put("a", &q);
        small.drain();
        let (hits, cands) = small.scan_topk_approx_batch_counted(std::slice::from_ref(&q), 1, 0);
        assert_eq!(hits[0][0].id, "a");
        assert_eq!(cands, 0);
        assert_eq!(small.obs().approx_candidates.count(), 0);
    }

    #[test]
    fn approx_falls_back_to_exact_below_min_rows() {
        let e =
            EpochArena::with_index_config(64, 2, small_cfg(), IndexConfig::for_shape(64, 2));
        assert!(e.has_index());
        for i in 0..60 {
            if e.put(&format!("s{i}"), &sketch(64, i)) {
                e.drain();
            }
        }
        e.drain();
        let q = sketch(64, 17);
        assert_eq!(e.scan_topk_approx(&q, 10, 2), e.scan_topk(&q, 10, 1));
    }

    #[test]
    fn approx_finds_duplicates_sees_pending_and_hides_removed() {
        // Enough sealed rows to clear the exact-fallback floor.
        let e = EpochArena::with_index_config(
            64,
            2,
            EpochConfig::default(),
            IndexConfig::for_shape(64, 2),
        );
        let n = (APPROX_MIN_ROWS + 200) as u64;
        for i in 0..n {
            let _ = e.put(&format!("r{i:05}"), &sketch(64, i));
        }
        e.drain();
        assert!(e.index_buckets() > 0);
        // Self-retrieval is guaranteed: every band of an exact
        // duplicate matches, so a stored row always finds itself.
        let q = sketch(64, 321);
        let hits = e.scan_topk_approx(&q, 3, 0);
        assert_eq!(hits[0].id, "r00321");
        assert_eq!(hits[0].collisions, 64);
        // Freshness: a pending duplicate is visible before any drain.
        let _ = e.put("fresh", &sketch(64, 321));
        let hits = e.scan_topk_approx(&q, 3, 0);
        assert_eq!(hits[0].id, "fresh", "pending rows must be swept exactly");
        assert_eq!(hits[0].collisions, 64);
        assert_eq!(hits[1].id, "r00321");
        // Removal hides a sealed row immediately (pending mask)...
        assert!(e.remove("r00321"));
        let hits = e.scan_topk_approx(&q, 3, 0);
        assert!(hits.iter().all(|h| h.id != "r00321"));
        // ...and stays hidden once the fold un-indexes it.
        e.drain();
        let hits = e.scan_topk_approx(&q, 3, 0);
        assert_eq!(hits[0].id, "fresh");
        assert!(hits.iter().all(|h| h.id != "r00321"));
    }

    #[test]
    fn approx_index_tracks_overwrites_and_compaction() {
        let e = EpochArena::with_index_config(
            64,
            2,
            EpochConfig {
                drain_threshold: 64,
                compact_ratio: 0.2,
                compact_min: 16,
            },
            IndexConfig::for_shape(64, 2),
        );
        let n = (APPROX_MIN_ROWS + 512) as u64;
        for i in 0..n {
            if e.put(&format!("r{i:05}"), &sketch(64, i)) {
                e.drain();
            }
        }
        e.drain();
        // Overwrite a block of rows with new content...
        for i in 0..64u64 {
            let _ = e.put(&format!("r{i:05}"), &sketch(64, 10_000 + i));
        }
        e.drain();
        // ...and remove enough rows that the next drain compacts.
        for i in 64..464u64 {
            assert!(e.remove(&format!("r{i:05}")));
        }
        e.drain();
        e.with_sealed(|s| assert_eq!(s.tombstones(), 0, "compaction must have fired"));
        // The overwritten rows retrieve by their *new* content only.
        let old_q = sketch(64, 5);
        let hits = e.scan_topk_approx(&old_q, 1, 0);
        assert!(
            hits.is_empty() || hits[0].collisions < 64,
            "stale band entries must not resurrect old content"
        );
        // Every surviving row still self-retrieves through the rebuilt
        // (row-remapped) index; removed rows never return.
        for i in [0u64, 5, 63, 500, n - 1] {
            let id = format!("r{i:05}");
            let q = if i < 64 {
                sketch(64, 10_000 + i)
            } else {
                sketch(64, i)
            };
            let hits = e.scan_topk_approx(&q, 1, 0);
            assert_eq!(hits[0].id, id, "row {i}");
            assert_eq!(hits[0].collisions, 64, "row {i}");
        }
        let gone = e.scan_topk_approx(&sketch(64, 100), 5, 2);
        assert!(gone.iter().all(|h| h.id != "r00100"));
    }
}
