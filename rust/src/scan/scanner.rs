//! The scan itself: sequential sweep over the arena, optionally sharded
//! across threads.
//!
//! A single query shards the row range via `std::thread::scope` and
//! merges the per-shard [`TopK`] selections; a batch of queries instead
//! fans whole queries out across threads (each sweep stays sequential,
//! which keeps every thread's access pattern a pure forward walk).
//! Both paths return exactly what a single-threaded sweep returns.
//!
//! The collision kernel is resolved once per scan (or once per
//! [`super::EpochArena`] at construction) through
//! [`CollisionKernel`] — AVX2/SSE2 when the CPU has them, SWAR
//! otherwise — and every sweep accepts a sorted `masked` row list so the
//! epoch-buffered ingest path can hide sealed rows that the pending
//! buffer overrides; skipping is a pointer walk, not a per-row lookup.

use super::arena::CodeArena;
use super::simd::CollisionKernel;
use super::topk::{TopEntry, TopK};
use crate::coding::PackedCodes;

/// One scan result: a live arena row and its collision count with the
/// query. ρ̂ is left to the caller (it is a monotone function of
/// `collisions`, so ranking does not depend on it).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct ScanHit {
    pub row: u32,
    pub id: String,
    pub collisions: usize,
}

impl From<TopEntry> for ScanHit {
    fn from(e: TopEntry) -> Self {
        ScanHit {
            row: e.row,
            id: e.id,
            collisions: e.collisions,
        }
    }
}

/// Below this many rows an auto-sized (`threads = 0`) scan stays on the
/// calling thread — spawning costs more than the sweep saves. An
/// explicit thread count is always honored.
const PAR_MIN_ROWS: usize = 16 * 1024;

/// Threads to use for `requested` (0 = auto-detect).
fn effective_threads(requested: usize, rows: usize) -> usize {
    let hw = std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1);
    match requested {
        0 if rows < PAR_MIN_ROWS => 1,
        0 => hw.clamp(1, rows),
        t => t.clamp(1, rows.max(1)),
    }
}

/// Sweep `rows` (a contiguous range) into a bounded top-`n` selection,
/// skipping tombstones and the sorted `masked` rows.
fn scan_range(
    arena: &CodeArena,
    kernel: CollisionKernel,
    qwords: &[u64],
    rows: std::ops::Range<u32>,
    masked: &[u32],
    n: usize,
) -> TopK {
    let mut top = TopK::new(n);
    let k = arena.k();
    let mut mi = masked.partition_point(|&m| m < rows.start);
    for row in rows {
        if mi < masked.len() && masked[mi] == row {
            mi += 1;
            continue; // masked by the pending epoch
        }
        let Some(id) = arena.id_of(row) else {
            continue; // tombstone
        };
        let c = kernel.count(k, qwords, arena.row_words(row));
        top.offer(row, id, c);
    }
    top
}

/// Candidate-set rerank: score only `cands` (sorted ascending, as the
/// banded [`crate::lsh::CodeIndex`] emits them) against the query
/// through the same collision kernel the full sweep uses, skipping
/// tombstones and the sorted `masked` rows. This is the approximate
/// path's second stage — bucket candidates in, exact-ranked top-k out —
/// so an `ApproxTopK` hit carries exactly the collision count (and ρ̂)
/// the exact scan would report for that row.
pub(crate) fn scan_candidates(
    arena: &CodeArena,
    kernel: CollisionKernel,
    query: &PackedCodes,
    cands: &[u32],
    masked: &[u32],
    n: usize,
) -> TopK {
    assert_eq!(query.len, arena.k(), "query length mismatch");
    assert_eq!(query.bits, arena.bits(), "query bit width mismatch");
    let mut top = TopK::new(n);
    let k = arena.k();
    let qwords = query.words();
    let mut mi = 0usize;
    for &row in cands {
        // Both lists are sorted: advance the mask cursor monotonically.
        while mi < masked.len() && masked[mi] < row {
            mi += 1;
        }
        if mi < masked.len() && masked[mi] == row {
            continue; // overridden or removed by the pending epoch
        }
        let Some(id) = arena.id_of(row) else {
            continue; // tombstone
        };
        top.offer(row, id, kernel.count(k, qwords, arena.row_words(row)));
    }
    top
}

/// Row-sharded sweep of one query with an explicit kernel and mask.
/// Internal engine shared by [`scan_topk`] and the epoch-buffered path.
pub(crate) fn scan_arena(
    arena: &CodeArena,
    kernel: CollisionKernel,
    query: &PackedCodes,
    masked: &[u32],
    n: usize,
    threads: usize,
) -> TopK {
    assert_eq!(query.len, arena.k(), "query length mismatch");
    assert_eq!(query.bits, arena.bits(), "query bit width mismatch");
    let rows = arena.rows_allocated() as u32;
    let threads = effective_threads(threads, rows as usize);
    let qwords = query.words();
    if threads <= 1 {
        return scan_range(arena, kernel, qwords, 0..rows, masked, n);
    }
    let chunk = rows.div_ceil(threads as u32).max(1);
    std::thread::scope(|s| {
        let handles: Vec<_> = (0..threads as u32)
            .map(|t| {
                let lo = (t * chunk).min(rows);
                let hi = ((t + 1) * chunk).min(rows);
                s.spawn(move || scan_range(arena, kernel, qwords, lo..hi, masked, n))
            })
            .collect();
        let mut merged = TopK::new(n);
        for h in handles {
            merged.merge(h.join().expect("scan shard panicked"));
        }
        merged
    })
}

/// Query-sharded sweep of a batch with an explicit kernel and mask.
/// Result `i` equals `scan_arena(arena, kernel, &queries[i], masked, n, 1)`.
pub(crate) fn scan_arena_batch(
    arena: &CodeArena,
    kernel: CollisionKernel,
    queries: &[PackedCodes],
    masked: &[u32],
    n: usize,
    threads: usize,
) -> Vec<TopK> {
    if queries.len() <= 1 {
        // A lone query still gets row-level parallelism.
        return queries
            .iter()
            .map(|q| scan_arena(arena, kernel, q, masked, n, threads))
            .collect();
    }
    let hw = std::thread::available_parallelism()
        .map(|h| h.get())
        .unwrap_or(1);
    let threads = (if threads == 0 { hw } else { threads }).clamp(1, queries.len());
    if threads <= 1 {
        return queries
            .iter()
            .map(|q| scan_arena(arena, kernel, q, masked, n, 1))
            .collect();
    }
    let chunk = queries.len().div_ceil(threads);
    std::thread::scope(|s| {
        let handles: Vec<_> = queries
            .chunks(chunk)
            .map(|qs| {
                s.spawn(move || {
                    qs.iter()
                        .map(|q| scan_arena(arena, kernel, q, masked, n, 1))
                        .collect::<Vec<_>>()
                })
            })
            .collect();
        handles
            .into_iter()
            .flat_map(|h| h.join().expect("scan batch shard panicked"))
            .collect()
    })
}

/// Exact top-`n` rows of `arena` by collision count with `query`,
/// ordered `(collisions desc, id asc)` — byte-identical to sorting the
/// per-pair estimator scores, in every kernel tier. `threads = 0`
/// auto-detects; small arenas always scan on the calling thread.
pub fn scan_topk(
    arena: &CodeArena,
    query: &PackedCodes,
    n: usize,
    threads: usize,
) -> Vec<ScanHit> {
    let kernel = CollisionKernel::select(arena.bits());
    scan_arena(arena, kernel, query, &[], n, threads)
        .into_sorted()
        .into_iter()
        .map(ScanHit::from)
        .collect()
}

/// Top-`n` for a batch of queries: queries fan out across threads, each
/// sweeping the whole arena sequentially. Result `i` corresponds to
/// `queries[i]` and equals `scan_topk(arena, &queries[i], n, 1)`.
pub fn scan_topk_batch(
    arena: &CodeArena,
    queries: &[PackedCodes],
    n: usize,
    threads: usize,
) -> Vec<Vec<ScanHit>> {
    let kernel = CollisionKernel::select(arena.bits());
    scan_arena_batch(arena, kernel, queries, &[], n, threads)
        .into_iter()
        .map(|top| top.into_sorted().into_iter().map(ScanHit::from).collect())
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coding::pack_codes;
    use crate::mathx::Pcg64;

    fn arena_with(n_rows: usize, k: usize, bits: u32, seed: u64) -> (CodeArena, Vec<Vec<u16>>) {
        let card = 1u16 << bits;
        let mut g = Pcg64::new(seed, 0);
        let mut arena = CodeArena::new(k, bits);
        let mut raw = Vec::new();
        for i in 0..n_rows {
            let codes: Vec<u16> = (0..k).map(|_| g.next_below(card as u64) as u16).collect();
            arena.insert(&format!("row{i:05}"), &pack_codes(&codes, bits));
            raw.push(codes);
        }
        (arena, raw)
    }

    fn brute_force(raw: &[Vec<u16>], query: &[u16], n: usize) -> Vec<(String, usize)> {
        let mut all: Vec<(String, usize)> = raw
            .iter()
            .enumerate()
            .map(|(i, codes)| {
                (
                    format!("row{i:05}"),
                    crate::coding::collision_count(codes, query),
                )
            })
            .collect();
        all.sort_by(|a, b| b.1.cmp(&a.1).then_with(|| a.0.cmp(&b.0)));
        all.truncate(n);
        all
    }

    #[test]
    fn matches_brute_force() {
        for &bits in &[1u32, 2, 4] {
            let (arena, raw) = arena_with(500, 129, bits, 50 + bits as u64);
            let mut g = Pcg64::new(9, 9);
            let query: Vec<u16> = (0..129)
                .map(|_| g.next_below(1 << bits as u64) as u16)
                .collect();
            let packed = pack_codes(&query, bits);
            let got: Vec<(String, usize)> = scan_topk(&arena, &packed, 10, 1)
                .into_iter()
                .map(|h| (h.id, h.collisions))
                .collect();
            assert_eq!(got, brute_force(&raw, &query, 10), "bits={bits}");
        }
    }

    #[test]
    fn parallel_equals_serial() {
        let (arena, _) = arena_with(3000, 64, 2, 4);
        let q = arena.get("row00042").unwrap();
        let serial = scan_topk(&arena, &q, 25, 1);
        // Explicit thread counts are honored even below the auto-mode
        // size threshold, so this genuinely exercises shard + merge.
        for threads in [2, 3, 4, 7] {
            assert_eq!(serial, scan_topk(&arena, &q, 25, threads), "threads={threads}");
        }
        assert_eq!(serial[0].id, "row00042");
        assert_eq!(serial[0].collisions, 64);
    }

    #[test]
    fn every_kernel_tier_ranks_identically() {
        use super::super::simd::{CollisionKernel, KernelKind};
        for &bits in &[1u32, 2] {
            let (arena, _) = arena_with(800, 193, bits, 77 + bits as u64);
            let q = arena.get("row00123").unwrap();
            let swar = CollisionKernel::with_kind(bits, KernelKind::Swar).unwrap();
            let want: Vec<ScanHit> = scan_arena(&arena, swar, &q, &[], 15, 1)
                .into_sorted()
                .into_iter()
                .map(ScanHit::from)
                .collect();
            for kind in [KernelKind::Sse2, KernelKind::Avx2, KernelKind::Avx512] {
                let Some(kernel) = CollisionKernel::with_kind(bits, kind) else {
                    continue;
                };
                for threads in [1usize, 3] {
                    let got: Vec<ScanHit> = scan_arena(&arena, kernel, &q, &[], 15, threads)
                        .into_sorted()
                        .into_iter()
                        .map(ScanHit::from)
                        .collect();
                    assert_eq!(got, want, "bits={bits} kind={kind:?} threads={threads}");
                }
            }
        }
    }

    #[test]
    fn masked_rows_are_hidden_like_tombstones() {
        let (mut arena, raw) = arena_with(200, 64, 2, 6);
        // Oracle: tombstone rows 3 and 77 for real.
        let kernel = CollisionKernel::select(2);
        let q = pack_codes(&raw[3], 2);
        let mut oracle = arena_with(200, 64, 2, 6).0;
        oracle.remove("row00003");
        oracle.remove("row00077");
        let want: Vec<(String, usize)> = scan_topk(&oracle, &q, 200, 1)
            .into_iter()
            .map(|h| (h.id, h.collisions))
            .collect();
        // Same scan, but masking instead of removing.
        for threads in [1usize, 4] {
            let got: Vec<(String, usize)> = scan_arena(&arena, kernel, &q, &[3, 77], 200, threads)
                .into_sorted()
                .into_iter()
                .map(|e| (e.id, e.collisions))
                .collect();
            assert_eq!(got, want, "threads={threads}");
        }
        // And masking composes with real tombstones.
        arena.remove("row00010");
        let got = scan_arena(&arena, kernel, &q, &[3, 77], 200, 1).into_sorted();
        assert_eq!(got.len(), 197);
        assert!(got
            .iter()
            .all(|e| e.id != "row00003" && e.id != "row00077" && e.id != "row00010"));
    }

    #[test]
    fn tombstones_are_skipped() {
        let (mut arena, raw) = arena_with(100, 64, 2, 8);
        arena.remove("row00007");
        arena.remove("row00031");
        let query = raw[7].clone();
        let hits = scan_topk(&arena, &pack_codes(&query, 2), 100, 1);
        assert_eq!(hits.len(), 98);
        assert!(hits.iter().all(|h| h.id != "row00007" && h.id != "row00031"));
    }

    #[test]
    fn batch_matches_individual() {
        let (arena, _) = arena_with(400, 96, 1, 12);
        let queries: Vec<_> = (0..7)
            .map(|i| arena.get(&format!("row{:05}", i * 13)).unwrap())
            .collect();
        let batched = scan_topk_batch(&arena, &queries, 5, 3);
        assert_eq!(batched.len(), queries.len());
        for (i, q) in queries.iter().enumerate() {
            assert_eq!(batched[i], scan_topk(&arena, q, 5, 1), "query {i}");
        }
    }

    #[test]
    fn candidate_rerank_matches_full_scan_on_its_set() {
        let (arena, _) = arena_with(300, 64, 2, 21);
        let kernel = CollisionKernel::select(2);
        let q = arena.get("row00050").unwrap();
        // A candidate set of every row is identical to the full sweep.
        let all: Vec<u32> = (0..300).collect();
        let full = scan_arena(&arena, kernel, &q, &[], 10, 1).into_sorted();
        let cand = scan_candidates(&arena, kernel, &q, &all, &[], 10).into_sorted();
        assert_eq!(cand, full);
        // A restricted set only ever scores its own rows, and masked
        // rows are hidden exactly like the full sweep hides them.
        let got = scan_candidates(&arena, kernel, &q, &[3, 50, 77, 123], &[50], 10).into_sorted();
        assert_eq!(got.len(), 3);
        assert!(got.iter().all(|e| [3, 77, 123].contains(&e.row)));
        assert!(got.iter().all(|e| e.id != "row00050"));
    }

    #[test]
    fn empty_arena_returns_nothing() {
        let arena = CodeArena::new(64, 2);
        let q = pack_codes(&[0u16; 64], 2);
        assert!(scan_topk(&arena, &q, 5, 0).is_empty());
        assert!(scan_topk_batch(&arena, &[], 5, 0).is_empty());
    }
}
