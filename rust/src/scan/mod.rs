//! Flat packed-code scan engine: columnar arena + runtime-dispatched
//! collision kernels + epoch-buffered ingest + top-k.
//!
//! The serving layer's original `Knn` path cloned every [`crate::coding::PackedCodes`]
//! out of a sharded `HashMap` and estimated pair by pair — pointer-chasing
//! over scattered allocations with a full sort at the end. This subsystem
//! replaces that with the layout the paper's storage story implies: all
//! sketches of one coding configuration are a dense matrix of a few bits
//! per coordinate, so a near-neighbor query is a single sequential sweep.
//!
//! * [`arena`] — [`CodeArena`]: word-major columnar storage, fixed stride
//!   per sketch, id ↔ row maps, tombstoned deletes, compaction.
//! * [`kernels`] — blockwise SWAR collision counting over raw word rows:
//!   unrolled XOR+popcount for 1-bit codes, nibble-equality for 2-bit,
//!   generic lane-collapse fallback for 4/8/16. The portable oracle.
//! * [`simd`] — [`CollisionKernel`]: explicit `std::arch` x86_64 kernels
//!   (AVX-512 `vpopcntq`, then AVX2, then SSE2) for the 1-bit and 2-bit
//!   sweeps, selected once per scanner by runtime feature detection;
//!   `CRP_SCAN_KERNEL=swar|sse2|avx2|avx512` forces a tier. Pinned
//!   byte-identical to [`kernels`].
//! * [`epoch`] — [`EpochArena`]: sealed arena + pending epoch buffer, so
//!   ingest never takes the write lock scans read behind; a bulk drain
//!   folds each epoch in, runs tombstone-aware compaction, and keeps the
//!   optional banded candidate index ([`crate::lsh::CodeIndex`]) in
//!   lock-step for `scan_topk_approx` — bucket candidates reranked
//!   through the same kernels, pending rows swept exactly, the exact
//!   scan kept as the oracle and the small-store fallback.
//! * [`topk`] — [`TopK`]: bounded worst-out heap for exact top-k with the
//!   deterministic `(collisions desc, id asc)` ordering the brute-force
//!   estimator path uses.
//! * [`scanner`] — [`scan_topk`] / [`scan_topk_batch`]: the sweep itself,
//!   sharded across threads via `std::thread::scope` for single queries
//!   and fanned out per query for batches.
//!
//! Ranking is byte-identical to the per-pair
//! [`crate::estimator::CollisionEstimator`] path — and across SWAR, SSE2,
//! AVX2, and the epoch-buffer/sealed-arena split: all order by collision
//! count (ρ̂ is monotone in it) and break ties by id.

pub mod arena;
pub mod epoch;
pub mod kernels;
pub mod scanner;
pub mod simd;
pub mod topk;

pub use arena::{ArenaImage, CodeArena};
pub use epoch::{ArenaObs, EngineHist, EpochArena, EpochConfig};
pub use scanner::{scan_topk, scan_topk_batch, ScanHit};
pub use simd::{CollisionKernel, KernelKind};
pub use topk::TopK;
