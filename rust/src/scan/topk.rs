//! Bounded worst-out heap for exact top-k selection.
//!
//! Keeps the `n` best `(collisions, id)` pairs seen so far, ordered
//! exactly as the brute-force estimator path orders its full sort:
//! collisions descending, then id ascending (ρ̂ is monotone in the
//! collision count, so this is also the ρ̂ ranking). Candidates that
//! cannot enter the heap cost one comparison and zero allocations.

use std::cmp::Ordering;
use std::collections::BinaryHeap;

/// One selected hit.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct TopEntry {
    pub row: u32,
    pub id: String,
    pub collisions: usize,
}

impl TopEntry {
    /// Heap order: the *maximum* entry is the worst hit (fewest
    /// collisions, then largest id), so `peek` exposes the eviction
    /// candidate.
    fn heap_cmp(&self, other: &Self) -> Ordering {
        other
            .collisions
            .cmp(&self.collisions)
            .then_with(|| self.id.cmp(&other.id))
    }
}

impl Ord for TopEntry {
    fn cmp(&self, other: &Self) -> Ordering {
        self.heap_cmp(other)
    }
}

impl PartialOrd for TopEntry {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}

/// Exact top-`n` accumulator.
#[derive(Debug)]
pub struct TopK {
    n: usize,
    heap: BinaryHeap<TopEntry>,
}

impl TopK {
    pub fn new(n: usize) -> Self {
        TopK {
            n,
            heap: BinaryHeap::with_capacity(n + 1),
        }
    }

    /// Capacity of the selection.
    pub fn capacity(&self) -> usize {
        self.n
    }

    /// Entries currently held.
    pub fn len(&self) -> usize {
        self.heap.len()
    }

    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }

    /// Offer a candidate; allocates only if it enters the selection.
    pub fn offer(&mut self, row: u32, id: &str, collisions: usize) {
        if self.heap.len() < self.n {
            self.heap.push(TopEntry {
                row,
                id: id.to_string(),
                collisions,
            });
            return;
        }
        let Some(worst) = self.heap.peek() else {
            return; // n == 0
        };
        let beats = collisions > worst.collisions
            || (collisions == worst.collisions && *id < *worst.id);
        if beats {
            self.heap.pop();
            self.heap.push(TopEntry {
                row,
                id: id.to_string(),
                collisions,
            });
        }
    }

    /// Fold another selection (e.g. a per-thread shard) into this one.
    pub fn merge(&mut self, other: TopK) {
        for e in other.heap {
            if self.heap.len() < self.n {
                self.heap.push(e);
            } else if let Some(worst) = self.heap.peek() {
                if e.heap_cmp(worst) == Ordering::Less {
                    self.heap.pop();
                    self.heap.push(e);
                }
            }
        }
    }

    /// The selection, best first (collisions descending, id ascending).
    pub fn into_sorted(self) -> Vec<TopEntry> {
        self.heap.into_sorted_vec()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn collect(n: usize, items: &[(&str, usize)]) -> Vec<(String, usize)> {
        let mut t = TopK::new(n);
        for (row, &(id, c)) in items.iter().enumerate() {
            t.offer(row as u32, id, c);
        }
        t.into_sorted()
            .into_iter()
            .map(|e| (e.id, e.collisions))
            .collect()
    }

    #[test]
    fn selects_and_orders_best_first() {
        let got = collect(3, &[("a", 5), ("b", 9), ("c", 1), ("d", 7), ("e", 9)]);
        assert_eq!(
            got,
            vec![
                ("b".to_string(), 9),
                ("e".to_string(), 9),
                ("d".to_string(), 7)
            ]
        );
    }

    #[test]
    fn ties_break_by_id_ascending() {
        let got = collect(2, &[("z", 4), ("m", 4), ("a", 4)]);
        assert_eq!(got, vec![("a".to_string(), 4), ("m".to_string(), 4)]);
    }

    #[test]
    fn matches_full_sort_on_random_input() {
        let mut g = crate::mathx::Pcg64::new(99, 0);
        for case in 0..30 {
            let n_items = 1 + g.next_below(200) as usize;
            let top = g.next_below(12) as usize;
            let items: Vec<(String, usize)> = (0..n_items)
                .map(|i| (format!("id{i:04}"), g.next_below(50) as usize))
                .collect();
            let mut t = TopK::new(top);
            for (i, (id, c)) in items.iter().enumerate() {
                t.offer(i as u32, id, *c);
            }
            let got: Vec<(String, usize)> = t
                .into_sorted()
                .into_iter()
                .map(|e| (e.id, e.collisions))
                .collect();
            let mut want: Vec<(String, usize)> =
                items.iter().map(|(id, c)| (id.clone(), *c)).collect();
            want.sort_by(|a, b| b.1.cmp(&a.1).then_with(|| a.0.cmp(&b.0)));
            want.truncate(top);
            assert_eq!(got, want, "case {case}");
        }
    }

    #[test]
    fn zero_capacity_is_empty() {
        let got = collect(0, &[("a", 5)]);
        assert!(got.is_empty());
    }

    #[test]
    fn merge_equals_single_accumulator() {
        let items: Vec<(String, usize)> = (0..100)
            .map(|i| (format!("v{i:03}"), (i * 7) % 23))
            .collect();
        let mut whole = TopK::new(10);
        for (i, (id, c)) in items.iter().enumerate() {
            whole.offer(i as u32, id, *c);
        }
        let mut left = TopK::new(10);
        let mut right = TopK::new(10);
        for (i, (id, c)) in items.iter().enumerate() {
            if i < 50 {
                left.offer(i as u32, id, *c);
            } else {
                right.offer(i as u32, id, *c);
            }
        }
        left.merge(right);
        assert_eq!(left.into_sorted(), whole.into_sorted());
    }
}
