//! Columnar code arena: every sketch of one coding configuration stored
//! contiguously at a fixed word stride.
//!
//! Rows are append-only `u32` indices into one flat `Vec<u64>`; a scan is
//! a pure sequential sweep with no per-row allocation or pointer chase.
//! Deletes tombstone the row (id cleared, words zeroed) and are reclaimed
//! by [`CodeArena::compact`], which remaps surviving rows downward while
//! preserving insertion order.

use std::collections::HashMap;

use crate::coding::{supported_width, PackedCodes};

/// Dense word-major storage for fixed-shape packed sketches.
#[derive(Clone, Debug)]
pub struct CodeArena {
    /// Codes per sketch.
    k: usize,
    /// Bit width per code (a supported packing width).
    bits: u32,
    /// `u64` words per row (`k.div_ceil(64 / bits)`).
    stride: usize,
    /// Row-major storage, `rows.len() * stride` words.
    words: Vec<u64>,
    /// Row → id; `None` marks a tombstone.
    ids: Vec<Option<String>>,
    /// Id → row.
    rows: HashMap<String, u32>,
}

impl CodeArena {
    /// An arena for sketches of `k` codes at `bits` per code (rounded up
    /// to a supported packing width).
    pub fn new(k: usize, bits: u32) -> Self {
        let bits = supported_width(bits);
        let per_word = (64 / bits) as usize;
        CodeArena {
            k,
            bits,
            stride: k.div_ceil(per_word),
            words: Vec::new(),
            ids: Vec::new(),
            rows: HashMap::new(),
        }
    }

    /// Codes per sketch.
    pub fn k(&self) -> usize {
        self.k
    }

    /// Bit width per code.
    pub fn bits(&self) -> u32 {
        self.bits
    }

    /// Words per row.
    pub fn stride(&self) -> usize {
        self.stride
    }

    /// Number of live (non-tombstoned) sketches.
    pub fn len(&self) -> usize {
        self.rows.len()
    }

    pub fn is_empty(&self) -> bool {
        self.rows.is_empty()
    }

    /// Rows allocated, including tombstones — the scan range.
    pub fn rows_allocated(&self) -> usize {
        self.ids.len()
    }

    /// Tombstoned rows awaiting [`CodeArena::compact`].
    pub fn tombstones(&self) -> usize {
        self.ids.len() - self.rows.len()
    }

    /// Bytes of packed sketch storage.
    pub fn storage_bytes(&self) -> usize {
        self.words.len() * 8
    }

    /// Insert or replace the sketch for `id`; returns its row. The codes
    /// must match the arena shape exactly.
    pub fn insert(&mut self, id: &str, codes: &PackedCodes) -> u32 {
        assert_eq!(codes.len, self.k, "sketch length mismatch");
        assert_eq!(codes.bits, self.bits, "sketch bit width mismatch");
        self.insert_row_words(id, codes.words())
    }

    /// Insert or replace the sketch for `id` from raw row words already
    /// in arena layout: exactly [`CodeArena::stride`] words with padding
    /// bits zero, as produced by [`crate::coding::pack_codes`] (or
    /// [`crate::coding::BatchEncoder`]) at this arena's shape. This is
    /// the fused-ingest path — no `PackedCodes` is materialized.
    pub fn insert_row_words(&mut self, id: &str, words: &[u64]) -> u32 {
        assert_eq!(words.len(), self.stride, "row word count mismatch");
        let row = match self.rows.get(id) {
            Some(&row) => row,
            None => {
                let row = self.ids.len() as u32;
                self.ids.push(Some(id.to_string()));
                self.words.resize(self.words.len() + self.stride, 0);
                self.rows.insert(id.to_string(), row);
                row
            }
        };
        let start = row as usize * self.stride;
        self.words[start..start + self.stride].copy_from_slice(words);
        row
    }

    /// Tombstone the sketch for `id`. Returns whether it was present.
    pub fn remove(&mut self, id: &str) -> bool {
        let Some(row) = self.rows.remove(id) else {
            return false;
        };
        self.ids[row as usize] = None;
        let start = row as usize * self.stride;
        self.words[start..start + self.stride].fill(0);
        true
    }

    /// Clone out the sketch for `id`.
    pub fn get(&self, id: &str) -> Option<PackedCodes> {
        let &row = self.rows.get(id)?;
        let start = row as usize * self.stride;
        Some(PackedCodes::from_words(
            self.bits,
            self.k,
            self.words[start..start + self.stride].to_vec(),
        ))
    }

    /// Row index for `id`, if live.
    pub fn row_of(&self, id: &str) -> Option<u32> {
        self.rows.get(id).copied()
    }

    /// Id stored at `row` (`None` for tombstones).
    pub fn id_of(&self, row: u32) -> Option<&str> {
        self.ids.get(row as usize)?.as_deref()
    }

    /// Raw words of `row` (zeros for tombstones).
    #[inline]
    pub fn row_words(&self, row: u32) -> &[u64] {
        let start = row as usize * self.stride;
        &self.words[start..start + self.stride]
    }

    /// Drop every row — ids, tombstones, and words — keeping the
    /// allocated capacity (the epoch buffer resets itself this way after
    /// each drain).
    pub fn clear(&mut self) {
        self.words.clear();
        self.ids.clear();
        self.rows.clear();
    }

    /// Copy out the raw row storage (words + ids) without rebuilding the
    /// id → row index — the cheap snapshot read-only sweeps need.
    pub fn rows_snapshot(&self) -> RowsSnapshot {
        RowsSnapshot {
            stride: self.stride,
            words: self.words.clone(),
            ids: self.ids.clone(),
        }
    }

    /// Owned, self-describing point-in-time copy of the whole arena:
    /// shape plus the contiguous word block and id table exactly as laid
    /// out in memory (tombstones included). This is the unit of
    /// persistence — serializing it is a sequential write of one flat
    /// buffer, and it is built under whatever lock the caller already
    /// holds (one clone, no per-row work).
    pub fn image(&self) -> ArenaImage {
        ArenaImage {
            k: self.k,
            bits: self.bits,
            stride: self.stride,
            words: self.words.clone(),
            ids: self.ids.clone(),
        }
    }

    /// Drop tombstoned rows, remapping survivors downward in insertion
    /// order. Returns the number of rows reclaimed.
    pub fn compact(&mut self) -> usize {
        let reclaimed = self.tombstones();
        if reclaimed == 0 {
            return 0;
        }
        let mut write = 0usize;
        for read in 0..self.ids.len() {
            if self.ids[read].is_none() {
                continue;
            }
            if write != read {
                self.ids.swap(write, read);
                let (dst, src) = (write * self.stride, read * self.stride);
                self.words.copy_within(src..src + self.stride, dst);
            }
            let id = self.ids[write].as_ref().expect("live row has id");
            *self.rows.get_mut(id).expect("live id has row") = write as u32;
            write += 1;
        }
        self.ids.truncate(write);
        self.words.truncate(write * self.stride);
        reclaimed
    }
}

/// A point-in-time copy of an arena's rows, sweepable without any lock
/// or id-index — see [`CodeArena::rows_snapshot`].
#[derive(Clone, Debug)]
pub struct RowsSnapshot {
    stride: usize,
    words: Vec<u64>,
    ids: Vec<Option<String>>,
}

impl RowsSnapshot {
    /// Rows captured, including tombstones — the sweep range.
    pub fn rows_allocated(&self) -> usize {
        self.ids.len()
    }

    /// Id stored at `row` (`None` for tombstones).
    #[inline]
    pub fn id_of(&self, row: u32) -> Option<&str> {
        self.ids.get(row as usize)?.as_deref()
    }

    /// Raw words of `row` (zeros for tombstones).
    #[inline]
    pub fn row_words(&self, row: u32) -> &[u64] {
        let start = row as usize * self.stride;
        &self.words[start..start + self.stride]
    }
}

/// An owned arena image: the contiguous word block, the id table
/// (`None` = tombstone, its words zeroed), and the shape that makes them
/// interpretable. Produced by [`CodeArena::image`] /
/// [`crate::scan::EpochArena::sealed_image`]; consumed by the
/// durability layer, which serializes it without holding any lock.
#[derive(Clone, Debug, PartialEq)]
pub struct ArenaImage {
    /// Codes per sketch.
    pub k: usize,
    /// Bit width per code (a supported packing width).
    pub bits: u32,
    /// `u64` words per row.
    pub stride: usize,
    /// Row-major word block, `ids.len() * stride` words.
    pub words: Vec<u64>,
    /// Row → id; `None` marks a tombstone.
    pub ids: Vec<Option<String>>,
}

impl ArenaImage {
    /// An empty image of the given shape (`bits` rounded up to a
    /// supported packing width, as arenas do).
    pub fn empty(k: usize, bits: u32) -> Self {
        let bits = supported_width(bits);
        let per_word = (64 / bits) as usize;
        ArenaImage {
            k,
            bits,
            stride: k.div_ceil(per_word),
            words: Vec::new(),
            ids: Vec::new(),
        }
    }

    /// Rows captured, including tombstones.
    pub fn rows(&self) -> usize {
        self.ids.len()
    }

    /// Live (non-tombstoned) rows.
    pub fn live(&self) -> usize {
        self.ids.iter().filter(|id| id.is_some()).count()
    }

    /// Raw words of `row` (zeros for tombstones).
    pub fn row_words(&self, row: usize) -> &[u64] {
        &self.words[row * self.stride..(row + 1) * self.stride]
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coding::pack_codes;

    fn sketch(k: usize, seed: u16) -> PackedCodes {
        let codes: Vec<u16> = (0..k).map(|i| ((i as u16).wrapping_add(seed)) % 4).collect();
        pack_codes(&codes, 2)
    }

    #[test]
    fn insert_get_roundtrip() {
        let mut a = CodeArena::new(100, 2);
        assert!(a.is_empty());
        let r0 = a.insert("a", &sketch(100, 0));
        let r1 = a.insert("b", &sketch(100, 1));
        assert_eq!((r0, r1), (0, 1));
        assert_eq!(a.len(), 2);
        assert_eq!(a.get("a").unwrap(), sketch(100, 0));
        assert_eq!(a.get("b").unwrap(), sketch(100, 1));
        assert!(a.get("zzz").is_none());
        assert_eq!(a.id_of(0), Some("a"));
        assert_eq!(a.row_of("b"), Some(1));
    }

    #[test]
    fn overwrite_reuses_row() {
        let mut a = CodeArena::new(64, 2);
        a.insert("x", &sketch(64, 0));
        let r = a.insert("x", &sketch(64, 9));
        assert_eq!(r, 0);
        assert_eq!(a.len(), 1);
        assert_eq!(a.rows_allocated(), 1);
        assert_eq!(a.get("x").unwrap(), sketch(64, 9));
    }

    #[test]
    fn remove_tombstones_and_compact_reclaims() {
        let mut a = CodeArena::new(64, 2);
        for i in 0..10 {
            a.insert(&format!("id{i}"), &sketch(64, i));
        }
        assert!(a.remove("id3"));
        assert!(!a.remove("id3"));
        assert!(a.remove("id7"));
        assert_eq!(a.len(), 8);
        assert_eq!(a.rows_allocated(), 10);
        assert_eq!(a.tombstones(), 2);
        assert_eq!(a.id_of(3), None);
        assert!(a.row_words(3).iter().all(|&w| w == 0));

        assert_eq!(a.compact(), 2);
        assert_eq!(a.rows_allocated(), 8);
        assert_eq!(a.tombstones(), 0);
        // Survivors keep insertion order and their exact codes.
        let live: Vec<u16> = [0u16, 1, 2, 4, 5, 6, 8, 9].to_vec();
        for (row, &i) in live.iter().enumerate() {
            let id = format!("id{i}");
            assert_eq!(a.id_of(row as u32), Some(id.as_str()));
            assert_eq!(a.row_of(&id), Some(row as u32));
            assert_eq!(a.get(&id).unwrap(), sketch(64, i));
        }
        assert_eq!(a.compact(), 0);
    }

    #[test]
    fn image_copies_rows_and_tombstones_verbatim() {
        let mut a = CodeArena::new(64, 2);
        for i in 0..5 {
            a.insert(&format!("id{i}"), &sketch(64, i));
        }
        a.remove("id2");
        let img = a.image();
        assert_eq!((img.k, img.bits, img.stride), (64, 2, a.stride()));
        assert_eq!(img.rows(), 5);
        assert_eq!(img.live(), 4);
        assert_eq!(img.ids[2], None);
        assert!(img.row_words(2).iter().all(|&w| w == 0));
        for i in [0u16, 1, 3, 4] {
            assert_eq!(img.ids[i as usize].as_deref(), Some(format!("id{i}").as_str()));
            assert_eq!(img.row_words(i as usize), sketch(64, i).words());
        }
        let empty = ArenaImage::empty(100, 2);
        assert_eq!(empty.stride, 4);
        assert_eq!(empty.rows(), 0);
    }

    #[test]
    fn stride_covers_partial_words() {
        let a = CodeArena::new(100, 2); // 100 2-bit codes = 3.125 words
        assert_eq!(a.stride(), 4);
        let a = CodeArena::new(64, 1);
        assert_eq!(a.stride(), 1);
    }

    #[test]
    #[should_panic(expected = "length mismatch")]
    fn shape_mismatch_panics() {
        let mut a = CodeArena::new(64, 2);
        a.insert("a", &sketch(65, 0));
    }
}
