//! Runtime-dispatched explicit SIMD collision kernels.
//!
//! [`CollisionKernel`] binds one code width to the widest instruction
//! tier the running CPU supports — AVX-512 (64 bytes per step, native
//! `vpopcntq` per-lane popcount), then AVX2 (32 bytes per step,
//! vectorized nibble-lookup popcount), then SSE2 (16 bytes per step,
//! in-register bit-slice popcount), then the portable SWAR kernels of
//! [`super::kernels`] — once at scanner construction; every scan after
//! that calls a plain function pointer with zero per-row dispatch.
//!
//! The SWAR path is the oracle: the SIMD kernels are pinned
//! byte-identical to it by the unit tests below and by
//! `tests/proptests.rs` (`equiv_*`).
//!
//! Dispatch policy:
//!
//! * Explicit SIMD exists for the paper's recommended 1-bit and 2-bit
//!   codes; wider codes (4/8/16 bits) always take the SWAR path.
//! * `CRP_SCAN_KERNEL=swar|sse2|avx2|avx512` forces a tier. An
//!   unavailable forced tier falls back to auto-selection; `swar` is
//!   always available and is the supported way to force the portable
//!   path.
//! * Non-x86_64 targets compile to SWAR only (`detect` reports the SIMD
//!   tiers as absent, and the x86 kernels are not built).

use std::fmt;

use super::kernels::collisions_words;
use crate::coding::supported_width;

/// Instruction-set tier of a selected kernel.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum KernelKind {
    /// Portable scalar SWAR (the oracle; always available).
    Swar,
    /// 128-bit SSE2 (the x86_64 baseline).
    Sse2,
    /// 256-bit AVX2 (plus hardware POPCNT for the scalar tail).
    Avx2,
    /// 512-bit AVX-512 with native per-lane popcount (`vpopcntq`,
    /// the AVX512VPOPCNTDQ extension).
    Avx512,
}

impl KernelKind {
    /// Every tier, widest first — the auto-selection preference order.
    pub const ALL: [KernelKind; 4] = [
        KernelKind::Avx512,
        KernelKind::Avx2,
        KernelKind::Sse2,
        KernelKind::Swar,
    ];

    pub fn label(self) -> &'static str {
        match self {
            KernelKind::Swar => "swar",
            KernelKind::Sse2 => "sse2",
            KernelKind::Avx2 => "avx2",
            KernelKind::Avx512 => "avx512",
        }
    }

    /// Whether the running CPU supports this tier.
    pub fn available(self) -> bool {
        detect(self)
    }
}

type KernelFn = fn(usize, &[u64], &[u64]) -> usize;

/// A collision-count kernel bound to one code width and one instruction
/// tier. `Copy`, so shards of a threaded scan share it freely.
#[derive(Clone, Copy)]
pub struct CollisionKernel {
    kind: KernelKind,
    bits: u32,
    f: KernelFn,
}

impl fmt::Debug for CollisionKernel {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "CollisionKernel({} @ {}-bit)", self.kind.label(), self.bits)
    }
}

impl CollisionKernel {
    /// Best kernel for `bits`-wide codes on this CPU, honoring the
    /// `CRP_SCAN_KERNEL` override (see the module docs for the policy).
    pub fn select(bits: u32) -> Self {
        if let Ok(forced) = std::env::var("CRP_SCAN_KERNEL") {
            let want = match forced.to_ascii_lowercase().as_str() {
                "swar" | "portable" | "scalar" => Some(KernelKind::Swar),
                "sse2" => Some(KernelKind::Sse2),
                "avx2" => Some(KernelKind::Avx2),
                "avx512" | "avx512vpopcntdq" => Some(KernelKind::Avx512),
                _ => None,
            };
            if let Some(kernel) = want.and_then(|kind| Self::with_kind(bits, kind)) {
                return kernel;
            }
        }
        KernelKind::ALL
            .iter()
            .find_map(|&kind| Self::with_kind(bits, kind))
            .expect("the SWAR kernel is always available")
    }

    /// Kernel of a specific tier, when the CPU supports it and an
    /// explicit kernel exists for `bits` (the SIMD tiers cover 1-bit and
    /// 2-bit codes only). `bits` is rounded up to a supported packing
    /// width first — packed storage only ever uses those, so e.g. a
    /// 5-bit scheme dispatches its 8-bit layout.
    pub fn with_kind(bits: u32, kind: KernelKind) -> Option<Self> {
        let bits = supported_width(bits);
        if !detect(kind) {
            return None;
        }
        Some(CollisionKernel {
            kind,
            bits,
            f: kernel_fn(bits, kind)?,
        })
    }

    pub fn kind(self) -> KernelKind {
        self.kind
    }

    pub fn bits(self) -> u32 {
        self.bits
    }

    /// Count agreeing coordinates of two `k`-code rows in arena layout
    /// (`k.div_ceil(64 / bits)` words each, padding bits zero).
    #[inline]
    pub fn count(self, k: usize, a: &[u64], b: &[u64]) -> usize {
        (self.f)(k, a, b)
    }
}

// ---- tier availability --------------------------------------------------

#[cfg(target_arch = "x86_64")]
fn detect(kind: KernelKind) -> bool {
    match kind {
        KernelKind::Swar => true,
        KernelKind::Sse2 => is_x86_feature_detected!("sse2"),
        // The scalar tails of the AVX2 kernels lean on hardware POPCNT
        // (present on every AVX2 CPU, but verified anyway).
        KernelKind::Avx2 => {
            is_x86_feature_detected!("avx2") && is_x86_feature_detected!("popcnt")
        }
        // AVX512F for the 512-bit lanes + VPOPCNTDQ for the native
        // per-lane popcount (Ice Lake / Zen 4 and later); POPCNT for
        // the scalar tails.
        KernelKind::Avx512 => {
            is_x86_feature_detected!("avx512f")
                && is_x86_feature_detected!("avx512vpopcntdq")
                && is_x86_feature_detected!("popcnt")
        }
    }
}

#[cfg(not(target_arch = "x86_64"))]
fn detect(kind: KernelKind) -> bool {
    matches!(kind, KernelKind::Swar)
}

// ---- dispatch table -----------------------------------------------------

fn swar_b1(k: usize, a: &[u64], b: &[u64]) -> usize {
    collisions_words(1, k, a, b)
}
fn swar_b2(k: usize, a: &[u64], b: &[u64]) -> usize {
    collisions_words(2, k, a, b)
}
fn swar_b4(k: usize, a: &[u64], b: &[u64]) -> usize {
    collisions_words(4, k, a, b)
}
fn swar_b8(k: usize, a: &[u64], b: &[u64]) -> usize {
    collisions_words(8, k, a, b)
}
fn swar_b16(k: usize, a: &[u64], b: &[u64]) -> usize {
    collisions_words(16, k, a, b)
}

fn kernel_fn(bits: u32, kind: KernelKind) -> Option<KernelFn> {
    match kind {
        KernelKind::Swar => Some(match bits {
            1 => swar_b1 as KernelFn,
            2 => swar_b2,
            4 => swar_b4,
            8 => swar_b8,
            16 => swar_b16,
            _ => return None,
        }),
        #[cfg(target_arch = "x86_64")]
        KernelKind::Sse2 => match bits {
            1 => Some(x86::b1_sse2 as KernelFn),
            2 => Some(x86::b2_sse2 as KernelFn),
            _ => None,
        },
        #[cfg(target_arch = "x86_64")]
        KernelKind::Avx2 => match bits {
            1 => Some(x86::b1_avx2 as KernelFn),
            2 => Some(x86::b2_avx2 as KernelFn),
            _ => None,
        },
        #[cfg(target_arch = "x86_64")]
        KernelKind::Avx512 => match bits {
            1 => Some(x86::b1_avx512 as KernelFn),
            2 => Some(x86::b2_avx512 as KernelFn),
            _ => None,
        },
        #[cfg(not(target_arch = "x86_64"))]
        _ => None,
    }
}

// ---- x86_64 kernels -----------------------------------------------------

#[cfg(target_arch = "x86_64")]
mod x86 {
    //! The explicit kernels. Every `unsafe fn` requires the CPU features
    //! named in its `#[target_feature]`; the safe wrappers at the bottom
    //! are reachable only through [`super::detect`]-guarded construction
    //! in [`super::CollisionKernel::with_kind`], which upholds that
    //! contract.

    use std::arch::x86_64::*;

    /// Low bit of every 2-bit lane.
    const B2_LO: u64 = 0x5555_5555_5555_5555;

    /// Mula's nibble-lookup popcount: per-byte counts via PSHUFB on each
    /// nibble, summed into the four u64 lanes by PSADBW.
    #[target_feature(enable = "avx2")]
    unsafe fn popcnt_epi64_avx2(v: __m256i) -> __m256i {
        let table = _mm256_setr_epi8(
            0, 1, 1, 2, 1, 2, 2, 3, 1, 2, 2, 3, 2, 3, 3, 4, //
            0, 1, 1, 2, 1, 2, 2, 3, 1, 2, 2, 3, 2, 3, 3, 4,
        );
        let low = _mm256_set1_epi8(0x0f);
        let lo = _mm256_and_si256(v, low);
        let hi = _mm256_and_si256(_mm256_srli_epi16::<4>(v), low);
        let counts = _mm256_add_epi8(
            _mm256_shuffle_epi8(table, lo),
            _mm256_shuffle_epi8(table, hi),
        );
        _mm256_sad_epu8(counts, _mm256_setzero_si256())
    }

    #[target_feature(enable = "avx2")]
    unsafe fn hsum_epi64_avx2(v: __m256i) -> u64 {
        let mut lanes = [0u64; 4];
        _mm256_storeu_si256(lanes.as_mut_ptr() as *mut __m256i, v);
        lanes[0] + lanes[1] + lanes[2] + lanes[3]
    }

    /// 1-bit: agreement = NOT(XOR), popcount, four words per vector step.
    #[target_feature(enable = "avx2,popcnt")]
    unsafe fn collisions_b1_avx2(k: usize, a: &[u64], b: &[u64]) -> usize {
        let full = k / 64;
        let blocks = full / 4;
        let ones = _mm256_set1_epi8(-1);
        let mut acc = _mm256_setzero_si256();
        for i in 0..blocks {
            let va = _mm256_loadu_si256(a.as_ptr().add(i * 4) as *const __m256i);
            let vb = _mm256_loadu_si256(b.as_ptr().add(i * 4) as *const __m256i);
            let agree = _mm256_xor_si256(_mm256_xor_si256(va, vb), ones);
            acc = _mm256_add_epi64(acc, popcnt_epi64_avx2(agree));
        }
        let mut total = hsum_epi64_avx2(acc) as usize;
        for i in blocks * 4..full {
            total += (!(a[i] ^ b[i])).count_ones() as usize;
        }
        let rem = k % 64;
        if rem > 0 {
            let mask = (1u64 << rem) - 1;
            total += ((!(a[full] ^ b[full])) & mask).count_ones() as usize;
        }
        total
    }

    /// 2-bit: a lane agrees iff both of its bits agree.
    #[target_feature(enable = "avx2,popcnt")]
    unsafe fn collisions_b2_avx2(k: usize, a: &[u64], b: &[u64]) -> usize {
        let full = k / 32;
        let blocks = full / 4;
        let ones = _mm256_set1_epi8(-1);
        let lo_bits = _mm256_set1_epi64x(B2_LO as i64);
        let mut acc = _mm256_setzero_si256();
        for i in 0..blocks {
            let va = _mm256_loadu_si256(a.as_ptr().add(i * 4) as *const __m256i);
            let vb = _mm256_loadu_si256(b.as_ptr().add(i * 4) as *const __m256i);
            let eq = _mm256_xor_si256(_mm256_xor_si256(va, vb), ones);
            let lanes =
                _mm256_and_si256(_mm256_and_si256(eq, _mm256_srli_epi64::<1>(eq)), lo_bits);
            acc = _mm256_add_epi64(acc, popcnt_epi64_avx2(lanes));
        }
        let mut total = hsum_epi64_avx2(acc) as usize;
        for i in blocks * 4..full {
            let eq = !(a[i] ^ b[i]);
            total += (eq & (eq >> 1) & B2_LO).count_ones() as usize;
        }
        let rem = k % 32;
        if rem > 0 {
            let eq = !(a[full] ^ b[full]);
            total += (eq & (eq >> 1) & B2_LO & ((1u64 << (2 * rem)) - 1)).count_ones() as usize;
        }
        total
    }

    /// In-register bit-slice popcount (no PSHUFB below SSSE3): the
    /// classic pair/nibble/byte reduction, then PSADBW into u64 lanes.
    /// Shifts are per-64-bit lane but the per-byte masks make each stage
    /// identical to the scalar SWAR popcount.
    #[target_feature(enable = "sse2")]
    unsafe fn popcnt_epi64_sse2(v: __m128i) -> __m128i {
        let m1 = _mm_set1_epi8(0x55);
        let m2 = _mm_set1_epi8(0x33);
        let m4 = _mm_set1_epi8(0x0f);
        let v = _mm_sub_epi8(v, _mm_and_si128(_mm_srli_epi64::<1>(v), m1));
        let v = _mm_add_epi8(
            _mm_and_si128(v, m2),
            _mm_and_si128(_mm_srli_epi64::<2>(v), m2),
        );
        let v = _mm_and_si128(_mm_add_epi8(v, _mm_srli_epi64::<4>(v)), m4);
        _mm_sad_epu8(v, _mm_setzero_si128())
    }

    #[target_feature(enable = "sse2")]
    unsafe fn hsum_epi64_sse2(v: __m128i) -> u64 {
        let mut lanes = [0u64; 2];
        _mm_storeu_si128(lanes.as_mut_ptr() as *mut __m128i, v);
        lanes[0] + lanes[1]
    }

    #[target_feature(enable = "sse2")]
    unsafe fn collisions_b1_sse2(k: usize, a: &[u64], b: &[u64]) -> usize {
        let full = k / 64;
        let pairs = full / 2;
        let ones = _mm_set1_epi8(-1);
        let mut acc = _mm_setzero_si128();
        for i in 0..pairs {
            let va = _mm_loadu_si128(a.as_ptr().add(i * 2) as *const __m128i);
            let vb = _mm_loadu_si128(b.as_ptr().add(i * 2) as *const __m128i);
            let agree = _mm_xor_si128(_mm_xor_si128(va, vb), ones);
            acc = _mm_add_epi64(acc, popcnt_epi64_sse2(agree));
        }
        let mut total = hsum_epi64_sse2(acc) as usize;
        for i in pairs * 2..full {
            total += (!(a[i] ^ b[i])).count_ones() as usize;
        }
        let rem = k % 64;
        if rem > 0 {
            let mask = (1u64 << rem) - 1;
            total += ((!(a[full] ^ b[full])) & mask).count_ones() as usize;
        }
        total
    }

    #[target_feature(enable = "sse2")]
    unsafe fn collisions_b2_sse2(k: usize, a: &[u64], b: &[u64]) -> usize {
        let full = k / 32;
        let pairs = full / 2;
        let ones = _mm_set1_epi8(-1);
        let lo_bits = _mm_set1_epi64x(B2_LO as i64);
        let mut acc = _mm_setzero_si128();
        for i in 0..pairs {
            let va = _mm_loadu_si128(a.as_ptr().add(i * 2) as *const __m128i);
            let vb = _mm_loadu_si128(b.as_ptr().add(i * 2) as *const __m128i);
            let eq = _mm_xor_si128(_mm_xor_si128(va, vb), ones);
            let lanes = _mm_and_si128(_mm_and_si128(eq, _mm_srli_epi64::<1>(eq)), lo_bits);
            acc = _mm_add_epi64(acc, popcnt_epi64_sse2(lanes));
        }
        let mut total = hsum_epi64_sse2(acc) as usize;
        for i in pairs * 2..full {
            let eq = !(a[i] ^ b[i]);
            total += (eq & (eq >> 1) & B2_LO).count_ones() as usize;
        }
        let rem = k % 32;
        if rem > 0 {
            let eq = !(a[full] ^ b[full]);
            total += (eq & (eq >> 1) & B2_LO & ((1u64 << (2 * rem)) - 1)).count_ones() as usize;
        }
        total
    }

    /// 1-bit, AVX-512: eight words per vector step, agreement =
    /// NOT(XOR), counted by the native per-u64-lane `vpopcntq` — no
    /// lookup tables, no PSADBW reduction.
    #[target_feature(enable = "avx512f,avx512vpopcntdq,popcnt")]
    unsafe fn collisions_b1_avx512(k: usize, a: &[u64], b: &[u64]) -> usize {
        let full = k / 64;
        let blocks = full / 8;
        let ones = _mm512_set1_epi64(-1);
        let mut acc = _mm512_setzero_si512();
        for i in 0..blocks {
            let va = _mm512_loadu_epi64(a.as_ptr().add(i * 8) as *const i64);
            let vb = _mm512_loadu_epi64(b.as_ptr().add(i * 8) as *const i64);
            let agree = _mm512_xor_si512(_mm512_xor_si512(va, vb), ones);
            acc = _mm512_add_epi64(acc, _mm512_popcnt_epi64(agree));
        }
        let mut total = _mm512_reduce_add_epi64(acc) as u64 as usize;
        for i in blocks * 8..full {
            total += (!(a[i] ^ b[i])).count_ones() as usize;
        }
        let rem = k % 64;
        if rem > 0 {
            let mask = (1u64 << rem) - 1;
            total += ((!(a[full] ^ b[full])) & mask).count_ones() as usize;
        }
        total
    }

    /// 2-bit, AVX-512: a lane agrees iff both of its bits agree;
    /// `vpopcntq` counts the collapsed low bits directly.
    #[target_feature(enable = "avx512f,avx512vpopcntdq,popcnt")]
    unsafe fn collisions_b2_avx512(k: usize, a: &[u64], b: &[u64]) -> usize {
        let full = k / 32;
        let blocks = full / 8;
        let ones = _mm512_set1_epi64(-1);
        let lo_bits = _mm512_set1_epi64(B2_LO as i64);
        let mut acc = _mm512_setzero_si512();
        for i in 0..blocks {
            let va = _mm512_loadu_epi64(a.as_ptr().add(i * 8) as *const i64);
            let vb = _mm512_loadu_epi64(b.as_ptr().add(i * 8) as *const i64);
            let eq = _mm512_xor_si512(_mm512_xor_si512(va, vb), ones);
            let lanes =
                _mm512_and_si512(_mm512_and_si512(eq, _mm512_srli_epi64::<1>(eq)), lo_bits);
            acc = _mm512_add_epi64(acc, _mm512_popcnt_epi64(lanes));
        }
        let mut total = _mm512_reduce_add_epi64(acc) as u64 as usize;
        for i in blocks * 8..full {
            let eq = !(a[i] ^ b[i]);
            total += (eq & (eq >> 1) & B2_LO).count_ones() as usize;
        }
        let rem = k % 32;
        if rem > 0 {
            let eq = !(a[full] ^ b[full]);
            total += (eq & (eq >> 1) & B2_LO & ((1u64 << (2 * rem)) - 1)).count_ones() as usize;
        }
        total
    }

    // Safe wrappers: sound because `with_kind` only hands these out after
    // `detect` confirmed the required CPU features.
    pub fn b1_avx512(k: usize, a: &[u64], b: &[u64]) -> usize {
        unsafe { collisions_b1_avx512(k, a, b) }
    }
    pub fn b2_avx512(k: usize, a: &[u64], b: &[u64]) -> usize {
        unsafe { collisions_b2_avx512(k, a, b) }
    }
    pub fn b1_avx2(k: usize, a: &[u64], b: &[u64]) -> usize {
        unsafe { collisions_b1_avx2(k, a, b) }
    }
    pub fn b2_avx2(k: usize, a: &[u64], b: &[u64]) -> usize {
        unsafe { collisions_b2_avx2(k, a, b) }
    }
    pub fn b1_sse2(k: usize, a: &[u64], b: &[u64]) -> usize {
        unsafe { collisions_b1_sse2(k, a, b) }
    }
    pub fn b2_sse2(k: usize, a: &[u64], b: &[u64]) -> usize {
        unsafe { collisions_b2_sse2(k, a, b) }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coding::{collision_count, pack_codes};
    use crate::mathx::Pcg64;

    fn random_codes(n: usize, card: u16, seed: u64) -> Vec<u16> {
        let mut g = Pcg64::new(seed, 3);
        (0..n).map(|_| g.next_below(card as u64) as u16).collect()
    }

    #[test]
    fn every_tier_matches_the_swar_oracle() {
        // Lengths spanning vector blocks (AVX2 1-bit step = 256 codes),
        // word boundaries, and ragged partial words.
        for &(bits, card) in &[(1u32, 2u16), (2, 4)] {
            for &k in &[
                1usize, 31, 32, 63, 64, 65, 127, 128, 129, 255, 256, 257, 300, 511, 512, 513,
                1024, 1027,
            ] {
                let a = random_codes(k, card, 11 + bits as u64);
                let b = random_codes(k, card, 1111 + bits as u64);
                let pa = pack_codes(&a, bits);
                let pb = pack_codes(&b, bits);
                let want = collision_count(&a, &b);
                for kind in KernelKind::ALL {
                    let Some(kernel) = CollisionKernel::with_kind(bits, kind) else {
                        continue;
                    };
                    assert_eq!(
                        kernel.count(k, pa.words(), pb.words()),
                        want,
                        "bits={bits} k={k} kind={kind:?}"
                    );
                    assert_eq!(
                        kernel.count(k, pa.words(), pa.words()),
                        k,
                        "self-collision bits={bits} k={k} kind={kind:?}"
                    );
                }
            }
        }
    }

    #[test]
    fn padding_bits_never_count_in_any_tier() {
        // 33 one-bit codes leave 31 zero padding bits; all-different
        // vectors must report zero collisions in every tier.
        let a = pack_codes(&[0u16; 33], 1);
        let b = pack_codes(&[1u16; 33], 1);
        for kind in KernelKind::ALL {
            if let Some(kernel) = CollisionKernel::with_kind(1, kind) {
                assert_eq!(kernel.count(33, a.words(), b.words()), 0, "{kind:?}");
            }
        }
    }

    #[test]
    fn wide_codes_always_dispatch_to_swar() {
        for bits in [4u32, 8, 16] {
            assert_eq!(CollisionKernel::select(bits).kind(), KernelKind::Swar);
            assert!(CollisionKernel::with_kind(bits, KernelKind::Avx512).is_none());
            assert!(CollisionKernel::with_kind(bits, KernelKind::Avx2).is_none());
            assert!(CollisionKernel::with_kind(bits, KernelKind::Sse2).is_none());
        }
    }

    #[test]
    fn selection_always_yields_a_kernel() {
        for bits in [1u32, 2, 4, 8, 16] {
            let kernel = CollisionKernel::select(bits);
            assert_eq!(kernel.bits(), bits);
            assert!(kernel.kind().available());
            // Zero-length rows are legal (empty arena sweep).
            assert_eq!(kernel.count(0, &[], &[]), 0);
        }
    }

    #[test]
    fn swar_tier_is_always_available() {
        assert!(KernelKind::Swar.available());
        assert!(CollisionKernel::with_kind(1, KernelKind::Swar).is_some());
    }

    #[test]
    fn unsupported_widths_round_like_the_packing_layer() {
        // A 5-bit scheme (e.g. WindowOffset at small w) packs at 8 bits;
        // selection must dispatch that layout instead of panicking.
        let kernel = CollisionKernel::select(5);
        assert_eq!(kernel.bits(), 8);
        assert_eq!(kernel.kind(), KernelKind::Swar);
        assert_eq!(CollisionKernel::select(3).bits(), 4);
        assert_eq!(CollisionKernel::select(9).bits(), 16);
    }
}
