//! Blockwise SWAR collision counting over raw word rows.
//!
//! These are the arena-scan counterparts of
//! [`crate::coding::collision_count_packed`]: they operate directly on
//! `&[u64]` rows (query vs arena row) so the scanner never materializes a
//! `PackedCodes` per candidate. The 1-bit and 2-bit paths — the paper's
//! recommended schemes — process four words per unrolled block; wider
//! codes fall back to the generic lane-collapse count.
//!
//! All paths mask the final partial word, so padding bits (zero on both
//! sides by the packing invariant) never count as collisions.

/// Count coordinates where two equal-shape rows of `k` codes at `bits`
/// per code agree. `a` and `b` must both hold `k.div_ceil(64 / bits)`
/// words.
#[inline]
pub fn collisions_words(bits: u32, k: usize, a: &[u64], b: &[u64]) -> usize {
    debug_assert_eq!(a.len(), b.len());
    debug_assert_eq!(a.len(), k.div_ceil((64 / bits) as usize));
    match bits {
        1 => collisions_b1(k, a, b),
        2 => collisions_b2(k, a, b),
        4 => collisions_generic(k, a, b, 4, 0x1111_1111_1111_1111),
        8 => collisions_generic(k, a, b, 8, 0x0101_0101_0101_0101),
        16 => collisions_generic(k, a, b, 16, 0x0001_0001_0001_0001),
        _ => unreachable!("unsupported width {bits}"),
    }
}

/// 1-bit: agreement = NOT(XOR) + popcount, four words per block.
fn collisions_b1(k: usize, a: &[u64], b: &[u64]) -> usize {
    let full = k / 64;
    let mut total = 0usize;
    let blocks = full / 4;
    for blk in 0..blocks {
        let i = blk * 4;
        total += (!(a[i] ^ b[i])).count_ones() as usize
            + (!(a[i + 1] ^ b[i + 1])).count_ones() as usize
            + (!(a[i + 2] ^ b[i + 2])).count_ones() as usize
            + (!(a[i + 3] ^ b[i + 3])).count_ones() as usize;
    }
    for i in blocks * 4..full {
        total += (!(a[i] ^ b[i])).count_ones() as usize;
    }
    let rem = k % 64;
    if rem > 0 {
        let mask = (1u64 << rem) - 1;
        total += ((!(a[full] ^ b[full])) & mask).count_ones() as usize;
    }
    total
}

/// 2-bit: a lane agrees iff both of its bits agree, four words per block.
fn collisions_b2(k: usize, a: &[u64], b: &[u64]) -> usize {
    const LO: u64 = 0x5555_5555_5555_5555;
    #[inline(always)]
    fn word(x: u64, y: u64) -> usize {
        let eq = !(x ^ y);
        (eq & (eq >> 1) & LO).count_ones() as usize
    }
    let full = k / 32;
    let mut total = 0usize;
    let blocks = full / 4;
    for blk in 0..blocks {
        let i = blk * 4;
        total += word(a[i], b[i])
            + word(a[i + 1], b[i + 1])
            + word(a[i + 2], b[i + 2])
            + word(a[i + 3], b[i + 3]);
    }
    for i in blocks * 4..full {
        total += word(a[i], b[i]);
    }
    let rem = k % 32;
    if rem > 0 {
        let eq = !(a[full] ^ b[full]);
        let lanes = eq & (eq >> 1) & LO & ((1u64 << (2 * rem)) - 1);
        total += lanes.count_ones() as usize;
    }
    total
}

/// Generic lane widths 4/8/16: a lane agrees iff its XOR is zero,
/// detected by OR-collapsing each lane onto its low bit.
fn collisions_generic(k: usize, a: &[u64], b: &[u64], bits: u32, lo_mask: u64) -> usize {
    let per_word = (64 / bits) as usize;
    let full = k / per_word;
    let mut total = 0usize;
    for i in 0..full {
        let x = a[i] ^ b[i];
        let mut y = x;
        let mut shift = bits / 2;
        while shift > 0 {
            y |= y >> shift;
            shift /= 2;
        }
        total += per_word - (y & lo_mask).count_ones() as usize;
    }
    let rem = k % per_word;
    if rem > 0 {
        let x = a[full] ^ b[full];
        let lane_mask = (1u64 << bits) - 1;
        for j in 0..rem {
            total += usize::from((x >> (j as u32 * bits)) & lane_mask == 0);
        }
    }
    total
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coding::{collision_count, pack_codes};
    use crate::mathx::Pcg64;

    fn random_codes(n: usize, card: u16, seed: u64) -> Vec<u16> {
        let mut g = Pcg64::new(seed, 1);
        (0..n).map(|_| g.next_below(card as u64) as u16).collect()
    }

    #[test]
    fn matches_scalar_all_widths_and_tails() {
        for &(bits, card) in &[(1u32, 2u16), (2, 4), (4, 16), (8, 200), (16, 999)] {
            // Lengths spanning block boundaries (4-word unroll = 256
            // one-bit codes), word boundaries, and partial words.
            for &k in &[1usize, 31, 32, 63, 64, 65, 255, 256, 257, 300, 1024, 1027] {
                let a = random_codes(k, card, 7 + bits as u64);
                let b = random_codes(k, card, 77 + bits as u64);
                let pa = pack_codes(&a, bits);
                let pb = pack_codes(&b, bits);
                assert_eq!(
                    collisions_words(bits, k, pa.words(), pb.words()),
                    collision_count(&a, &b),
                    "bits={bits} k={k}"
                );
            }
        }
    }

    #[test]
    fn identical_rows_collide_everywhere() {
        for &bits in &[1u32, 2, 4] {
            let codes = random_codes(513, 1 << bits, 3);
            let p = pack_codes(&codes, bits);
            assert_eq!(collisions_words(bits, 513, p.words(), p.words()), 513);
        }
    }

    #[test]
    fn padding_never_counts() {
        // 33 one-bit codes leave 31 zero padding bits in the only word;
        // two all-different vectors must report zero collisions.
        let a = pack_codes(&[0u16; 33], 1);
        let b = pack_codes(&[1u16; 33], 1);
        assert_eq!(collisions_words(1, 33, a.words(), b.words()), 0);
    }
}
