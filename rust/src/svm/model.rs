//! Trained linear model: scoring and evaluation.

use crate::data::sparse::CsrMatrix;

/// A linear classifier `sign(w·x)` (no bias, matching the paper's setup
/// of unit-normalized inputs fed to LIBLINEAR without an explicit bias).
#[derive(Clone, Debug, Default)]
pub struct LinearModel {
    pub w: Vec<f32>,
}

impl LinearModel {
    /// Decision value `w·x` for a sparse row.
    pub fn score_sparse(&self, idx: &[u32], val: &[f32]) -> f64 {
        idx.iter()
            .zip(val)
            .map(|(&i, &v)| self.w[i as usize] as f64 * v as f64)
            .sum()
    }

    /// Decision value for a dense vector.
    pub fn score_dense(&self, x: &[f32]) -> f64 {
        assert_eq!(x.len(), self.w.len());
        x.iter()
            .zip(&self.w)
            .map(|(&a, &b)| a as f64 * b as f64)
            .sum()
    }

    /// Predicted label (±1) for a sparse row.
    pub fn predict_sparse(&self, idx: &[u32], val: &[f32]) -> f32 {
        if self.score_sparse(idx, val) >= 0.0 {
            1.0
        } else {
            -1.0
        }
    }

    /// Classification accuracy over a CSR matrix.
    pub fn accuracy(&self, x: &CsrMatrix, y: &[f32]) -> f64 {
        assert_eq!(x.rows(), y.len());
        let mut correct = 0usize;
        for r in 0..x.rows() {
            let (idx, val) = x.row(r);
            if self.predict_sparse(idx, val) == y[r].signum() {
                correct += 1;
            }
        }
        correct as f64 / y.len().max(1) as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scoring_and_prediction() {
        let m = LinearModel {
            w: vec![1.0, -2.0, 0.5],
        };
        assert!((m.score_sparse(&[0, 2], &[2.0, 4.0]) - 4.0).abs() < 1e-9);
        assert_eq!(m.predict_sparse(&[1], &[1.0]), -1.0);
        assert_eq!(m.predict_sparse(&[0], &[1.0]), 1.0);
        assert!((m.score_dense(&[1.0, 1.0, 1.0]) + 0.5).abs() < 1e-9);
    }

    #[test]
    fn accuracy_counts() {
        let mut x = CsrMatrix::with_capacity(2, 2, 1);
        x.push_row(&[0], &[1.0]);
        x.push_row(&[0], &[-1.0]);
        let m = LinearModel { w: vec![1.0] };
        assert_eq!(m.accuracy(&x, &[1.0, -1.0]), 1.0);
        assert_eq!(m.accuracy(&x, &[-1.0, 1.0]), 0.0);
        assert_eq!(m.accuracy(&x, &[1.0, 1.0]), 0.5);
    }
}
