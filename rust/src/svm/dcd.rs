//! Dual coordinate descent for L2-regularized linear SVM
//! (Hsieh et al., ICML 2008; Algorithm 1 of the LIBLINEAR paper).
//!
//! Solves `min_w ½‖w‖² + C Σ_i ξ(w; x_i, y_i)` with hinge (`L1`) or
//! squared hinge (`L2`) loss via its dual: coordinate updates on
//! `α_i ∈ [0, U]` with `U = C` (L1) or `U = ∞`, `Q_ii += 1/(2C)` (L2),
//! maintaining `w = Σ_i α_i y_i x_i` incrementally. Random permutations
//! each epoch and the projected-gradient stopping rule follow the paper.

use super::model::LinearModel;
use crate::data::sparse::CsrMatrix;
use crate::mathx::Pcg64;

/// Loss variant.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Loss {
    /// Hinge loss (L1-SVM).
    L1,
    /// Squared hinge loss (L2-SVM; LIBLINEAR's default solver `-s 1`).
    L2,
}

/// Solver configuration.
#[derive(Clone, Debug)]
pub struct DcdConfig {
    pub c: f64,
    pub loss: Loss,
    /// Stop when the projected-gradient range falls below this.
    pub tol: f64,
    pub max_epochs: usize,
    pub seed: u64,
}

impl Default for DcdConfig {
    fn default() -> Self {
        DcdConfig {
            c: 1.0,
            loss: Loss::L2,
            tol: 0.1,
            max_epochs: 200,
            seed: 1,
        }
    }
}

/// Train on CSR features with ±1 labels. Returns the primal weights.
pub fn train_dcd(x: &CsrMatrix, y: &[f32], cfg: &DcdConfig) -> LinearModel {
    let n = x.rows();
    assert_eq!(n, y.len());
    let dim = x.cols;
    let c = cfg.c;
    let (u_bound, diag) = match cfg.loss {
        Loss::L1 => (c, 0.0),
        Loss::L2 => (f64::INFINITY, 1.0 / (2.0 * c)),
    };
    // Q_ii = x_i·x_i (+ diag).
    let qii: Vec<f64> = (0..n)
        .map(|i| {
            let (_, v) = x.row(i);
            v.iter().map(|&a| (a as f64) * (a as f64)).sum::<f64>() + diag
        })
        .collect();
    let mut alpha = vec![0.0f64; n];
    let mut w = vec![0.0f64; dim];
    let mut order: Vec<usize> = (0..n).collect();
    let mut rng = Pcg64::new(cfg.seed, 0xDCD);

    // Shrinking-free DCD with the PG stopping criterion.
    for _epoch in 0..cfg.max_epochs {
        // Fisher–Yates shuffle.
        for i in (1..n).rev() {
            let j = rng.next_below(i as u64 + 1) as usize;
            order.swap(i, j);
        }
        let mut pg_max = f64::NEG_INFINITY;
        let mut pg_min = f64::INFINITY;
        for &i in &order {
            if qii[i] <= 0.0 {
                continue; // empty row
            }
            let (idx, val) = x.row(i);
            let yi = y[i] as f64;
            // G = y_i w·x_i − 1 + diag·α_i
            let mut wx = 0.0f64;
            for (&j, &v) in idx.iter().zip(val) {
                wx += w[j as usize] * v as f64;
            }
            let g = yi * wx - 1.0 + diag * alpha[i];
            // Projected gradient.
            let pg = if alpha[i] <= 0.0 {
                g.min(0.0)
            } else if alpha[i] >= u_bound {
                g.max(0.0)
            } else {
                g
            };
            pg_max = pg_max.max(pg);
            pg_min = pg_min.min(pg);
            if pg.abs() > 1e-12 {
                let old = alpha[i];
                alpha[i] = (old - g / qii[i]).clamp(0.0, u_bound);
                let delta = (alpha[i] - old) * yi;
                if delta != 0.0 {
                    for (&j, &v) in idx.iter().zip(val) {
                        w[j as usize] += delta * v as f64;
                    }
                }
            }
        }
        if pg_max - pg_min < cfg.tol {
            break;
        }
    }
    LinearModel {
        w: w.iter().map(|&v| v as f32).collect(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::sparse::CsrMatrix;
    use crate::mathx::NormalSampler;

    /// Linearly separable 2-D toy data.
    fn toy(n: usize, seed: u64, margin: f32) -> (CsrMatrix, Vec<f32>) {
        let mut ns = NormalSampler::new(seed, 0);
        let mut x = CsrMatrix::with_capacity(n, 2 * n, 2);
        let mut y = Vec::with_capacity(n);
        for i in 0..n {
            let label: f32 = if i % 2 == 0 { 1.0 } else { -1.0 };
            let a = ns.next() as f32 + label * margin;
            let b = ns.next() as f32 * 0.3;
            x.push_row(&[0, 1], &[a, b]);
            y.push(label);
        }
        (x, y)
    }

    #[test]
    fn separable_data_fits() {
        let (x, y) = toy(200, 1, 2.0);
        for loss in [Loss::L1, Loss::L2] {
            let m = train_dcd(
                &x,
                &y,
                &DcdConfig {
                    loss,
                    ..Default::default()
                },
            );
            let acc = m.accuracy(&x, &y);
            assert!(acc > 0.97, "{loss:?}: acc {acc}");
        }
    }

    #[test]
    fn noisy_data_reasonable() {
        let (x, y) = toy(400, 2, 0.7);
        let m = train_dcd(&x, &y, &DcdConfig::default());
        let acc = m.accuracy(&x, &y);
        assert!(acc > 0.70, "acc {acc} (Bayes rate at margin 0.7 is ~0.76)");
    }

    #[test]
    fn c_controls_regularization() {
        // Tiny C ⇒ heavily regularized ⇒ small weights.
        let (x, y) = toy(100, 3, 1.0);
        let m_small = train_dcd(
            &x,
            &y,
            &DcdConfig {
                c: 1e-4,
                ..Default::default()
            },
        );
        let m_big = train_dcd(
            &x,
            &y,
            &DcdConfig {
                c: 10.0,
                ..Default::default()
            },
        );
        let n_small: f32 = m_small.w.iter().map(|v| v * v).sum();
        let n_big: f32 = m_big.w.iter().map(|v| v * v).sum();
        assert!(n_small < n_big, "‖w‖ small-C {n_small} vs big-C {n_big}");
    }

    #[test]
    fn dual_feasibility_l1() {
        // For L1 loss all alphas must stay in [0, C]; verify via KKT-ish
        // sanity: the trained model misclassifies at most the noise.
        let (x, y) = toy(300, 4, 1.5);
        let m = train_dcd(
            &x,
            &y,
            &DcdConfig {
                loss: Loss::L1,
                c: 1.0,
                ..Default::default()
            },
        );
        assert!(m.accuracy(&x, &y) > 0.95);
    }

    #[test]
    fn deterministic_given_seed() {
        let (x, y) = toy(150, 5, 1.0);
        let cfg = DcdConfig::default();
        let a = train_dcd(&x, &y, &cfg);
        let b = train_dcd(&x, &y, &cfg);
        assert_eq!(a.w, b.w);
    }

    #[test]
    fn empty_rows_handled() {
        let mut x = CsrMatrix::with_capacity(3, 2, 2);
        x.push_row(&[0], &[1.0]);
        x.push_row(&[], &[]);
        x.push_row(&[0], &[-1.0]);
        let y = vec![1.0, 1.0, -1.0];
        let m = train_dcd(&x, &y, &DcdConfig::default());
        assert!(m.w[0] > 0.0);
    }

    #[test]
    fn matches_primal_objective_sanity() {
        // The dual solution should achieve a lower primal objective than
        // a few arbitrary alternatives.
        let (x, y) = toy(100, 6, 1.0);
        let cfg = DcdConfig {
            c: 1.0,
            loss: Loss::L2,
            tol: 1e-3,
            max_epochs: 2000,
            seed: 1,
        };
        let m = train_dcd(&x, &y, &cfg);
        let primal = |w: &[f32]| -> f64 {
            let reg: f64 = w.iter().map(|&v| (v as f64).powi(2)).sum::<f64>() * 0.5;
            let mut loss = 0.0f64;
            for i in 0..x.rows() {
                let (idx, val) = x.row(i);
                let wx: f64 = idx
                    .iter()
                    .zip(val)
                    .map(|(&j, &v)| w[j as usize] as f64 * v as f64)
                    .sum();
                let xi = (1.0 - y[i] as f64 * wx).max(0.0);
                loss += xi * xi;
            }
            reg + cfg.c * loss
        };
        let obj = primal(&m.w);
        for scale in [0.5f32, 1.5, 2.0, 0.0] {
            let alt: Vec<f32> = m.w.iter().map(|&v| v * scale).collect();
            assert!(
                obj <= primal(&alt) + 1e-6,
                "scaled-{scale} model beats DCD: {obj} vs {}",
                primal(&alt)
            );
        }
    }
}
