//! L2-regularized linear SVM, reimplementing what the paper runs through
//! LIBLINEAR [9] for its Section-6 experiments.
//!
//! * [`dcd`] — dual coordinate descent (Hsieh et al., ICML 2008 — the
//!   algorithm inside LIBLINEAR for L1-/L2-loss linear SVM).
//! * [`model`] — the trained linear model: predict, score, accuracy.
//! * [`sweep`] — the Section-6 experiment pipeline: project → code →
//!   expand → train → test, swept over `(k, w, C, scheme)`.

pub mod dcd;
pub mod model;
pub mod sweep;

pub use dcd::{train_dcd, DcdConfig, Loss};
pub use model::LinearModel;
pub use sweep::{run_coded_svm, CodedSvmResult, SvmTask};
