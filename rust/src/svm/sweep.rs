//! The Section-6 experiment pipeline: random-project a dataset, code the
//! projections with one of the four schemes, expand to the sparse binary
//! representation, train the linear SVM, report test accuracy.
//!
//! This is the machinery behind Figures 11–14 and the `svm_pipeline`
//! example. "Orig" (uncoded) uses the raw projected values, unit-
//! normalized, as dense features — the paper's reference curve.

use crate::coding::{expand_to_sparse, CodingParams, Scheme};
use crate::data::sparse::{CsrMatrix, Dataset};
use crate::projection::Projector;
use crate::svm::dcd::{train_dcd, DcdConfig};

/// What to train on.
#[derive(Clone, Debug)]
pub enum SvmTask {
    /// Coded projections with the given scheme and bin width.
    Coded(CodingParams),
    /// Raw (uncoded) projections, unit-normalized — the "Orig" curves.
    Orig,
}

/// Result of one (task, k, C) cell.
#[derive(Clone, Debug)]
pub struct CodedSvmResult {
    pub scheme: String,
    pub w: f64,
    pub k: usize,
    pub c: f64,
    pub train_acc: f64,
    pub test_acc: f64,
    pub train_seconds: f64,
}

/// Project every row of a dataset (sparse path) into `x[rows, k]`.
pub fn project_dataset(ds: &Dataset, proj: &Projector) -> Vec<f32> {
    let k = proj.cfg.k;
    let mut out = vec![0.0f32; ds.len() * k];
    for r in 0..ds.len() {
        let (idx, val) = ds.x.row(r);
        let x = proj.project_sparse(idx, val);
        out[r * k..(r + 1) * k].copy_from_slice(&x);
    }
    out
}

/// Build the feature matrix for a task from projected values.
fn featurize(projected: &[f32], rows: usize, k: usize, task: &SvmTask) -> CsrMatrix {
    match task {
        SvmTask::Coded(params) => {
            let card = params.cardinality();
            let mut m = CsrMatrix::with_capacity(rows, rows * k, k * card);
            let offsets = match params.scheme {
                Scheme::WindowOffset => Some(params.offsets(k)),
                _ => None,
            };
            let mut codes = vec![0u16; k];
            for r in 0..rows {
                params.encode_into(
                    &projected[r * k..(r + 1) * k],
                    offsets.as_deref(),
                    &mut codes,
                );
                let (idx, val) = expand_to_sparse(&codes, card);
                m.push_row(&idx, &val);
            }
            m
        }
        SvmTask::Orig => {
            // Dense projected features, unit-normalized per row.
            let idx: Vec<u32> = (0..k as u32).collect();
            let mut m = CsrMatrix::with_capacity(rows, rows * k, k);
            for r in 0..rows {
                let row = &projected[r * k..(r + 1) * k];
                let norm: f32 = row.iter().map(|v| v * v).sum::<f32>().sqrt();
                let scale = if norm > 0.0 { 1.0 / norm } else { 0.0 };
                let vals: Vec<f32> = row.iter().map(|&v| v * scale).collect();
                m.push_row(&idx, &vals);
            }
            m
        }
    }
}

/// Run the full project → code → expand → train → test pipeline.
///
/// `projected_*` are the precomputed projections (so the expensive
/// projection step is shared across the (w, C, scheme) sweep, exactly as
/// the paper's experiments reuse one set of projections).
pub fn run_coded_svm(
    projected_train: &[f32],
    y_train: &[f32],
    projected_test: &[f32],
    y_test: &[f32],
    k: usize,
    task: &SvmTask,
    c: f64,
) -> CodedSvmResult {
    let n_train = y_train.len();
    let n_test = y_test.len();
    assert_eq!(projected_train.len(), n_train * k);
    assert_eq!(projected_test.len(), n_test * k);
    let x_train = featurize(projected_train, n_train, k, task);
    let x_test = featurize(projected_test, n_test, k, task);
    let t0 = std::time::Instant::now();
    let model = train_dcd(
        &x_train,
        y_train,
        &DcdConfig {
            c,
            ..Default::default()
        },
    );
    let train_seconds = t0.elapsed().as_secs_f64();
    let (scheme, w) = match task {
        SvmTask::Coded(p) => (p.scheme.label().to_string(), p.w),
        SvmTask::Orig => ("orig".to_string(), 0.0),
    };
    CodedSvmResult {
        scheme,
        w,
        k,
        c,
        train_acc: model.accuracy(&x_train, y_train),
        test_acc: model.accuracy(&x_test, y_test),
        train_seconds,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::{SynthKind, SynthSpec};
    use crate::projection::{ProjectionConfig, Projector};

    fn setup(k: usize) -> (Vec<f32>, Vec<f32>, Vec<f32>, Vec<f32>) {
        let spec = SynthSpec::small(SynthKind::FarmLike);
        let (tr, te) = spec.generate();
        let proj = Projector::new_cpu(ProjectionConfig {
            k,
            seed: 5,
            ..Default::default()
        });
        (
            project_dataset(&tr, &proj),
            tr.y.clone(),
            project_dataset(&te, &proj),
            te.y.clone(),
        )
    }

    #[test]
    fn coded_svm_learns_signal() {
        let k = 128;
        let (ptr, ytr, pte, yte) = setup(k);
        for task in [
            SvmTask::Orig,
            SvmTask::Coded(CodingParams::new(Scheme::Uniform, 1.0)),
            SvmTask::Coded(CodingParams::new(Scheme::TwoBit, 0.75)),
            SvmTask::Coded(CodingParams::new(Scheme::OneBit, 0.0)),
            SvmTask::Coded(CodingParams::new(Scheme::WindowOffset, 1.0)),
        ] {
            let r = run_coded_svm(&ptr, &ytr, &pte, &yte, k, &task, 1.0);
            assert!(
                r.test_acc > 0.62,
                "{} w={} only {:.3}",
                r.scheme,
                r.w,
                r.test_acc
            );
        }
    }

    #[test]
    fn fig11_shape_large_w_hurts_offset_scheme() {
        // The paper's Figure 11 finding: at large w, h_{w,q} degrades
        // while h_w holds up (collisions of dissimilar points).
        let k = 128;
        let (ptr, ytr, pte, yte) = setup(k);
        let w = 8.0;
        let hw = run_coded_svm(
            &ptr,
            &ytr,
            &pte,
            &yte,
            k,
            &SvmTask::Coded(CodingParams::new(Scheme::Uniform, w)),
            1.0,
        );
        let hwq = run_coded_svm(
            &ptr,
            &ytr,
            &pte,
            &yte,
            k,
            &SvmTask::Coded(CodingParams::new(Scheme::WindowOffset, w)),
            1.0,
        );
        assert!(
            hw.test_acc >= hwq.test_acc - 0.02,
            "h_w {:.3} should not trail h_wq {:.3} at large w",
            hw.test_acc,
            hwq.test_acc
        );
    }

    #[test]
    fn expanded_dims_correct() {
        let k = 16;
        let (ptr, ytr, _, _) = setup(k);
        let params = CodingParams::new(Scheme::TwoBit, 0.75);
        let x = featurize(&ptr, ytr.len(), k, &SvmTask::Coded(params));
        assert_eq!(x.cols, k * 4);
        // exactly k ones per row
        for r in 0..x.rows() {
            assert_eq!(x.row(r).0.len(), k);
        }
    }
}
