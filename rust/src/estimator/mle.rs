//! Contingency-table MLE for the 2-bit scheme — the refinement the paper
//! flags as future work (Sections 5 and 7): "we can treat this problem
//! as a contingency table whose cell probabilities are functions of the
//! similarity ρ and hence we can estimate ρ by solving a maximum
//! likelihood equation."
//!
//! For `h_{w,2}` the pair `(c_u[j], c_v[j])` lands in a 4×4 table whose
//! cell probabilities are bivariate-normal rectangle masses
//! `π_ab(ρ) = Pr(x ∈ I_a, y ∈ I_b)` over the four regions
//! `I_0 = (-∞,-w), I_1 = [-w,0), I_2 = [0,w), I_3 = [w,∞)`. The linear
//! estimator uses only `Σ_a π_aa`; the MLE uses all 16 cells and is
//! never worse asymptotically.

use crate::mathx::normal::bvn_rect;
use crate::mathx::golden_section_min;

/// MLE estimator for `h_{w,2}` codes.
#[derive(Clone, Debug)]
pub struct TwoBitMle {
    pub w: f64,
    /// π tables pre-tabulated on a ρ grid for fast likelihood evaluation.
    grid: Vec<f64>,
    tables: Vec<[[f64; 4]; 4]>,
}

impl TwoBitMle {
    /// Build with `n` grid points over ρ ∈ [0, 1).
    pub fn new(w: f64, n: usize) -> Self {
        assert!(w > 0.0 && n >= 16);
        let grid: Vec<f64> = (0..n)
            .map(|i| i as f64 / (n - 1) as f64 * (1.0 - 1e-6))
            .collect();
        let tables = grid.iter().map(|&r| Self::cell_probs(w, r)).collect();
        TwoBitMle { w, grid, tables }
    }

    pub fn new_default(w: f64) -> Self {
        Self::new(w, 256)
    }

    /// Region boundaries of `h_{w,2}`.
    fn region(w: f64, a: usize) -> (f64, f64) {
        match a {
            0 => (f64::NEG_INFINITY, -w),
            1 => (-w, 0.0),
            2 => (0.0, w),
            3 => (w, f64::INFINITY),
            _ => unreachable!(),
        }
    }

    /// Exact 4×4 cell probabilities at (w, ρ).
    pub fn cell_probs(w: f64, rho: f64) -> [[f64; 4]; 4] {
        let mut t = [[0.0; 4]; 4];
        for a in 0..4 {
            let (s0, s1) = Self::region(w, a);
            for b in 0..4 {
                let (t0, t1) = Self::region(w, b);
                t[a][b] = bvn_rect(s0, s1, t0, t1, rho).max(1e-300);
            }
        }
        t
    }

    /// Interpolated cell probabilities at ρ (from the grid).
    fn cells_at(&self, rho: f64) -> [[f64; 4]; 4] {
        let n = self.grid.len();
        let t = rho.clamp(0.0, self.grid[n - 1]) / self.grid[n - 1] * (n - 1) as f64;
        let i = (t.floor() as usize).min(n - 2);
        let frac = t - i as f64;
        let mut out = [[0.0; 4]; 4];
        for a in 0..4 {
            for b in 0..4 {
                out[a][b] =
                    self.tables[i][a][b] * (1.0 - frac) + self.tables[i + 1][a][b] * frac;
            }
        }
        out
    }

    /// Tally the 4×4 contingency table from code vectors.
    pub fn tally(cu: &[u16], cv: &[u16]) -> [[u64; 4]; 4] {
        assert_eq!(cu.len(), cv.len());
        let mut n = [[0u64; 4]; 4];
        for (&a, &b) in cu.iter().zip(cv) {
            n[a as usize & 3][b as usize & 3] += 1;
        }
        n
    }

    /// Negative log-likelihood of the table at ρ.
    pub fn nll(&self, counts: &[[u64; 4]; 4], rho: f64) -> f64 {
        let pi = self.cells_at(rho);
        let mut ll = 0.0;
        for a in 0..4 {
            for b in 0..4 {
                if counts[a][b] > 0 {
                    ll += counts[a][b] as f64 * pi[a][b].max(1e-300).ln();
                }
            }
        }
        -ll
    }

    /// MLE ρ̂ by golden-section over the (empirically unimodal) negative
    /// log-likelihood on [0, 1).
    pub fn estimate_from_counts(&self, counts: &[[u64; 4]; 4]) -> f64 {
        let hi = *self.grid.last().unwrap();
        let (rho, _) = golden_section_min(|r| self.nll(counts, r), 0.0, hi, 1e-9);
        rho
    }

    /// MLE ρ̂ from raw code vectors.
    pub fn estimate(&self, cu: &[u16], cv: &[u16]) -> f64 {
        self.estimate_from_counts(&Self::tally(cu, cv))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coding::{CodingParams, Scheme};
    use crate::data::pairs::bivariate_normal_batch;

    #[test]
    fn cell_probs_sum_to_one() {
        for &rho in &[0.0, 0.4, 0.9] {
            let t = TwoBitMle::cell_probs(0.75, rho);
            let sum: f64 = t.iter().flatten().sum();
            assert!((sum - 1.0).abs() < 1e-8, "rho={rho}: {sum}");
        }
    }

    #[test]
    fn diagonal_mass_equals_p_w2() {
        use crate::theory::p_w2;
        for &rho in &[0.0, 0.3, 0.7] {
            let t = TwoBitMle::cell_probs(0.75, rho);
            let diag: f64 = (0..4).map(|a| t[a][a]).sum();
            let want = p_w2(rho, 0.75);
            assert!((diag - want).abs() < 1e-7, "rho={rho}: {diag} vs {want}");
        }
    }

    #[test]
    fn symmetry_of_cells() {
        // x and y are exchangeable: π_ab = π_ba. Also sign symmetry:
        // π_ab = π_{3−a,3−b}.
        let t = TwoBitMle::cell_probs(1.0, 0.5);
        for a in 0..4 {
            for b in 0..4 {
                assert!((t[a][b] - t[b][a]).abs() < 1e-9);
                assert!((t[a][b] - t[3 - a][3 - b]).abs() < 1e-8);
            }
        }
    }

    #[test]
    fn mle_recovers_rho() {
        let mle = TwoBitMle::new_default(0.75);
        let params = CodingParams::new(Scheme::TwoBit, 0.75);
        for &rho in &[0.2, 0.5, 0.8, 0.95] {
            let (x, y) = bivariate_normal_batch(50_000, rho, 42);
            let cu = params.encode(&x);
            let cv = params.encode(&y);
            let est = mle.estimate(&cu, &cv);
            assert!((est - rho).abs() < 0.02, "rho={rho}: mle {est}");
        }
    }

    #[test]
    fn mle_beats_or_matches_linear_estimator() {
        // Section 7's point: the MLE uses strictly more information.
        // Compare MSEs over repetitions at a mid ρ.
        use crate::estimator::CollisionEstimator;
        let rho = 0.5;
        let k = 512;
        let w = 0.75;
        let params = CodingParams::new(Scheme::TwoBit, w);
        let lin = CollisionEstimator::new(params.clone());
        let mle = TwoBitMle::new_default(w);
        let reps = 300;
        let (mut mse_lin, mut mse_mle) = (0.0, 0.0);
        for r in 0..reps {
            let (x, y) = bivariate_normal_batch(k, rho, 9000 + r);
            let cu = params.encode(&x);
            let cv = params.encode(&y);
            let e1 = lin.estimate(&cu, &cv);
            let e2 = mle.estimate(&cu, &cv);
            mse_lin += (e1 - rho) * (e1 - rho);
            mse_mle += (e2 - rho) * (e2 - rho);
        }
        assert!(
            mse_mle <= mse_lin * 1.10,
            "MLE mse {mse_mle:.4} vs linear {mse_lin:.4}"
        );
    }

    #[test]
    fn tally_counts_everything() {
        let cu = vec![0u16, 1, 2, 3, 0, 0];
        let cv = vec![0u16, 1, 1, 3, 2, 0];
        let t = TwoBitMle::tally(&cu, &cv);
        let total: u64 = t.iter().flatten().sum();
        assert_eq!(total, 6);
        assert_eq!(t[0][0], 2);
        assert_eq!(t[2][1], 1);
    }
}
