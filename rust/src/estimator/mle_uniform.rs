//! Contingency-table MLE for the *uniform* scheme `h_w` — the second
//! half of the paper's Section-7 program ("we can substantially improve
//! linear estimators by solving nonlinear MLE equations"), here for the
//! scheme with more than four cells.
//!
//! With bins `I_c = [l_c, l_{c+1})` (the clamped uniform lattice of
//! `h_w`), the pair `(c_u[j], c_v[j])` lands in an `m×m` table with
//! `π_ab(ρ) = Pr(x ∈ I_a, y ∈ I_b)` — bivariate-normal rectangle
//! masses. The linear estimator keeps only `Σ_a π_aa`; the MLE uses the
//! full table. Cell probabilities are tabulated on a ρ grid once per
//! `(w, cutoff)` and interpolated.

use crate::coding::CodingParams;
use crate::mathx::golden_section_min;
use crate::mathx::normal::bvn_rect;

/// MLE estimator over the full `h_w` contingency table.
#[derive(Clone, Debug)]
pub struct UniformMle {
    pub params: CodingParams,
    m: usize,
    grid: Vec<f64>,
    /// `tables[g][a * m + b]` = π_ab at grid ρ `g`.
    tables: Vec<Vec<f64>>,
}

impl UniformMle {
    /// Build for uniform-scheme params (`scheme` must be `Uniform`).
    /// `n_grid` controls the ρ-grid resolution (≥ 16).
    pub fn new(params: CodingParams, n_grid: usize) -> Self {
        assert_eq!(
            params.scheme,
            crate::coding::Scheme::Uniform,
            "UniformMle requires the uniform scheme"
        );
        assert!(n_grid >= 16);
        let m = params.cardinality();
        let grid: Vec<f64> = (0..n_grid)
            .map(|i| i as f64 / (n_grid - 1) as f64 * (1.0 - 1e-6))
            .collect();
        let tables = grid
            .iter()
            .map(|&rho| Self::cell_probs(&params, rho))
            .collect();
        UniformMle {
            params,
            m,
            grid,
            tables,
        }
    }

    pub fn new_default(w: f64) -> Self {
        Self::new(CodingParams::new(crate::coding::Scheme::Uniform, w), 128)
    }

    /// Bin boundaries of code `c` (the clamped uniform lattice: extreme
    /// codes absorb the tails).
    fn bin(params: &CodingParams, c: usize) -> (f64, f64) {
        let b = (params.cutoff / params.w).ceil() as i64;
        let lo_code = c as i64 - b;
        let lo = if c == 0 {
            f64::NEG_INFINITY
        } else {
            lo_code as f64 * params.w
        };
        let hi = if c as i64 == 2 * b - 1 {
            f64::INFINITY
        } else {
            (lo_code + 1) as f64 * params.w
        };
        (lo, hi)
    }

    /// Exact `m×m` cell probabilities at ρ.
    pub fn cell_probs(params: &CodingParams, rho: f64) -> Vec<f64> {
        let m = params.cardinality();
        let mut t = vec![0.0; m * m];
        for a in 0..m {
            let (s0, s1) = Self::bin(params, a);
            // Symmetry π_ab = π_ba: fill the upper triangle only.
            for b in a..m {
                let (t0, t1) = Self::bin(params, b);
                let p = bvn_rect(s0, s1, t0, t1, rho).max(1e-300);
                t[a * m + b] = p;
                t[b * m + a] = p;
            }
        }
        t
    }

    fn cells_at(&self, rho: f64) -> Vec<f64> {
        let n = self.grid.len();
        let t = rho.clamp(0.0, self.grid[n - 1]) / self.grid[n - 1] * (n - 1) as f64;
        let i = (t.floor() as usize).min(n - 2);
        let frac = t - i as f64;
        self.tables[i]
            .iter()
            .zip(&self.tables[i + 1])
            .map(|(&a, &b)| a * (1.0 - frac) + b * frac)
            .collect()
    }

    /// Tally the contingency table from code vectors.
    pub fn tally(&self, cu: &[u16], cv: &[u16]) -> Vec<u64> {
        assert_eq!(cu.len(), cv.len());
        let mut n = vec![0u64; self.m * self.m];
        for (&a, &b) in cu.iter().zip(cv) {
            n[(a as usize).min(self.m - 1) * self.m + (b as usize).min(self.m - 1)] += 1;
        }
        n
    }

    /// Negative log-likelihood at ρ.
    pub fn nll(&self, counts: &[u64], rho: f64) -> f64 {
        let pi = self.cells_at(rho);
        let mut ll = 0.0;
        for (c, p) in counts.iter().zip(&pi) {
            if *c > 0 {
                ll += *c as f64 * p.max(1e-300).ln();
            }
        }
        -ll
    }

    /// MLE ρ̂ by golden-section on [0, 1).
    pub fn estimate(&self, cu: &[u16], cv: &[u16]) -> f64 {
        let counts = self.tally(cu, cv);
        let hi = *self.grid.last().unwrap();
        golden_section_min(|r| self.nll(&counts, r), 0.0, hi, 1e-9).0
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coding::Scheme;
    use crate::data::pairs::bivariate_normal_batch;

    #[test]
    fn cells_sum_to_one() {
        let params = CodingParams::new(Scheme::Uniform, 1.0);
        for &rho in &[0.0, 0.5, 0.9] {
            let t = UniformMle::cell_probs(&params, rho);
            let sum: f64 = t.iter().sum();
            assert!((sum - 1.0).abs() < 1e-7, "rho={rho}: {sum}");
        }
    }

    #[test]
    fn diagonal_mass_equals_p_w() {
        // Σ_a π_aa must equal the Theorem-1 collision probability (up to
        // tail clamping: the extreme bins absorb |x| > cutoff, which P_w
        // treats as separate bins — mass beyond 6 is ~1e-9).
        use crate::theory::p_w;
        let params = CodingParams::new(Scheme::Uniform, 0.75);
        let m = params.cardinality();
        for &rho in &[0.1, 0.5, 0.8] {
            let t = UniformMle::cell_probs(&params, rho);
            let diag: f64 = (0..m).map(|a| t[a * m + a]).sum();
            let want = p_w(rho, 0.75);
            assert!((diag - want).abs() < 1e-6, "rho={rho}: {diag} vs {want}");
        }
    }

    #[test]
    fn mle_recovers_rho() {
        let mle = UniformMle::new_default(0.75);
        let params = mle.params.clone();
        for &rho in &[0.3, 0.6, 0.9] {
            let (x, y) = bivariate_normal_batch(30_000, rho, 11);
            let est = mle.estimate(&params.encode(&x), &params.encode(&y));
            assert!((est - rho).abs() < 0.02, "rho={rho}: mle {est}");
        }
    }

    #[test]
    fn mle_at_least_as_good_as_linear() {
        use crate::estimator::CollisionEstimator;
        let w = 0.75;
        let rho = 0.5;
        let k = 512;
        let mle = UniformMle::new_default(w);
        let params = mle.params.clone();
        let lin = CollisionEstimator::new(params.clone());
        let reps = 200;
        let (mut mse_l, mut mse_m) = (0.0, 0.0);
        for r in 0..reps {
            let (x, y) = bivariate_normal_batch(k, rho, 7000 + r);
            let cu = params.encode(&x);
            let cv = params.encode(&y);
            mse_l += (lin.estimate(&cu, &cv) - rho).powi(2);
            mse_m += (mle.estimate(&cu, &cv) - rho).powi(2);
        }
        assert!(
            mse_m <= mse_l * 1.05,
            "uniform MLE mse {mse_m:.5} vs linear {mse_l:.5}"
        );
    }

    #[test]
    #[should_panic(expected = "uniform scheme")]
    fn rejects_wrong_scheme() {
        UniformMle::new(CodingParams::new(Scheme::TwoBit, 0.75), 32);
    }
}
