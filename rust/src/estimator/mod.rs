//! Similarity estimation from coded projections.
//!
//! * [`collision`] — the paper's linear estimator: invert the empirical
//!   collision rate through the monotone `P(ρ)` map (Section 3), with
//!   asymptotic standard errors from Theorems 2–4.
//! * [`mle`] — the contingency-table maximum-likelihood estimator the
//!   paper defers to future work (Section 5/7): for `h_{w,2}`, use all
//!   16 cell counts, not just the diagonal collision mass.

pub mod collision;
pub mod mle;
pub mod mle_uniform;

pub use collision::CollisionEstimator;
pub use mle::TwoBitMle;
pub use mle_uniform::UniformMle;
