//! The linear (collision-rate inversion) estimator ρ̂.

use crate::coding::{collision_count, collision_count_packed, CodingParams, PackedCodes};
use crate::theory::{InversionTable, SchemeKind};

/// Estimator for one `(scheme, w)` configuration. Holds the precomputed
/// inversion table; cheap to share across threads.
#[derive(Clone, Debug)]
pub struct CollisionEstimator {
    pub params: CodingParams,
    table: InversionTable,
}

/// A point estimate with its asymptotic standard error.
#[derive(Clone, Copy, Debug)]
pub struct Estimate {
    pub rho: f64,
    /// Asymptotic std error `√(V(ρ̂, w)/k)` (Theorems 2–4).
    pub std_err: f64,
    /// The empirical collision rate the estimate was inverted from.
    pub p_hat: f64,
    /// Number of projections used.
    pub k: usize,
}

impl CollisionEstimator {
    pub fn new(params: CodingParams) -> Self {
        let table = InversionTable::build_default(params.scheme, params.w);
        CollisionEstimator { params, table }
    }

    /// Scheme kind of this estimator.
    pub fn scheme(&self) -> SchemeKind {
        self.params.scheme
    }

    /// ρ̂ from two code vectors.
    pub fn estimate(&self, cu: &[u16], cv: &[u16]) -> f64 {
        assert_eq!(cu.len(), cv.len());
        assert!(!cu.is_empty());
        let p_hat = collision_count(cu, cv) as f64 / cu.len() as f64;
        self.table.rho(p_hat)
    }

    /// ρ̂ from packed code vectors (hot path).
    pub fn estimate_packed(&self, cu: &PackedCodes, cv: &PackedCodes) -> f64 {
        assert!(cu.len > 0);
        let p_hat = collision_count_packed(cu, cv) as f64 / cu.len as f64;
        self.table.rho(p_hat)
    }

    /// ρ̂ from a precomputed collision count.
    pub fn estimate_from_count(&self, collisions: usize, k: usize) -> f64 {
        assert!(k > 0 && collisions <= k);
        self.table.rho(collisions as f64 / k as f64)
    }

    /// Full estimate with asymptotic standard error.
    pub fn estimate_with_error(&self, cu: &[u16], cv: &[u16]) -> Estimate {
        let k = cu.len();
        let p_hat = collision_count(cu, cv) as f64 / k as f64;
        let rho = self.table.rho(p_hat);
        let v = self.params.scheme.variance_factor(rho.min(0.999), self.params.w);
        Estimate {
            rho,
            std_err: (v / k as f64).sqrt(),
            p_hat,
            k,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coding::Scheme;
    use crate::data::pairs::bivariate_normal_batch;

    fn estimate_once(scheme: Scheme, w: f64, rho: f64, k: usize, seed: u64) -> Estimate {
        let params = CodingParams::new(scheme, w);
        let est = CollisionEstimator::new(params.clone());
        let (x, y) = bivariate_normal_batch(k, rho, seed);
        let cu = params.encode(&x);
        let cv = params.encode(&y);
        est.estimate_with_error(&cu, &cv)
    }

    #[test]
    fn recovers_rho_all_schemes() {
        for scheme in [Scheme::Uniform, Scheme::WindowOffset, Scheme::TwoBit, Scheme::OneBit] {
            for &rho in &[0.1, 0.5, 0.8] {
                let e = estimate_once(scheme, 0.75, rho, 100_000, 77);
                assert!(
                    (e.rho - rho).abs() < 0.02,
                    "{scheme:?} rho={rho}: est {}",
                    e.rho
                );
            }
        }
    }

    #[test]
    fn error_shrinks_with_k() {
        let e_small = estimate_once(Scheme::TwoBit, 0.75, 0.6, 256, 3);
        let e_big = estimate_once(Scheme::TwoBit, 0.75, 0.6, 65536, 3);
        assert!(e_big.std_err < e_small.std_err / 10.0);
        assert!((e_big.rho - 0.6).abs() < 3.0 * e_big.std_err + 0.01);
    }

    #[test]
    fn packed_matches_unpacked() {
        let params = CodingParams::new(Scheme::TwoBit, 0.75);
        let est = CollisionEstimator::new(params.clone());
        let (x, y) = bivariate_normal_batch(4096, 0.7, 5);
        let cu = params.encode(&x);
        let cv = params.encode(&y);
        let pu = crate::coding::pack_codes(&cu, params.bits_per_code());
        let pv = crate::coding::pack_codes(&cv, params.bits_per_code());
        let a = est.estimate(&cu, &cv);
        let b = est.estimate_packed(&pu, &pv);
        assert!((a - b).abs() < 1e-12);
    }

    #[test]
    fn coverage_of_asymptotic_interval() {
        // ~95% of estimates should fall within 2 std errors (asymptotic
        // normality of P̂); check loosely over repetitions.
        let rho = 0.5;
        let k = 2048;
        let params = CodingParams::new(Scheme::Uniform, 1.0);
        let est = CollisionEstimator::new(params.clone());
        let mut covered = 0;
        let reps = 200;
        for r in 0..reps {
            let (x, y) = bivariate_normal_batch(k, rho, 1000 + r);
            let e = est.estimate_with_error(&params.encode(&x), &params.encode(&y));
            if (e.rho - rho).abs() <= 2.0 * e.std_err {
                covered += 1;
            }
        }
        let frac = covered as f64 / reps as f64;
        assert!(frac > 0.85, "coverage only {frac}");
    }

    #[test]
    fn empirical_variance_matches_theory() {
        // The headline claim of Section 3: Var(ρ̂) ≈ V/k. Monte-Carlo the
        // estimator and compare against the theoretical factor.
        let rho = 0.5;
        let k = 1024;
        for (scheme, w) in [(Scheme::Uniform, 0.75), (Scheme::TwoBit, 0.75), (Scheme::OneBit, 0.0)] {
            let params = CodingParams::new(scheme, w);
            let est = CollisionEstimator::new(params.clone());
            let reps = 400;
            let mut sum = 0.0;
            let mut sumsq = 0.0;
            for r in 0..reps {
                let (x, y) = bivariate_normal_batch(k, rho, 5000 + r);
                let e = est.estimate(&params.encode(&x), &params.encode(&y));
                sum += e;
                sumsq += e * e;
            }
            let mean = sum / reps as f64;
            let var = sumsq / reps as f64 - mean * mean;
            let want = scheme.variance_factor(rho, w) / k as f64;
            let ratio = var / want;
            assert!(
                (0.6..1.6).contains(&ratio),
                "{scheme:?}: empirical {var:.3e} vs theory {want:.3e} (ratio {ratio:.2})"
            );
        }
    }
}
