//! Monte-Carlo validation of the variance theorems (Theorems 2–4,
//! Eq. 20) and of the MLE extension — the "extra" experiments listed in
//! DESIGN.md's per-experiment index.

use super::table::Table;
use crate::coding::{CodingParams, Scheme};
use crate::data::pairs::bivariate_normal_batch;
use crate::estimator::{CollisionEstimator, TwoBitMle};
use crate::theory::SchemeKind;

/// Empirical `k · Var(ρ̂)` vs the theoretical variance factor `V`, per
/// scheme, across ρ. Validates the delta-method asymptotics end to end
/// (sampling → coding → inversion).
pub fn mc_variance_table(k: usize, reps: u64, w: f64, seed: u64) -> Table {
    let mut t = Table::new(
        "mc_variance",
        "Monte-Carlo k*Var(rho_hat) vs theory V (Theorems 2-4, Eq 20)",
        &[
            "rho", "scheme", "w", "k", "empirical_kvar", "theory_v", "ratio",
        ],
    );
    let rhos = [0.1, 0.25, 0.5, 0.75, 0.9];
    for (si, scheme) in SchemeKind::ALL.into_iter().enumerate() {
        let wv = if scheme == SchemeKind::OneBit { 0.0 } else { w };
        let params = CodingParams::new(scheme, wv);
        let est = CollisionEstimator::new(params.clone());
        for &rho in &rhos {
            let mut sum = 0.0;
            let mut sumsq = 0.0;
            for r in 0..reps {
                let (x, y) = bivariate_normal_batch(k, rho, seed + r * 31 + si as u64 * 7777);
                let e = est.estimate(&params.encode(&x), &params.encode(&y));
                sum += e;
                sumsq += e * e;
            }
            let mean = sum / reps as f64;
            let var = (sumsq / reps as f64 - mean * mean).max(0.0);
            let kvar = var * k as f64;
            let v = scheme.variance_factor(rho, wv);
            t.push(vec![rho, si as f64, wv, k as f64, kvar, v, kvar / v]);
        }
    }
    t
}

/// MLE vs linear estimator for `h_{w,2}`: MSE ratio over ρ.
pub fn mc_mle_table(k: usize, reps: u64, w: f64, seed: u64) -> Table {
    let mut t = Table::new(
        "mc_mle",
        "2-bit contingency-table MLE vs linear estimator (paper Section 7 future work)",
        &["rho", "k", "mse_linear", "mse_mle", "mse_ratio"],
    );
    let params = CodingParams::new(Scheme::TwoBit, w);
    let lin = CollisionEstimator::new(params.clone());
    let mle = TwoBitMle::new_default(w);
    for &rho in &[0.1, 0.3, 0.5, 0.7, 0.9] {
        let (mut mse_l, mut mse_m) = (0.0, 0.0);
        for r in 0..reps {
            let (x, y) = bivariate_normal_batch(k, rho, seed + r * 17);
            let cu = params.encode(&x);
            let cv = params.encode(&y);
            let el = lin.estimate(&cu, &cv);
            let em = mle.estimate(&cu, &cv);
            mse_l += (el - rho) * (el - rho);
            mse_m += (em - rho) * (em - rho);
        }
        mse_l /= reps as f64;
        mse_m /= reps as f64;
        t.push(vec![rho, k as f64, mse_l, mse_m, mse_m / mse_l]);
    }
    t
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mc_variance_ratios_near_one() {
        let t = mc_variance_table(1024, 120, 0.75, 77);
        for row in &t.rows {
            let ratio = row[6];
            assert!(
                (0.45..2.2).contains(&ratio),
                "rho={} scheme={} ratio {ratio}",
                row[0],
                row[1]
            );
        }
    }

    #[test]
    fn mle_never_much_worse() {
        let t = mc_mle_table(512, 60, 0.75, 5);
        for row in &t.rows {
            assert!(row[4] < 1.3, "rho={}: mse ratio {}", row[0], row[4]);
        }
    }
}
