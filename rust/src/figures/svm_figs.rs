//! Figures 11–14: the Section-6 linear SVM experiments, on the
//! synthetic stand-ins for URL / FARM / ARCENE (DESIGN.md §4).
//!
//! `scale ∈ (0, 1]` shrinks dataset sizes for quick runs; `scale = 1.0`
//! is the paper-scale configuration.

use super::table::Table;
use crate::coding::{CodingParams, Scheme};
use crate::data::synth::{SynthKind, SynthSpec};
use crate::projection::{ProjectionConfig, Projector};
use crate::svm::sweep::{project_dataset, run_coded_svm, SvmTask};

/// The paper's C grid (Figure 12+ restricts to 10^-3..10).
pub fn c_grid() -> Vec<f64> {
    vec![1e-3, 1e-2, 1e-1, 1.0, 10.0]
}

fn scaled_spec(kind: SynthKind, scale: f64) -> SynthSpec {
    let mut s = SynthSpec::paper(kind);
    if scale < 1.0 {
        s.train_n = ((s.train_n as f64 * scale) as usize).max(120);
        s.test_n = ((s.test_n as f64 * scale) as usize).max(120);
        s.dim = ((s.dim as f64 * scale.max(0.05)) as usize).max(500);
        s.n_informative = (s.n_informative as f64 * scale.max(0.05)) as usize + 40;
        if kind == SynthKind::ArceneLike {
            s.avg_nnz = s.dim;
        }
    }
    s
}

/// Shared projection cache for one dataset at the max k needed: project
/// once at k_max, reuse prefixes for smaller k (valid because projection
/// j only depends on stream j — columns are independent).
struct ProjectedData {
    train: Vec<f32>,
    y_train: Vec<f32>,
    test: Vec<f32>,
    y_test: Vec<f32>,
    k_max: usize,
}

fn project_at_kmax(kind: SynthKind, scale: f64, k_max: usize, seed: u64) -> ProjectedData {
    let spec = scaled_spec(kind, scale);
    let (tr, te) = spec.generate();
    let proj = Projector::new_cpu(ProjectionConfig {
        k: k_max,
        seed,
        ..Default::default()
    });
    ProjectedData {
        train: project_dataset(&tr, &proj),
        y_train: tr.y,
        test: project_dataset(&te, &proj),
        y_test: te.y,
        k_max,
    }
}

impl ProjectedData {
    /// Slice the first `k` projections out of the k_max-wide buffers.
    fn at_k(&self, k: usize) -> (Vec<f32>, Vec<f32>) {
        assert!(k <= self.k_max);
        let take = |buf: &[f32], n: usize| -> Vec<f32> {
            let mut out = vec![0.0f32; n * k];
            for r in 0..n {
                out[r * k..(r + 1) * k]
                    .copy_from_slice(&buf[r * self.k_max..r * self.k_max + k]);
            }
            out
        };
        (
            take(&self.train, self.y_train.len()),
            take(&self.test, self.y_test.len()),
        )
    }
}

/// Figure 11: URL-like, `h_w` vs `h_{w,q}` across k ∈ {16,64,256},
/// w ∈ {0.5,1,2,4}, C grid.
pub fn fig11_url_hw_vs_hwq(scale: f64) -> Table {
    let ks = [16usize, 64, 256];
    let ws = [0.5f64, 1.0, 2.0, 4.0];
    let data = project_at_kmax(SynthKind::UrlLike, scale, 256, 1101);
    let mut t = Table::new(
        "fig11_url_hw_vs_hwq",
        "Fig 11: URL-like test accuracy, h_w vs h_{w,q} over (k, w, C)",
        &["k", "w", "c", "acc_hw", "acc_hwq"],
    );
    for &k in &ks {
        let (ptr, pte) = data.at_k(k);
        for &w in &ws {
            for &c in &c_grid() {
                let hw = run_coded_svm(
                    &ptr,
                    &data.y_train,
                    &pte,
                    &data.y_test,
                    k,
                    &SvmTask::Coded(CodingParams::new(Scheme::Uniform, w)),
                    c,
                );
                let hwq = run_coded_svm(
                    &ptr,
                    &data.y_train,
                    &pte,
                    &data.y_test,
                    k,
                    &SvmTask::Coded(CodingParams::new(Scheme::WindowOffset, w)),
                    c,
                );
                t.push(vec![k as f64, w, c, hw.test_acc, hwq.test_acc]);
            }
        }
    }
    t
}

/// The four-scheme comparison used by Figures 12 (URL) and 13 (FARM):
/// orig vs `h_w` vs `h_{w,2}` vs `h_1` across k ∈ {16, 256}, w sweep.
fn four_scheme_fig(name: &str, title: &str, kind: SynthKind, scale: f64, seed: u64) -> Table {
    let ks = [16usize, 256];
    let ws = [0.5f64, 0.75, 1.0, 2.0];
    let data = project_at_kmax(kind, scale, 256, seed);
    let mut t = Table::new(
        name,
        title,
        &["k", "w", "c", "acc_orig", "acc_hw", "acc_hw2", "acc_h1"],
    );
    for &k in &ks {
        let (ptr, pte) = data.at_k(k);
        for &c in &c_grid() {
            let orig = run_coded_svm(
                &ptr,
                &data.y_train,
                &pte,
                &data.y_test,
                k,
                &SvmTask::Orig,
                c,
            );
            let h1 = run_coded_svm(
                &ptr,
                &data.y_train,
                &pte,
                &data.y_test,
                k,
                &SvmTask::Coded(CodingParams::new(Scheme::OneBit, 0.0)),
                c,
            );
            for &w in &ws {
                let hw = run_coded_svm(
                    &ptr,
                    &data.y_train,
                    &pte,
                    &data.y_test,
                    k,
                    &SvmTask::Coded(CodingParams::new(Scheme::Uniform, w)),
                    c,
                );
                let hw2 = run_coded_svm(
                    &ptr,
                    &data.y_train,
                    &pte,
                    &data.y_test,
                    k,
                    &SvmTask::Coded(CodingParams::new(Scheme::TwoBit, w)),
                    c,
                );
                t.push(vec![
                    k as f64,
                    w,
                    c,
                    orig.test_acc,
                    hw.test_acc,
                    hw2.test_acc,
                    h1.test_acc,
                ]);
            }
        }
    }
    t
}

/// Figure 12: URL-like, four schemes.
pub fn fig12_url_four_schemes(scale: f64) -> Table {
    four_scheme_fig(
        "fig12_url_four_schemes",
        "Fig 12: URL-like test accuracy, orig vs h_w vs h_{w,2} vs h_1",
        SynthKind::UrlLike,
        scale,
        1201,
    )
}

/// Figure 13: FARM-like, four schemes.
pub fn fig13_farm_four_schemes(scale: f64) -> Table {
    four_scheme_fig(
        "fig13_farm_four_schemes",
        "Fig 13: FARM-like test accuracy, orig vs h_w vs h_{w,2} vs h_1",
        SynthKind::FarmLike,
        scale,
        1301,
    )
}

/// Figure 14: all three datasets — best accuracy over (C, w) per k
/// (upper panels) and the w attaining it (lower panels).
pub fn fig14_summary(scale: f64) -> Vec<Table> {
    let ks = [16usize, 32, 64, 128, 256];
    let ws = [0.5f64, 0.75, 1.0, 2.0];
    let mut best = Table::new(
        "fig14_best_acc",
        "Fig 14 upper: best test accuracy over (C, w) per k",
        &[
            "dataset", "k", "acc_orig", "acc_hw", "acc_hw2", "acc_h1",
        ],
    );
    let mut best_w = Table::new(
        "fig14_best_w",
        "Fig 14 lower: w attaining the best accuracy",
        &["dataset", "k", "w_best_hw", "w_best_hw2"],
    );
    for (di, kind) in [SynthKind::UrlLike, SynthKind::FarmLike, SynthKind::ArceneLike]
        .into_iter()
        .enumerate()
    {
        let data = project_at_kmax(kind, scale, *ks.last().unwrap(), 1400 + di as u64);
        for &k in &ks {
            let (ptr, pte) = data.at_k(k);
            let mut acc_orig: f64 = 0.0;
            let mut acc_h1: f64 = 0.0;
            let mut acc_hw: f64 = 0.0;
            let mut acc_hw2: f64 = 0.0;
            let mut w_hw = f64::NAN;
            let mut w_hw2 = f64::NAN;
            for &c in &c_grid() {
                acc_orig = acc_orig.max(
                    run_coded_svm(&ptr, &data.y_train, &pte, &data.y_test, k, &SvmTask::Orig, c)
                        .test_acc,
                );
                acc_h1 = acc_h1.max(
                    run_coded_svm(
                        &ptr,
                        &data.y_train,
                        &pte,
                        &data.y_test,
                        k,
                        &SvmTask::Coded(CodingParams::new(Scheme::OneBit, 0.0)),
                        c,
                    )
                    .test_acc,
                );
                for &w in &ws {
                    let a = run_coded_svm(
                        &ptr,
                        &data.y_train,
                        &pte,
                        &data.y_test,
                        k,
                        &SvmTask::Coded(CodingParams::new(Scheme::Uniform, w)),
                        c,
                    )
                    .test_acc;
                    if a > acc_hw {
                        acc_hw = a;
                        w_hw = w;
                    }
                    let a2 = run_coded_svm(
                        &ptr,
                        &data.y_train,
                        &pte,
                        &data.y_test,
                        k,
                        &SvmTask::Coded(CodingParams::new(Scheme::TwoBit, w)),
                        c,
                    )
                    .test_acc;
                    if a2 > acc_hw2 {
                        acc_hw2 = a2;
                        w_hw2 = w;
                    }
                }
            }
            best.push(vec![di as f64, k as f64, acc_orig, acc_hw, acc_hw2, acc_h1]);
            best_w.push(vec![di as f64, k as f64, w_hw, w_hw2]);
        }
    }
    vec![best, best_w]
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Smoke-scale run of fig 12 machinery: the qualitative ordering
    /// h_w ≈ h_{w,2} ≥ h_1 at k=256 should emerge even at tiny scale.
    #[test]
    fn fig12_ordering_holds_at_small_scale() {
        let t = fig12_url_four_schemes(0.04);
        // Collect per-scheme best accuracy at the larger k.
        let mut best = [0.0f64; 4]; // orig, hw, hw2, h1
        for row in &t.rows {
            if row[0] as usize == 256 {
                for (i, b) in best.iter_mut().enumerate() {
                    *b = b.max(row[3 + i]);
                }
            }
        }
        assert!(best[1] >= best[3] - 0.02, "h_w {} vs h_1 {}", best[1], best[3]);
        assert!(best[2] >= best[3] - 0.02, "h_w2 {} vs h_1 {}", best[2], best[3]);
    }

    #[test]
    fn scaled_spec_shrinks() {
        let s = scaled_spec(SynthKind::UrlLike, 0.05);
        assert!(s.train_n < 1000);
        assert!(s.dim >= 500);
    }

    #[test]
    fn prefix_slicing_matches_direct_projection() {
        // Column j of the k_max projection equals column j of a k-wide
        // projection (streams are per-column) — validates at_k reuse.
        let data = project_at_kmax(SynthKind::FarmLike, 0.04, 32, 9);
        let (p16, _) = data.at_k(16);
        let spec = scaled_spec(SynthKind::FarmLike, 0.04);
        let (tr, _) = spec.generate();
        let proj16 = Projector::new_cpu(ProjectionConfig {
            k: 16,
            seed: 9,
            ..Default::default()
        });
        let direct = project_dataset(&tr, &proj16);
        // Note: RowMatrix streams are per (seed,row), so row i of R at
        // k=32 begins with row i of R at k=16 ⇒ prefixes match exactly.
        for (a, b) in p16.iter().zip(&direct) {
            assert!((a - b).abs() < 1e-5, "{a} vs {b}");
        }
    }
}
