//! Figures 1–10: the paper's theory curves, computed exactly.

use super::table::Table;
use crate::theory::{
    optimum_w, p_w, p_w2, p_wq, v_1, v_w, v_w2, v_wq, SchemeKind,
};
use crate::theory::variance::v_wq_scale_free;

/// ρ values the paper uses in the collision-probability panels.
pub const PANEL_RHOS: [f64; 6] = [0.0, 0.25, 0.5, 0.75, 0.9, 0.99];
/// ρ values in the variance panels (Figures 4 & 7 have 8 panels).
pub const VAR_RHOS: [f64; 8] = [0.0, 0.1, 0.25, 0.5, 0.56, 0.75, 0.9, 0.99];

fn w_grid(lo: f64, hi: f64, n: usize) -> Vec<f64> {
    (0..n)
        .map(|i| lo + (hi - lo) * i as f64 / (n - 1) as f64)
        .collect()
}

/// Figure 1: `P_w` vs `P_{w,q}` over w for six ρ values.
pub fn fig1_collision_probabilities() -> Table {
    let mut cols = vec!["w".to_string()];
    for r in PANEL_RHOS {
        cols.push(format!("Pw_rho{r}"));
        cols.push(format!("Pwq_rho{r}"));
    }
    let cols_ref: Vec<&str> = cols.iter().map(|s| s.as_str()).collect();
    let mut t = Table::new(
        "fig01_collision",
        "Fig 1: collision probabilities P_w (proposed) vs P_{w,q} (Datar et al.)",
        &cols_ref,
    );
    for w in w_grid(0.1, 10.0, 100) {
        let mut row = vec![w];
        for r in PANEL_RHOS {
            row.push(p_w(r, w));
            row.push(p_wq(r, w));
        }
        t.push(row);
    }
    t
}

/// Figure 2: the scale-free variance factor `V_{w,q}·4/d²` against
/// `t = w/√d`; minimum 7.6797 at t = 1.6476.
pub fn fig2_vwq_scale_free() -> Table {
    let mut t = Table::new(
        "fig02_vwq_scale_free",
        "Fig 2: V_{w,q} x 4/d^2 vs w/sqrt(d); min 7.6797 at 1.6476",
        &["t", "v"],
    );
    for x in w_grid(0.2, 8.0, 160) {
        t.push(vec![x, v_wq_scale_free(x)]);
    }
    t
}

/// Figure 3: `V_w|ρ=0` over w, approaching π²/4.
pub fn fig3_vw_rho0() -> Table {
    let mut t = Table::new(
        "fig03_vw_rho0",
        "Fig 3: V_w at rho=0 vs w -> pi^2/4 = 2.4674",
        &["w", "v_w", "pi2_over_4"],
    );
    let limit = std::f64::consts::PI.powi(2) / 4.0;
    for w in w_grid(0.2, 12.0, 120) {
        t.push(vec![w, v_w(0.0, w), limit]);
    }
    t
}

/// Figure 4: `V_w` vs `V_{w,q}` over w at fixed ρ panels.
pub fn fig4_vw_vs_vwq() -> Table {
    let mut cols = vec!["w".to_string()];
    for r in VAR_RHOS {
        cols.push(format!("Vw_rho{r}"));
        cols.push(format!("Vwq_rho{r}"));
    }
    let cols_ref: Vec<&str> = cols.iter().map(|s| s.as_str()).collect();
    let mut t = Table::new(
        "fig04_vw_vs_vwq",
        "Fig 4: variance factors V_w vs V_{w,q} at fixed w",
        &cols_ref,
    );
    for w in w_grid(0.1, 8.0, 80) {
        let mut row = vec![w];
        for r in VAR_RHOS {
            row.push(v_w(r, w));
            row.push(v_wq(r, w));
        }
        t.push(row);
    }
    t
}

/// Figure 5: optimized (over w) variance factors and the optimizing w,
/// per ρ. Two tables: left (best V) and right (argmin w).
pub fn fig5_optimized() -> Vec<Table> {
    let mut left = Table::new(
        "fig05_left_best_v",
        "Fig 5 left: min_w V_w vs min_w V_{w,q}",
        &["rho", "Vw_best", "Vwq_best"],
    );
    let mut right = Table::new(
        "fig05_right_opt_w",
        "Fig 5 right: argmin_w V_w vs argmin_w V_{w,q} (cap = 20 marks divergence)",
        &["rho", "w_opt_hw", "w_opt_hwq", "hw_at_cap"],
    );
    for i in 1..=49 {
        let rho = i as f64 / 50.0;
        let rw = optimum_w(SchemeKind::Uniform, rho);
        let rq = optimum_w(SchemeKind::WindowOffset, rho);
        left.push(vec![rho, rw.v, rq.v]);
        right.push(vec![rho, rw.w, rq.w, f64::from(u8::from(rw.at_cap))]);
    }
    vec![left, right]
}

/// Figure 6: `P_{w,2}` vs `P_w` over w at the six panel ρ values.
pub fn fig6_pw2_vs_pw() -> Table {
    let mut cols = vec!["w".to_string()];
    for r in PANEL_RHOS {
        cols.push(format!("Pw2_rho{r}"));
        cols.push(format!("Pw_rho{r}"));
    }
    let cols_ref: Vec<&str> = cols.iter().map(|s| s.as_str()).collect();
    let mut t = Table::new(
        "fig06_pw2_vs_pw",
        "Fig 6: collision probabilities of h_{w,2} vs h_w",
        &cols_ref,
    );
    for w in w_grid(0.05, 5.0, 100) {
        let mut row = vec![w];
        for r in PANEL_RHOS {
            row.push(p_w2(r, w));
            row.push(p_w(r, w));
        }
        t.push(row);
    }
    t
}

/// Figure 7: `V_{w,2}` vs `V_w` over w at the eight variance ρ panels.
pub fn fig7_vw2_vs_vw() -> Table {
    let mut cols = vec!["w".to_string()];
    for r in VAR_RHOS {
        cols.push(format!("Vw2_rho{r}"));
        cols.push(format!("Vw_rho{r}"));
    }
    let cols_ref: Vec<&str> = cols.iter().map(|s| s.as_str()).collect();
    let mut t = Table::new(
        "fig07_vw2_vs_vw",
        "Fig 7: variance factors V_{w,2} vs V_w",
        &cols_ref,
    );
    for w in w_grid(0.05, 5.0, 100) {
        let mut row = vec![w];
        for r in VAR_RHOS {
            row.push(v_w2(r, w));
            row.push(v_w(r, w));
        }
        t.push(row);
    }
    t
}

/// Figure 8: smallest `V_{w,2}` (and `V_w`) and the optimizing w, per ρ.
pub fn fig8_optimized_2bit() -> Vec<Table> {
    let mut left = Table::new(
        "fig08_left_best_v",
        "Fig 8 left: min_w V_{w,2} vs min_w V_w",
        &["rho", "Vw2_best", "Vw_best"],
    );
    let mut right = Table::new(
        "fig08_right_opt_w",
        "Fig 8 right: argmin_w V_{w,2} vs argmin_w V_w",
        &["rho", "w_opt_hw2", "w_opt_hw"],
    );
    for i in 1..=49 {
        let rho = i as f64 / 50.0;
        let r2 = optimum_w(SchemeKind::TwoBit, rho);
        let rw = optimum_w(SchemeKind::Uniform, rho);
        left.push(vec![rho, r2.v, rw.v]);
        right.push(vec![rho, r2.w, rw.w]);
    }
    vec![left, right]
}

/// Figure 9: max-over-w variance ratios `V_1/V_w` and `V_1/V_{w,2}`
/// against `1 − ρ` (log scale in the paper; we emit 1−ρ as a column).
pub fn fig9_onebit_ratio_max() -> Table {
    let mut t = Table::new(
        "fig09_onebit_ratio_max",
        "Fig 9: max-over-w Var(rho1)/Var(rho_w) and /Var(rho_w2) vs 1-rho",
        &["one_minus_rho", "rho", "ratio_hw", "ratio_hw2"],
    );
    // Log-spaced 1−ρ from 1 down to 10^-3 (ρ up to 0.999).
    let n = 60;
    for i in 0..n {
        let log1m = -3.0 * i as f64 / (n - 1) as f64; // 0 .. −3
        let one_m = 10f64.powf(log1m);
        let rho = 1.0 - one_m;
        let v1 = v_1(rho);
        let rw = optimum_w(SchemeKind::Uniform, rho);
        let r2 = optimum_w(SchemeKind::TwoBit, rho);
        t.push(vec![one_m, rho, v1 / rw.v, v1 / r2.v]);
    }
    t
}

/// Figure 10: the same ratios at fixed w ∈ {0.25, 0.5, 0.75, 1}.
pub fn fig10_onebit_ratio_fixed_w() -> Table {
    let ws = [0.25, 0.5, 0.75, 1.0];
    let mut cols = vec!["one_minus_rho".to_string(), "rho".to_string()];
    for w in ws {
        cols.push(format!("ratio_hw_w{w}"));
        cols.push(format!("ratio_hw2_w{w}"));
    }
    let cols_ref: Vec<&str> = cols.iter().map(|s| s.as_str()).collect();
    let mut t = Table::new(
        "fig10_onebit_ratio_fixed_w",
        "Fig 10: Var(rho1)/Var(rho_w) and /Var(rho_w2) at fixed w",
        &cols_ref,
    );
    let n = 60;
    for i in 0..n {
        let log1m = -3.0 * i as f64 / (n - 1) as f64;
        let one_m = 10f64.powf(log1m);
        let rho = 1.0 - one_m;
        let v1 = v_1(rho);
        let mut row = vec![one_m, rho];
        for w in ws {
            row.push(v1 / v_w(rho, w));
            row.push(v1 / v_w2(rho, w));
        }
        t.push(row);
    }
    t
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig1_has_expected_shape() {
        let t = fig1_collision_probabilities();
        assert_eq!(t.columns.len(), 13);
        assert_eq!(t.rows.len(), 100);
        // At rho=0 (cols 1,2): P_w plateaus near 0.5, P_wq → 1.
        let last = t.rows.last().unwrap();
        assert!((last[1] - 0.5).abs() < 0.01, "P_w(0, 10) = {}", last[1]);
        assert!(last[2] > 0.85, "P_wq(0, 10) = {}", last[2]);
    }

    #[test]
    fn fig2_min_matches_paper_constant() {
        let t = fig2_vwq_scale_free();
        let min = t
            .rows
            .iter()
            .map(|r| r[1])
            .fold(f64::INFINITY, f64::min);
        assert!((min - 7.6797).abs() < 0.01, "min {min}");
    }

    #[test]
    fn fig3_approaches_limit() {
        let t = fig3_vw_rho0();
        let last = t.rows.last().unwrap();
        assert!((last[1] - last[2]).abs() < 0.01);
    }

    #[test]
    fn fig5_shapes() {
        let ts = fig5_optimized();
        assert_eq!(ts.len(), 2);
        // ρ = 0.02 row: h_w optimum at cap, h_wq around 2.
        let right = &ts[1];
        let first = &right.rows[0];
        assert!(first[1] > 6.0, "h_w optimum {first:?}");
        assert!(first[2] < 4.0);
        // High ρ row: h_w optimum small.
        let last = right.rows.last().unwrap();
        assert!(last[1] < 2.0, "{last:?}");
    }

    #[test]
    fn fig9_monotone_advantage_at_high_rho() {
        let t = fig9_onebit_ratio_max();
        // ratio_hw at the highest ρ (last row) should be large (>3);
        // at ρ=0 (first row) ≈ 1.
        let first = &t.rows[0];
        let last = t.rows.last().unwrap();
        assert!((first[2] - 1.0).abs() < 0.05, "rho=0 ratio {}", first[2]);
        assert!(last[2] > 3.0, "rho→1 ratio {}", last[2]);
    }

    #[test]
    fn fig10_recommended_regime() {
        // Paper: at w = 0.75 and high ρ, V_1/V_{w,2} is between 2 and 3.
        let t = fig10_onebit_ratio_fixed_w();
        let hi = t
            .rows
            .iter()
            .find(|r| (r[1] - 0.99).abs() < 0.005)
            .expect("rho=0.99 row");
        // columns: [1-rho, rho, (hw,hw2) x {0.25,0.5,0.75,1.0}]
        let ratio_hw2_w075 = hi[2 + 2 * 2 + 1];
        assert!(
            (1.5..4.0).contains(&ratio_hw2_w075),
            "V1/Vw2 at w=0.75, rho=0.99: {ratio_hw2_w075}"
        );
    }

    #[test]
    fn all_theory_figs_render() {
        for f in [1u32, 2, 3, 4, 6, 7, 9, 10] {
            let ts = crate::figures::run_figure(f, 1.0).unwrap();
            for t in ts {
                assert!(!t.rows.is_empty());
                let _ = t.render_text(8);
            }
        }
    }
}
