//! Regeneration of every figure in the paper's evaluation.
//!
//! Each `figN()` produces a [`Table`] containing the same series the
//! paper plots; the CLI writes them as CSV under `results/` and prints
//! aligned summaries. Figures 1–10 are pure theory (exact curves);
//! Figures 11–14 run the Section-6 SVM pipeline on the synthetic
//! stand-in corpora; the `mc_*` extras validate the variance theorems by
//! Monte-Carlo and benchmark the MLE extension.

pub mod table;
pub mod theory_figs;
pub mod svm_figs;
pub mod mc;

pub use table::Table;

/// Run a figure by number with default parameters, returning its tables.
/// SVM figures accept a `scale` in (0,1] shrinking the dataset/grid for
/// quick runs.
pub fn run_figure(fig: u32, scale: f64) -> crate::Result<Vec<Table>> {
    Ok(match fig {
        1 => vec![theory_figs::fig1_collision_probabilities()],
        2 => vec![theory_figs::fig2_vwq_scale_free()],
        3 => vec![theory_figs::fig3_vw_rho0()],
        4 => vec![theory_figs::fig4_vw_vs_vwq()],
        5 => theory_figs::fig5_optimized(),
        6 => vec![theory_figs::fig6_pw2_vs_pw()],
        7 => vec![theory_figs::fig7_vw2_vs_vw()],
        8 => theory_figs::fig8_optimized_2bit(),
        9 => vec![theory_figs::fig9_onebit_ratio_max()],
        10 => vec![theory_figs::fig10_onebit_ratio_fixed_w()],
        11 => vec![svm_figs::fig11_url_hw_vs_hwq(scale)],
        12 => vec![svm_figs::fig12_url_four_schemes(scale)],
        13 => vec![svm_figs::fig13_farm_four_schemes(scale)],
        14 => svm_figs::fig14_summary(scale),
        _ => anyhow::bail!("unknown figure {fig} (paper has figures 1–14)"),
    })
}

/// All figure numbers in the paper.
pub const ALL_FIGURES: [u32; 14] = [1, 2, 3, 4, 5, 6, 7, 8, 9, 10, 11, 12, 13, 14];
