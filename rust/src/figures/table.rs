//! Minimal tabular result container with CSV and aligned-text output.

use std::io::Write;
use std::path::Path;

/// Format with ~`sig` significant digits, trimming trailing zeros
/// (`printf %g`-style; Rust's formatter has no `g` conversion).
pub fn fmt_sig(v: f64, sig: usize) -> String {
    if v == 0.0 {
        return "0".to_string();
    }
    let mag = v.abs().log10().floor() as i32;
    if !(-4..=9).contains(&mag) {
        return format!("{v:.*e}", sig.saturating_sub(1));
    }
    let decimals = (sig as i32 - 1 - mag).max(0) as usize;
    let s = format!("{v:.decimals$}");
    if s.contains('.') {
        s.trim_end_matches('0').trim_end_matches('.').to_string()
    } else {
        s
    }
}

/// A named table of f64 columns (NaN marks missing cells).
#[derive(Clone, Debug)]
pub struct Table {
    /// Identifier, used as the CSV file stem (e.g. `fig5_left`).
    pub name: String,
    /// Human description (printed as a comment header).
    pub title: String,
    pub columns: Vec<String>,
    pub rows: Vec<Vec<f64>>,
}

impl Table {
    pub fn new(name: &str, title: &str, columns: &[&str]) -> Self {
        Table {
            name: name.to_string(),
            title: title.to_string(),
            columns: columns.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn push(&mut self, row: Vec<f64>) {
        assert_eq!(row.len(), self.columns.len(), "row arity mismatch");
        self.rows.push(row);
    }

    /// Write `results/<name>.csv`.
    pub fn write_csv(&self, dir: impl AsRef<Path>) -> crate::Result<std::path::PathBuf> {
        std::fs::create_dir_all(dir.as_ref())?;
        let path = dir.as_ref().join(format!("{}.csv", self.name));
        let mut f = std::io::BufWriter::new(std::fs::File::create(&path)?);
        writeln!(f, "# {}", self.title)?;
        writeln!(f, "{}", self.columns.join(","))?;
        for row in &self.rows {
            let cells: Vec<String> = row
                .iter()
                .map(|v| {
                    if v.is_nan() {
                        String::new()
                    } else {
                        fmt_sig(*v, 7)
                    }
                })
                .collect();
            writeln!(f, "{}", cells.join(","))?;
        }
        f.flush()?;
        Ok(path)
    }

    /// Aligned text rendering (first/last rows if long).
    pub fn render_text(&self, max_rows: usize) -> String {
        let mut out = String::new();
        out.push_str(&format!("== {} — {}\n", self.name, self.title));
        let widths: Vec<usize> = self.columns.iter().map(|c| c.len().max(10)).collect();
        for (c, w) in self.columns.iter().zip(&widths) {
            out.push_str(&format!("{c:>w$} ", w = w));
        }
        out.push('\n');
        let n = self.rows.len();
        let show: Vec<usize> = if n <= max_rows {
            (0..n).collect()
        } else {
            let head = max_rows / 2;
            let tail = max_rows - head;
            (0..head).chain(n - tail..n).collect()
        };
        let mut last = 0usize;
        for &i in &show {
            if i > last + 1 {
                out.push_str("   ...\n");
            }
            for (v, w) in self.rows[i].iter().zip(&widths) {
                if v.is_nan() {
                    out.push_str(&format!("{:>w$} ", "-", w = w));
                } else {
                    out.push_str(&format!("{v:>w$.4} ", w = w));
                }
            }
            out.push('\n');
            last = i;
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn build_and_render() {
        let mut t = Table::new("test_fig", "a test", &["x", "y"]);
        t.push(vec![1.0, 2.0]);
        t.push(vec![3.0, f64::NAN]);
        let text = t.render_text(10);
        assert!(text.contains("test_fig"));
        assert!(text.contains('-'));
    }

    #[test]
    #[should_panic(expected = "arity")]
    fn arity_checked() {
        let mut t = Table::new("t", "t", &["x", "y"]);
        t.push(vec![1.0]);
    }

    #[test]
    fn csv_roundtrip_shape() {
        let mut t = Table::new("csv_test", "desc", &["a", "b"]);
        t.push(vec![0.5, 1.5]);
        let dir = std::env::temp_dir().join(format!("crp_fig_{}", std::process::id()));
        let path = t.write_csv(&dir).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        assert!(text.contains("a,b"));
        assert!(text.contains("0.5,1.5"));
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn render_truncates_long_tables() {
        let mut t = Table::new("long", "long", &["x"]);
        for i in 0..100 {
            t.push(vec![i as f64]);
        }
        let text = t.render_text(6);
        assert!(text.contains("..."));
        assert!(text.lines().count() < 15);
    }
}
