//! The PJRT executor: one CPU client, each artifact compiled once and
//! cached, typed execute helpers for the shapes the engine dispatches.

use std::collections::HashMap;
use std::sync::Mutex;

use anyhow::{anyhow, Context};

use super::artifact::{ArtifactId, ArtifactRegistry};

/// A PJRT client plus a cache of compiled executables, keyed by artifact
/// id. Compilation happens on first use; execution is thread-safe (the
/// cache is behind a mutex, execution itself goes through `&self` on the
/// cached executable).
pub struct PjrtRuntime {
    client: xla::PjRtClient,
    registry: ArtifactRegistry,
    cache: Mutex<HashMap<ArtifactId, std::sync::Arc<xla::PjRtLoadedExecutable>>>,
    /// Serializes `execute` calls: the wrapper crate's handles hold
    /// non-atomic `Rc`s that may be cloned inside execute, so concurrent
    /// execution on shared handles is confined to one thread at a time.
    exec_lock: Mutex<()>,
}

// SAFETY: the `xla` crate wraps its C++ handles in `Rc`/raw pointers and
// therefore derives neither Send nor Sync, but the underlying PJRT CPU
// client and loaded executables are thread-safe by the PJRT API contract
// (XLA documents `PJRT_Client` / `PJRT_LoadedExecutable_Execute` as
// thread-safe; the CPU plugin serializes internally where required). We
// never hand out interior `Rc` clones: the client and executables live
// for the runtime's lifetime inside this struct, the compile cache is
// guarded by a `Mutex`, and the only Rc-refcount mutation (cloning the
// cached executable handle) happens under that mutex.
unsafe impl Send for PjrtRuntime {}
unsafe impl Sync for PjrtRuntime {}

impl std::fmt::Debug for PjrtRuntime {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("PjrtRuntime")
            .field("registry", &self.registry)
            .finish_non_exhaustive()
    }
}

impl PjrtRuntime {
    /// Create a CPU-backed runtime over the given artifact directory.
    pub fn cpu(registry: ArtifactRegistry) -> crate::Result<Self> {
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("PJRT cpu client: {e:?}"))?;
        Ok(PjrtRuntime {
            client,
            registry,
            cache: Mutex::new(HashMap::new()),
            exec_lock: Mutex::new(()),
        })
    }

    /// Create a CPU runtime over the default `artifacts/` directory.
    pub fn cpu_default() -> crate::Result<Self> {
        Self::cpu(ArtifactRegistry::default_location())
    }

    pub fn registry(&self) -> &ArtifactRegistry {
        &self.registry
    }

    pub fn platform_name(&self) -> String {
        self.client.platform_name()
    }

    /// True when the artifact exists on disk (compilable on demand).
    pub fn has(&self, id: &ArtifactId) -> bool {
        self.registry.exists(id)
    }

    /// Get (compiling and caching on first use) the executable for `id`.
    pub fn executable(
        &self,
        id: &ArtifactId,
    ) -> crate::Result<std::sync::Arc<xla::PjRtLoadedExecutable>> {
        {
            let cache = self.cache.lock().unwrap();
            if let Some(exe) = cache.get(id) {
                return Ok(exe.clone());
            }
        }
        let path = self.registry.path_of(id);
        let proto = xla::HloModuleProto::from_text_file(&path)
            .map_err(|e| anyhow!("load HLO text {path:?}: {e:?}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| anyhow!("compile {id:?}: {e:?}"))?;
        let exe = std::sync::Arc::new(exe);
        self.cache
            .lock()
            .unwrap()
            .insert(id.clone(), exe.clone());
        Ok(exe)
    }

    /// Execute an artifact on literal inputs; returns the tuple elements
    /// of the (always `return_tuple=True`-lowered) result.
    pub fn execute(
        &self,
        id: &ArtifactId,
        inputs: &[xla::Literal],
    ) -> crate::Result<Vec<xla::Literal>> {
        let exe = self.executable(id)?;
        let _guard = self.exec_lock.lock().unwrap();
        let result = exe
            .execute::<xla::Literal>(inputs)
            .map_err(|e| anyhow!("execute {id:?}: {e:?}"))?;
        let lit = result[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("fetch result of {id:?}: {e:?}"))?;
        lit.to_tuple().map_err(|e| anyhow!("untuple {id:?}: {e:?}"))
    }

    /// Helper: f32 literal of shape `dims` from a row-major slice.
    pub fn literal_f32(data: &[f32], dims: &[i64]) -> crate::Result<xla::Literal> {
        let n: i64 = dims.iter().product();
        anyhow::ensure!(n as usize == data.len(), "shape/data mismatch");
        xla::Literal::vec1(data)
            .reshape(dims)
            .map_err(|e| anyhow!("reshape: {e:?}"))
            .context("literal_f32")
    }

    /// Helper: i32 literal of shape `dims`.
    pub fn literal_i32(data: &[i32], dims: &[i64]) -> crate::Result<xla::Literal> {
        let n: i64 = dims.iter().product();
        anyhow::ensure!(n as usize == data.len(), "shape/data mismatch");
        xla::Literal::vec1(data)
            .reshape(dims)
            .map_err(|e| anyhow!("reshape: {e:?}"))
    }

    /// Helper: scalar f32 literal.
    pub fn literal_scalar_f32(v: f32) -> xla::Literal {
        xla::Literal::from(v)
    }

    /// Extract an f32 vector from a literal.
    pub fn to_vec_f32(lit: &xla::Literal) -> crate::Result<Vec<f32>> {
        lit.to_vec::<f32>().map_err(|e| anyhow!("to_vec f32: {e:?}"))
    }

    /// Extract an i32 vector from a literal.
    pub fn to_vec_i32(lit: &xla::Literal) -> crate::Result<Vec<i32>> {
        lit.to_vec::<i32>().map_err(|e| anyhow!("to_vec i32: {e:?}"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Write a tiny hand-rolled HLO module and round-trip it through the
    /// runtime — validates load → compile → execute → untuple without
    /// requiring `make artifacts`.
    #[test]
    fn hand_rolled_hlo_roundtrip() {
        let dir = std::env::temp_dir().join(format!("crp_rt_test_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let hlo = r#"
HloModule add_two, entry_computation_layout={(f32[4]{0})->(f32[4]{0})}

ENTRY main {
  p = f32[4]{0} parameter(0)
  c = f32[] constant(2)
  cb = f32[4]{0} broadcast(c), dimensions={}
  s = f32[4]{0} add(p, cb)
  ROOT t = (f32[4]{0}) tuple(s)
}
"#;
        let id = ArtifactId("add_two".to_string());
        std::fs::write(dir.join(id.file_name()), hlo).unwrap();
        let rt = PjrtRuntime::cpu(ArtifactRegistry::new(&dir)).unwrap();
        assert!(rt.has(&id));
        let input = PjrtRuntime::literal_f32(&[1.0, 2.0, 3.0, 4.0], &[4]).unwrap();
        let out = rt.execute(&id, &[input]).unwrap();
        assert_eq!(out.len(), 1);
        let v = PjrtRuntime::to_vec_f32(&out[0]).unwrap();
        assert_eq!(v, vec![3.0, 4.0, 5.0, 6.0]);
        // Second execution hits the compile cache.
        let input = PjrtRuntime::literal_f32(&[0.0, 0.0, 0.0, 0.0], &[4]).unwrap();
        let v = PjrtRuntime::to_vec_f32(&rt.execute(&id, &[input]).unwrap()[0]).unwrap();
        assert_eq!(v, vec![2.0; 4]);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn missing_artifact_is_error() {
        let dir = std::env::temp_dir().join(format!("crp_rt_missing_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let rt = PjrtRuntime::cpu(ArtifactRegistry::new(&dir)).unwrap();
        let id = ArtifactId("nope".to_string());
        assert!(!rt.has(&id));
        assert!(rt.execute(&id, &[]).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn literal_shape_mismatch_rejected() {
        assert!(PjrtRuntime::literal_f32(&[1.0, 2.0], &[3]).is_err());
        assert!(PjrtRuntime::literal_i32(&[1, 2, 3], &[2, 2]).is_err());
    }
}
