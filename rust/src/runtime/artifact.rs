//! Artifact naming and discovery.
//!
//! Every exported computation has a fixed shape baked in at AOT time; the
//! engine tiles arbitrary workloads onto these shapes. Names encode the
//! shape so Rust and Python agree by construction:
//!
//! * `proj_acc_b{B}_d{D}_k{K}` — `(u[B,D], r[D,K], acc[B,K]) → acc + u·r`
//! * `quantize_all_b{B}_k{K}` — `(x[B,K], w, offs[K]) → (hw, hwq, hw2, h1)`
//! * `proj_code_b{B}_d{D}_k{K}` — fused project + 2-bit code epilogue
//! * `collision_b{B}_k{K}` — `(a[B,K] i32, b[B,K] i32) → counts[B] i32`

use std::path::{Path, PathBuf};

/// Identifier of an AOT artifact (name without extension).
#[derive(Clone, Debug, PartialEq, Eq, Hash)]
pub struct ArtifactId(pub String);

impl ArtifactId {
    pub fn proj_acc(b: usize, d: usize, k: usize) -> Self {
        ArtifactId(format!("proj_acc_b{b}_d{d}_k{k}"))
    }
    pub fn quantize_all(b: usize, k: usize) -> Self {
        ArtifactId(format!("quantize_all_b{b}_k{k}"))
    }
    pub fn proj_code(b: usize, d: usize, k: usize) -> Self {
        ArtifactId(format!("proj_code_b{b}_d{d}_k{k}"))
    }
    pub fn collision(b: usize, k: usize) -> Self {
        ArtifactId(format!("collision_b{b}_k{k}"))
    }

    pub fn file_name(&self) -> String {
        format!("{}.hlo.txt", self.0)
    }
}

/// Resolve the artifacts directory: `$CRP_ARTIFACTS` if set, else
/// `artifacts/` relative to the crate root (works from `cargo test`,
/// `cargo bench`, and installed binaries run from the repo).
pub fn artifacts_dir() -> PathBuf {
    if let Ok(dir) = std::env::var("CRP_ARTIFACTS") {
        return PathBuf::from(dir);
    }
    let manifest = PathBuf::from(env!("CARGO_MANIFEST_DIR"));
    manifest.join("artifacts")
}

/// Discovery over the artifacts directory.
#[derive(Clone, Debug)]
pub struct ArtifactRegistry {
    dir: PathBuf,
}

impl ArtifactRegistry {
    pub fn new(dir: impl AsRef<Path>) -> Self {
        ArtifactRegistry {
            dir: dir.as_ref().to_path_buf(),
        }
    }

    pub fn default_location() -> Self {
        Self::new(artifacts_dir())
    }

    pub fn dir(&self) -> &Path {
        &self.dir
    }

    pub fn path_of(&self, id: &ArtifactId) -> PathBuf {
        self.dir.join(id.file_name())
    }

    pub fn exists(&self, id: &ArtifactId) -> bool {
        self.path_of(id).is_file()
    }

    /// All artifact ids present on disk.
    pub fn list(&self) -> Vec<ArtifactId> {
        let mut out = Vec::new();
        if let Ok(entries) = std::fs::read_dir(&self.dir) {
            for e in entries.flatten() {
                let name = e.file_name().to_string_lossy().to_string();
                if let Some(stem) = name.strip_suffix(".hlo.txt") {
                    out.push(ArtifactId(stem.to_string()));
                }
            }
        }
        out.sort_by(|a, b| a.0.cmp(&b.0));
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn naming_scheme() {
        assert_eq!(
            ArtifactId::proj_acc(64, 1024, 256).0,
            "proj_acc_b64_d1024_k256"
        );
        assert_eq!(
            ArtifactId::quantize_all(64, 256).file_name(),
            "quantize_all_b64_k256.hlo.txt"
        );
    }

    #[test]
    fn registry_list_and_exists() {
        let tmp = std::env::temp_dir().join(format!("crp_art_test_{}", std::process::id()));
        std::fs::create_dir_all(&tmp).unwrap();
        let reg = ArtifactRegistry::new(&tmp);
        let id = ArtifactId::collision(64, 256);
        assert!(!reg.exists(&id));
        std::fs::write(reg.path_of(&id), "HloModule dummy").unwrap();
        assert!(reg.exists(&id));
        assert!(reg.list().contains(&id));
        std::fs::remove_dir_all(&tmp).ok();
    }

    #[test]
    fn artifacts_dir_env_override() {
        // Uses the env var when present (checked without mutating global
        // env in parallel tests — just verify the default shape).
        let d = artifacts_dir();
        assert!(d.ends_with("artifacts") || std::env::var("CRP_ARTIFACTS").is_ok());
    }
}
