//! PJRT runtime: load and execute the AOT-compiled artifacts.
//!
//! `make artifacts` runs the build-time Python (`python/compile/aot.py`)
//! once, lowering the JAX/Pallas computations to **HLO text** in
//! `artifacts/*.hlo.txt`. This module wraps the `xla` crate to load the
//! text (`HloModuleProto::from_text_file` — the text parser reassigns
//! instruction ids, which is why text, not serialized protos, is the
//! interchange format), compile each module once on the PJRT CPU client,
//! and execute from the Layer-3 hot path. Python never runs at serve time.

pub mod artifact;
pub mod exec;

pub use artifact::{artifacts_dir, ArtifactId, ArtifactRegistry};
pub use exec::PjrtRuntime;
