//! libsvm/svmlight format I/O (`label idx:val idx:val ...`, 1-based
//! indices) — the format the paper's datasets ship in. Lets users run
//! the Section-6 experiments on the real ARCENE/FARM/URL files when
//! available; our synthetic substitutes use the same loader in tests.

use std::io::{BufRead, BufWriter, Write};
use std::path::Path;

use super::sparse::{CsrMatrix, Dataset};

/// Parse a libsvm file. Labels are coerced to ±1 (`> 0 → +1`).
/// `cols` may force a dimensionality (0 = infer from max index).
pub fn read_libsvm(path: impl AsRef<Path>, cols: usize) -> crate::Result<Dataset> {
    let file = std::fs::File::open(path.as_ref())
        .map_err(|e| anyhow::anyhow!("open {:?}: {e}", path.as_ref()))?;
    let reader = std::io::BufReader::new(file);
    parse_libsvm(reader, cols, path.as_ref().display().to_string())
}

/// Parse libsvm-format text from any reader, streaming line by line
/// into the CSR buffers directly (one reused line buffer — no
/// whole-file read, no per-row intermediate vectors).
///
/// Indices on the wire are 1-based (the libsvm convention) and are
/// shifted to 0-based storage here; an explicit `0:` index is rejected
/// rather than silently wrapped. Unsorted or duplicate column indices
/// are rejected with a line-numbered error — silently re-sorting would
/// mask producer bugs and duplicate mass.
pub fn parse_libsvm(mut reader: impl BufRead, cols: usize, name: String) -> crate::Result<Dataset> {
    let mut x = CsrMatrix::with_capacity(0, 0, cols);
    let mut labels = Vec::new();
    let mut max_idx = 0u32;
    let mut buf = String::new();
    let mut lineno = 0usize;
    loop {
        buf.clear();
        if reader.read_line(&mut buf)? == 0 {
            break;
        }
        lineno += 1;
        let line = buf.trim();
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        labels.push(parse_row(line, lineno, &mut x, &mut max_idx)?);
    }
    x.cols = if cols > 0 { cols } else { max_idx as usize + 1 };
    x.validate()?; // e.g. a forced `cols` smaller than an index seen
    let ds = Dataset { x, y: labels, name };
    ds.validate()?;
    Ok(ds)
}

/// Parse one non-blank libsvm line, appending the row to `x` (indices,
/// values, and the closing indptr entry) and widening `max_idx`.
/// Returns the ±1-coerced label. Shared by the whole-file parser above
/// and the chunked streaming reader below so both enforce identical
/// token / ordering / 1-based-index rules.
fn parse_row(
    line: &str,
    lineno: usize,
    x: &mut CsrMatrix,
    max_idx: &mut u32,
) -> crate::Result<f32> {
    let mut parts = line.split_ascii_whitespace();
    let label: f32 = parts
        .next()
        .ok_or_else(|| anyhow::anyhow!("line {lineno}: empty"))?
        .parse()
        .map_err(|e| anyhow::anyhow!("line {lineno}: bad label: {e}"))?;
    let mut prev: Option<u32> = None;
    for tok in parts {
        let (i, v) = tok
            .split_once(':')
            .ok_or_else(|| anyhow::anyhow!("line {lineno}: bad token {tok:?}"))?;
        let i: u32 = i
            .parse()
            .map_err(|e| anyhow::anyhow!("line {lineno}: bad index: {e}"))?;
        anyhow::ensure!(
            i >= 1,
            "line {lineno}: libsvm indices are 1-based (index 0 seen)"
        );
        let i = i - 1;
        if let Some(p) = prev {
            anyhow::ensure!(i != p, "line {lineno}: duplicate column index {}", i + 1);
            anyhow::ensure!(
                i > p,
                "line {lineno}: unsorted column index {} after {}",
                i + 1,
                p + 1
            );
        }
        prev = Some(i);
        let v: f32 = v
            .parse()
            .map_err(|e| anyhow::anyhow!("line {lineno}: bad value: {e}"))?;
        x.indices.push(i);
        x.values.push(v);
        *max_idx = (*max_idx).max(i);
    }
    x.indptr.push(x.indices.len());
    Ok(if label > 0.0 { 1.0 } else { -1.0 })
}

/// Chunked streaming libsvm reader: yields CSR batches of at most
/// `chunk` rows as the file is read, so bulk ingest never materializes
/// the whole dataset — peak memory is one chunk plus the line buffer,
/// regardless of file size.
///
/// When `cols` is 0 each chunk's `cols` is the running max index seen
/// *so far* (monotone across chunks); a forced `cols` pins every chunk
/// and rejects any larger index at the chunk that contains it, exactly
/// like [`parse_libsvm`]. Line numbers in errors are file-absolute.
pub struct LibsvmChunks<R: BufRead> {
    reader: R,
    /// Forced column count (0 = infer from the running max index).
    cols: usize,
    chunk: usize,
    max_idx: u32,
    buf: String,
    lineno: usize,
    done: bool,
}

impl LibsvmChunks<std::io::BufReader<std::fs::File>> {
    /// Open a file for chunked streaming.
    pub fn open(path: impl AsRef<Path>, cols: usize, chunk: usize) -> crate::Result<Self> {
        let file = std::fs::File::open(path.as_ref())
            .map_err(|e| anyhow::anyhow!("open {:?}: {e}", path.as_ref()))?;
        Ok(Self::new(std::io::BufReader::new(file), cols, chunk))
    }
}

impl<R: BufRead> LibsvmChunks<R> {
    pub fn new(reader: R, cols: usize, chunk: usize) -> Self {
        LibsvmChunks {
            reader,
            cols,
            chunk: chunk.max(1),
            max_idx: 0,
            buf: String::new(),
            lineno: 0,
            done: false,
        }
    }

    /// The next batch: up to `chunk` rows as a validated [`CsrMatrix`]
    /// plus their ±1 labels, or `None` at end of input.
    pub fn next_chunk(&mut self) -> crate::Result<Option<(CsrMatrix, Vec<f32>)>> {
        if self.done {
            return Ok(None);
        }
        let mut x = CsrMatrix::with_capacity(self.chunk, 0, self.cols);
        let mut labels = Vec::with_capacity(self.chunk);
        while labels.len() < self.chunk {
            self.buf.clear();
            if self.reader.read_line(&mut self.buf)? == 0 {
                self.done = true;
                break;
            }
            self.lineno += 1;
            let line = self.buf.trim();
            if line.is_empty() || line.starts_with('#') {
                continue;
            }
            let lineno = self.lineno;
            labels.push(parse_row(line, lineno, &mut x, &mut self.max_idx)?);
        }
        if labels.is_empty() {
            return Ok(None);
        }
        x.cols = if self.cols > 0 {
            self.cols
        } else {
            self.max_idx as usize + 1
        };
        x.validate()?;
        Ok(Some((x, labels)))
    }
}

/// Write a dataset in libsvm format.
pub fn write_libsvm(ds: &Dataset, path: impl AsRef<Path>) -> crate::Result<()> {
    let file = std::fs::File::create(path)?;
    let mut w = BufWriter::new(file);
    for r in 0..ds.len() {
        let label = if ds.y[r] > 0.0 { "+1" } else { "-1" };
        write!(w, "{label}")?;
        let (idx, val) = ds.x.row(r);
        for (&i, &v) in idx.iter().zip(val) {
            write!(w, " {}:{}", i + 1, v)?;
        }
        writeln!(w)?;
    }
    w.flush()?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_basic() {
        let text = "+1 1:0.5 3:1.5\n-1 2:2.0\n# comment\n\n+1 1:1.0\n";
        let ds = parse_libsvm(std::io::Cursor::new(text), 0, "t".into()).unwrap();
        assert_eq!(ds.len(), 3);
        assert_eq!(ds.x.cols, 3);
        assert_eq!(ds.y, vec![1.0, -1.0, 1.0]);
        assert_eq!(ds.x.row(0), (&[0u32, 2][..], &[0.5f32, 1.5][..]));
    }

    #[test]
    fn unsorted_indices_rejected_with_line_number() {
        let text = "+1 1:1.0\n+1 5:1.0 2:2.0\n";
        let err = parse_libsvm(std::io::Cursor::new(text), 0, "t".into()).unwrap_err();
        let msg = err.to_string();
        assert!(msg.contains("line 2"), "{msg}");
        assert!(msg.contains("unsorted"), "{msg}");
    }

    #[test]
    fn duplicate_indices_rejected_with_line_number() {
        let text = "+1 1:1.0\n-1 2:1.0 3:0.5 3:0.25\n";
        let err = parse_libsvm(std::io::Cursor::new(text), 0, "t".into()).unwrap_err();
        let msg = err.to_string();
        assert!(msg.contains("line 2"), "{msg}");
        assert!(msg.contains("duplicate"), "{msg}");
    }

    #[test]
    fn rejects_zero_based() {
        let text = "+1 0:1.0\n";
        let err = parse_libsvm(std::io::Cursor::new(text), 0, "t".into()).unwrap_err();
        assert!(err.to_string().contains("1-based"), "{err}");
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse_libsvm(std::io::Cursor::new("+1 abc\n"), 0, "t".into()).is_err());
        assert!(parse_libsvm(std::io::Cursor::new("xyz 1:1\n"), 0, "t".into()).is_err());
        // Malformed tokens with line numbers in the error.
        for (text, needle) in [
            ("+1 1:\n", "line 1"),          // empty value
            ("+1 :1.0\n", "line 1"),        // empty index
            ("+1 1:1\n-1 x:2\n", "line 2"), // non-numeric index
            ("+1 1:1\n-1 2:y\n", "line 2"), // non-numeric value
        ] {
            let err = parse_libsvm(std::io::Cursor::new(text), 0, "t".into()).unwrap_err();
            assert!(err.to_string().contains(needle), "{text:?}: {err}");
        }
    }

    #[test]
    fn forced_cols_smaller_than_seen_index_errors_cleanly() {
        let text = "+1 50:1.0\n";
        assert!(parse_libsvm(std::io::Cursor::new(text), 10, "t".into()).is_err());
    }

    #[test]
    fn roundtrip_through_file() {
        let text = "+1 1:0.25 4:1\n-1 2:3\n";
        let ds = parse_libsvm(std::io::Cursor::new(text), 0, "t".into()).unwrap();
        let path = std::env::temp_dir().join(format!("crp_libsvm_{}.txt", std::process::id()));
        write_libsvm(&ds, &path).unwrap();
        let back = read_libsvm(&path, 0).unwrap();
        assert_eq!(back.len(), ds.len());
        assert_eq!(back.x.indices, ds.x.indices);
        assert_eq!(back.x.values, ds.x.values);
        assert_eq!(back.y, ds.y);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn forced_cols() {
        let text = "+1 1:1.0\n";
        let ds = parse_libsvm(std::io::Cursor::new(text), 100, "t".into()).unwrap();
        assert_eq!(ds.x.cols, 100);
    }

    /// Chunked streaming must agree exactly with the whole-file parse:
    /// concatenated chunk rows = dataset rows, labels included,
    /// comments and blanks skipped without consuming chunk capacity.
    #[test]
    fn chunks_concatenate_to_whole_file_parse() {
        let text = "+1 1:0.5 3:1.5\n# comment\n-1 2:2.0\n\n+1 1:1.0\n-1 4:0.25\n+1 2:0.125\n";
        let whole = parse_libsvm(std::io::Cursor::new(text), 0, "t".into()).unwrap();
        for chunk in [1usize, 2, 3, 100] {
            let mut rd = LibsvmChunks::new(std::io::Cursor::new(text), 0, chunk);
            let mut rows = 0usize;
            let mut labels = Vec::new();
            while let Some((x, y)) = rd.next_chunk().unwrap() {
                assert!(x.rows() <= chunk, "chunk {chunk} overflowed: {}", x.rows());
                assert_eq!(x.rows(), y.len());
                for r in 0..x.rows() {
                    assert_eq!(x.row(r), whole.x.row(rows + r), "row {} chunk {chunk}", rows + r);
                }
                rows += x.rows();
                labels.extend(y);
            }
            assert_eq!(rows, whole.len(), "chunk {chunk}");
            assert_eq!(labels, whole.y, "chunk {chunk}");
            assert!(rd.next_chunk().unwrap().is_none(), "EOF is sticky");
        }
    }

    /// Inferred cols grow monotonically with the running max index;
    /// forced cols pin every chunk.
    #[test]
    fn chunk_cols_track_running_max() {
        let text = "+1 1:1.0\n+1 7:1.0\n+1 3:1.0\n";
        let mut rd = LibsvmChunks::new(std::io::Cursor::new(text), 0, 1);
        assert_eq!(rd.next_chunk().unwrap().unwrap().0.cols, 1);
        assert_eq!(rd.next_chunk().unwrap().unwrap().0.cols, 7);
        // Running max is sticky even though this row only touches col 3.
        assert_eq!(rd.next_chunk().unwrap().unwrap().0.cols, 7);
        assert!(rd.next_chunk().unwrap().is_none());

        let mut rd = LibsvmChunks::new(std::io::Cursor::new(text), 100, 2);
        assert_eq!(rd.next_chunk().unwrap().unwrap().0.cols, 100);
        assert_eq!(rd.next_chunk().unwrap().unwrap().0.cols, 100);
    }

    /// Errors carry file-absolute line numbers and surface at the
    /// chunk containing the bad line — prior chunks are delivered.
    #[test]
    fn chunk_errors_use_absolute_line_numbers() {
        let text = "+1 1:1.0\n+1 2:1.0\n+1 5:1.0 2:2.0\n";
        let mut rd = LibsvmChunks::new(std::io::Cursor::new(text), 0, 2);
        assert_eq!(rd.next_chunk().unwrap().unwrap().0.rows(), 2);
        let err = rd.next_chunk().unwrap_err().to_string();
        assert!(err.contains("line 3"), "{err}");
        assert!(err.contains("unsorted"), "{err}");

        // A forced-cols violation errors at its chunk too.
        let mut rd = LibsvmChunks::new(std::io::Cursor::new("+1 1:1\n+1 50:1\n"), 10, 1);
        assert!(rd.next_chunk().unwrap().is_some());
        assert!(rd.next_chunk().is_err());
    }

    /// Empty input (or all comments) yields no chunks, not an empty one.
    #[test]
    fn empty_input_yields_no_chunks() {
        let mut rd = LibsvmChunks::new(std::io::Cursor::new("# nothing\n\n"), 0, 8);
        assert!(rd.next_chunk().unwrap().is_none());
    }
}
