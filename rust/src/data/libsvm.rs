//! libsvm/svmlight format I/O (`label idx:val idx:val ...`, 1-based
//! indices) — the format the paper's datasets ship in. Lets users run
//! the Section-6 experiments on the real ARCENE/FARM/URL files when
//! available; our synthetic substitutes use the same loader in tests.

use std::io::{BufRead, BufWriter, Write};
use std::path::Path;

use super::sparse::{CsrMatrix, Dataset};

/// Parse a libsvm file. Labels are coerced to ±1 (`> 0 → +1`).
/// `cols` may force a dimensionality (0 = infer from max index).
pub fn read_libsvm(path: impl AsRef<Path>, cols: usize) -> crate::Result<Dataset> {
    let file = std::fs::File::open(path.as_ref())
        .map_err(|e| anyhow::anyhow!("open {:?}: {e}", path.as_ref()))?;
    let reader = std::io::BufReader::new(file);
    parse_libsvm(reader, cols, path.as_ref().display().to_string())
}

/// Parse libsvm-format text from any reader, streaming line by line
/// into the CSR buffers directly (one reused line buffer — no
/// whole-file read, no per-row intermediate vectors).
///
/// Indices on the wire are 1-based (the libsvm convention) and are
/// shifted to 0-based storage here; an explicit `0:` index is rejected
/// rather than silently wrapped. Unsorted or duplicate column indices
/// are rejected with a line-numbered error — silently re-sorting would
/// mask producer bugs and duplicate mass.
pub fn parse_libsvm(mut reader: impl BufRead, cols: usize, name: String) -> crate::Result<Dataset> {
    let mut x = CsrMatrix::with_capacity(0, 0, cols);
    let mut labels = Vec::new();
    let mut max_idx = 0u32;
    let mut buf = String::new();
    let mut lineno = 0usize;
    loop {
        buf.clear();
        if reader.read_line(&mut buf)? == 0 {
            break;
        }
        lineno += 1;
        let line = buf.trim();
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        let mut parts = line.split_ascii_whitespace();
        let label: f32 = parts
            .next()
            .ok_or_else(|| anyhow::anyhow!("line {lineno}: empty"))?
            .parse()
            .map_err(|e| anyhow::anyhow!("line {lineno}: bad label: {e}"))?;
        labels.push(if label > 0.0 { 1.0 } else { -1.0 });
        let mut prev: Option<u32> = None;
        for tok in parts {
            let (i, v) = tok
                .split_once(':')
                .ok_or_else(|| anyhow::anyhow!("line {lineno}: bad token {tok:?}"))?;
            let i: u32 = i
                .parse()
                .map_err(|e| anyhow::anyhow!("line {lineno}: bad index: {e}"))?;
            anyhow::ensure!(
                i >= 1,
                "line {lineno}: libsvm indices are 1-based (index 0 seen)"
            );
            let i = i - 1;
            if let Some(p) = prev {
                anyhow::ensure!(
                    i != p,
                    "line {lineno}: duplicate column index {}",
                    i + 1
                );
                anyhow::ensure!(
                    i > p,
                    "line {lineno}: unsorted column index {} after {}",
                    i + 1,
                    p + 1
                );
            }
            prev = Some(i);
            let v: f32 = v
                .parse()
                .map_err(|e| anyhow::anyhow!("line {lineno}: bad value: {e}"))?;
            x.indices.push(i);
            x.values.push(v);
            max_idx = max_idx.max(i);
        }
        x.indptr.push(x.indices.len());
    }
    x.cols = if cols > 0 { cols } else { max_idx as usize + 1 };
    x.validate()?; // e.g. a forced `cols` smaller than an index seen
    let ds = Dataset { x, y: labels, name };
    ds.validate()?;
    Ok(ds)
}

/// Write a dataset in libsvm format.
pub fn write_libsvm(ds: &Dataset, path: impl AsRef<Path>) -> crate::Result<()> {
    let file = std::fs::File::create(path)?;
    let mut w = BufWriter::new(file);
    for r in 0..ds.len() {
        let label = if ds.y[r] > 0.0 { "+1" } else { "-1" };
        write!(w, "{label}")?;
        let (idx, val) = ds.x.row(r);
        for (&i, &v) in idx.iter().zip(val) {
            write!(w, " {}:{}", i + 1, v)?;
        }
        writeln!(w)?;
    }
    w.flush()?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_basic() {
        let text = "+1 1:0.5 3:1.5\n-1 2:2.0\n# comment\n\n+1 1:1.0\n";
        let ds = parse_libsvm(std::io::Cursor::new(text), 0, "t".into()).unwrap();
        assert_eq!(ds.len(), 3);
        assert_eq!(ds.x.cols, 3);
        assert_eq!(ds.y, vec![1.0, -1.0, 1.0]);
        assert_eq!(ds.x.row(0), (&[0u32, 2][..], &[0.5f32, 1.5][..]));
    }

    #[test]
    fn unsorted_indices_rejected_with_line_number() {
        let text = "+1 1:1.0\n+1 5:1.0 2:2.0\n";
        let err = parse_libsvm(std::io::Cursor::new(text), 0, "t".into()).unwrap_err();
        let msg = err.to_string();
        assert!(msg.contains("line 2"), "{msg}");
        assert!(msg.contains("unsorted"), "{msg}");
    }

    #[test]
    fn duplicate_indices_rejected_with_line_number() {
        let text = "+1 1:1.0\n-1 2:1.0 3:0.5 3:0.25\n";
        let err = parse_libsvm(std::io::Cursor::new(text), 0, "t".into()).unwrap_err();
        let msg = err.to_string();
        assert!(msg.contains("line 2"), "{msg}");
        assert!(msg.contains("duplicate"), "{msg}");
    }

    #[test]
    fn rejects_zero_based() {
        let text = "+1 0:1.0\n";
        let err = parse_libsvm(std::io::Cursor::new(text), 0, "t".into()).unwrap_err();
        assert!(err.to_string().contains("1-based"), "{err}");
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse_libsvm(std::io::Cursor::new("+1 abc\n"), 0, "t".into()).is_err());
        assert!(parse_libsvm(std::io::Cursor::new("xyz 1:1\n"), 0, "t".into()).is_err());
        // Malformed tokens with line numbers in the error.
        for (text, needle) in [
            ("+1 1:\n", "line 1"),          // empty value
            ("+1 :1.0\n", "line 1"),        // empty index
            ("+1 1:1\n-1 x:2\n", "line 2"), // non-numeric index
            ("+1 1:1\n-1 2:y\n", "line 2"), // non-numeric value
        ] {
            let err = parse_libsvm(std::io::Cursor::new(text), 0, "t".into()).unwrap_err();
            assert!(err.to_string().contains(needle), "{text:?}: {err}");
        }
    }

    #[test]
    fn forced_cols_smaller_than_seen_index_errors_cleanly() {
        let text = "+1 50:1.0\n";
        assert!(parse_libsvm(std::io::Cursor::new(text), 10, "t".into()).is_err());
    }

    #[test]
    fn roundtrip_through_file() {
        let text = "+1 1:0.25 4:1\n-1 2:3\n";
        let ds = parse_libsvm(std::io::Cursor::new(text), 0, "t".into()).unwrap();
        let path = std::env::temp_dir().join(format!("crp_libsvm_{}.txt", std::process::id()));
        write_libsvm(&ds, &path).unwrap();
        let back = read_libsvm(&path, 0).unwrap();
        assert_eq!(back.len(), ds.len());
        assert_eq!(back.x.indices, ds.x.indices);
        assert_eq!(back.x.values, ds.x.values);
        assert_eq!(back.y, ds.y);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn forced_cols() {
        let text = "+1 1:1.0\n";
        let ds = parse_libsvm(std::io::Cursor::new(text), 100, "t".into()).unwrap();
        assert_eq!(ds.x.cols, 100);
    }
}
