//! libsvm/svmlight format I/O (`label idx:val idx:val ...`, 1-based
//! indices) — the format the paper's datasets ship in. Lets users run
//! the Section-6 experiments on the real ARCENE/FARM/URL files when
//! available; our synthetic substitutes use the same loader in tests.

use std::io::{BufRead, BufWriter, Write};
use std::path::Path;

use super::sparse::{CsrMatrix, Dataset};

/// Parse a libsvm file. Labels are coerced to ±1 (`> 0 → +1`).
/// `cols` may force a dimensionality (0 = infer from max index).
pub fn read_libsvm(path: impl AsRef<Path>, cols: usize) -> crate::Result<Dataset> {
    let file = std::fs::File::open(path.as_ref())
        .map_err(|e| anyhow::anyhow!("open {:?}: {e}", path.as_ref()))?;
    let reader = std::io::BufReader::new(file);
    parse_libsvm(reader, cols, path.as_ref().display().to_string())
}

/// Parse libsvm-format text from any reader.
pub fn parse_libsvm(reader: impl BufRead, cols: usize, name: String) -> crate::Result<Dataset> {
    let mut rows: Vec<(Vec<u32>, Vec<f32>)> = Vec::new();
    let mut labels = Vec::new();
    let mut max_idx = 0u32;
    for (lineno, line) in reader.lines().enumerate() {
        let line = line?;
        let line = line.trim();
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        let mut parts = line.split_ascii_whitespace();
        let label: f32 = parts
            .next()
            .ok_or_else(|| anyhow::anyhow!("line {}: empty", lineno + 1))?
            .parse()
            .map_err(|e| anyhow::anyhow!("line {}: bad label: {e}", lineno + 1))?;
        labels.push(if label > 0.0 { 1.0 } else { -1.0 });
        let mut idx = Vec::new();
        let mut val = Vec::new();
        for tok in parts {
            let (i, v) = tok
                .split_once(':')
                .ok_or_else(|| anyhow::anyhow!("line {}: bad token {tok:?}", lineno + 1))?;
            let i: u32 = i
                .parse()
                .map_err(|e| anyhow::anyhow!("line {}: bad index: {e}", lineno + 1))?;
            anyhow::ensure!(i >= 1, "line {}: libsvm indices are 1-based", lineno + 1);
            let v: f32 = v
                .parse()
                .map_err(|e| anyhow::anyhow!("line {}: bad value: {e}", lineno + 1))?;
            idx.push(i - 1);
            val.push(v);
        }
        // Sort by index (libsvm files are usually sorted; be tolerant).
        let mut order: Vec<usize> = (0..idx.len()).collect();
        order.sort_by_key(|&p| idx[p]);
        let idx: Vec<u32> = order.iter().map(|&p| idx[p]).collect();
        let val: Vec<f32> = order.iter().map(|&p| val[p]).collect();
        if let Some(&m) = idx.last() {
            max_idx = max_idx.max(m);
        }
        rows.push((idx, val));
    }
    let cols = if cols > 0 {
        cols
    } else {
        max_idx as usize + 1
    };
    let nnz = rows.iter().map(|(i, _)| i.len()).sum();
    let mut x = CsrMatrix::with_capacity(rows.len(), nnz, cols);
    for (idx, val) in &rows {
        x.push_row(idx, val);
    }
    let ds = Dataset { x, y: labels, name };
    ds.validate()?;
    Ok(ds)
}

/// Write a dataset in libsvm format.
pub fn write_libsvm(ds: &Dataset, path: impl AsRef<Path>) -> crate::Result<()> {
    let file = std::fs::File::create(path)?;
    let mut w = BufWriter::new(file);
    for r in 0..ds.len() {
        let label = if ds.y[r] > 0.0 { "+1" } else { "-1" };
        write!(w, "{label}")?;
        let (idx, val) = ds.x.row(r);
        for (&i, &v) in idx.iter().zip(val) {
            write!(w, " {}:{}", i + 1, v)?;
        }
        writeln!(w)?;
    }
    w.flush()?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_basic() {
        let text = "+1 1:0.5 3:1.5\n-1 2:2.0\n# comment\n\n+1 1:1.0\n";
        let ds = parse_libsvm(std::io::Cursor::new(text), 0, "t".into()).unwrap();
        assert_eq!(ds.len(), 3);
        assert_eq!(ds.x.cols, 3);
        assert_eq!(ds.y, vec![1.0, -1.0, 1.0]);
        assert_eq!(ds.x.row(0), (&[0u32, 2][..], &[0.5f32, 1.5][..]));
    }

    #[test]
    fn unsorted_indices_tolerated() {
        let text = "+1 5:1.0 2:2.0\n";
        let ds = parse_libsvm(std::io::Cursor::new(text), 0, "t".into()).unwrap();
        assert_eq!(ds.x.row(0).0, &[1u32, 4][..]);
        assert_eq!(ds.x.row(0).1, &[2.0f32, 1.0][..]);
    }

    #[test]
    fn rejects_zero_based() {
        let text = "+1 0:1.0\n";
        assert!(parse_libsvm(std::io::Cursor::new(text), 0, "t".into()).is_err());
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse_libsvm(std::io::Cursor::new("+1 abc\n"), 0, "t".into()).is_err());
        assert!(parse_libsvm(std::io::Cursor::new("xyz 1:1\n"), 0, "t".into()).is_err());
    }

    #[test]
    fn roundtrip_through_file() {
        let text = "+1 1:0.25 4:1\n-1 2:3\n";
        let ds = parse_libsvm(std::io::Cursor::new(text), 0, "t".into()).unwrap();
        let path = std::env::temp_dir().join(format!("crp_libsvm_{}.txt", std::process::id()));
        write_libsvm(&ds, &path).unwrap();
        let back = read_libsvm(&path, 0).unwrap();
        assert_eq!(back.len(), ds.len());
        assert_eq!(back.x.indices, ds.x.indices);
        assert_eq!(back.x.values, ds.x.values);
        assert_eq!(back.y, ds.y);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn forced_cols() {
        let text = "+1 1:1.0\n";
        let ds = parse_libsvm(std::io::Cursor::new(text), 100, "t".into()).unwrap();
        assert_eq!(ds.x.cols, 100);
    }
}
