//! Controlled-similarity vector pairs for the estimation experiments.
//!
//! The paper's theory is stated for a pair `(u, v)` of unit-norm vectors
//! with inner product ρ (Eq. 2). These samplers construct pairs whose
//! inner product is *exactly* ρ, so Monte-Carlo collision rates can be
//! compared against `P(ρ)` with no data-side slack.

use crate::mathx::NormalSampler;

/// A random unit pair `(u, v)` in `R^d` with `⟨u, v⟩ = ρ` exactly
/// (up to f32 rounding): `v = ρ·u + √(1−ρ²)·u⊥`.
pub fn unit_pair_with_rho(d: usize, rho: f64, seed: u64) -> (Vec<f32>, Vec<f32>) {
    assert!(d >= 2, "need d >= 2 to build an orthogonal direction");
    assert!((-1.0..=1.0).contains(&rho));
    let mut ns = NormalSampler::new(seed, 0xBAD5EED);
    // u: random direction, normalized.
    let mut u: Vec<f64> = (0..d).map(|_| ns.next()).collect();
    normalize(&mut u);
    // g orthogonalized against u, normalized.
    let mut g: Vec<f64> = (0..d).map(|_| ns.next()).collect();
    let dot: f64 = g.iter().zip(&u).map(|(a, b)| a * b).sum();
    for (gi, ui) in g.iter_mut().zip(&u) {
        *gi -= dot * ui;
    }
    normalize(&mut g);
    let c = (1.0 - rho * rho).sqrt();
    let v: Vec<f32> = u
        .iter()
        .zip(&g)
        .map(|(&ui, &gi)| (rho * ui + c * gi) as f32)
        .collect();
    let u: Vec<f32> = u.iter().map(|&x| x as f32).collect();
    (u, v)
}

fn normalize(v: &mut [f64]) {
    let n: f64 = v.iter().map(|x| x * x).sum::<f64>().sqrt();
    assert!(n > 0.0);
    for x in v.iter_mut() {
        *x /= n;
    }
}

/// Correlated standard-normal coordinate pairs `(x_j, y_j)` drawn
/// directly from the bivariate normal of Eq. (2) — the *projected*
/// distribution, bypassing the projection step. Used by the Monte-Carlo
/// variance experiments where only the marginal law matters.
pub fn bivariate_normal_batch(k: usize, rho: f64, seed: u64) -> (Vec<f32>, Vec<f32>) {
    let mut ns = NormalSampler::new(seed, 0xB1AA);
    let c = (1.0 - rho * rho).sqrt();
    let mut x = Vec::with_capacity(k);
    let mut y = Vec::with_capacity(k);
    for _ in 0..k {
        let z1 = ns.next();
        let z2 = ns.next();
        x.push(z1 as f32);
        y.push((rho * z1 + c * z2) as f32);
    }
    (x, y)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn exact_rho_and_unit_norms() {
        for &rho in &[0.0, 0.25, 0.56, 0.9, 0.99, 1.0] {
            let (u, v) = unit_pair_with_rho(128, rho, 7);
            let nu: f64 = u.iter().map(|&x| (x as f64).powi(2)).sum::<f64>().sqrt();
            let nv: f64 = v.iter().map(|&x| (x as f64).powi(2)).sum::<f64>().sqrt();
            let dot: f64 = u.iter().zip(&v).map(|(&a, &b)| (a as f64) * (b as f64)).sum();
            assert!((nu - 1.0).abs() < 1e-5, "‖u‖ = {nu}");
            assert!((nv - 1.0).abs() < 1e-5, "‖v‖ = {nv}");
            assert!((dot - rho).abs() < 1e-5, "ρ = {dot}, want {rho}");
        }
    }

    #[test]
    fn different_seeds_different_pairs() {
        let (u1, _) = unit_pair_with_rho(32, 0.5, 1);
        let (u2, _) = unit_pair_with_rho(32, 0.5, 2);
        assert_ne!(u1, u2);
    }

    #[test]
    fn bivariate_batch_correlation() {
        let k = 200_000;
        let rho = 0.6;
        let (x, y) = bivariate_normal_batch(k, rho, 3);
        let mut sxy = 0.0f64;
        let mut sxx = 0.0f64;
        let mut syy = 0.0f64;
        for (&a, &b) in x.iter().zip(&y) {
            sxy += (a as f64) * (b as f64);
            sxx += (a as f64) * (a as f64);
            syy += (b as f64) * (b as f64);
        }
        let corr = sxy / (sxx.sqrt() * syy.sqrt());
        assert!((corr - rho).abs() < 0.01, "corr {corr}");
    }

    #[test]
    #[should_panic]
    fn d1_rejected() {
        unit_pair_with_rho(1, 0.5, 0);
    }
}
