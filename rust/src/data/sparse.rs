//! Compressed sparse row matrices and labeled datasets.

/// CSR matrix with f32 values and u32 column indices.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct CsrMatrix {
    /// Row start offsets, length `rows + 1`.
    pub indptr: Vec<usize>,
    /// Column indices, sorted within each row.
    pub indices: Vec<u32>,
    /// Values, parallel to `indices`.
    pub values: Vec<f32>,
    /// Number of columns (dimensionality `D`).
    pub cols: usize,
}

impl CsrMatrix {
    pub fn with_capacity(rows: usize, nnz: usize, cols: usize) -> Self {
        let mut indptr = Vec::with_capacity(rows + 1);
        indptr.push(0);
        CsrMatrix {
            indptr,
            indices: Vec::with_capacity(nnz),
            values: Vec::with_capacity(nnz),
            cols,
        }
    }

    pub fn rows(&self) -> usize {
        self.indptr.len() - 1
    }

    pub fn nnz(&self) -> usize {
        self.indices.len()
    }

    /// Append a row given as sorted (indices, values).
    pub fn push_row(&mut self, idx: &[u32], val: &[f32]) {
        assert_eq!(idx.len(), val.len());
        debug_assert!(idx.windows(2).all(|w| w[0] < w[1]), "indices must be sorted");
        if let Some(&last) = idx.last() {
            assert!((last as usize) < self.cols, "index {last} >= cols {}", self.cols);
        }
        self.indices.extend_from_slice(idx);
        self.values.extend_from_slice(val);
        self.indptr.push(self.indices.len());
    }

    /// Row view as (indices, values).
    pub fn row(&self, r: usize) -> (&[u32], &[f32]) {
        let (s, e) = (self.indptr[r], self.indptr[r + 1]);
        (&self.indices[s..e], &self.values[s..e])
    }

    /// L2 norm of a row.
    pub fn row_norm(&self, r: usize) -> f32 {
        let (_, v) = self.row(r);
        v.iter().map(|x| x * x).sum::<f32>().sqrt()
    }

    /// Normalize every row to unit L2 norm (the paper's standing
    /// assumption ‖u‖ = 1; zero rows are left as-is).
    pub fn normalize_rows(&mut self) {
        for r in 0..self.rows() {
            let n = self.row_norm(r);
            if n > 0.0 {
                let (s, e) = (self.indptr[r], self.indptr[r + 1]);
                for v in &mut self.values[s..e] {
                    *v /= n;
                }
            }
        }
    }

    /// Dense inner product of two rows (both index-sorted).
    pub fn row_dot(&self, a: usize, b: usize) -> f64 {
        let (ia, va) = self.row(a);
        let (ib, vb) = self.row(b);
        let mut dot = 0.0f64;
        let (mut p, mut q) = (0usize, 0usize);
        while p < ia.len() && q < ib.len() {
            match ia[p].cmp(&ib[q]) {
                std::cmp::Ordering::Less => p += 1,
                std::cmp::Ordering::Greater => q += 1,
                std::cmp::Ordering::Equal => {
                    dot += (va[p] * vb[q]) as f64;
                    p += 1;
                    q += 1;
                }
            }
        }
        dot
    }

    /// Structural consistency check: indptr non-empty, starts at 0,
    /// monotone, and ends at the nnz count; indices/values parallel;
    /// per-row indices strictly increasing and below `cols`. Called at
    /// every protocol decode boundary so a crafted frame errors cleanly
    /// instead of panicking on slice indexing downstream.
    pub fn validate(&self) -> crate::Result<()> {
        anyhow::ensure!(!self.indptr.is_empty(), "indptr must hold at least [0]");
        anyhow::ensure!(self.indptr[0] == 0, "indptr must start at 0");
        anyhow::ensure!(
            self.indptr.windows(2).all(|w| w[0] <= w[1]),
            "indptr must be monotone non-decreasing"
        );
        anyhow::ensure!(
            *self.indptr.last().unwrap() == self.indices.len(),
            "indptr end {} != nnz {}",
            self.indptr.last().unwrap(),
            self.indices.len()
        );
        anyhow::ensure!(
            self.indices.len() == self.values.len(),
            "indices {} != values {}",
            self.indices.len(),
            self.values.len()
        );
        for r in 0..self.rows() {
            let idx = &self.indices[self.indptr[r]..self.indptr[r + 1]];
            anyhow::ensure!(
                idx.windows(2).all(|w| w[0] < w[1]),
                "row {r}: indices must be strictly increasing"
            );
            if let Some(&last) = idx.last() {
                anyhow::ensure!(
                    (last as usize) < self.cols,
                    "row {r}: index {last} >= cols {}",
                    self.cols
                );
            }
        }
        Ok(())
    }

    /// Materialize a row densely (for the dense projection path).
    pub fn row_dense(&self, r: usize) -> Vec<f32> {
        let mut out = vec![0.0f32; self.cols];
        let (idx, val) = self.row(r);
        for (&i, &v) in idx.iter().zip(val) {
            out[i as usize] = v;
        }
        out
    }
}

/// A labeled dataset: features + ±1 labels.
#[derive(Clone, Debug, Default)]
pub struct Dataset {
    pub x: CsrMatrix,
    pub y: Vec<f32>,
    pub name: String,
}

impl Dataset {
    pub fn len(&self) -> usize {
        self.y.len()
    }

    pub fn is_empty(&self) -> bool {
        self.y.is_empty()
    }

    /// Consistency check: label count matches row count, labels are ±1.
    pub fn validate(&self) -> crate::Result<()> {
        anyhow::ensure!(
            self.x.rows() == self.y.len(),
            "rows {} != labels {}",
            self.x.rows(),
            self.y.len()
        );
        anyhow::ensure!(
            self.y.iter().all(|&l| l == 1.0 || l == -1.0),
            "labels must be ±1"
        );
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> CsrMatrix {
        let mut m = CsrMatrix::with_capacity(3, 6, 10);
        m.push_row(&[0, 3, 7], &[1.0, 2.0, 2.0]);
        m.push_row(&[3, 9], &[3.0, 4.0]);
        m.push_row(&[], &[]);
        m
    }

    #[test]
    fn shape_and_rows() {
        let m = sample();
        assert_eq!(m.rows(), 3);
        assert_eq!(m.nnz(), 5);
        assert_eq!(m.row(0), (&[0u32, 3, 7][..], &[1.0f32, 2.0, 2.0][..]));
        assert_eq!(m.row(2).0.len(), 0);
    }

    #[test]
    fn norms_and_normalization() {
        let mut m = sample();
        assert!((m.row_norm(0) - 3.0).abs() < 1e-6);
        m.normalize_rows();
        assert!((m.row_norm(0) - 1.0).abs() < 1e-6);
        assert!((m.row_norm(1) - 1.0).abs() < 1e-6);
        assert_eq!(m.row_norm(2), 0.0); // zero row untouched
    }

    #[test]
    fn dot_product_sparse() {
        let m = sample();
        // rows 0 and 1 share only index 3: 2.0 * 3.0 = 6.
        assert!((m.row_dot(0, 1) - 6.0).abs() < 1e-9);
        assert_eq!(m.row_dot(0, 2), 0.0);
    }

    #[test]
    fn row_dense_roundtrip() {
        let m = sample();
        let d = m.row_dense(0);
        assert_eq!(d.len(), 10);
        assert_eq!(d[0], 1.0);
        assert_eq!(d[3], 2.0);
        assert_eq!(d[7], 2.0);
        assert_eq!(d[1], 0.0);
    }

    #[test]
    #[should_panic(expected = "index")]
    fn out_of_range_index_rejected() {
        let mut m = CsrMatrix::with_capacity(1, 1, 5);
        m.push_row(&[5], &[1.0]);
    }

    #[test]
    fn validate_accepts_well_formed_and_empty() {
        sample().validate().unwrap();
        CsrMatrix::with_capacity(0, 0, 10).validate().unwrap();
    }

    #[test]
    fn validate_rejects_each_inconsistency() {
        let good = sample();
        // Empty indptr (what a zeroed/default decode would produce).
        let m = CsrMatrix {
            indptr: vec![],
            ..good.clone()
        };
        assert!(m.validate().is_err());
        // indptr not starting at 0.
        let mut m = good.clone();
        m.indptr[0] = 1;
        assert!(m.validate().is_err());
        // Non-monotone indptr.
        let mut m = good.clone();
        m.indptr[1] = 4;
        m.indptr[2] = 2;
        assert!(m.validate().is_err());
        // indptr end disagreeing with nnz.
        let mut m = good.clone();
        *m.indptr.last_mut().unwrap() = 99;
        assert!(m.validate().is_err());
        // indices/values length mismatch.
        let mut m = good.clone();
        m.values.pop();
        assert!(m.validate().is_err());
        // Unsorted / duplicate indices within a row.
        let mut m = good.clone();
        m.indices[1] = 0;
        assert!(m.validate().is_err());
        // Column index out of range.
        let mut m = good.clone();
        m.indices[4] = 10;
        assert!(m.validate().is_err());
    }

    #[test]
    fn dataset_validation() {
        let mut ds = Dataset {
            x: sample(),
            y: vec![1.0, -1.0, 1.0],
            name: "t".into(),
        };
        ds.validate().unwrap();
        ds.y[0] = 0.5;
        assert!(ds.validate().is_err());
        ds.y = vec![1.0, -1.0];
        assert!(ds.validate().is_err());
    }
}
