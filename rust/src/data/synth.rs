//! Synthetic corpora calibrated to the paper's three evaluation datasets.
//!
//! The real ARCENE/FARM/URL files are UCI downloads unavailable offline;
//! these generators reproduce the *statistical shape* that drives the
//! Section-6 experiments: dimensionality regime, sparsity, feature-
//! frequency skew, and a sparse linear decision boundary with margin
//! noise. What the experiments measure is how quantized projections
//! degrade a linear separator — a function of the ρ-structure and margin
//! the generator controls, not of feature provenance (DESIGN.md §4).
//!
//! | kind        | paper dataset | rows (tr/te) | D          | nnz/row |
//! |-------------|---------------|--------------|------------|---------|
//! | `UrlLike`   | URL day-0     | 10000/10000  | 3.2M → 10^5| ~115    |
//! | `FarmLike`  | FARM ads      | 2059/2084    | 54877      | ~100    |
//! | `ArceneLike`| ARCENE        | 100/100      | 10^4 dense | 10^4    |

use super::sparse::{CsrMatrix, Dataset};
use crate::mathx::{NormalSampler, Pcg64};

/// Which corpus shape to generate.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SynthKind {
    UrlLike,
    FarmLike,
    ArceneLike,
}

impl SynthKind {
    pub fn label(self) -> &'static str {
        match self {
            SynthKind::UrlLike => "URL-like",
            SynthKind::FarmLike => "FARM-like",
            SynthKind::ArceneLike => "ARCENE-like",
        }
    }
}

/// Generation spec.
#[derive(Clone, Debug)]
pub struct SynthSpec {
    pub kind: SynthKind,
    pub train_n: usize,
    pub test_n: usize,
    pub dim: usize,
    /// Mean nonzeros per row (ignored by `ArceneLike`, which is dense).
    pub avg_nnz: usize,
    /// Number of class-informative features.
    pub n_informative: usize,
    /// Label-flip noise rate.
    pub label_noise: f64,
    pub seed: u64,
}

impl SynthSpec {
    /// Paper-scale shapes (D reduced for URL: the projection only sees
    /// rows of R that nonzeros touch, so D beyond ~10⁵ adds nothing but
    /// index width).
    pub fn paper(kind: SynthKind) -> Self {
        match kind {
            SynthKind::UrlLike => SynthSpec {
                kind,
                train_n: 10_000,
                test_n: 10_000,
                dim: 100_000,
                avg_nnz: 115,
                n_informative: 4_000,
                label_noise: 0.03,
                seed: 20140601,
            },
            SynthKind::FarmLike => SynthSpec {
                kind,
                train_n: 2_059,
                test_n: 2_084,
                dim: 54_877,
                avg_nnz: 100,
                n_informative: 3_000,
                label_noise: 0.05,
                seed: 20140602,
            },
            SynthKind::ArceneLike => SynthSpec {
                kind,
                train_n: 100,
                test_n: 100,
                dim: 10_000,
                avg_nnz: 10_000,
                n_informative: 700,
                label_noise: 0.05,
                seed: 20140603,
            },
        }
    }

    /// Scaled-down shape for unit/integration tests.
    pub fn small(kind: SynthKind) -> Self {
        let mut s = Self::paper(kind);
        s.train_n = (s.train_n / 20).max(60);
        s.test_n = (s.test_n / 20).max(60);
        s.dim = (s.dim / 50).max(200);
        s.n_informative = (s.n_informative / 50).max(40);
        if s.kind == SynthKind::ArceneLike {
            s.avg_nnz = s.dim;
        } else {
            s.avg_nnz = s.avg_nnz.min(s.dim / 4).max(8);
        }
        s
    }

    /// Generate `(train, test)` datasets.
    pub fn generate(&self) -> (Dataset, Dataset) {
        let train = self.generate_split(self.train_n, 1);
        let test = self.generate_split(self.test_n, 2);
        (train, test)
    }

    fn generate_split(&self, n: usize, split_stream: u64) -> Dataset {
        match self.kind {
            SynthKind::ArceneLike => self.generate_dense(n, split_stream),
            _ => self.generate_sparse(n, split_stream),
        }
    }

    /// Per-feature class weights `s_f ∈ [-1, 1]` for informative features
    /// (deterministic in the seed; shared between splits).
    fn feature_signs(&self) -> Vec<f32> {
        let mut rng = Pcg64::new(self.seed, 0x51_61);
        (0..self.n_informative)
            .map(|_| (rng.next_f64() * 2.0 - 1.0) as f32)
            .collect()
    }

    fn generate_sparse(&self, n: usize, split_stream: u64) -> Dataset {
        let signs = self.feature_signs();
        let mut rng = Pcg64::new(self.seed, 0x1000 + split_stream);
        let mut ns = NormalSampler::new(self.seed, 0x2000 + split_stream);
        let mut x = CsrMatrix::with_capacity(n, n * self.avg_nnz, self.dim);
        let mut y = Vec::with_capacity(n);
        // Power-law feature sampler: f = floor(dim * u^alpha) concentrates
        // mass on small indices, mimicking token-frequency skew.
        const ALPHA: f64 = 2.2;
        for _ in 0..n {
            let label: f32 = if rng.next_f64() < 0.5 { 1.0 } else { -1.0 };
            // Row length: geometric-ish around avg_nnz.
            let nnz = ((self.avg_nnz as f64) * (0.5 + rng.next_f64())) as usize;
            let nnz = nnz.clamp(4, self.dim / 2);
            let mut feats: Vec<u32> = Vec::with_capacity(nnz);
            let mut margin = 0.0f32;
            let mut guard = 0;
            while feats.len() < nnz && guard < nnz * 50 {
                guard += 1;
                let f = (self.dim as f64 * rng.next_f64().powf(ALPHA)) as u32;
                let f = f.min(self.dim as u32 - 1);
                if feats.contains(&f) {
                    continue;
                }
                // Class-conditional acceptance for informative features:
                // feature f is more likely in the class matching sign(s_f).
                if (f as usize) < self.n_informative {
                    let s = signs[f as usize];
                    let p_accept = 0.5 + 0.45 * (label * s) as f64;
                    if rng.next_f64() > p_accept {
                        continue;
                    }
                    margin += label * s;
                }
                feats.push(f);
            }
            feats.sort_unstable();
            feats.dedup();
            // Informative features carry ~2.5x the mass of background
            // tokens (tf-idf-like upweighting of discriminative terms) so
            // the class direction survives projection to moderate k.
            let vals: Vec<f32> = feats
                .iter()
                .map(|&f| {
                    let base = 1.0 + (ns.next().abs() * 0.5) as f32;
                    if (f as usize) < self.n_informative {
                        base * 2.5
                    } else {
                        base
                    }
                })
                .collect();
            // Flip label by noise (margin already baked into features).
            let noisy = if rng.next_f64() < self.label_noise {
                -label
            } else {
                label
            };
            let _ = margin;
            x.push_row(&feats, &vals);
            y.push(noisy);
        }
        x.normalize_rows();
        let ds = Dataset {
            x,
            y,
            name: format!("{}-synth", self.kind.label()),
        };
        ds.validate().expect("generator produced invalid dataset");
        ds
    }

    fn generate_dense(&self, n: usize, split_stream: u64) -> Dataset {
        let signs = self.feature_signs();
        let mut rng = Pcg64::new(self.seed, 0x1000 + split_stream);
        let mut ns = NormalSampler::new(self.seed, 0x2000 + split_stream);
        let mut x = CsrMatrix::with_capacity(n, n * self.dim, self.dim);
        let mut y = Vec::with_capacity(n);
        // Strong per-feature shift: ARCENE is a small-n dataset where the
        // paper still reaches ~70-85% accuracy after coding; the class
        // signal must survive unit normalization over `dim` features and
        // quantized projection to k ~ 10^2.
        let shift = 1.0f32;
        let idx: Vec<u32> = (0..self.dim as u32).collect();
        for _ in 0..n {
            let label: f32 = if rng.next_f64() < 0.5 { 1.0 } else { -1.0 };
            let vals: Vec<f32> = (0..self.dim)
                .map(|f| {
                    // Heavy-tailed positive intensities (|N|^1.5), with a
                    // class-dependent mean shift on informative features.
                    let base = ns.next().abs().powf(1.5) as f32;
                    if f < self.n_informative {
                        (base + shift * label * signs[f]).max(0.0)
                    } else {
                        base
                    }
                })
                .collect();
            let noisy = if rng.next_f64() < self.label_noise {
                -label
            } else {
                label
            };
            x.push_row(&idx, &vals);
            y.push(noisy);
        }
        x.normalize_rows();
        let ds = Dataset {
            x,
            y,
            name: format!("{}-synth", self.kind.label()),
        };
        ds.validate().expect("generator produced invalid dataset");
        ds
    }
}

/// Synthetic ANN corpus in *projection space*: the paper's model has
/// projected coordinates iid N(0,1), so rows are sampled directly as
/// `k` Gaussian values and encoded with `params`. For each of
/// `queries` base vectors, `planted` neighbors at similarity `rho`
/// (`rho·base + √(1−ρ²)·noise`) are hidden among the first rows; the
/// remainder up to `n` are independent. Returns `(rows, queries)` with
/// the query being each base itself — the exact top-k for query `i` is
/// then dominated by its planted neighbors, which is what a recall
/// measurement against the exact scanner needs. Shared by the ANN
/// acceptance tests, `scan_bench`, and `crp topk --approx`.
pub fn planted_code_corpus(
    params: &crate::coding::CodingParams,
    k: usize,
    n: usize,
    queries: usize,
    planted: usize,
    rho: f64,
    seed: u64,
) -> (Vec<crate::coding::PackedCodes>, Vec<crate::coding::PackedCodes>) {
    assert!(queries * planted <= n, "planted rows exceed the corpus");
    let bits = params.bits_per_code();
    let encode = |v: &[f32]| crate::coding::pack_codes(&params.encode(v), bits);
    let mut ns = NormalSampler::new(seed, 2);
    let c = (1.0 - rho * rho).sqrt();
    let mut buf = vec![0f32; k];
    let mut rows = Vec::with_capacity(n);
    let mut qs = Vec::with_capacity(queries);
    for _ in 0..queries {
        ns.fill_f32(&mut buf);
        for _ in 0..planted {
            let nb: Vec<f32> = buf
                .iter()
                .map(|&x| (rho * x as f64 + c * ns.next()) as f32)
                .collect();
            rows.push(encode(&nb));
        }
        qs.push(encode(&buf));
    }
    while rows.len() < n {
        ns.fill_f32(&mut buf);
        rows.push(encode(&buf));
    }
    (rows, qs)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn planted_corpus_shapes_and_similarity() {
        let params = crate::coding::CodingParams::new(crate::coding::Scheme::TwoBit, 0.75);
        let (rows, qs) = planted_code_corpus(&params, 64, 500, 4, 3, 0.95, 9);
        assert_eq!(rows.len(), 500);
        assert_eq!(qs.len(), 4);
        // A query's planted neighbors collide far above the random
        // baseline (~0.25 per code for 2-bit at rho = 0).
        for (qi, q) in qs.iter().enumerate() {
            for p in 0..3 {
                let c = crate::coding::collision_count_packed(q, &rows[qi * 3 + p]);
                assert!(c > 32, "query {qi} planted {p}: {c}/64");
            }
            let far = crate::coding::collision_count_packed(q, &rows[499]);
            assert!(far < 32, "random row colliding {far}/64");
        }
    }

    #[test]
    fn shapes_match_spec() {
        let spec = SynthSpec::small(SynthKind::FarmLike);
        let (tr, te) = spec.generate();
        assert_eq!(tr.len(), spec.train_n);
        assert_eq!(te.len(), spec.test_n);
        assert_eq!(tr.x.cols, spec.dim);
        tr.validate().unwrap();
        te.validate().unwrap();
    }

    #[test]
    fn rows_unit_norm() {
        let (tr, _) = SynthSpec::small(SynthKind::UrlLike).generate();
        for r in 0..tr.len() {
            let n = tr.x.row_norm(r);
            assert!((n - 1.0).abs() < 1e-4, "row {r} norm {n}");
        }
    }

    #[test]
    fn sparse_kinds_are_sparse_dense_kind_is_dense() {
        let (tr, _) = SynthSpec::small(SynthKind::UrlLike).generate();
        let avg = tr.x.nnz() as f64 / tr.len() as f64;
        assert!(avg < tr.x.cols as f64 * 0.2, "URL-like too dense: {avg}");
        let (tr, _) = SynthSpec::small(SynthKind::ArceneLike).generate();
        assert_eq!(tr.x.nnz(), tr.len() * tr.x.cols);
    }

    #[test]
    fn deterministic_in_seed() {
        let spec = SynthSpec::small(SynthKind::FarmLike);
        let (a, _) = spec.generate();
        let (b, _) = spec.generate();
        assert_eq!(a.x.indices, b.x.indices);
        assert_eq!(a.x.values, b.x.values);
        assert_eq!(a.y, b.y);
        let mut spec2 = spec.clone();
        spec2.seed += 1;
        let (c, _) = spec2.generate();
        assert_ne!(a.x.indices, c.x.indices);
    }

    #[test]
    fn classes_roughly_balanced() {
        let (tr, _) = SynthSpec::small(SynthKind::UrlLike).generate();
        let pos = tr.y.iter().filter(|&&l| l > 0.0).count();
        let frac = pos as f64 / tr.len() as f64;
        assert!((0.3..0.7).contains(&frac), "class balance {frac}");
    }

    #[test]
    fn linearly_separable_signal_exists() {
        // A trivial prototype classifier (mean difference direction) must
        // beat chance clearly — otherwise the SVM experiments measure
        // nothing but noise.
        let (tr, te) = SynthSpec::small(SynthKind::FarmLike).generate();
        let d = tr.x.cols;
        let mut wpos = vec![0.0f64; d];
        let mut wneg = vec![0.0f64; d];
        let (mut npos, mut nneg) = (0.0f64, 0.0f64);
        for r in 0..tr.len() {
            let (idx, val) = tr.x.row(r);
            let (wv, n) = if tr.y[r] > 0.0 {
                npos += 1.0;
                (&mut wpos, ())
            } else {
                nneg += 1.0;
                (&mut wneg, ())
            };
            let _ = n;
            for (&i, &v) in idx.iter().zip(val) {
                wv[i as usize] += v as f64;
            }
        }
        let w: Vec<f64> = wpos
            .iter()
            .zip(&wneg)
            .map(|(p, q)| p / npos.max(1.0) - q / nneg.max(1.0))
            .collect();
        let mut correct = 0usize;
        for r in 0..te.len() {
            let (idx, val) = te.x.row(r);
            let score: f64 = idx
                .iter()
                .zip(val)
                .map(|(&i, &v)| w[i as usize] * v as f64)
                .sum();
            if (score > 0.0) == (te.y[r] > 0.0) {
                correct += 1;
            }
        }
        let acc = correct as f64 / te.len() as f64;
        assert!(acc > 0.7, "prototype accuracy only {acc}");
    }
}
