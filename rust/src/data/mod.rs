//! Datasets: sparse matrices, libsvm-format I/O, synthetic corpora
//! calibrated to the paper's three evaluation datasets, and controlled
//! similarity-pair samplers for the estimation experiments.
//!
//! The paper evaluates on *ARCENE* (100×10000, dense-ish), *FARM*
//! (2059×54877, sparse text) and *URL* day-0 (10000×3231961, extremely
//! sparse) from UCI. Those downloads are not available offline, so
//! [`synth`] generates corpora with the same statistical shape (see
//! DESIGN.md §4 for the substitution argument); [`libsvm`] can load the
//! real files if the user drops them in.

pub mod sparse;
pub mod libsvm;
pub mod synth;
pub mod pairs;

pub use sparse::{CsrMatrix, Dataset};
pub use synth::{planted_code_corpus, SynthSpec, SynthKind};
