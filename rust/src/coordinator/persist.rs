//! Sketch-store persistence: snapshot the packed codes to disk and
//! restore them on restart. Sketches are tiny (2 bits/projection), so a
//! full-store snapshot is cheap; the format is a versioned binary file:
//!
//! ```text
//! magic "CRPSNAP1" | u32 k | u32 bits | u64 count |
//!   repeated: u32 id_len | id bytes | u32 n_words | u64 words...
//! ```
//!
//! All sketches in one store share `(k, bits)` — enforced on save.

use std::io::{BufReader, BufWriter, Read, Write};
use std::path::Path;

use crate::coding::{pack_codes, PackedCodes};
use crate::coordinator::store::SketchStore;

const MAGIC: &[u8; 8] = b"CRPSNAP1";

/// Write a snapshot of every sketch in the store.
pub fn save_store(store: &SketchStore, path: impl AsRef<Path>) -> crate::Result<u64> {
    let mut entries: Vec<(String, PackedCodes)> = Vec::new();
    store.for_each(|id, codes| entries.push((id.to_string(), codes.clone())));
    entries.sort_by(|a, b| a.0.cmp(&b.0));
    let (k, bits) = match entries.first() {
        Some((_, c)) => (c.len as u32, c.bits),
        None => (0, 0),
    };
    for (id, c) in &entries {
        anyhow::ensure!(
            c.len as u32 == k && c.bits == bits,
            "heterogeneous sketch shapes in store (id {id:?})"
        );
    }
    let f = std::fs::File::create(path)?;
    let mut w = BufWriter::new(f);
    w.write_all(MAGIC)?;
    w.write_all(&k.to_le_bytes())?;
    w.write_all(&bits.to_le_bytes())?;
    w.write_all(&(entries.len() as u64).to_le_bytes())?;
    for (id, codes) in &entries {
        w.write_all(&(id.len() as u32).to_le_bytes())?;
        w.write_all(id.as_bytes())?;
        let words = codes.words();
        w.write_all(&(words.len() as u32).to_le_bytes())?;
        for word in words {
            w.write_all(&word.to_le_bytes())?;
        }
    }
    w.flush()?;
    Ok(entries.len() as u64)
}

/// Load a snapshot into a fresh store. Returns `(store, k, bits)`.
pub fn load_store(path: impl AsRef<Path>) -> crate::Result<(SketchStore, usize, u32)> {
    let f = std::fs::File::open(path)?;
    let mut r = BufReader::new(f);
    let mut magic = [0u8; 8];
    r.read_exact(&mut magic)?;
    anyhow::ensure!(&magic == MAGIC, "not a CRP snapshot");
    let mut b4 = [0u8; 4];
    let mut b8 = [0u8; 8];
    r.read_exact(&mut b4)?;
    let k = u32::from_le_bytes(b4) as usize;
    r.read_exact(&mut b4)?;
    let bits = u32::from_le_bytes(b4);
    r.read_exact(&mut b8)?;
    let count = u64::from_le_bytes(b8);
    anyhow::ensure!(count < 1 << 40, "implausible snapshot count");
    let store = SketchStore::new();
    for _ in 0..count {
        r.read_exact(&mut b4)?;
        let id_len = u32::from_le_bytes(b4) as usize;
        anyhow::ensure!(id_len <= 1 << 20, "implausible id length");
        let mut id = vec![0u8; id_len];
        r.read_exact(&mut id)?;
        let id = String::from_utf8(id)?;
        r.read_exact(&mut b4)?;
        let n_words = u32::from_le_bytes(b4) as usize;
        anyhow::ensure!(n_words <= 1 << 26, "implausible word count");
        let mut codes_words = vec![0u64; n_words];
        for wslot in codes_words.iter_mut() {
            r.read_exact(&mut b8)?;
            *wslot = u64::from_le_bytes(b8);
        }
        // Reconstruct through unpack/pack so PackedCodes' internal
        // invariants stay owned by the packing module.
        let codes = unpack_words(bits, k, &codes_words);
        store.put(id, pack_codes(&codes, bits));
    }
    Ok((store, k, bits))
}

fn unpack_words(bits: u32, len: usize, words: &[u64]) -> Vec<u16> {
    let per_word = (64 / bits) as usize;
    let mask = (1u64 << bits) - 1;
    (0..len)
        .map(|i| ((words[i / per_word] >> ((i % per_word) as u32 * bits)) & mask) as u16)
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mathx::Pcg64;

    fn filled_store(n: usize, k: usize) -> SketchStore {
        let store = SketchStore::new();
        let mut g = Pcg64::new(5, 0);
        for i in 0..n {
            let codes: Vec<u16> = (0..k).map(|_| g.next_below(4) as u16).collect();
            store.put(format!("vec-{i}"), pack_codes(&codes, 2));
        }
        store
    }

    #[test]
    fn snapshot_roundtrip() {
        let store = filled_store(50, 256);
        let path = std::env::temp_dir().join(format!("crp_snap_{}.bin", std::process::id()));
        let n = save_store(&store, &path).unwrap();
        assert_eq!(n, 50);
        let (back, k, bits) = load_store(&path).unwrap();
        std::fs::remove_file(&path).ok();
        assert_eq!(k, 256);
        assert_eq!(bits, 2);
        assert_eq!(back.len(), 50);
        for i in 0..50 {
            let id = format!("vec-{i}");
            assert_eq!(back.get(&id), store.get(&id), "{id}");
        }
    }

    #[test]
    fn empty_store_roundtrip() {
        let store = SketchStore::new();
        let path = std::env::temp_dir().join(format!("crp_snap_e_{}.bin", std::process::id()));
        save_store(&store, &path).unwrap();
        let (back, _, _) = load_store(&path).unwrap();
        std::fs::remove_file(&path).ok();
        assert!(back.is_empty());
    }

    #[test]
    fn corrupt_file_rejected() {
        let path = std::env::temp_dir().join(format!("crp_snap_c_{}.bin", std::process::id()));
        std::fs::write(&path, b"garbage data").unwrap();
        assert!(load_store(&path).is_err());
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn heterogeneous_store_rejected_on_save() {
        let store = SketchStore::new();
        store.put("a".into(), pack_codes(&[1, 2, 3], 2));
        store.put("b".into(), pack_codes(&[1, 2], 2)); // different k
        let path = std::env::temp_dir().join(format!("crp_snap_h_{}.bin", std::process::id()));
        assert!(save_store(&store, &path).is_err());
        std::fs::remove_file(&path).ok();
    }
}
