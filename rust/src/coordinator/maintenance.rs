//! Background maintenance: periodic epoch drains (and the tombstone
//! compaction that rides on them), auto checkpoints, and the graceful-
//! shutdown flush — taken off the threshold-crossing writer and
//! multiplexed across every collection in the registry.
//!
//! Before this thread existed, the register that crossed the drain
//! threshold paid for the fold itself (ROADMAP PR-2 follow-up). With a
//! [`Maintenance`] attached, every collection store's writers only
//! *notify* the registry's one shared [`DrainSignal`] on threshold
//! crossings and fold inline solely past the relief cap
//! ([`crate::scan::epoch::RELIEF_FACTOR`]× the threshold), the hard
//! bound on pending growth if this thread stalls. Each wake-up sweeps
//! the current collection set, so collections created at runtime are
//! picked up automatically and dropped ones are skipped.

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::Duration;

use crate::coordinator::metrics::Metrics;
use crate::coordinator::obs;
use crate::coordinator::registry::{Collection, Registry};
use crate::coordinator::store::DrainSignal;

/// Cadence knobs for the maintenance thread.
#[derive(Clone, Debug)]
pub struct MaintenanceConfig {
    /// Idle wake-up interval; drain notifications wake it sooner.
    pub tick: Duration,
}

impl Default for MaintenanceConfig {
    fn default() -> Self {
        MaintenanceConfig {
            tick: Duration::from_millis(200),
        }
    }
}

/// One sweep over a collection: fold its backlog if due, checkpoint if
/// due. Dropped collections are skipped so a stale handle can never
/// resurrect files in a directory its replacement owns.
fn sweep(c: &Collection, final_flush: bool) {
    if c.is_dropped() {
        return;
    }
    if let Some(arena) = c.store.arena() {
        if final_flush || arena.drain_due() {
            arena.drain();
        }
    }
    if let Some(d) = &c.durability {
        // Group-commit backstop: an idle WAL tail must not stay
        // un-fdatasync'd past its interval just because no later
        // append came along to carry the sync.
        if let Err(e) = d.sync_wal_due() {
            obs::log::warn(
                "crp::maintenance",
                "wal sync failed",
                &[("collection", c.name.clone()), ("error", e.to_string())],
            );
        }
        if final_flush || d.checkpoint_due() {
            if let Err(e) = d.checkpoint(&c.store) {
                obs::log::error(
                    "crp::maintenance",
                    "checkpoint failed",
                    &[("collection", c.name.clone()), ("error", e.to_string())],
                );
            }
        }
    }
}

/// Handle to the background maintenance thread. Dropping it performs a
/// graceful shutdown: a final drain and checkpoint of every collection,
/// then a join.
pub struct Maintenance {
    stop: Arc<AtomicBool>,
    signal: Arc<DrainSignal>,
    handle: Option<std::thread::JoinHandle<()>>,
}

impl Maintenance {
    /// Spawn the thread with fold/checkpoint duty over every collection
    /// in `registry` (their stores already notify the registry's shared
    /// signal; see [`crate::coordinator::registry`]).
    pub fn spawn(
        registry: Arc<Registry>,
        metrics: Arc<Metrics>,
        cfg: MaintenanceConfig,
    ) -> Maintenance {
        let stop = Arc::new(AtomicBool::new(false));
        let signal = registry.signal();
        let handle = {
            let (stop, signal) = (stop.clone(), signal.clone());
            std::thread::Builder::new()
                .name("crp-maintenance".into())
                .spawn(move || {
                    while !stop.load(Ordering::Relaxed) {
                        signal.wait_timeout(cfg.tick);
                        if stop.load(Ordering::Relaxed) {
                            break;
                        }
                        metrics.maintenance_wakeups.fetch_add(1, Ordering::Relaxed);
                        for c in registry.list() {
                            sweep(&c, false);
                        }
                    }
                    // Graceful shutdown: fold what is pending and leave
                    // every durable collection at a clean checkpoint so
                    // restart is a pure bulk restore.
                    for c in registry.list() {
                        sweep(&c, true);
                    }
                })
                .expect("spawn crp-maintenance thread")
        };
        Maintenance {
            stop,
            signal,
            handle: Some(handle),
        }
    }

    /// Stop the thread and run its shutdown flush. Idempotent.
    pub fn shutdown(&mut self) {
        if let Some(handle) = self.handle.take() {
            self.stop.store(true, Ordering::Relaxed);
            self.signal.notify();
            let _ = handle.join();
        }
    }
}

impl Drop for Maintenance {
    fn drop(&mut self) {
        self.shutdown();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coding::{CodingParams, Scheme};
    use crate::coordinator::batcher::BatcherConfig;
    use crate::coordinator::durability::FsyncPolicy;
    use crate::coordinator::registry::{CollectionOptions, CollectionSpec, RegistryConfig};
    use crate::projection::{ProjectionConfig, Projector};
    use crate::scan::EpochConfig;

    fn small_registry(drain_threshold: usize) -> Arc<Registry> {
        let projector = Arc::new(Projector::new_cpu(ProjectionConfig {
            k: 64,
            seed: 3,
            ..Default::default()
        }));
        Registry::open(
            RegistryConfig {
                root: None,
                epoch: EpochConfig {
                    drain_threshold,
                    ..EpochConfig::default()
                },
                batcher: BatcherConfig::default(),
                checkpoint_every: 0,
                fsync: FsyncPolicy::Os,
            },
            Arc::new(Metrics::default()),
            projector,
            CodingParams::new(Scheme::TwoBit, 0.75),
            None,
        )
        .unwrap()
    }

    #[test]
    fn maintenance_sweeps_every_collection_and_writers_only_notify() {
        let registry = small_registry(8);
        let second_spec = CollectionSpec {
            scheme: Scheme::OneBit,
            w: 0.0,
            k: 32,
            seed: 9,
            kind: crate::projection::MatrixKind::Gaussian,
        };
        registry
            .create("second", second_spec, CollectionOptions::for_spec(&second_spec))
            .unwrap();
        let metrics = Arc::new(Metrics::default());
        let mut m = Maintenance::spawn(
            registry.clone(),
            metrics.clone(),
            MaintenanceConfig {
                tick: Duration::from_millis(5),
            },
        );
        let default = registry.get("default").unwrap();
        let second = registry.get("second").unwrap();
        for i in 0..120 {
            default.register(format!("d{i}"), vec![i as f32 * 0.01; 16]);
            second.register(format!("s{i}"), vec![-(i as f32) * 0.01; 16]);
        }
        // The thread must fold both backlogs without any writer folding.
        let deadline = std::time::Instant::now() + Duration::from_secs(10);
        let arenas = [
            default.store.arena().unwrap(),
            second.store.arena().unwrap(),
        ];
        while arenas.iter().any(|a| a.drain_due())
            && std::time::Instant::now() < deadline
        {
            std::thread::sleep(Duration::from_millis(2));
        }
        for (i, a) in arenas.iter().enumerate() {
            assert!(!a.drain_due(), "collection {i} never drained");
            assert_eq!(a.len(), 120, "collection {i}");
        }
        // The 5ms tick guarantees a counted wake-up well within the
        // deadline; don't race shutdown against the first tick.
        while metrics.maintenance_wakeups.load(Ordering::Relaxed) == 0
            && std::time::Instant::now() < deadline
        {
            std::thread::sleep(Duration::from_millis(2));
        }
        m.shutdown();
        assert!(
            metrics.maintenance_wakeups.load(Ordering::Relaxed) >= 1,
            "wakeups must be counted"
        );
        // Shutdown drained both tails; the stores stay fully usable.
        assert_eq!(default.store.arena().unwrap().pending_load(), 0);
        assert_eq!(second.store.arena().unwrap().pending_load(), 0);
        default.register("late".into(), vec![0.5; 16]);
        assert_eq!(default.store.len(), 121);
        assert_eq!(second.store.len(), 120);
    }
}
