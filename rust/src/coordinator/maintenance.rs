//! Background maintenance: periodic epoch drains (and the tombstone
//! compaction that rides on them), auto checkpoints, and the graceful-
//! shutdown flush — taken off the threshold-crossing writer.
//!
//! Before this thread existed, the register that crossed the drain
//! threshold paid for the fold itself (ROADMAP PR-2 follow-up). With a
//! [`Maintenance`] attached, the store's writers only *notify* a
//! [`DrainSignal`] on threshold crossings and fold inline solely past
//! the relief cap ([`crate::scan::epoch::RELIEF_FACTOR`]× the
//! threshold), the hard bound on pending growth if this thread stalls.

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::Duration;

use crate::coordinator::durability::Durability;
use crate::coordinator::metrics::Metrics;
use crate::coordinator::store::{DrainSignal, SketchStore};

/// Cadence knobs for the maintenance thread.
#[derive(Clone, Debug)]
pub struct MaintenanceConfig {
    /// Idle wake-up interval; drain notifications wake it sooner.
    pub tick: Duration,
}

impl Default for MaintenanceConfig {
    fn default() -> Self {
        MaintenanceConfig {
            tick: Duration::from_millis(200),
        }
    }
}

/// Handle to the background maintenance thread. Dropping it performs a
/// graceful shutdown: a final drain, a final checkpoint (when
/// durability is attached), and a join.
pub struct Maintenance {
    stop: Arc<AtomicBool>,
    signal: Arc<DrainSignal>,
    handle: Option<std::thread::JoinHandle<()>>,
}

impl Maintenance {
    /// Spawn the thread and hand it fold/checkpoint duty: the store's
    /// writers are switched to notify-only draining via
    /// [`SketchStore::delegate_drains`].
    pub fn spawn(
        store: Arc<SketchStore>,
        durability: Option<Arc<Durability>>,
        metrics: Arc<Metrics>,
        cfg: MaintenanceConfig,
    ) -> Maintenance {
        let stop = Arc::new(AtomicBool::new(false));
        let signal = Arc::new(DrainSignal::default());
        store.delegate_drains(signal.clone());
        let handle = {
            let (stop, signal) = (stop.clone(), signal.clone());
            std::thread::Builder::new()
                .name("crp-maintenance".into())
                .spawn(move || {
                    while !stop.load(Ordering::Relaxed) {
                        signal.wait_timeout(cfg.tick);
                        if stop.load(Ordering::Relaxed) {
                            break;
                        }
                        metrics.maintenance_wakeups.fetch_add(1, Ordering::Relaxed);
                        if let Some(arena) = store.arena() {
                            if arena.drain_due() {
                                arena.drain();
                            }
                        }
                        if let Some(d) = &durability {
                            if d.checkpoint_due() {
                                if let Err(e) = d.checkpoint(&store) {
                                    eprintln!("crp-maintenance: checkpoint failed: {e}");
                                }
                            }
                        }
                    }
                    // Graceful shutdown: fold what is pending and leave a
                    // clean checkpoint so restart is a pure bulk restore.
                    if let Some(arena) = store.arena() {
                        arena.drain();
                    }
                    if let Some(d) = &durability {
                        if let Err(e) = d.checkpoint(&store) {
                            eprintln!("crp-maintenance: final checkpoint failed: {e}");
                        }
                    }
                })
                .expect("spawn crp-maintenance thread")
        };
        Maintenance {
            stop,
            signal,
            handle: Some(handle),
        }
    }

    /// Stop the thread and run its shutdown flush. Idempotent.
    pub fn shutdown(&mut self) {
        if let Some(handle) = self.handle.take() {
            self.stop.store(true, Ordering::Relaxed);
            self.signal.notify();
            let _ = handle.join();
        }
    }
}

impl Drop for Maintenance {
    fn drop(&mut self) {
        self.shutdown();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coding::pack_codes;
    use crate::scan::EpochConfig;

    fn sketch(seed: u16) -> crate::coding::PackedCodes {
        let codes: Vec<u16> = (0..64).map(|i| ((i as u16 + seed) % 4)).collect();
        pack_codes(&codes, 2)
    }

    #[test]
    fn maintenance_owns_drains_and_writers_only_notify() {
        let store = Arc::new(SketchStore::with_arena_config(
            64,
            2,
            EpochConfig {
                drain_threshold: 8,
                ..EpochConfig::default()
            },
        ));
        let metrics = Arc::new(Metrics::default());
        let mut m = Maintenance::spawn(
            store.clone(),
            None,
            metrics.clone(),
            MaintenanceConfig {
                tick: Duration::from_millis(5),
            },
        );
        for i in 0..200 {
            store.put(format!("id{i}"), sketch(i));
        }
        // The thread must fold the backlog without any writer folding.
        let arena = store.arena().unwrap();
        let deadline = std::time::Instant::now() + Duration::from_secs(10);
        while arena.drain_due() && std::time::Instant::now() < deadline {
            std::thread::sleep(Duration::from_millis(2));
        }
        assert!(!arena.drain_due(), "maintenance thread never drained");
        assert!(arena.drains() >= 1);
        assert_eq!(arena.len(), 200);
        // The 5ms tick guarantees a counted wake-up well within the
        // deadline; don't race shutdown against the first tick.
        while metrics.maintenance_wakeups.load(Ordering::Relaxed) == 0
            && std::time::Instant::now() < deadline
        {
            std::thread::sleep(Duration::from_millis(2));
        }
        m.shutdown();
        assert!(
            metrics.maintenance_wakeups.load(Ordering::Relaxed) >= 1,
            "wakeups must be counted"
        );
        // Shutdown drained the tail; the store stays fully usable.
        assert_eq!(arena.pending_load(), 0);
        store.put("late".into(), sketch(9));
        assert_eq!(store.len(), 201);
    }
}
