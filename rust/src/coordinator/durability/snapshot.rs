//! Arena-image snapshots (`CRPSNAP2`): the sealed arena serialized
//! verbatim — shape header, id table, one contiguous word block, CRC —
//! so writing a snapshot is a sequential dump of memory and restoring
//! one is a bulk ingest, not a per-sketch re-encode. The legacy
//! per-sketch `CRPSNAP1` format is still readable (never written).
//!
//! ```text
//! magic "CRPSNAP2" | u32 k | u32 bits | u64 rows |
//!   id table: rows × (u32 id_len | id bytes)   (len = u32::MAX ⇒ tombstone)
//!   word block: rows · stride × u64
//! | u32 crc32 (everything after the magic)
//! ```

use std::fs::File;
use std::io::{BufReader, BufWriter, Read, Write};
use std::path::Path;

use super::crc32_update;
use crate::coding::supported_width;
use crate::coordinator::store::SketchStore;
use crate::scan::ArenaImage;

pub const MAGIC_V2: &[u8; 8] = b"CRPSNAP2";
pub const MAGIC_V1: &[u8; 8] = b"CRPSNAP1";

/// Id-table length marker for a tombstoned row.
const TOMBSTONE: u32 = u32::MAX;
/// Rows per bulk `put_rows` call on restore — one pending-buffer
/// round-trip per chunk instead of per sketch.
const RESTORE_CHUNK: usize = 4096;

struct Sink<W: Write> {
    w: W,
    crc: u32,
}

impl<W: Write> Sink<W> {
    fn put(&mut self, b: &[u8]) -> std::io::Result<()> {
        self.crc = crc32_update(self.crc, b);
        self.w.write_all(b)
    }
}

/// Serialize `img` to `w`. The image is an owned copy, so this holds no
/// store lock — a slow disk never stalls writers or scans. Returns the
/// number of live rows written.
pub fn write_image<W: Write>(w: W, img: &ArenaImage) -> crate::Result<u64> {
    debug_assert_eq!(img.words.len(), img.rows() * img.stride, "image shape");
    let mut s = Sink { w, crc: 0 };
    s.w.write_all(MAGIC_V2)?;
    s.put(&(img.k as u32).to_le_bytes())?;
    s.put(&img.bits.to_le_bytes())?;
    s.put(&(img.rows() as u64).to_le_bytes())?;
    for id in &img.ids {
        match id {
            Some(id) => {
                anyhow::ensure!(
                    id.len() <= 1 << 20,
                    "id of {} bytes too long to snapshot",
                    id.len()
                );
                s.put(&(id.len() as u32).to_le_bytes())?;
                s.put(id.as_bytes())?;
            }
            None => s.put(&TOMBSTONE.to_le_bytes())?,
        }
    }
    // The word block, staged through a flat byte buffer: one sequential
    // stream, no per-row framing.
    let mut buf = Vec::with_capacity(8 * 1024);
    for word in &img.words {
        buf.extend_from_slice(&word.to_le_bytes());
        if buf.len() >= 8 * 1024 {
            s.put(&buf)?;
            buf.clear();
        }
    }
    if !buf.is_empty() {
        s.put(&buf)?;
    }
    let crc = s.crc;
    s.w.write_all(&crc.to_le_bytes())?;
    s.w.flush()?;
    Ok(img.live() as u64)
}

/// Write `img` to `path` atomically (tmp file, fsync, rename), so a
/// crash mid-write leaves the previous snapshot intact. Returns
/// `(live rows written, file bytes)`.
pub fn save(path: &Path, img: &ArenaImage) -> crate::Result<(u64, u64)> {
    let tmp = path.with_extension("tmp");
    let f = File::create(&tmp)?;
    let mut w = BufWriter::new(f);
    let rows = write_image(&mut w, img)?;
    let f = w
        .into_inner()
        .map_err(|e| anyhow::anyhow!("snapshot flush failed: {e}"))?;
    f.sync_all()?;
    let bytes = f.metadata()?.len();
    drop(f);
    std::fs::rename(&tmp, path)?;
    Ok((rows, bytes))
}

/// Shape `(k, bits)` from a snapshot header without loading the body
/// (both formats store them at the same offsets). `None` if `path` is
/// not a file.
pub fn peek_shape(path: &Path) -> crate::Result<Option<(usize, u32)>> {
    if !path.is_file() {
        return Ok(None);
    }
    let mut r = BufReader::new(File::open(path)?);
    let mut magic = [0u8; 8];
    r.read_exact(&mut magic)?;
    anyhow::ensure!(&magic == MAGIC_V2 || &magic == MAGIC_V1, "not a CRP snapshot");
    let mut b4 = [0u8; 4];
    r.read_exact(&mut b4)?;
    let k = u32::from_le_bytes(b4) as usize;
    r.read_exact(&mut b4)?;
    let bits = u32::from_le_bytes(b4);
    Ok(Some((k, bits)))
}

/// Load a snapshot of either format into an owned arena image.
pub fn load(path: &Path) -> crate::Result<ArenaImage> {
    let mut r = BufReader::new(File::open(path)?);
    let mut magic = [0u8; 8];
    r.read_exact(&mut magic)?;
    if &magic == MAGIC_V2 {
        load_v2(&mut r)
    } else if &magic == MAGIC_V1 {
        load_v1(&mut r)
    } else {
        anyhow::bail!("not a CRP snapshot")
    }
}

/// Load a snapshot image shipped as an in-memory byte blob — the
/// replication bootstrap path, where the primary sends its snapshot
/// file verbatim over the wire. Same validation as [`load`].
pub fn load_bytes(bytes: &[u8]) -> crate::Result<ArenaImage> {
    let mut r = bytes;
    let mut magic = [0u8; 8];
    r.read_exact(&mut magic)?;
    if &magic == MAGIC_V2 {
        load_v2(&mut r)
    } else if &magic == MAGIC_V1 {
        load_v1(&mut r)
    } else {
        anyhow::bail!("not a CRP snapshot")
    }
}

struct Source<R: Read> {
    r: R,
    crc: u32,
}

impl<R: Read> Source<R> {
    fn get(&mut self, buf: &mut [u8]) -> crate::Result<()> {
        self.r.read_exact(buf)?;
        self.crc = crc32_update(self.crc, buf);
        Ok(())
    }
    fn u32(&mut self) -> crate::Result<u32> {
        let mut b = [0u8; 4];
        self.get(&mut b)?;
        Ok(u32::from_le_bytes(b))
    }
    fn u64(&mut self) -> crate::Result<u64> {
        let mut b = [0u8; 8];
        self.get(&mut b)?;
        Ok(u64::from_le_bytes(b))
    }
}

/// Validate a snapshot shape header before any stride arithmetic — a
/// crafted `bits = 0` (or any unsupported width) must be an error, not
/// a divide-by-zero panic downstream.
fn check_shape(k: usize, bits: u32) -> crate::Result<()> {
    anyhow::ensure!(k >= 1 && k <= 1 << 24, "implausible snapshot k {k}");
    anyhow::ensure!(
        bits != 0 && bits == supported_width(bits),
        "unsupported snapshot bit width {bits}"
    );
    Ok(())
}

fn load_v2(r: &mut impl Read) -> crate::Result<ArenaImage> {
    let mut s = Source { r, crc: 0 };
    let k = s.u32()? as usize;
    let bits = s.u32()?;
    let rows = s.u64()?;
    check_shape(k, bits)?;
    anyhow::ensure!(rows <= 1 << 32, "implausible snapshot row count {rows}");
    let rows = rows as usize;
    let mut img = ArenaImage::empty(k, bits);
    img.ids.reserve(rows.min(1 << 20));
    for _ in 0..rows {
        let len = s.u32()?;
        if len == TOMBSTONE {
            img.ids.push(None);
        } else {
            anyhow::ensure!(len <= 1 << 20, "implausible id length {len}");
            let mut id = vec![0u8; len as usize];
            s.get(&mut id)?;
            img.ids.push(Some(String::from_utf8(id)?));
        }
    }
    let n_words = rows * img.stride;
    img.words.reserve(n_words.min(1 << 22));
    let mut buf = [0u8; 8 * 1024];
    let mut remaining = n_words;
    while remaining > 0 {
        let take = remaining.min(1024);
        let bytes = &mut buf[..take * 8];
        s.get(bytes)?;
        for c in bytes.chunks_exact(8) {
            img.words.push(u64::from_le_bytes(c.try_into().unwrap()));
        }
        remaining -= take;
    }
    let want = s.crc;
    let mut crc_bytes = [0u8; 4];
    s.r.read_exact(&mut crc_bytes)?;
    anyhow::ensure!(
        u32::from_le_bytes(crc_bytes) == want,
        "snapshot checksum mismatch"
    );
    Ok(img)
}

/// Legacy per-sketch format reader (`CRPSNAP1`, no checksum).
fn load_v1(r: &mut impl Read) -> crate::Result<ArenaImage> {
    let mut b4 = [0u8; 4];
    let mut b8 = [0u8; 8];
    r.read_exact(&mut b4)?;
    let k = u32::from_le_bytes(b4) as usize;
    r.read_exact(&mut b4)?;
    let bits = u32::from_le_bytes(b4);
    r.read_exact(&mut b8)?;
    let count = u64::from_le_bytes(b8);
    anyhow::ensure!(count < 1 << 40, "implausible snapshot count");
    if count == 0 {
        // Legacy empty snapshots recorded k = 0, bits = 0.
        return Ok(ArenaImage::empty(k, bits.max(1)));
    }
    check_shape(k, bits)?;
    let mut img = ArenaImage::empty(k, bits);
    for _ in 0..count {
        r.read_exact(&mut b4)?;
        let id_len = u32::from_le_bytes(b4) as usize;
        anyhow::ensure!(id_len <= 1 << 20, "implausible id length {id_len}");
        let mut id = vec![0u8; id_len];
        r.read_exact(&mut id)?;
        r.read_exact(&mut b4)?;
        let n_words = u32::from_le_bytes(b4) as usize;
        anyhow::ensure!(
            n_words == img.stride,
            "snapshot row has {n_words} words, stride is {}",
            img.stride
        );
        img.ids.push(Some(String::from_utf8(id)?));
        for _ in 0..n_words {
            r.read_exact(&mut b8)?;
            img.words.push(u64::from_le_bytes(b8));
        }
    }
    Ok(img)
}

/// Bulk-restore an image into an arena-backed store through the
/// `put_rows` path — [`RESTORE_CHUNK`] rows per pending-buffer
/// round-trip, zero per-sketch trips. Tombstoned rows are skipped.
/// Returns live rows restored.
pub fn restore_into(store: &SketchStore, img: &ArenaImage) -> crate::Result<u64> {
    if img.rows() == 0 {
        return Ok(0);
    }
    let arena = store
        .arena()
        .ok_or_else(|| anyhow::anyhow!("snapshot restore requires an arena-backed store"))?;
    anyhow::ensure!(
        img.k == arena.k() && img.bits == arena.bits(),
        "snapshot shape (k={}, bits={}) does not match store (k={}, bits={})",
        img.k,
        img.bits,
        arena.k(),
        arena.bits()
    );
    let mut ids: Vec<String> = Vec::with_capacity(RESTORE_CHUNK);
    let mut words: Vec<u64> = Vec::with_capacity(RESTORE_CHUNK * img.stride);
    let mut restored = 0u64;
    for row in 0..img.rows() {
        let Some(id) = &img.ids[row] else { continue };
        ids.push(id.clone());
        words.extend_from_slice(img.row_words(row));
        if ids.len() == RESTORE_CHUNK {
            store.put_rows(&ids, &words)?;
            restored += ids.len() as u64;
            ids.clear();
            words.clear();
        }
    }
    if !ids.is_empty() {
        store.put_rows(&ids, &words)?;
        restored += ids.len() as u64;
    }
    Ok(restored)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coding::pack_codes;
    use crate::mathx::Pcg64;
    use crate::scan::CodeArena;
    use std::path::PathBuf;

    fn temp_file(tag: &str) -> PathBuf {
        std::env::temp_dir().join(format!("crp_snap_{tag}_{}.bin", std::process::id()))
    }

    fn filled_arena(n: usize, k: usize) -> CodeArena {
        let mut a = CodeArena::new(k, 2);
        let mut g = Pcg64::new(5, 0);
        for i in 0..n {
            let codes: Vec<u16> = (0..k).map(|_| g.next_below(4) as u16).collect();
            a.insert(&format!("vec-{i}"), &pack_codes(&codes, 2));
        }
        a
    }

    #[test]
    fn v2_roundtrip_with_tombstones() {
        let mut a = filled_arena(50, 256);
        a.remove("vec-7");
        a.remove("vec-31");
        let img = a.image();
        let path = temp_file("rt");
        let (n, bytes) = save(&path, &img).unwrap();
        assert_eq!(n, 48);
        assert_eq!(bytes, std::fs::metadata(&path).unwrap().len());
        let back = load(&path).unwrap();
        std::fs::remove_file(&path).ok();
        assert_eq!(back, img, "image survives the round trip verbatim");

        // Restore through the bulk path lands exactly the live rows.
        let store = SketchStore::with_arena(256, 2);
        let restored = restore_into(&store, &back).unwrap();
        assert_eq!(restored, 48);
        assert_eq!(store.len(), 48);
        assert!(store.get("vec-7").is_none());
        assert_eq!(store.get("vec-3"), a.get("vec-3"));
        assert_eq!(store.arena().unwrap().single_puts(), 0, "bulk ingest only");
    }

    #[test]
    fn empty_image_roundtrip() {
        let img = CodeArena::new(64, 2).image();
        let path = temp_file("empty");
        assert_eq!(save(&path, &img).unwrap().0, 0);
        let back = load(&path).unwrap();
        assert_eq!(back.rows(), 0);
        assert_eq!((back.k, back.bits), (64, 2));
        assert_eq!(peek_shape(&path).unwrap(), Some((64, 2)));
        std::fs::remove_file(&path).ok();
        assert!(peek_shape(&path).unwrap().is_none());
    }

    #[test]
    fn corrupt_and_garbage_rejected() {
        let path = temp_file("bad");
        std::fs::write(&path, b"garbage data").unwrap();
        assert!(load(&path).is_err());
        // Bit-flip inside a valid file: caught by the checksum.
        let img = filled_arena(20, 64).image();
        save(&path, &img).unwrap();
        let mut bytes = std::fs::read(&path).unwrap();
        let mid = bytes.len() / 2;
        bytes[mid] ^= 0x40;
        std::fs::write(&path, &bytes).unwrap();
        assert!(load(&path).is_err());
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn unsupported_width_header_is_error_not_panic() {
        // CRPSNAP2 with bits = 0 and a nonzero row count.
        let path = temp_file("w2");
        let mut bytes = Vec::new();
        bytes.extend_from_slice(MAGIC_V2);
        bytes.extend_from_slice(&64u32.to_le_bytes()); // k
        bytes.extend_from_slice(&0u32.to_le_bytes()); // bits = 0
        bytes.extend_from_slice(&3u64.to_le_bytes()); // rows > 0
        std::fs::write(&path, &bytes).unwrap();
        assert!(load(&path).is_err());
        // Legacy CRPSNAP1 with the same crafted header used to divide by
        // zero in word unpacking; now it is a clean error.
        for bad_bits in [0u32, 3, 5, 63] {
            let mut bytes = Vec::new();
            bytes.extend_from_slice(MAGIC_V1);
            bytes.extend_from_slice(&64u32.to_le_bytes());
            bytes.extend_from_slice(&bad_bits.to_le_bytes());
            bytes.extend_from_slice(&1u64.to_le_bytes()); // count > 0
            bytes.extend_from_slice(&2u32.to_le_bytes()); // id_len
            bytes.extend_from_slice(b"aa");
            std::fs::write(&path, &bytes).unwrap();
            let got = load(&path);
            assert!(got.is_err(), "bits={bad_bits} must be rejected");
        }
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn legacy_v1_files_still_load() {
        // Hand-write a CRPSNAP1 file the way the old persist layer did.
        let (k, bits) = (96usize, 2u32);
        let mut g = Pcg64::new(9, 0);
        let mut entries = Vec::new();
        for i in 0..12 {
            let codes: Vec<u16> = (0..k).map(|_| g.next_below(4) as u16).collect();
            entries.push((format!("v{i:02}"), pack_codes(&codes, bits)));
        }
        let mut bytes = Vec::new();
        bytes.extend_from_slice(MAGIC_V1);
        bytes.extend_from_slice(&(k as u32).to_le_bytes());
        bytes.extend_from_slice(&bits.to_le_bytes());
        bytes.extend_from_slice(&(entries.len() as u64).to_le_bytes());
        for (id, codes) in &entries {
            bytes.extend_from_slice(&(id.len() as u32).to_le_bytes());
            bytes.extend_from_slice(id.as_bytes());
            bytes.extend_from_slice(&(codes.words().len() as u32).to_le_bytes());
            for w in codes.words() {
                bytes.extend_from_slice(&w.to_le_bytes());
            }
        }
        let path = temp_file("v1");
        std::fs::write(&path, &bytes).unwrap();
        let img = load(&path).unwrap();
        std::fs::remove_file(&path).ok();
        assert_eq!((img.k, img.bits), (k, bits));
        assert_eq!(img.rows(), 12);
        let store = SketchStore::with_arena(k, bits);
        assert_eq!(restore_into(&store, &img).unwrap(), 12);
        for (id, codes) in &entries {
            assert_eq!(store.get(id).as_ref(), Some(codes), "{id}");
        }
    }

    #[test]
    fn restore_rejects_shape_mismatch() {
        let img = filled_arena(5, 64).image();
        let store = SketchStore::with_arena(128, 2);
        let err = restore_into(&store, &img).unwrap_err().to_string();
        assert!(err.contains("does not match store"), "{err}");
        assert!(restore_into(&SketchStore::new(), &img).is_err());
    }
}
