//! Arena-native durability: sealed-word snapshots + an epoch WAL.
//!
//! The paper's storage story — 1–2 bits per projection — makes full-
//! fidelity persistence nearly free, so the serving stack keeps *all*
//! of it durable: every acknowledged mutation is appended to a
//! checksummed write-ahead log ([`wal`]), and checkpoints serialize the
//! sealed arena verbatim ([`snapshot`], `CRPSNAP2`) so restart is a
//! bulk ingest of one contiguous word block, not a re-encode.
//!
//! ## Checkpoint protocol (snapshot-then-truncate)
//!
//! 1. **Rotate** the WAL to a fresh segment. Append + store-apply share
//!    the WAL mutex, so every op in the retired segments is already
//!    applied to the store when rotation returns.
//! 2. **Drain** the epoch arena (one short write-lock hold, no I/O), so
//!    the sealed arena covers everything in the retired segments.
//! 3. **Image** the sealed arena (one short read-lock hold, one flat
//!    clone), then write `CRPSNAP2` to a tmp file and rename — with no
//!    store lock held across any disk write, so puts and scans flow
//!    freely for the whole file write.
//! 4. **Retire** the old segments.
//!
//! Ops that land between rotation and the sealed image appear in both
//! the snapshot and the new segment; replay is idempotent and ordered,
//! so recovery (snapshot, then all surviving segments oldest-first)
//! always reconstructs the state at the last acknowledged op. Every
//! crash window — mid-append (torn tail), mid-snapshot (tmp discarded),
//! between rename and retire (stale segments replay idempotently) —
//! resolves to that same state.

pub mod snapshot;
pub mod wal;

use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Mutex, OnceLock};
use std::time::{Duration, Instant};

use crate::coding::{supported_width, PackedCodes};
use crate::coordinator::metrics::LatencyHistogram;
use crate::coordinator::store::SketchStore;

/// Incremental IEEE CRC-32 (chain as `crc32_update(crc32_update(0, a), b)`).
pub(crate) fn crc32_update(state: u32, bytes: &[u8]) -> u32 {
    static TABLE: OnceLock<[u32; 256]> = OnceLock::new();
    let table = TABLE.get_or_init(|| {
        let mut t = [0u32; 256];
        for (i, e) in t.iter_mut().enumerate() {
            let mut c = i as u32;
            for _ in 0..8 {
                c = if c & 1 != 0 { 0xEDB8_8320 ^ (c >> 1) } else { c >> 1 };
            }
            *e = c;
        }
        t
    });
    let mut c = !state;
    for &b in bytes {
        c = table[((c ^ b as u32) & 0xFF) as usize] ^ (c >> 8);
    }
    !c
}

pub use wal::FsyncPolicy;

/// How long a replica's last `ReplSync` keeps its retention floor
/// alive. A replica silent for longer stops pinning WAL segments and
/// will re-bootstrap from a snapshot when it returns.
const REPL_TTL: Duration = Duration::from_secs(30);

/// Default cap on WAL bytes a lagging replica may pin past a
/// checkpoint before retention gives up on it (forced re-bootstrap
/// instead of unbounded disk growth).
pub const DEFAULT_REPL_LAG_CAP: u64 = 256 * 1024 * 1024;

/// Where durable state lives and how often it is checkpointed.
#[derive(Clone, Debug)]
pub struct DurabilityConfig {
    /// Arena-image snapshot file (rewritten atomically at each checkpoint).
    pub snapshot: PathBuf,
    /// Directory of WAL segment files.
    pub wal_dir: PathBuf,
    /// Logged rows between automatic checkpoints (0 = only explicit
    /// `Persist` requests and graceful shutdown checkpoint).
    pub checkpoint_every: u64,
    /// When acknowledged WAL records reach stable storage (see
    /// [`FsyncPolicy`]): per-record fsync, OS-buffer flush, or timed
    /// group commit.
    pub fsync: FsyncPolicy,
}

/// What recovery found on disk.
#[derive(Clone, Debug, Default)]
pub struct RecoverStats {
    /// Live rows bulk-restored from the snapshot.
    pub snapshot_rows: u64,
    pub wal_segments: u64,
    pub wal_records: u64,
    pub wal_bytes: u64,
    /// The final WAL segment ended in a truncated/corrupt record (a
    /// crash mid-append); its clean prefix was applied.
    pub wal_torn: bool,
    /// Torn final segment + clean-prefix length (see
    /// [`wal::ReplayStats::torn_tail`]).
    pub torn_tail: Option<(PathBuf, u64)>,
    /// Live sketches after snapshot + replay.
    pub live: u64,
}

/// Replay `snapshot` (if it exists) and every WAL segment under
/// `wal_dir` into `store`.
pub fn recover_into(
    store: &SketchStore,
    snapshot_path: &Path,
    wal_dir: &Path,
) -> crate::Result<RecoverStats> {
    let mut stats = RecoverStats::default();
    if snapshot_path.is_file() {
        let img = snapshot::load(snapshot_path)?;
        stats.snapshot_rows = snapshot::restore_into(store, &img)?;
    }
    let replay = wal::replay_into(store, wal_dir)?;
    stats.wal_segments = replay.segments;
    stats.wal_records = replay.records;
    stats.wal_bytes = replay.bytes;
    stats.wal_torn = replay.torn;
    stats.torn_tail = replay.torn_tail;
    stats.live = store.len() as u64;
    Ok(stats)
}

/// Recover into a fresh arena-backed store, discovering the sketch
/// shape from the snapshot header (or the oldest WAL segment when no
/// snapshot exists). Returns `(store, k, bits, stats)`.
pub fn recover(
    snapshot_path: &Path,
    wal_dir: &Path,
) -> crate::Result<(SketchStore, usize, u32, RecoverStats)> {
    let snap_shape = snapshot::peek_shape(snapshot_path)?.filter(|(k, _)| *k > 0);
    let (k, bits) = match snap_shape {
        Some(shape) => shape,
        None => wal::peek_shape(wal_dir)?.ok_or_else(|| {
            anyhow::anyhow!(
                "nothing to recover: no snapshot at {} and no WAL segments in {}",
                snapshot_path.display(),
                wal_dir.display()
            )
        })?,
    };
    let bits = supported_width(bits.max(1));
    let store = SketchStore::with_arena(k, bits);
    let stats = recover_into(&store, snapshot_path, wal_dir)?;
    Ok((store, k, bits, stats))
}

/// The service's durability engine: recovery at open, per-op WAL
/// appends, and snapshot-then-truncate checkpoints.
pub struct Durability {
    cfg: DurabilityConfig,
    wal: wal::Wal,
    /// Serializes whole checkpoints (maintenance tick vs explicit
    /// `Persist` requests).
    checkpoint_mu: Mutex<()>,
    since_checkpoint: AtomicU64,
    last_checkpoint_rows: AtomicU64,
    /// Append+apply+flush latency of the three `log_*` entry points,
    /// timed outside the WAL mutex (the hold is part of the measured
    /// path, never extended by it). Under `--fsync always` this is
    /// dominated by the per-record fsync, which is exactly what the
    /// `fsync` exposition label lets dashboards attribute.
    wal_append_us: LatencyHistogram,
    /// Wall time of each checkpoint's `snapshot::save` (tmp write +
    /// fsync + rename), excluding WAL rotation and arena drain.
    snapshot_write_us: LatencyHistogram,
    /// On-disk size of the most recent snapshot file (0 before one).
    snapshot_bytes: AtomicU64,
    /// Retention floors of attached replicas: replica id → (oldest WAL
    /// segment it still needs, last time it synced). Entries older
    /// than [`REPL_TTL`] stop gating retirement.
    repl_floors: Mutex<HashMap<String, (u64, Instant)>>,
    /// WAL bytes a replica may pin past a checkpoint before retention
    /// stops waiting for it (see [`DEFAULT_REPL_LAG_CAP`]).
    repl_lag_cap: AtomicU64,
}

impl Durability {
    /// Recover `store` from the snapshot + WAL named by `cfg`, then
    /// open a fresh WAL segment for new appends.
    pub fn open(
        cfg: DurabilityConfig,
        store: &SketchStore,
    ) -> crate::Result<(Durability, RecoverStats)> {
        let arena = store
            .arena()
            .ok_or_else(|| anyhow::anyhow!("durability requires an arena-backed store"))?;
        std::fs::create_dir_all(&cfg.wal_dir)?;
        if let Some(parent) = cfg.snapshot.parent() {
            if !parent.as_os_str().is_empty() {
                std::fs::create_dir_all(parent)?;
            }
        }
        let stats = recover_into(store, &cfg.snapshot, &cfg.wal_dir)?;
        // Heal a torn tail before opening a new segment: the tail past
        // the clean prefix was never acknowledged, and truncating it
        // now means the segment can never wedge a later recovery once
        // newer segments sit behind it.
        if let Some((path, clean_len)) = &stats.torn_tail {
            let f = std::fs::OpenOptions::new().write(true).open(path)?;
            f.set_len(*clean_len)?;
            f.sync_all()?;
        }
        let wal = wal::Wal::create_with(&cfg.wal_dir, arena.k(), arena.bits(), cfg.fsync)?;
        Ok((
            Durability {
                cfg,
                wal,
                checkpoint_mu: Mutex::new(()),
                since_checkpoint: AtomicU64::new(0),
                last_checkpoint_rows: AtomicU64::new(0),
                wal_append_us: LatencyHistogram::default(),
                snapshot_write_us: LatencyHistogram::default(),
                snapshot_bytes: AtomicU64::new(0),
                repl_floors: Mutex::new(HashMap::new()),
                repl_lag_cap: AtomicU64::new(DEFAULT_REPL_LAG_CAP),
            },
            stats,
        ))
    }

    /// WAL-append a put, then (under the same hold) apply it via
    /// `apply`. An `Err` means the op was never logged and must not be
    /// acknowledged.
    pub fn log_put(
        &self,
        id: &str,
        codes: &PackedCodes,
        apply: impl FnOnce(),
    ) -> crate::Result<()> {
        let t0 = Instant::now();
        self.wal.append_put(id, codes.words(), apply)?;
        self.wal_append_us.record(t0.elapsed().as_micros() as u64);
        self.since_checkpoint.fetch_add(1, Ordering::Relaxed);
        Ok(())
    }

    /// WAL-append a bulk put (one record for the whole batch), then
    /// apply it.
    pub fn log_put_rows(
        &self,
        ids: &[String],
        words: &[u64],
        apply: impl FnOnce() -> crate::Result<()>,
    ) -> crate::Result<()> {
        let n = ids.len() as u64;
        let t0 = Instant::now();
        self.wal.append_put_rows(ids, words, apply)??;
        self.wal_append_us.record(t0.elapsed().as_micros() as u64);
        self.since_checkpoint.fetch_add(n, Ordering::Relaxed);
        Ok(())
    }

    /// WAL-append a removal, then apply it; returns what `apply`
    /// reported (whether the id existed).
    pub fn log_remove(&self, id: &str, apply: impl FnOnce() -> bool) -> crate::Result<bool> {
        let t0 = Instant::now();
        let existed = self.wal.append_remove(id, apply)?;
        self.wal_append_us.record(t0.elapsed().as_micros() as u64);
        self.since_checkpoint.fetch_add(1, Ordering::Relaxed);
        Ok(existed)
    }

    /// Whether the maintenance thread should checkpoint now: the row
    /// threshold has been crossed, or the active WAL segment is broken
    /// after a failed append — only the checkpoint's rotation heals
    /// that, so it must not wait for rows that can no longer be logged.
    pub fn checkpoint_due(&self) -> bool {
        self.wal.is_broken()
            || (self.cfg.checkpoint_every > 0
                && self.since_checkpoint.load(Ordering::Relaxed) >= self.cfg.checkpoint_every)
    }

    /// Run the snapshot-then-truncate protocol (see the module docs).
    /// No shard or arena lock is held across any disk write. Returns
    /// `(live rows snapshotted, WAL bytes retired)`.
    pub fn checkpoint(&self, store: &SketchStore) -> crate::Result<(u64, u64)> {
        let _serialize = self.checkpoint_mu.lock().unwrap();
        let arena = store
            .arena()
            .ok_or_else(|| anyhow::anyhow!("durability requires an arena-backed store"))?;
        let retired = self.wal.rotate()?;
        arena.drain();
        let image = arena.sealed_image();
        let s0 = Instant::now();
        let (rows, snap_bytes) = match snapshot::save(&self.cfg.snapshot, &image) {
            Ok(rows) => rows,
            Err(e) => {
                // The snapshot failed, so the retired segments must
                // survive for the next attempt — except header-only
                // ones (no record was ever acknowledged into them),
                // which would otherwise pile up one per retry while
                // the snapshot path stays unwritable.
                for (_, p) in &retired {
                    let empty = std::fs::metadata(p)
                        .map(|m| m.len() <= wal::SEGMENT_HEADER)
                        .unwrap_or(false);
                    if empty {
                        let _ = std::fs::remove_file(p);
                    }
                }
                return Err(e);
            }
        };
        self.snapshot_write_us.record(s0.elapsed().as_micros() as u64);
        self.snapshot_bytes.store(snap_bytes, Ordering::Relaxed);
        // Retention gating: segments at or above the oldest fresh
        // replica floor stay on disk so the stream never loses records
        // a replica still needs — but only while their total stays
        // under the lag cap. Past the cap the replica is too far
        // behind to chase the log; everything retires and it will
        // re-bootstrap from the snapshot just written (all-or-nothing:
        // keeping a partial suffix would leave a hole in the stream).
        let floor = self.repl_floor();
        let sized: Vec<(u64, &PathBuf, u64)> = retired
            .iter()
            .map(|(s, p)| (*s, p, std::fs::metadata(p).map(|m| m.len()).unwrap_or(0)))
            .collect();
        let keep_all = match floor {
            None => false,
            Some(floor) => {
                let pinned: u64 =
                    sized.iter().filter(|(s, _, _)| *s >= floor).map(|(_, _, n)| n).sum();
                pinned <= self.repl_lag_cap.load(Ordering::Relaxed)
            }
        };
        let mut retired_bytes = 0u64;
        for (s, p, len) in &sized {
            if keep_all && floor.is_some_and(|f| *s >= f) {
                continue;
            }
            retired_bytes += len;
            let _ = std::fs::remove_file(p);
        }
        self.since_checkpoint.store(0, Ordering::Relaxed);
        self.last_checkpoint_rows.store(rows, Ordering::Relaxed);
        Ok((rows, retired_bytes))
    }

    /// Flush buffered WAL frames to the OS.
    pub fn flush(&self) -> crate::Result<()> {
        self.wal.flush()
    }

    /// Group-commit backstop: `fdatasync` WAL appends left unsynced
    /// past their interval, so an idle tail never stays exposed beyond
    /// the bound `--fsync group:<ms>` promises. No-op for `always`/`os`
    /// (the maintenance tick calls this every sweep).
    pub fn sync_wal_due(&self) -> crate::Result<()> {
        self.wal.sync_due()
    }

    /// WAL records appended by this process.
    pub fn wal_records(&self) -> u64 {
        self.wal.records()
    }

    /// WAL bytes appended by this process.
    pub fn wal_bytes(&self) -> u64 {
        self.wal.bytes()
    }

    /// Live rows written by the most recent checkpoint (0 before one).
    pub fn last_checkpoint_rows(&self) -> u64 {
        self.last_checkpoint_rows.load(Ordering::Relaxed)
    }

    /// Append+apply+flush latency histogram of the `log_*` calls.
    pub fn wal_append_hist(&self) -> &LatencyHistogram {
        &self.wal_append_us
    }

    /// Snapshot file-write latency histogram (one sample per checkpoint).
    pub fn snapshot_write_hist(&self) -> &LatencyHistogram {
        &self.snapshot_write_us
    }

    /// On-disk size of the most recent snapshot file (0 before one).
    pub fn snapshot_bytes(&self) -> u64 {
        self.snapshot_bytes.load(Ordering::Relaxed)
    }

    /// The fsync discipline WAL appends run under (its label tags the
    /// `crp_wal_append_us` exposition series).
    pub fn fsync_policy(&self) -> FsyncPolicy {
        self.cfg.fsync
    }

    // ---- replication feed (primary side) ----------------------------

    /// Record that `replica` has applied everything before `segment`
    /// (its retention floor) and is alive right now.
    pub fn repl_note(&self, replica: &str, segment: u64) {
        let mut g = self.repl_floors.lock().unwrap();
        g.insert(replica.to_string(), (segment, Instant::now()));
    }

    /// Oldest segment any *fresh* replica still needs (stale entries
    /// are dropped here, so an abandoned replica stops pinning disk
    /// after [`REPL_TTL`]).
    fn repl_floor(&self) -> Option<u64> {
        let mut g = self.repl_floors.lock().unwrap();
        g.retain(|_, (_, seen)| seen.elapsed() < REPL_TTL);
        g.values().map(|(seg, _)| *seg).min()
    }

    /// Override the replica lag cap (bytes of retired WAL a checkpoint
    /// may keep for a lagging replica).
    pub fn set_repl_lag_cap(&self, bytes: u64) {
        self.repl_lag_cap.store(bytes, Ordering::Relaxed);
    }

    /// The configured replica lag cap in bytes.
    pub fn repl_lag_cap(&self) -> u64 {
        self.repl_lag_cap.load(Ordering::Relaxed)
    }

    /// WAL bytes on disk past a replica position — the backlog the
    /// stream still has to ship (approximate while appends race it).
    pub fn repl_backlog(&self, segment: u64, offset: u64) -> u64 {
        let _ = self.wal.flush();
        let mut behind = 0u64;
        for (s, p) in wal::segments(&self.cfg.wal_dir).unwrap_or_default() {
            let len = std::fs::metadata(&p).map(|m| m.len()).unwrap_or(0);
            if s == segment {
                behind += len.saturating_sub(offset);
            } else if s > segment {
                behind += len.saturating_sub(wal::SEGMENT_HEADER);
            }
        }
        behind
    }

    /// Read the next replication chunk from segment `seq` at `offset`
    /// (see [`wal::Wal::read_chunk`]); `None` forces a re-bootstrap.
    pub fn read_chunk(&self, seq: u64, offset: u64) -> crate::Result<Option<wal::WalChunk>> {
        self.wal.read_chunk(seq, offset, wal::MAX_CHUNK)
    }

    /// Segment currently accepting appends (a bootstrap resumes the
    /// stream here).
    pub fn active_seq(&self) -> u64 {
        self.wal.active_seq()
    }

    /// The snapshot file checkpoints rewrite (the bootstrap image).
    pub fn snapshot_path(&self) -> &Path {
        &self.cfg.snapshot
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coding::pack_codes;
    use crate::mathx::Pcg64;

    fn sketch(g: &mut Pcg64, k: usize) -> PackedCodes {
        let codes: Vec<u16> = (0..k).map(|_| g.next_below(4) as u16).collect();
        pack_codes(&codes, 2)
    }

    fn temp_dir(tag: &str) -> PathBuf {
        let d = std::env::temp_dir().join(format!("crp_dur_{tag}_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&d);
        std::fs::create_dir_all(&d).unwrap();
        d
    }

    fn cfg(dir: &Path, every: u64) -> DurabilityConfig {
        DurabilityConfig {
            snapshot: dir.join("snapshot.bin"),
            wal_dir: dir.join("wal"),
            checkpoint_every: every,
            fsync: FsyncPolicy::Os,
        }
    }

    #[test]
    fn crc32_matches_known_vectors() {
        // IEEE CRC-32 of "123456789" is the classic check value.
        assert_eq!(crc32_update(0, b"123456789"), 0xCBF4_3926);
        assert_eq!(crc32_update(0, b""), 0);
        // Incremental chaining equals one-shot.
        let one = crc32_update(0, b"hello world");
        let two = crc32_update(crc32_update(0, b"hello "), b"world");
        assert_eq!(one, two);
    }

    #[test]
    fn open_log_checkpoint_recover_cycle() {
        let dir = temp_dir("cycle");
        let k = 64usize;
        let store = SketchStore::with_arena(k, 2);
        let (d, stats) = Durability::open(cfg(&dir, 0), &store).unwrap();
        assert_eq!(stats.live, 0);
        let mut g = Pcg64::new(1, 1);
        for i in 0..20 {
            let codes = sketch(&mut g, k);
            let id = format!("id{i}");
            d.log_put(&id, &codes, || store.put(id.clone(), codes.clone()))
                .unwrap();
        }
        assert!(d.log_remove("id3", || store.remove("id3")).unwrap());
        assert_eq!(d.wal_records(), 21);
        // Every log_* call left one sample in the append histogram.
        assert_eq!(d.wal_append_hist().count(), 21);
        assert_eq!(d.fsync_policy().label(), "os");

        // Checkpoint: snapshot written, WAL retired, counters reset.
        let (rows, retired) = d.checkpoint(&store).unwrap();
        assert_eq!(rows, 19);
        assert!(retired > 0, "old segment bytes must be retired");
        assert_eq!(d.last_checkpoint_rows(), 19);
        assert_eq!(d.snapshot_write_hist().count(), 1);
        let snap_len = std::fs::metadata(dir.join("snapshot.bin")).unwrap().len();
        assert_eq!(d.snapshot_bytes(), snap_len);
        assert!(snap_len > 0);
        assert_eq!(wal::segments(&dir.join("wal")).unwrap().len(), 1);

        // More ops after the checkpoint land in the new segment only.
        let codes = sketch(&mut g, k);
        d.log_put("post", &codes, || store.put("post".into(), codes.clone()))
            .unwrap();

        // Recovery = snapshot + surviving WAL tail.
        let (back, rk, rbits, rstats) =
            recover(&dir.join("snapshot.bin"), &dir.join("wal")).unwrap();
        assert_eq!((rk, rbits), (k, 2));
        assert_eq!(rstats.snapshot_rows, 19);
        assert_eq!(rstats.wal_records, 1);
        assert!(!rstats.wal_torn);
        assert_eq!(back.len(), store.len());
        assert_eq!(back.get("post"), store.get("post"));
        assert!(back.get("id3").is_none());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn torn_tail_is_truncated_at_open_and_never_wedges() {
        let dir = temp_dir("heal");
        let store = SketchStore::with_arena(32, 2);
        let (d, _) = Durability::open(cfg(&dir, 0), &store).unwrap();
        let mut g = Pcg64::new(3, 3);
        for i in 0..4 {
            let codes = sketch(&mut g, 32);
            let id = format!("id{i}");
            d.log_put(&id, &codes, || store.put(id.clone(), codes.clone()))
                .unwrap();
        }
        drop(d);
        // Tear the tail: a crash mid-append of the 4th (unacked) record.
        let (_, seg) = wal::segments(&dir.join("wal")).unwrap().pop().unwrap();
        let full = std::fs::read(&seg).unwrap();
        std::fs::write(&seg, &full[..full.len() - 5]).unwrap();

        // Restart 1: clean prefix replays, the tear is truncated away,
        // and new acknowledged ops land in a fresh segment.
        let store2 = SketchStore::with_arena(32, 2);
        let (d2, st) = Durability::open(cfg(&dir, 0), &store2).unwrap();
        assert!(st.wal_torn);
        assert_eq!(st.live, 3);
        let healed = std::fs::metadata(&seg).unwrap().len();
        assert!(healed < (full.len() - 5) as u64, "torn tail not truncated");
        let codes = sketch(&mut g, 32);
        d2.log_put("post", &codes, || store2.put("post".into(), codes.clone()))
            .unwrap();
        drop(d2);

        // Restart 2: the once-torn segment is now non-final — recovery
        // must still succeed and see every acknowledged op.
        let store3 = SketchStore::with_arena(32, 2);
        let (_, st) = Durability::open(cfg(&dir, 0), &store3).unwrap();
        assert!(!st.wal_torn, "healed segment must replay cleanly");
        assert_eq!(st.live, 4);
        assert!(store3.get("post").is_some());
        assert!(store3.get("id3").is_none(), "the torn put was never acked");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn replica_floor_gates_retirement_until_the_lag_cap() {
        let dir = temp_dir("repl_gate");
        let k = 32usize;
        let store = SketchStore::with_arena(k, 2);
        let (d, _) = Durability::open(cfg(&dir, 0), &store).unwrap();
        let mut g = Pcg64::new(5, 5);
        for i in 0..8 {
            let codes = sketch(&mut g, k);
            let id = format!("id{i}");
            d.log_put(&id, &codes, || store.put(id.clone(), codes.clone()))
                .unwrap();
        }
        let wal_dir = dir.join("wal");

        // A fresh replica still at segment 1 pins the retired segment
        // through a checkpoint...
        d.repl_note("r1", 1);
        let (_, retired) = d.checkpoint(&store).unwrap();
        assert_eq!(retired, 0, "pinned segment must not be deleted");
        let segs = wal::segments(&wal_dir).unwrap();
        assert!(segs.iter().any(|(s, _)| *s == 1), "segment 1 kept for r1");

        // ...until its floor advances past it: the next checkpoint
        // retires everything below the new floor.
        d.repl_note("r1", d.active_seq());
        let (_, retired) = d.checkpoint(&store).unwrap();
        assert!(retired > 0, "unpinned segments retire");
        assert!(!wal::segments(&wal_dir).unwrap().iter().any(|(s, _)| *s == 1));

        // A replica pinned below a tiny lag cap loses its hold: the
        // backlog would exceed the cap, so everything retires and the
        // replica must re-bootstrap.
        for i in 8..16 {
            let codes = sketch(&mut g, k);
            let id = format!("id{i}");
            d.log_put(&id, &codes, || store.put(id.clone(), codes.clone()))
                .unwrap();
        }
        d.repl_note("r1", 1);
        d.set_repl_lag_cap(1);
        assert_eq!(d.repl_lag_cap(), 1);
        let (_, retired) = d.checkpoint(&store).unwrap();
        assert!(retired > 0, "over-cap backlog retires wholesale");
        assert_eq!(wal::segments(&wal_dir).unwrap().len(), 1, "only the active segment");

        // Backlog accounting sees bytes past a position.
        let codes = sketch(&mut g, k);
        d.log_put("tail", &codes, || store.put("tail".into(), codes.clone()))
            .unwrap();
        assert!(d.repl_backlog(d.active_seq(), wal::SEGMENT_HEADER) > 0);
        assert_eq!(d.repl_backlog(d.active_seq() + 1, 0), 0);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn auto_checkpoint_threshold_counts_rows() {
        let dir = temp_dir("auto");
        let store = SketchStore::with_arena(32, 2);
        let (d, _) = Durability::open(cfg(&dir, 10), &store).unwrap();
        let mut g = Pcg64::new(2, 2);
        for i in 0..9 {
            let codes = sketch(&mut g, 32);
            let id = format!("a{i}");
            d.log_put(&id, &codes, || store.put(id.clone(), codes.clone()))
                .unwrap();
        }
        assert!(!d.checkpoint_due());
        let ids: Vec<String> = (0..3).map(|i| format!("b{i}")).collect();
        let stride = store.arena().unwrap().stride();
        let mut words = Vec::with_capacity(3 * stride);
        for _ in 0..3 {
            words.extend_from_slice(sketch(&mut g, 32).words());
        }
        // A bulk record counts its row count, not 1.
        d.log_put_rows(&ids, &words, || store.put_rows(&ids, &words))
            .unwrap();
        assert!(d.checkpoint_due());
        d.checkpoint(&store).unwrap();
        assert!(!d.checkpoint_due());
        std::fs::remove_dir_all(&dir).ok();
    }
}
