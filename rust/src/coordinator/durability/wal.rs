//! Append-only epoch WAL (`CRPWAL1`): every acknowledged mutation —
//! put, bulk put_rows, remove — becomes a length-prefixed, checksummed
//! record in a numbered segment file. Replay applies the longest clean
//! prefix, so a crash (or `kill -9`) mid-append loses at most the one
//! record that was never acknowledged.
//!
//! Layout per segment (`wal.<seq>.log`):
//!
//! ```text
//! magic "CRPWAL1\0" | u32 k | u32 bits |
//!   repeated: u32 payload_len | u32 crc32(payload) | payload
//! payload: u8 op |
//!   op 1 Put:     u32 id_len | id | stride × u64 words
//!   op 2 PutRows: u32 n | n × (u32 id_len | id) | n·stride × u64 words
//!   op 3 Remove:  u32 id_len | id
//! ```
//!
//! Appends serialize on one mutex and the store apply runs under the
//! same hold, so segment rotation (which takes the mutex) can never
//! observe a logged-but-unapplied op — the invariant the checkpoint
//! protocol in [`super`] builds on. Each record is flushed to the OS
//! before the op is acknowledged. No shard or arena lock is ever taken
//! here: WAL pressure slows writers, never scans.

use std::fs::File;
use std::io::{BufReader, BufWriter, Read, Seek, SeekFrom, Write};
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::{Duration, Instant};

use super::crc32_update;
use crate::coding::{supported_width, PackedCodes};
use crate::coordinator::store::SketchStore;

/// Segment-file magic (the version lives in the name: `CRPWAL1`).
pub const MAGIC: &[u8; 8] = b"CRPWAL1\0";

const OP_PUT: u8 = 1;
const OP_PUT_ROWS: u8 = 2;
const OP_REMOVE: u8 = 3;
/// Segment header bytes: magic + k + bits. A segment of exactly this
/// size has never held an acknowledged record.
pub(crate) const SEGMENT_HEADER: u64 = 16;
/// Frame header bytes: payload length + payload checksum.
const FRAME_HEADER: usize = 8;
/// Upper bound on one record payload; anything larger read back is
/// treated as corruption, and appends refuse to write it.
const MAX_PAYLOAD: u32 = 1 << 27;
/// Replication chunk budget: [`Wal::read_chunk`] packs complete frames
/// up to roughly this many bytes per pull (a single oversized record
/// still ships alone — a chunk always makes progress).
pub(crate) const MAX_CHUNK: usize = 1 << 20;

/// When acknowledged WAL records reach *stable storage* (not just the
/// OS page cache). Every policy flushes each record to the OS before
/// the op is acknowledged, so all of them survive `kill -9`; they
/// differ in what survives power loss / kernel panic:
///
/// * `Always` — `fdatasync` after every record. Full durability, one
///   disk round-trip per op.
/// * `Os` — flush to the page cache only (the pre-knob behavior and
///   default). Fastest; power loss can lose the OS-buffered tail.
/// * `Group(interval)` — flush per record, `fdatasync` at most once per
///   `interval`, riding on whichever append crosses it. Bounds
///   power-loss exposure to one interval without paying a sync per op.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum FsyncPolicy {
    Always,
    #[default]
    Os,
    Group(Duration),
}

impl FsyncPolicy {
    /// Parse the CLI spelling: `always`, `os`, or `group:<ms>`.
    pub fn parse(s: &str) -> crate::Result<FsyncPolicy> {
        if let Some(ms) = s.strip_prefix("group:") {
            let ms: u64 = ms
                .parse()
                .map_err(|e| anyhow::anyhow!("bad group-commit interval {ms:?}: {e}"))?;
            anyhow::ensure!(ms >= 1, "group-commit interval must be >= 1ms");
            return Ok(FsyncPolicy::Group(Duration::from_millis(ms)));
        }
        match s {
            "always" => Ok(FsyncPolicy::Always),
            "os" => Ok(FsyncPolicy::Os),
            other => anyhow::bail!("unknown fsync policy {other:?} (always|os|group:<ms>)"),
        }
    }

    /// CLI spelling of this policy.
    pub fn label(&self) -> String {
        match self {
            FsyncPolicy::Always => "always".to_string(),
            FsyncPolicy::Os => "os".to_string(),
            FsyncPolicy::Group(iv) => format!("group:{}ms", iv.as_millis()),
        }
    }
}

fn segment_name(seq: u64) -> String {
    format!("wal.{seq:012}.log")
}

/// Existing segment files in `dir`, ascending by sequence number.
pub fn segments(dir: &Path) -> crate::Result<Vec<(u64, PathBuf)>> {
    let mut out = Vec::new();
    if !dir.is_dir() {
        return Ok(out);
    }
    for entry in std::fs::read_dir(dir)? {
        let entry = entry?;
        let name = entry.file_name();
        let Some(name) = name.to_str() else { continue };
        if let Some(num) = name.strip_prefix("wal.").and_then(|r| r.strip_suffix(".log")) {
            if let Ok(seq) = num.parse::<u64>() {
                out.push((seq, entry.path()));
            }
        }
    }
    out.sort();
    Ok(out)
}

fn open_segment(dir: &Path, seq: u64, k: usize, bits: u32) -> crate::Result<BufWriter<File>> {
    let file = File::create(dir.join(segment_name(seq)))?;
    let mut w = BufWriter::new(file);
    w.write_all(MAGIC)?;
    w.write_all(&(k as u32).to_le_bytes())?;
    w.write_all(&bits.to_le_bytes())?;
    w.flush()?;
    Ok(w)
}

struct Writer {
    seq: u64,
    file: BufWriter<File>,
    /// Last `fdatasync` on the active segment (group-commit clock).
    last_sync: Instant,
    /// When the oldest not-yet-fdatasync'd group-commit append landed
    /// (`None` = nothing deferred). Drives the idle-tail backstop.
    dirty_since: Option<Instant>,
}

/// An open write-ahead log: one active segment accepting appends, plus
/// any retired-but-not-yet-deleted segments recovery still replays.
pub struct Wal {
    k: usize,
    bits: u32,
    stride: usize,
    dir: PathBuf,
    fsync: FsyncPolicy,
    inner: Mutex<Writer>,
    /// Set when an append failed partway (the segment tail may be
    /// garbage); further appends error out until a rotation cuts over
    /// to a clean segment.
    broken: AtomicBool,
    records: AtomicU64,
    bytes: AtomicU64,
}

impl Wal {
    /// Open `dir` for appends into a fresh segment numbered above every
    /// existing one, with the default [`FsyncPolicy::Os`]. Existing
    /// segments are never appended to — recovery replays them and the
    /// next checkpoint retires them.
    pub fn create(dir: &Path, k: usize, bits: u32) -> crate::Result<Wal> {
        Self::create_with(dir, k, bits, FsyncPolicy::Os)
    }

    /// As [`Wal::create`] with an explicit fsync policy.
    pub fn create_with(
        dir: &Path,
        k: usize,
        bits: u32,
        fsync: FsyncPolicy,
    ) -> crate::Result<Wal> {
        let bits = supported_width(bits);
        std::fs::create_dir_all(dir)?;
        let seq = segments(dir)?.last().map_or(1, |(s, _)| s + 1);
        let file = open_segment(dir, seq, k, bits)?;
        Ok(Wal {
            k,
            bits,
            stride: k.div_ceil((64 / bits) as usize),
            dir: dir.to_path_buf(),
            fsync,
            inner: Mutex::new(Writer {
                seq,
                file,
                last_sync: Instant::now(),
                dirty_since: None,
            }),
            broken: AtomicBool::new(false),
            records: AtomicU64::new(0),
            bytes: AtomicU64::new(0),
        })
    }

    /// Codes per sketch, as recorded in every segment header.
    pub fn k(&self) -> usize {
        self.k
    }

    /// Bit width per code (a supported packing width).
    pub fn bits(&self) -> u32 {
        self.bits
    }

    /// `u64` words per logged row.
    pub fn stride(&self) -> usize {
        self.stride
    }

    /// Records appended by this process.
    pub fn records(&self) -> u64 {
        self.records.load(Ordering::Relaxed)
    }

    /// Bytes appended by this process (frame headers included).
    pub fn bytes(&self) -> u64 {
        self.bytes.load(Ordering::Relaxed)
    }

    /// Whether the active segment is wedged after a failed append (its
    /// tail may be garbage). Only a rotation heals it — callers should
    /// checkpoint promptly when this turns true.
    pub fn is_broken(&self) -> bool {
        self.broken.load(Ordering::Relaxed)
    }

    /// Append one framed payload and, under the same mutex hold, run
    /// `apply`. The frame is flushed to the OS first; an append error
    /// means the op was never acknowledged and `apply` does not run.
    fn append<R>(&self, payload: &[u8], apply: impl FnOnce() -> R) -> crate::Result<R> {
        anyhow::ensure!(
            payload.len() as u64 <= MAX_PAYLOAD as u64,
            "WAL record of {} bytes exceeds the {MAX_PAYLOAD}-byte cap",
            payload.len()
        );
        let mut g = self.inner.lock().unwrap();
        // Checked under the mutex: a writer that was blocked behind the
        // append that broke the segment must not land (and ack) a frame
        // after the garbage tail — replay would stop before it.
        anyhow::ensure!(
            !self.broken.load(Ordering::Relaxed),
            "WAL segment is broken after a failed append; checkpoint to rotate it"
        );
        let frame = (|| -> std::io::Result<()> {
            g.file.write_all(&(payload.len() as u32).to_le_bytes())?;
            g.file.write_all(&crc32_update(0, payload).to_le_bytes())?;
            g.file.write_all(payload)?;
            g.file.flush()?;
            match self.fsync {
                FsyncPolicy::Os => {}
                FsyncPolicy::Always => g.file.get_ref().sync_data()?,
                FsyncPolicy::Group(interval) => {
                    if g.last_sync.elapsed() >= interval {
                        g.file.get_ref().sync_data()?;
                        g.last_sync = Instant::now();
                        g.dirty_since = None;
                    } else if g.dirty_since.is_none() {
                        g.dirty_since = Some(Instant::now());
                    }
                }
            }
            Ok(())
        })();
        if let Err(e) = frame {
            self.broken.store(true, Ordering::Relaxed);
            return Err(e.into());
        }
        let out = apply();
        self.records.fetch_add(1, Ordering::Relaxed);
        self.bytes
            .fetch_add((FRAME_HEADER + payload.len()) as u64, Ordering::Relaxed);
        Ok(out)
    }

    fn push_str(payload: &mut Vec<u8>, s: &str) {
        payload.extend_from_slice(&(s.len() as u32).to_le_bytes());
        payload.extend_from_slice(s.as_bytes());
    }

    fn push_words(payload: &mut Vec<u8>, words: &[u64]) {
        for w in words {
            payload.extend_from_slice(&w.to_le_bytes());
        }
    }

    /// Log an insert/overwrite of `id` with its packed row words
    /// (exactly [`Wal::stride`] of them, as [`PackedCodes::words`]
    /// yields at this shape), then apply it.
    pub fn append_put<R>(
        &self,
        id: &str,
        words: &[u64],
        apply: impl FnOnce() -> R,
    ) -> crate::Result<R> {
        anyhow::ensure!(
            words.len() == self.stride,
            "WAL put row has {} words, stride is {}",
            words.len(),
            self.stride
        );
        let mut payload = Vec::with_capacity(1 + 4 + id.len() + words.len() * 8);
        payload.push(OP_PUT);
        Self::push_str(&mut payload, id);
        Self::push_words(&mut payload, words);
        self.append(&payload, apply)
    }

    /// Log a bulk insert (`ids[i]` owns `words[i·stride..(i+1)·stride]`),
    /// then apply it — one record, one flush, for the whole batch.
    pub fn append_put_rows<R>(
        &self,
        ids: &[String],
        words: &[u64],
        apply: impl FnOnce() -> R,
    ) -> crate::Result<R> {
        anyhow::ensure!(
            words.len() == ids.len() * self.stride,
            "WAL bulk record has {} words for {} rows of stride {}",
            words.len(),
            ids.len(),
            self.stride
        );
        let id_bytes: usize = ids.iter().map(|id| 4 + id.len()).sum();
        let mut payload = Vec::with_capacity(1 + 4 + id_bytes + words.len() * 8);
        payload.push(OP_PUT_ROWS);
        payload.extend_from_slice(&(ids.len() as u32).to_le_bytes());
        for id in ids {
            Self::push_str(&mut payload, id);
        }
        Self::push_words(&mut payload, words);
        self.append(&payload, apply)
    }

    /// Log a removal of `id`, then apply it.
    pub fn append_remove<R>(&self, id: &str, apply: impl FnOnce() -> R) -> crate::Result<R> {
        let mut payload = Vec::with_capacity(1 + 4 + id.len());
        payload.push(OP_REMOVE);
        Self::push_str(&mut payload, id);
        self.append(&payload, apply)
    }

    /// Cut over to a fresh segment; returns the retired older segments
    /// as `(seq, path)` (delete them only once a snapshot covering them
    /// is durable — and, with replicas attached, only past the
    /// retention floor). Takes the append mutex, so every op in a
    /// retired segment has already been applied to the store.
    pub fn rotate(&self) -> crate::Result<Vec<(u64, PathBuf)>> {
        let mut g = self.inner.lock().unwrap();
        let _ = g.file.flush();
        let old: Vec<(u64, PathBuf)> = segments(&self.dir)?
            .into_iter()
            .filter(|(s, _)| *s <= g.seq)
            .collect();
        let seq = g.seq + 1;
        g.file = open_segment(&self.dir, seq, self.k, self.bits)?;
        g.seq = seq;
        g.last_sync = Instant::now();
        g.dirty_since = None;
        self.broken.store(false, Ordering::Relaxed);
        Ok(old)
    }

    /// Flush buffered frames to the OS.
    pub fn flush(&self) -> crate::Result<()> {
        self.inner.lock().unwrap().file.flush()?;
        Ok(())
    }

    /// Whether group-commit appends are awaiting their deferred
    /// `fdatasync` (always false under `Always`/`Os`).
    pub fn unsynced(&self) -> bool {
        self.inner.lock().unwrap().dirty_since.is_some()
    }

    /// Group-commit backstop: `fdatasync` the active segment if
    /// unsynced appends are older than the interval. Appends normally
    /// ride the sync on a later append; this covers idle tails (the
    /// maintenance tick calls it), so power-loss exposure stays bounded
    /// near one interval even when traffic stops. No-op under
    /// `Always`/`Os`.
    pub fn sync_due(&self) -> crate::Result<()> {
        let FsyncPolicy::Group(interval) = self.fsync else {
            return Ok(());
        };
        let mut g = self.inner.lock().unwrap();
        if let Some(t) = g.dirty_since {
            if t.elapsed() >= interval {
                g.file.flush()?;
                g.file.get_ref().sync_data()?;
                g.dirty_since = None;
                g.last_sync = Instant::now();
            }
        }
        Ok(())
    }

    /// Sequence number of the segment currently accepting appends.
    pub fn active_seq(&self) -> u64 {
        self.inner.lock().unwrap().seq
    }

    /// Read a run of complete, CRC-verified frames from segment `seq`
    /// starting at byte `offset` — the primary side of the replication
    /// feed. Frames are returned verbatim (header + payload) so the
    /// replica can re-verify them end to end. The run stops at the
    /// first incomplete or failed frame: on the active segment that is
    /// a record still landing (poll again later); on a retired segment
    /// it is a never-acknowledged garbage tail from a broken append,
    /// skipped exactly as [`replay_into`] skips it.
    ///
    /// `Ok(None)` means the segment no longer exists (retired and
    /// deleted) or is ahead of the writer — the replica must
    /// re-bootstrap from a snapshot.
    pub fn read_chunk(
        &self,
        seq: u64,
        offset: u64,
        max_bytes: usize,
    ) -> crate::Result<Option<WalChunk>> {
        let active = self.active_seq();
        if seq == 0 || seq > active {
            return Ok(None);
        }
        if seq == active {
            // Appends buffer through a BufWriter; make sure the file
            // reflects every acknowledged record before reading it.
            self.flush()?;
        }
        let Ok(file) = File::open(self.dir.join(segment_name(seq))) else {
            return Ok(None);
        };
        let offset = offset.max(SEGMENT_HEADER);
        let mut r = BufReader::new(file);
        r.seek(SeekFrom::Start(offset))?;
        let mut bytes = Vec::new();
        let mut records = 0u64;
        let mut next_offset = offset;
        // True when the byte budget cut the run short with intact
        // frames still behind it — the segment is not done yet.
        let mut budget_stop = false;
        loop {
            let mut hdr = [0u8; FRAME_HEADER];
            match read_some(&mut r, &mut hdr)? {
                ReadOutcome::Full => {}
                _ => break,
            }
            let len = u32::from_le_bytes(hdr[0..4].try_into().unwrap());
            let crc = u32::from_le_bytes(hdr[4..8].try_into().unwrap());
            if len > MAX_PAYLOAD {
                break;
            }
            if !bytes.is_empty() && bytes.len() + FRAME_HEADER + len as usize > max_bytes {
                budget_stop = true;
                break;
            }
            let mut payload = vec![0u8; len as usize];
            match read_some(&mut r, &mut payload)? {
                ReadOutcome::Full => {}
                _ => break,
            }
            if crc32_update(0, &payload) != crc {
                break;
            }
            bytes.extend_from_slice(&hdr);
            bytes.extend_from_slice(&payload);
            records += 1;
            next_offset += (FRAME_HEADER + len as usize) as u64;
            if bytes.len() >= max_bytes {
                budget_stop = true;
                break;
            }
        }
        Ok(Some(WalChunk {
            bytes,
            records,
            next_offset,
            end_of_segment: seq < active && !budget_stop,
        }))
    }
}

/// One replication chunk as read by [`Wal::read_chunk`].
#[derive(Clone, Debug)]
pub struct WalChunk {
    /// Complete CRC-framed records, verbatim (possibly empty).
    pub bytes: Vec<u8>,
    pub records: u64,
    /// Byte offset the next pull of this segment resumes from.
    pub next_offset: u64,
    /// The retired segment is fully consumed — advance to `seq + 1` at
    /// offset [`SEGMENT_HEADER`]. Never set for the active segment.
    pub end_of_segment: bool,
}

/// Replica side of the feed: verify every frame of a shipped chunk
/// end to end (length, checksum, payload shape) and only then apply
/// them in order — a torn or corrupt chunk errors *before* any record
/// touches the store. Returns the records applied.
pub fn apply_chunk(store: &SketchStore, bytes: &[u8]) -> crate::Result<u64> {
    let arena = store
        .arena()
        .ok_or_else(|| anyhow::anyhow!("WAL apply requires an arena-backed store"))?;
    let stride = arena.stride();
    let mut frames = Vec::new();
    let mut pos = 0usize;
    while pos < bytes.len() {
        anyhow::ensure!(
            pos + FRAME_HEADER <= bytes.len(),
            "torn replicated chunk: truncated frame header"
        );
        let len = u32::from_le_bytes(bytes[pos..pos + 4].try_into().unwrap());
        let crc = u32::from_le_bytes(bytes[pos + 4..pos + 8].try_into().unwrap());
        anyhow::ensure!(len <= MAX_PAYLOAD, "replicated frame of {len} bytes exceeds cap");
        let end = pos + FRAME_HEADER + len as usize;
        anyhow::ensure!(end <= bytes.len(), "torn replicated chunk: truncated payload");
        let payload = &bytes[pos + FRAME_HEADER..end];
        anyhow::ensure!(
            crc32_update(0, payload) == crc,
            "replicated frame failed its checksum"
        );
        frames.push(payload);
        pos = end;
    }
    for payload in &frames {
        anyhow::ensure!(
            apply_record(store, stride, payload),
            "malformed replicated WAL record"
        );
    }
    Ok(frames.len() as u64)
}

// ---- replay -------------------------------------------------------------

/// Outcome of replaying a WAL directory.
#[derive(Clone, Debug, Default)]
pub struct ReplayStats {
    pub segments: u64,
    pub records: u64,
    pub bytes: u64,
    /// Replay stopped at a truncated or corrupt tail record — expected
    /// after a crash mid-append; the clean prefix was applied.
    pub torn: bool,
    /// The torn final segment and the byte length of its clean prefix.
    /// The tail past that length was never acknowledged; truncating to
    /// it (as [`super::Durability::open`] does) heals the segment so it
    /// cannot wedge a later recovery once newer segments sit behind it.
    pub torn_tail: Option<(PathBuf, u64)>,
}

/// Shape `(k, bits)` from the oldest segment with a readable header,
/// if any. Header-truncated segments (a crash before the header
/// flushed; nothing acknowledged in them) are skipped, mirroring
/// [`replay_into`], so offline `crp recover` accepts exactly the
/// states the server itself recovers from.
pub fn peek_shape(dir: &Path) -> crate::Result<Option<(usize, u32)>> {
    for (_, path) in segments(dir)? {
        let mut r = BufReader::new(File::open(&path)?);
        match read_header(&mut r) {
            Ok(shape) => return Ok(Some(shape)),
            Err(e) if is_truncation(&e) => continue,
            Err(e) => return Err(e),
        }
    }
    Ok(None)
}

fn read_header(r: &mut impl Read) -> crate::Result<(usize, u32)> {
    let mut magic = [0u8; 8];
    r.read_exact(&mut magic)?;
    anyhow::ensure!(&magic == MAGIC, "not a CRP WAL segment");
    let mut b4 = [0u8; 4];
    r.read_exact(&mut b4)?;
    let k = u32::from_le_bytes(b4) as usize;
    r.read_exact(&mut b4)?;
    let bits = u32::from_le_bytes(b4);
    anyhow::ensure!(k >= 1 && k <= 1 << 24, "implausible WAL k {k}");
    anyhow::ensure!(
        bits != 0 && bits == supported_width(bits),
        "unsupported WAL bit width {bits}"
    );
    Ok((k, bits))
}

/// Replay every segment in `dir` into `store`, oldest first, applying
/// the longest clean prefix of records. A torn tail is tolerated only
/// in the final segment; corruption in an earlier one is an error
/// (acknowledged ops would silently go missing).
pub fn replay_into(store: &SketchStore, dir: &Path) -> crate::Result<ReplayStats> {
    let arena = store
        .arena()
        .ok_or_else(|| anyhow::anyhow!("WAL replay requires an arena-backed store"))?;
    let (want_k, want_bits, stride) = (arena.k(), arena.bits(), arena.stride());
    let mut stats = ReplayStats::default();
    let segs = segments(dir)?;
    for (i, (_, path)) in segs.iter().enumerate() {
        let mut r = BufReader::new(File::open(path)?);
        let (k, bits) = match read_header(&mut r) {
            Ok(shape) => shape,
            // A segment whose header never finished landing holds no
            // acknowledged record (appends ack only after the header
            // and frame are flushed), so it is safe to skip wherever
            // it sits — a crash between segment creation and header
            // flush must not wedge every later restart.
            Err(e) if is_truncation(&e) => {
                stats.segments += 1;
                stats.torn = true;
                continue;
            }
            Err(e) => return Err(e),
        };
        anyhow::ensure!(
            k == want_k && bits == want_bits,
            "WAL segment shape (k={k}, bits={bits}) does not match store \
             (k={want_k}, bits={want_bits})"
        );
        stats.segments += 1;
        let bytes_before = stats.bytes;
        if replay_segment(store, stride, &mut r, &mut stats)? {
            anyhow::ensure!(
                i + 1 == segs.len(),
                "corrupt record inside non-final WAL segment {}",
                path.display()
            );
            stats.torn = true;
            stats.torn_tail =
                Some((path.clone(), SEGMENT_HEADER + (stats.bytes - bytes_before)));
        }
    }
    Ok(stats)
}

fn is_truncation(e: &anyhow::Error) -> bool {
    e.downcast_ref::<std::io::Error>()
        .is_some_and(|io| io.kind() == std::io::ErrorKind::UnexpectedEof)
}

enum ReadOutcome {
    Full,
    Partial,
    Eof,
}

fn read_some(r: &mut impl Read, buf: &mut [u8]) -> crate::Result<ReadOutcome> {
    let mut got = 0usize;
    while got < buf.len() {
        match r.read(&mut buf[got..]) {
            Ok(0) => {
                return Ok(if got == 0 {
                    ReadOutcome::Eof
                } else {
                    ReadOutcome::Partial
                })
            }
            Ok(n) => got += n,
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
            Err(e) => return Err(e.into()),
        }
    }
    Ok(ReadOutcome::Full)
}

/// Returns whether the segment ended torn (truncated/corrupt record).
fn replay_segment(
    store: &SketchStore,
    stride: usize,
    r: &mut impl Read,
    stats: &mut ReplayStats,
) -> crate::Result<bool> {
    loop {
        let mut hdr = [0u8; FRAME_HEADER];
        match read_some(r, &mut hdr)? {
            ReadOutcome::Eof => return Ok(false), // clean end of segment
            ReadOutcome::Partial => return Ok(true),
            ReadOutcome::Full => {}
        }
        let len = u32::from_le_bytes(hdr[0..4].try_into().unwrap());
        let crc = u32::from_le_bytes(hdr[4..8].try_into().unwrap());
        if len > MAX_PAYLOAD {
            return Ok(true);
        }
        let mut payload = vec![0u8; len as usize];
        match read_some(r, &mut payload)? {
            ReadOutcome::Full => {}
            _ => return Ok(true),
        }
        if crc32_update(0, &payload) != crc {
            return Ok(true);
        }
        // The record is intact end-to-end; only now touch the store —
        // "no partial record applied" is the replay contract.
        if !apply_record(store, stride, &payload) {
            return Ok(true);
        }
        stats.records += 1;
        stats.bytes += (FRAME_HEADER + len as usize) as u64;
    }
}

struct Cur<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Cur<'a> {
    fn take(&mut self, n: usize) -> Option<&'a [u8]> {
        let end = self.pos.checked_add(n)?;
        if end > self.buf.len() {
            return None;
        }
        let s = &self.buf[self.pos..end];
        self.pos = end;
        Some(s)
    }
    fn u8(&mut self) -> Option<u8> {
        self.take(1).map(|s| s[0])
    }
    fn u32(&mut self) -> Option<u32> {
        self.take(4).map(|s| u32::from_le_bytes(s.try_into().unwrap()))
    }
    fn str(&mut self) -> Option<String> {
        let n = self.u32()? as usize;
        if n > 1 << 20 {
            return None;
        }
        String::from_utf8(self.take(n)?.to_vec()).ok()
    }
    fn words(&mut self, n: usize) -> Option<Vec<u64>> {
        let raw = self.take(n.checked_mul(8)?)?;
        Some(
            raw.chunks_exact(8)
                .map(|c| u64::from_le_bytes(c.try_into().unwrap()))
                .collect(),
        )
    }
    fn done(&self) -> bool {
        self.pos == self.buf.len()
    }
}

/// Apply one intact record; `false` means the payload is malformed
/// (treated as corruption by the caller).
fn apply_record(store: &SketchStore, stride: usize, payload: &[u8]) -> bool {
    let arena = store.arena().expect("caller checked arena-backed");
    let (k, bits) = (arena.k(), arena.bits());
    let mut c = Cur { buf: payload, pos: 0 };
    let Some(op) = c.u8() else { return false };
    match op {
        OP_PUT => {
            let Some(id) = c.str() else { return false };
            let Some(words) = c.words(stride) else { return false };
            if !c.done() {
                return false;
            }
            store.put(id, PackedCodes::from_words(bits, k, words));
            true
        }
        OP_PUT_ROWS => {
            let Some(n) = c.u32() else { return false };
            let n = n as usize;
            if n > 1 << 24 {
                return false;
            }
            let mut ids = Vec::with_capacity(n.min(1 << 16));
            for _ in 0..n {
                let Some(id) = c.str() else { return false };
                ids.push(id);
            }
            let Some(words) = c.words(n.checked_mul(stride).unwrap_or(usize::MAX)) else {
                return false;
            };
            if !c.done() {
                return false;
            }
            store.put_rows(&ids, &words).is_ok()
        }
        OP_REMOVE => {
            let Some(id) = c.str() else { return false };
            if !c.done() {
                return false;
            }
            store.remove(&id);
            true
        }
        _ => false,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coding::pack_codes;

    fn sketch(k: usize, seed: u16) -> PackedCodes {
        let codes: Vec<u16> = (0..k).map(|i| ((i as u16).wrapping_add(seed)) % 4).collect();
        pack_codes(&codes, 2)
    }

    fn temp_dir(tag: &str) -> PathBuf {
        let d = std::env::temp_dir().join(format!("crp_wal_{tag}_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&d);
        std::fs::create_dir_all(&d).unwrap();
        d
    }

    #[test]
    fn append_replay_roundtrip_all_ops() {
        let dir = temp_dir("rt");
        let (k, bits) = (64usize, 2u32);
        let live = SketchStore::with_arena(k, bits);
        let wal = Wal::create(&dir, k, bits).unwrap();
        for i in 0..10u16 {
            let codes = sketch(k, i);
            let id = format!("id{i}");
            wal.append_put(&id, codes.words(), || live.put(id.clone(), codes.clone()))
                .unwrap();
        }
        let ids: Vec<String> = (10..14u16).map(|i| format!("id{i}")).collect();
        let mut words = Vec::new();
        for i in 10..14u16 {
            words.extend_from_slice(sketch(k, i).words());
        }
        wal.append_put_rows(&ids, &words, || live.put_rows(&ids, &words).unwrap())
            .unwrap();
        let existed = wal.append_remove("id3", || live.remove("id3")).unwrap();
        assert!(existed);
        assert_eq!(wal.records(), 12);
        assert!(wal.bytes() > 0);

        let back = SketchStore::with_arena(k, bits);
        let stats = replay_into(&back, &dir).unwrap();
        assert_eq!(stats.segments, 1);
        assert_eq!(stats.records, 12);
        assert!(!stats.torn);
        assert_eq!(back.len(), live.len());
        for i in 0..14u16 {
            let id = format!("id{i}");
            assert_eq!(back.get(&id), live.get(&id), "{id}");
        }
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn torn_tail_replays_clean_prefix() {
        let dir = temp_dir("torn");
        let (k, bits) = (32usize, 2u32);
        let wal = Wal::create(&dir, k, bits).unwrap();
        for i in 0..5u16 {
            wal.append_put(&format!("id{i}"), sketch(k, i).words(), || ())
                .unwrap();
        }
        drop(wal);
        let (_, path) = segments(&dir).unwrap().pop().unwrap();
        let full = std::fs::read(&path).unwrap();
        // Chop mid-record: the last record loses its tail.
        std::fs::write(&path, &full[..full.len() - 3]).unwrap();
        let back = SketchStore::with_arena(k, bits);
        let stats = replay_into(&back, &dir).unwrap();
        assert!(stats.torn);
        assert_eq!(stats.records, 4);
        assert_eq!(back.len(), 4);
        assert!(back.get("id4").is_none());
        // A flipped payload byte is caught by the checksum too.
        let mut flipped = full.clone();
        let n = flipped.len();
        flipped[n - 1] ^= 0xFF;
        std::fs::write(&path, &flipped).unwrap();
        let back = SketchStore::with_arena(k, bits);
        let stats = replay_into(&back, &dir).unwrap();
        assert!(stats.torn);
        assert_eq!(stats.records, 4);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn rotation_retires_old_segments_and_replay_spans_them() {
        let dir = temp_dir("rot");
        let (k, bits) = (32usize, 2u32);
        let wal = Wal::create(&dir, k, bits).unwrap();
        wal.append_put("a", sketch(k, 1).words(), || ()).unwrap();
        let retired = wal.rotate().unwrap();
        assert_eq!(retired.len(), 1);
        wal.append_put("b", sketch(k, 2).words(), || ()).unwrap();
        wal.append_remove("a", || ()).unwrap();
        // Both segments still on disk: replay sees put(a), put(b), rm(a).
        let back = SketchStore::with_arena(k, bits);
        let stats = replay_into(&back, &dir).unwrap();
        assert_eq!(stats.segments, 2);
        assert_eq!(stats.records, 3);
        assert_eq!(back.len(), 1);
        assert!(back.get("b").is_some());
        // After the retired segment is deleted, only the tail replays.
        for (_, p) in &retired {
            std::fs::remove_file(p).unwrap();
        }
        let back = SketchStore::with_arena(k, bits);
        let stats = replay_into(&back, &dir).unwrap();
        assert_eq!(stats.segments, 1);
        assert_eq!(stats.records, 2);
        assert_eq!(back.len(), 1);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn read_chunk_ships_exactly_what_apply_chunk_replays() {
        let dir = temp_dir("chunk");
        let (k, bits) = (32usize, 2u32);
        let live = SketchStore::with_arena(k, bits);
        let wal = Wal::create(&dir, k, bits).unwrap();
        assert_eq!(wal.active_seq(), 1);
        for i in 0..6u16 {
            let codes = sketch(k, i);
            let id = format!("id{i}");
            wal.append_put(&id, codes.words(), || live.put(id.clone(), codes.clone()))
                .unwrap();
        }
        wal.append_remove("id2", || live.remove("id2")).unwrap();

        // Pull the active segment in one oversized chunk.
        let replica = SketchStore::with_arena(k, bits);
        let chunk = wal.read_chunk(1, SEGMENT_HEADER, 1 << 20).unwrap().unwrap();
        assert_eq!(chunk.records, 7);
        assert!(!chunk.end_of_segment, "active segment never reports end");
        assert_eq!(apply_chunk(&replica, &chunk.bytes).unwrap(), 7);
        assert_eq!(replica.len(), live.len());
        for i in 0..6u16 {
            let id = format!("id{i}");
            assert_eq!(replica.get(&id), live.get(&id), "{id}");
        }
        // Caught up: an empty chunk from the current tail.
        let tail = wal.read_chunk(1, chunk.next_offset, 1 << 20).unwrap().unwrap();
        assert_eq!(tail.records, 0);
        assert_eq!(tail.next_offset, chunk.next_offset);

        // A tiny byte budget still ships at least one whole frame per
        // pull and walks the same total.
        let step = SketchStore::with_arena(k, bits);
        let mut off = SEGMENT_HEADER;
        let mut total = 0u64;
        loop {
            let c = wal.read_chunk(1, off, 1).unwrap().unwrap();
            if c.records == 0 {
                break;
            }
            total += apply_chunk(&step, &c.bytes).unwrap();
            off = c.next_offset;
        }
        assert_eq!(total, 7);
        assert_eq!(step.len(), live.len());

        // Rotation: the retired segment reads to a clean end, then the
        // stream resumes on the new active segment.
        wal.rotate().unwrap();
        assert_eq!(wal.active_seq(), 2);
        wal.append_put("post", sketch(k, 9).words(), || ()).unwrap();
        let done = wal.read_chunk(1, off, 1 << 20).unwrap().unwrap();
        assert_eq!(done.records, 0);
        assert!(done.end_of_segment);
        let next = wal.read_chunk(2, SEGMENT_HEADER, 1 << 20).unwrap().unwrap();
        assert_eq!(next.records, 1);

        // Deleted or future segments force a bootstrap.
        std::fs::remove_file(dir.join("wal.000000000001.log")).unwrap();
        assert!(wal.read_chunk(1, SEGMENT_HEADER, 1 << 20).unwrap().is_none());
        assert!(wal.read_chunk(9, SEGMENT_HEADER, 1 << 20).unwrap().is_none());
        assert!(wal.read_chunk(0, 0, 1 << 20).unwrap().is_none());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn apply_chunk_rejects_torn_and_corrupt_chunks_wholesale() {
        let dir = temp_dir("chunk_torn");
        let (k, bits) = (32usize, 2u32);
        let wal = Wal::create(&dir, k, bits).unwrap();
        for i in 0..3u16 {
            wal.append_put(&format!("id{i}"), sketch(k, i).words(), || ())
                .unwrap();
        }
        let chunk = wal.read_chunk(1, SEGMENT_HEADER, 1 << 20).unwrap().unwrap();

        // Truncated mid-record: nothing applies, not even the intact
        // leading frames.
        let replica = SketchStore::with_arena(k, bits);
        let torn = &chunk.bytes[..chunk.bytes.len() - 3];
        assert!(apply_chunk(&replica, torn).is_err());
        assert_eq!(replica.len(), 0, "no partial chunk may touch the store");

        // A flipped byte in the *last* frame also rejects the whole
        // chunk before the first frame applies.
        let mut flipped = chunk.bytes.clone();
        let n = flipped.len();
        flipped[n - 1] ^= 0xFF;
        assert!(apply_chunk(&replica, flipped.as_slice()).is_err());
        assert_eq!(replica.len(), 0);

        // The intact chunk applies fully.
        assert_eq!(apply_chunk(&replica, &chunk.bytes).unwrap(), 3);
        assert_eq!(replica.len(), 3);

        // The primary never ships a torn tail in the first place: chop
        // the segment mid-record and the chunk stops at the clean
        // prefix.
        drop(wal);
        let (_, path) = segments(&dir).unwrap().pop().unwrap();
        let full = std::fs::read(&path).unwrap();
        std::fs::write(&path, &full[..full.len() - 3]).unwrap();
        let wal = Wal::create(&dir, k, bits).unwrap(); // opens segment 2
        let c = wal.read_chunk(1, SEGMENT_HEADER, 1 << 20).unwrap().unwrap();
        assert_eq!(c.records, 2);
        assert!(c.end_of_segment, "garbage tail of a retired segment is skipped");
        let clean = SketchStore::with_arena(k, bits);
        assert_eq!(apply_chunk(&clean, &c.bytes).unwrap(), 2);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn header_truncated_segments_skipped_at_any_position() {
        let dir = temp_dir("hdr");
        let (k, bits) = (32usize, 2u32);
        let wal = Wal::create(&dir, k, bits).unwrap();
        wal.append_put("a", sketch(k, 1).words(), || ()).unwrap();
        drop(wal);
        // A crash between segment creation and header flush leaves an
        // empty/truncated file — both older and newer than the good
        // segment here. Neither holds an acknowledged record, so
        // neither may wedge recovery.
        std::fs::write(dir.join("wal.000000000000.log"), b"").unwrap();
        std::fs::write(dir.join("wal.000000000007.log"), b"CRPW").unwrap();
        let back = SketchStore::with_arena(k, bits);
        let stats = replay_into(&back, &dir).unwrap();
        assert_eq!(stats.segments, 3);
        assert_eq!(stats.records, 1);
        assert!(stats.torn);
        assert_eq!(back.len(), 1);
        // Shape discovery skips them the same way.
        assert_eq!(peek_shape(&dir).unwrap(), Some((k, bits)));
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn fsync_policy_parse_and_label() {
        assert_eq!(FsyncPolicy::parse("always").unwrap(), FsyncPolicy::Always);
        assert_eq!(FsyncPolicy::parse("os").unwrap(), FsyncPolicy::Os);
        assert_eq!(
            FsyncPolicy::parse("group:25").unwrap(),
            FsyncPolicy::Group(Duration::from_millis(25))
        );
        assert!(FsyncPolicy::parse("group:0").is_err());
        assert!(FsyncPolicy::parse("group:abc").is_err());
        assert!(FsyncPolicy::parse("sometimes").is_err());
        assert_eq!(FsyncPolicy::parse("group:25").unwrap().label(), "group:25ms");
        assert_eq!(FsyncPolicy::default(), FsyncPolicy::Os);
    }

    #[test]
    fn every_fsync_policy_replays_identically() {
        for (tag, policy) in [
            ("sync_always", FsyncPolicy::Always),
            ("sync_os", FsyncPolicy::Os),
            ("sync_group", FsyncPolicy::Group(Duration::from_millis(1))),
        ] {
            let dir = temp_dir(tag);
            let (k, bits) = (32usize, 2u32);
            let wal = Wal::create_with(&dir, k, bits, policy).unwrap();
            for i in 0..8u16 {
                wal.append_put(&format!("id{i}"), sketch(k, i).words(), || ())
                    .unwrap();
            }
            wal.append_remove("id5", || ()).unwrap();
            drop(wal);
            let back = SketchStore::with_arena(k, bits);
            let stats = replay_into(&back, &dir).unwrap();
            assert_eq!(stats.records, 9, "{tag}");
            assert_eq!(back.len(), 7, "{tag}");
            assert!(back.get("id5").is_none(), "{tag}");
            std::fs::remove_dir_all(&dir).ok();
        }
    }

    #[test]
    fn group_commit_backstop_syncs_idle_tail() {
        let dir = temp_dir("group_idle");
        let (k, bits) = (32usize, 2u32);
        // A huge interval: the deferred sync can never ride an append
        // or come due inside the test, so the dirty flag is
        // deterministic.
        let policy = FsyncPolicy::Group(Duration::from_secs(3600));
        let wal = Wal::create_with(&dir, k, bits, policy).unwrap();
        wal.append_put("a", sketch(k, 1).words(), || ()).unwrap();
        assert!(wal.unsynced(), "group append defers its fdatasync");
        wal.sync_due().unwrap();
        assert!(wal.unsynced(), "not yet due: the tail stays deferred");
        // Rotation cuts over to a clean segment.
        wal.rotate().unwrap();
        assert!(!wal.unsynced());
        drop(wal);

        // A tiny interval: the maintenance-tick backstop syncs the
        // idle tail once it is older than the interval.
        let policy = FsyncPolicy::Group(Duration::from_millis(1));
        let wal = Wal::create_with(&dir, k, bits, policy).unwrap();
        wal.append_put("b", sketch(k, 2).words(), || ()).unwrap();
        std::thread::sleep(Duration::from_millis(5));
        wal.sync_due().unwrap();
        assert!(!wal.unsynced(), "idle tail must be synced once due");
        drop(wal);

        // Always / Os never defer.
        for policy in [FsyncPolicy::Always, FsyncPolicy::Os] {
            let wal = Wal::create_with(&dir, k, bits, policy).unwrap();
            wal.append_put("c", sketch(k, 3).words(), || ()).unwrap();
            assert!(!wal.unsynced(), "{policy:?}");
            wal.sync_due().unwrap();
        }
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn shape_mismatch_and_bad_magic_rejected() {
        let dir = temp_dir("shape");
        let wal = Wal::create(&dir, 64, 2).unwrap();
        wal.append_put("a", sketch(64, 1).words(), || ()).unwrap();
        drop(wal);
        let other = SketchStore::with_arena(128, 2);
        assert!(replay_into(&other, &dir).is_err());
        assert_eq!(peek_shape(&dir).unwrap(), Some((64, 2)));
        // Garbage segment: a full-length header with the wrong magic is
        // corruption, not truncation.
        std::fs::write(dir.join("wal.000000000009.log"), b"garbage-garbage!").unwrap();
        let back = SketchStore::with_arena(64, 2);
        assert!(replay_into(&back, &dir).is_err());
        std::fs::remove_dir_all(&dir).ok();
        // Nonexistent dir: clean empty replay.
        let back = SketchStore::with_arena(64, 2);
        let stats = replay_into(&back, &dir).unwrap();
        assert_eq!(stats.segments, 0);
        assert!(peek_shape(&dir).unwrap().is_none());
    }
}
