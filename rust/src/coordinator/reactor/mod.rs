//! Event-driven serving front-end: N sharded epoll loops (SO_REUSEPORT)
//! with an optional bounded worker pool for off-loop bulk dispatch.
//!
//! The thread-per-connection path in `server.rs` is the oracle — this
//! module exists so fan-in stops being bounded by OS threads, and (as
//! of the multi-reactor front-end) so the front-end stops being bounded
//! by one core. Layout:
//!
//! - [`sys`] — raw syscalls (`std::arch::asm!`, gated to linux
//!   x86_64/aarch64 — no `libc`/`mio` in the dependency budget): epoll,
//!   rlimit, SO_REUSEPORT socket setup, eventfd.
//! - [`loop_core`] — the per-loop reactor: accept, in-place framing,
//!   pipelining, write backpressure, the coarse idle sweep, clean
//!   shutdown. One instance per listener, one thread per instance.
//! - [`dispatch`] — the shared dispatch layer: request routing plus
//!   Register/RegisterSparse/TopK fusion, and the offload path that
//!   hands fused runs to the worker pool.
//! - [`pool`] — the bounded worker pool: per-loop SPSC submission and
//!   completion rings with eventfd wakeups, loop `i` statically served
//!   by worker `i % W` so ordering needs no sequencer.
//!
//! Sharding model: `--reactor-threads N` binds N SO_REUSEPORT listeners
//! on the same address; the kernel hashes incoming connections across
//! the accept queues, so the loops share *nothing* on the hot path — no
//! accept lock, no cross-loop handoff, per-loop connection slabs and
//! metric shards. `--reactor-threads 0` keeps PR 8's single loop on a
//! normally-bound listener, byte-identical in behavior and in
//! `StatsDetailed` legacy framing. Each loop independently preserves
//! PR 8's guarantees: responses byte-identical to the blocking oracle,
//! zero steady-state allocation per request, per-connection program
//! order.
//!
//! Worker offload (`--reactor-workers W`, default 0 = inline): fused
//! bulk runs — the only requests whose handle time is unbounded — are
//! pushed to an SPSC ring and executed off-loop while the loop keeps
//! parsing and writing. Per-connection program order and per-frame ack
//! order are preserved: a connection with an offloaded run in flight is
//! parked until the completion (drained in submission order) writes its
//! acks. Everything else — Ping, Estimate, Stats, admin — stays inline
//! at loop latency.
//!
//! Error-path caveat, documented rather than papered over: if a *fused*
//! bulk register fails (WAL I/O error mid-batch), every member receives
//! the batch error frame, whose message differs from the per-request
//! error thread mode would produce. Healthy-path responses are pinned
//! byte-identical across modes by `tests/serve.rs`.

use std::sync::atomic::AtomicBool;
use std::sync::Arc;
use std::time::Duration;

/// Reactor front-end options, carried from `ServerConfig` by `serve`.
#[derive(Clone, Debug, Default)]
pub(crate) struct ReactorOptions {
    /// Global connection cap (0 = unlimited), shared across loops.
    pub max_conns: usize,
    /// Worker-pool size; 0 executes fused runs inline on the loop.
    pub workers: usize,
    /// Idle-disconnect limit, enforced by the per-loop coarse sweep.
    pub conn_timeout: Option<Duration>,
    /// Cooperative shutdown: when set to true, every loop closes its
    /// connections, workers join, and `serve` returns `Ok`.
    pub shutdown: Option<Arc<AtomicBool>>,
}

/// Default loop count for `--reactor-threads`: enough to matter, small
/// enough not to strand cores the engine needs.
pub fn default_reactor_threads() -> usize {
    std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1)
        .min(4)
}

#[cfg(all(target_os = "linux", any(target_arch = "x86_64", target_arch = "aarch64")))]
mod dispatch;
#[cfg(all(target_os = "linux", any(target_arch = "x86_64", target_arch = "aarch64")))]
mod loop_core;
#[cfg(all(target_os = "linux", any(target_arch = "x86_64", target_arch = "aarch64")))]
mod pool;
#[cfg(all(target_os = "linux", any(target_arch = "x86_64", target_arch = "aarch64")))]
mod sys;

#[cfg(all(target_os = "linux", any(target_arch = "x86_64", target_arch = "aarch64")))]
pub use sys::raise_nofile_limit;

#[cfg(all(target_os = "linux", any(target_arch = "x86_64", target_arch = "aarch64")))]
pub(crate) use sys::bind_reuseport_group;

#[cfg(all(target_os = "linux", any(target_arch = "x86_64", target_arch = "aarch64")))]
mod imp {
    use std::net::TcpListener;
    use std::sync::atomic::{AtomicBool, Ordering};
    use std::sync::Arc;

    use super::{loop_core, pool, ReactorOptions};
    use crate::coordinator::obs;
    use crate::coordinator::server::ServiceState;

    /// Run the reactor front-end: one event loop per listener, each on
    /// its own thread, plus the shared worker pool. Never returns in
    /// healthy operation unless `opts.shutdown` is tripped; then every
    /// loop drains, workers join, and the result is `Ok`.
    pub(crate) fn serve_reactor(
        listeners: Vec<TcpListener>,
        state: Arc<ServiceState>,
        opts: ReactorOptions,
    ) -> crate::Result<()> {
        anyhow::ensure!(!listeners.is_empty(), "reactor needs at least one listener");
        let n = listeners.len();
        let shards = state.metrics.install_reactor_loops(n);
        let (workers, lanes) = if opts.workers > 0 {
            let (p, lanes) = pool::WorkerPool::spawn(n, opts.workers)?;
            (Some(p), lanes.into_iter().map(Some).collect())
        } else {
            (None, vec![None; n])
        };
        // Tripped by the first loop that errors so siblings drain too.
        let trip = Arc::new(AtomicBool::new(false));
        obs::log::info(
            "crp::server",
            "reactor front-end up",
            &[
                ("loops", n.to_string()),
                ("workers", opts.workers.to_string()),
                ("max_conns", opts.max_conns.to_string()),
            ],
        );
        let mut handles = Vec::with_capacity(n);
        for (i, ((listener, shard), lane)) in listeners
            .into_iter()
            .zip(shards)
            .zip(lanes)
            .enumerate()
        {
            let state = state.clone();
            let trip = trip.clone();
            let cfg = loop_core::LoopConfig {
                idx: i,
                max_conns: opts.max_conns,
                conn_timeout: opts.conn_timeout,
                external_stop: opts.shutdown.clone(),
                trip: trip.clone(),
                block_forever: n == 1 && opts.shutdown.is_none() && opts.conn_timeout.is_none(),
            };
            handles.push(
                std::thread::Builder::new()
                    .name(format!("crp-reactor-{i}"))
                    .spawn(move || {
                        let r = loop_core::run_loop(listener, state, shard, lane, cfg);
                        if r.is_err() {
                            trip.store(true, Ordering::SeqCst);
                        }
                        r
                    })?,
            );
        }
        let mut first_err = None;
        for h in handles {
            match h.join() {
                Ok(Ok(())) => {}
                Ok(Err(e)) => {
                    first_err.get_or_insert(e);
                }
                Err(_) => {
                    first_err.get_or_insert_with(|| anyhow::anyhow!("reactor loop panicked"));
                }
            }
        }
        if let Some(p) = workers {
            p.shutdown();
        }
        match first_err {
            Some(e) => Err(e),
            None => Ok(()),
        }
    }
}

#[cfg(all(target_os = "linux", any(target_arch = "x86_64", target_arch = "aarch64")))]
pub(crate) use imp::serve_reactor;

/// `--server-mode reactor` needs epoll; everywhere else the flag fails
/// fast with a clear error instead of a degraded emulation.
#[cfg(not(all(target_os = "linux", any(target_arch = "x86_64", target_arch = "aarch64"))))]
pub(crate) fn serve_reactor(
    _listeners: Vec<std::net::TcpListener>,
    _state: std::sync::Arc<crate::coordinator::server::ServiceState>,
    _opts: ReactorOptions,
) -> crate::Result<()> {
    anyhow::bail!(
        "--server-mode reactor requires linux on x86_64/aarch64 (epoll); \
         use --server-mode threads"
    )
}

/// SO_REUSEPORT sharding is a linux feature like the reactor itself.
#[cfg(not(all(target_os = "linux", any(target_arch = "x86_64", target_arch = "aarch64"))))]
pub(crate) fn bind_reuseport_group(
    _addr: &str,
    _n: usize,
) -> crate::Result<Vec<std::net::TcpListener>> {
    anyhow::bail!(
        "--server-mode reactor requires linux on x86_64/aarch64 (epoll); \
         use --server-mode threads"
    )
}

/// No-op off linux: the connection-scaling bench degrades gracefully.
#[cfg(not(all(target_os = "linux", any(target_arch = "x86_64", target_arch = "aarch64"))))]
pub fn raise_nofile_limit() -> Option<u64> {
    None
}
