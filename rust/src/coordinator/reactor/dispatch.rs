//! The shared dispatch layer: drains parsed-request queues, fuses
//! same-collection `Register`/`RegisterSparse` runs and
//! same-`(collection, n)` `TopK` runs across a loop's connections into
//! the bulk engine paths, and — when a worker-pool lane is attached —
//! hands the fused run off the loop thread.
//!
//! Fusion only ever consumes the *front* run of each connection's
//! queue, so per-connection program order (and therefore state) is
//! preserved. Offload keeps that guarantee with two rules:
//!
//! - A connection with an offloaded run in flight (`blocked > 0`) is
//!   *parked*: its queue is not dispatched and it is skipped as a
//!   fusion donor until the completion is applied. The in-flight acks
//!   are always written before anything queued behind them.
//! - Completions are drained in submission order (the lane is a FIFO
//!   served by a single worker), so fused runs retire exactly as if
//!   they had executed inline.
//!
//! A fused run offloads only when the lane has a free in-flight slot;
//! otherwise it executes inline on the loop thread — same calls, same
//! response bytes. Single-member groups always stay inline so
//! unfusable traffic keeps thread-mode latency and metrics.

use std::sync::atomic::Ordering;
use std::sync::Arc;
use std::time::Instant;

use super::loop_core::{rewrap, Pending, Reactor};
use super::pool::{self, BulkJob};
use crate::coordinator::obs;
use crate::coordinator::protocol::{Request, Response};
use crate::coordinator::registry::{Collection, DEFAULT_COLLECTION, MAX_BULK_CELLS};
use crate::data::sparse::CsrMatrix;

/// Fused-group member cap (also the fused-TopK total-query cap).
const MAX_FUSE: usize = 256;

/// A fused-group member: which connection it came from (token plus the
/// slot generation valid at fuse time), how it was scoped (meta parity
/// with thread mode), and its share of the fused work.
pub(super) struct FuseMember {
    pub tok: usize,
    /// Slot generation at fuse time: a completion whose member
    /// generation no longer matches hit a closed/recycled slot and is
    /// dropped.
    pub gen: u64,
    pub scope: Option<String>,
    pub decode_us: u64,
    /// Work items contributed: queries for TopK fusion, CSR rows
    /// for RegisterSparse fusion, always 1 for Register.
    pub count: usize,
}

/// What a fused run owes each member once the bulk call returns.
pub(super) enum BulkDone {
    /// Per-member `Registered{id}` echoes.
    Register { echo_ids: Vec<String> },
    /// Per-member `RegisteredBatch{count}`; `nnzs` parallels members
    /// (each member's slow-query candidates magnitude).
    Sparse { nnzs: Vec<u64> },
    /// Split the fused result rows back by member `count`.
    TopK,
}

/// An offloaded fused run awaiting its completion.
pub(super) struct InFlight {
    pub seq: u64,
    pub members: Vec<FuseMember>,
    pub done: BulkDone,
}

impl Reactor {
    fn member(&self, tok: usize, scope: Option<String>, decode_us: u64, count: usize) -> FuseMember {
        FuseMember {
            tok,
            gen: self.gens[tok],
            scope,
            decode_us,
            count,
        }
    }

    /// Drain every connection's parsed-request queue, fusing
    /// same-collection `Register` runs and same-`(collection, n)`
    /// `TopK` runs across connections into the bulk paths.
    pub(super) fn dispatch(&mut self) {
        let replica_active = self.state.replica.as_ref().is_some_and(|r| r.is_active());
        let active = std::mem::take(&mut self.active);
        for &tok in &active {
            loop {
                // Parked while an offloaded run is in flight: the
                // completion must write its acks first.
                match self.conns.get(tok) {
                    Some(Some(c)) if c.blocked == 0 => {}
                    _ => break,
                }
                let Some(head) = self.conns[tok].as_mut().and_then(|c| c.queue.pop_front())
                else {
                    break;
                };
                match head {
                    Pending::Bad { message, decode_us } => {
                        self.respond_bad(tok, message, decode_us)
                    }
                    Pending::Req { req, decode_us } => match req {
                        // Register fusion is a write: on an active
                        // replica route through the router so every
                        // member gets the exact redirect error.
                        Request::Register { id, vector } if !replica_active => {
                            self.fuse_register(&active, tok, None, id, vector, decode_us)
                        }
                        Request::Scoped { collection, inner }
                            if !replica_active && matches!(*inner, Request::Register { .. }) =>
                        {
                            if let Request::Register { id, vector } = *inner {
                                self.fuse_register(
                                    &active,
                                    tok,
                                    Some(collection),
                                    id,
                                    vector,
                                    decode_us,
                                );
                            }
                        }
                        // Sparse bulk ingest fuses like Register:
                        // CSR frames concatenate into one call.
                        Request::RegisterSparse { ids, csr } if !replica_active => {
                            self.fuse_register_sparse(&active, tok, None, ids, csr, decode_us)
                        }
                        Request::Scoped { collection, inner }
                            if !replica_active
                                && matches!(*inner, Request::RegisterSparse { .. }) =>
                        {
                            if let Request::RegisterSparse { ids, csr } = *inner {
                                self.fuse_register_sparse(
                                    &active,
                                    tok,
                                    Some(collection),
                                    ids,
                                    csr,
                                    decode_us,
                                );
                            }
                        }
                        Request::TopK { vectors, n } => {
                            self.fuse_topk(&active, tok, None, vectors, n, decode_us)
                        }
                        Request::Scoped { collection, inner }
                            if matches!(*inner, Request::TopK { .. }) =>
                        {
                            if let Request::TopK { vectors, n } = *inner {
                                self.fuse_topk(&active, tok, Some(collection), vectors, n, decode_us);
                            }
                        }
                        other => self.respond_one(tok, other, decode_us),
                    },
                }
            }
        }
        self.active = active;
        if self.tick_dispatched > 0 {
            // Count histogram: the "µs" axis reads as requests/tick.
            self.state
                .metrics
                .reactor_dispatch_batch
                .record(self.tick_dispatched);
            self.tick_dispatched = 0;
        }
    }

    /// Resolve a fusion target; `None` means the collection is
    /// unknown and the caller must replay through the router for
    /// the exact per-request error bytes.
    fn fuse_target(&self, scope: Option<&str>) -> Option<Arc<Collection>> {
        self.state.registry.get(scope.unwrap_or(DEFAULT_COLLECTION))
    }

    /// Run a fused group: off-loop through the lane when a slot is
    /// free, inline otherwise. Either way the bulk call, the response
    /// bytes, and the per-member metrics are identical.
    fn execute_bulk(&mut self, job: BulkJob, members: Vec<FuseMember>, done: BulkDone) {
        self.state
            .metrics
            .reactor_coalesced_batches
            .fetch_add(1, Ordering::Relaxed);
        self.shard.coalesced_batches.fetch_add(1, Ordering::Relaxed);
        let b = members.len() as u64;
        let mut job = job;
        if self.inflight < pool::MAX_INFLIGHT {
            if let Some(lane) = self.lane.clone() {
                match lane.sub.push(pool::Submission {
                    seq: self.next_seq,
                    job,
                }) {
                    Ok(()) => {
                        for m in &members {
                            if let Some(c) = self.conns[m.tok].as_mut() {
                                c.blocked += 1;
                            }
                        }
                        self.pending_bulk.push_back(InFlight {
                            seq: self.next_seq,
                            members,
                            done,
                        });
                        self.next_seq += 1;
                        self.inflight += 1;
                        let m = &self.state.metrics;
                        m.reactor_offloaded_batches.fetch_add(1, Ordering::Relaxed);
                        m.reactor_worker_queue_depth.fetch_add(1, Ordering::Relaxed);
                        self.shard.offloaded_batches.fetch_add(1, Ordering::Relaxed);
                        lane.worker_wake.signal();
                        return;
                    }
                    // Ring full (slots outran MAX_INFLIGHT bookkeeping
                    // cannot happen, but stay safe): run inline.
                    Err(back) => job = back.job,
                }
            }
        }
        let h0 = Instant::now();
        let resp = job.run();
        let handle_each = (h0.elapsed().as_micros() as u64 / b).max(1);
        self.finish_bulk(members, done, resp, handle_each);
    }

    /// Apply completions in submission order. Members whose slot
    /// generation moved on (connection closed, slot possibly recycled)
    /// are dropped; everyone else gets exactly the frame the inline
    /// path would have written.
    pub(super) fn drain_completions(&mut self) {
        let Some(lane) = self.lane.clone() else {
            return;
        };
        lane.comp_wake.drain();
        while let Some(c) = lane.comp.pop() {
            let Some(inf) = self.pending_bulk.pop_front() else {
                debug_assert!(false, "completion without a pending submission");
                return;
            };
            debug_assert_eq!(inf.seq, c.seq, "completions retire in submission order");
            self.inflight -= 1;
            self.state
                .metrics
                .reactor_worker_queue_depth
                .fetch_sub(1, Ordering::Relaxed);
            for m in &inf.members {
                if self.gens[m.tok] == m.gen {
                    if let Some(conn) = self.conns[m.tok].as_mut() {
                        conn.blocked = conn.blocked.saturating_sub(1);
                    }
                    // Unparked: dispatch + flush this tick.
                    self.mark_active(m.tok);
                }
            }
            let b = inf.members.len() as u64;
            let handle_each = (c.handle_us / b.max(1)).max(1);
            self.finish_bulk(inf.members, inf.done, c.resp, handle_each);
        }
    }

    /// Write each member's share of a fused result. Dead members
    /// (generation mismatch) still consume their share of the split so
    /// the remaining members stay aligned.
    fn finish_bulk(
        &mut self,
        members: Vec<FuseMember>,
        done: BulkDone,
        resp: Response,
        handle_each: u64,
    ) {
        match done {
            BulkDone::Register { echo_ids } => {
                let fused_ok = matches!(resp, Response::RegisteredBatch { .. });
                for (m, id) in members.into_iter().zip(echo_ids) {
                    if self.gens[m.tok] != m.gen {
                        continue;
                    }
                    let meta = obs::ReqMeta {
                        kind: obs::RequestKind::Register,
                        collection: m.scope,
                        candidates: None,
                    };
                    if fused_ok {
                        let one = Response::Registered { id };
                        self.push_response(m.tok, &one, &meta, m.decode_us, handle_each);
                    } else {
                        self.push_response(m.tok, &resp, &meta, m.decode_us, handle_each);
                    }
                }
            }
            BulkDone::Sparse { nnzs } => {
                let fused_ok = matches!(resp, Response::RegisteredBatch { .. });
                for (m, nnz) in members.into_iter().zip(nnzs) {
                    if self.gens[m.tok] != m.gen {
                        continue;
                    }
                    let meta = obs::ReqMeta {
                        kind: obs::RequestKind::RegisterSparse,
                        collection: m.scope,
                        candidates: Some(nnz),
                    };
                    if fused_ok {
                        let one = Response::RegisteredBatch {
                            count: m.count as u64,
                        };
                        self.push_response(m.tok, &one, &meta, m.decode_us, handle_each);
                    } else {
                        self.push_response(m.tok, &resp, &meta, m.decode_us, handle_each);
                    }
                }
            }
            BulkDone::TopK => match resp {
                Response::TopK { results } => {
                    let mut it = results.into_iter();
                    for m in members {
                        let chunk: Vec<_> = it.by_ref().take(m.count).collect();
                        if self.gens[m.tok] != m.gen {
                            continue;
                        }
                        let meta = obs::ReqMeta {
                            kind: obs::RequestKind::TopK,
                            collection: m.scope,
                            candidates: None,
                        };
                        let one = Response::TopK { results: chunk };
                        self.push_response(m.tok, &one, &meta, m.decode_us, handle_each);
                    }
                }
                err => {
                    // A sketch failure surfaces the same
                    // `sketch failed: ...` message per-request topk
                    // would produce (the failing vector may belong to
                    // another member; the message text is identical).
                    for m in members {
                        if self.gens[m.tok] != m.gen {
                            continue;
                        }
                        let meta = obs::ReqMeta {
                            kind: obs::RequestKind::TopK,
                            collection: m.scope,
                            candidates: None,
                        };
                        self.push_response(m.tok, &err, &meta, m.decode_us, handle_each);
                    }
                }
            },
        }
    }

    fn fuse_register(
        &mut self,
        active: &[usize],
        tok: usize,
        scope: Option<String>,
        id: String,
        vector: Vec<f32>,
        decode_us: u64,
    ) {
        let Some(col) = self.fuse_target(scope.as_deref()) else {
            self.respond_one(tok, rewrap(scope, Request::Register { id, vector }), decode_us);
            return;
        };
        let mut ids = Vec::new();
        let mut vecs = Vec::new();
        let mut members = Vec::new();
        let mut maxd = vector.len().max(1);
        ids.push(id);
        vecs.push(vector);
        members.push(self.member(tok, scope, decode_us, 1));
        self.pull_registers(tok, &col.name, &mut ids, &mut vecs, &mut members, &mut maxd);
        for &other in active {
            if other != tok {
                let name = &col.name;
                self.pull_registers(other, name, &mut ids, &mut vecs, &mut members, &mut maxd);
            }
        }
        if members.len() == 1 {
            // Nothing to fuse with this tick: the per-request path
            // keeps single-register metrics identical to thread mode.
            let m = members.pop().unwrap();
            let req = Request::Register {
                id: ids.pop().unwrap(),
                vector: vecs.pop().unwrap(),
            };
            self.respond_one(m.tok, rewrap(m.scope, req), m.decode_us);
            return;
        }
        let echo_ids = ids.clone();
        self.execute_bulk(
            BulkJob::Register { col, ids, vecs },
            members,
            BulkDone::Register { echo_ids },
        );
    }

    /// Pop the leading run of same-collection `Register` requests
    /// off one connection's queue into the fused batch. Only the
    /// front run is taken, so program order within the connection
    /// is untouched.
    fn pull_registers(
        &mut self,
        tok: usize,
        name: &str,
        ids: &mut Vec<String>,
        vecs: &mut Vec<Vec<f32>>,
        members: &mut Vec<FuseMember>,
        maxd: &mut usize,
    ) {
        loop {
            if members.len() >= MAX_FUSE {
                return;
            }
            let gen = self.gens[tok];
            let Some(conn) = self.conns[tok].as_mut() else {
                return;
            };
            if conn.blocked > 0 {
                // Parked behind an offloaded run: its front frame must
                // not retire before the in-flight acks.
                return;
            }
            let dim = match conn.queue.front() {
                Some(Pending::Req {
                    req: Request::Register { vector, .. },
                    ..
                }) if name == DEFAULT_COLLECTION => vector.len().max(1),
                Some(Pending::Req {
                    req: Request::Scoped { collection, inner },
                    ..
                }) if collection == name => match inner.as_ref() {
                    Request::Register { vector, .. } => vector.len().max(1),
                    _ => return,
                },
                _ => return,
            };
            // Keep the fused batch inside the bulk workspace the
            // members would individually never hit.
            if (members.len() + 1) * dim.max(*maxd) > MAX_BULK_CELLS {
                return;
            }
            let Some(Pending::Req { req, decode_us }) = conn.queue.pop_front() else {
                return;
            };
            let (scope, id, vector) = match req {
                Request::Register { id, vector } => (None, id, vector),
                Request::Scoped { collection, inner } => match *inner {
                    Request::Register { id, vector } => (Some(collection), id, vector),
                    other => {
                        // Defensive: restore anything unexpected.
                        conn.queue.push_front(Pending::Req {
                            req: Request::Scoped {
                                collection,
                                inner: Box::new(other),
                            },
                            decode_us,
                        });
                        return;
                    }
                },
                other => {
                    conn.queue.push_front(Pending::Req {
                        req: other,
                        decode_us,
                    });
                    return;
                }
            };
            *maxd = (*maxd).max(vector.len().max(1));
            ids.push(id);
            vecs.push(vector);
            members.push(FuseMember {
                tok,
                gen,
                scope,
                decode_us,
                count: 1,
            });
        }
    }

    fn fuse_register_sparse(
        &mut self,
        active: &[usize],
        tok: usize,
        scope: Option<String>,
        ids: Vec<String>,
        csr: CsrMatrix,
        decode_us: u64,
    ) {
        let Some(col) = self.fuse_target(scope.as_deref()) else {
            let req = Request::RegisterSparse { ids, csr };
            self.respond_one(tok, rewrap(scope, req), decode_us);
            return;
        };
        if ids.len() != csr.rows() {
            // A malformed frame replays through the router for the
            // exact per-request error instead of poisoning a fuse.
            let req = Request::RegisterSparse { ids, csr };
            self.respond_one(tok, rewrap(scope, req), decode_us);
            return;
        }
        let mut all_ids = ids;
        let mut merged = csr;
        let rows = merged.rows();
        let mut members = vec![self.member(tok, scope, decode_us, rows)];
        // Per-frame nnz, parallel to `members` (each member's
        // slow-query candidates magnitude — thread-mode parity).
        let mut nnzs = vec![merged.nnz() as u64];
        self.pull_register_sparse(tok, &col, &mut all_ids, &mut merged, &mut members, &mut nnzs);
        for &other in active {
            if other != tok {
                self.pull_register_sparse(
                    other, &col, &mut all_ids, &mut merged, &mut members, &mut nnzs,
                );
            }
        }
        if members.len() == 1 {
            let m = members.pop().unwrap();
            let req = Request::RegisterSparse {
                ids: all_ids,
                csr: merged,
            };
            self.respond_one(m.tok, rewrap(m.scope, req), m.decode_us);
            return;
        }
        self.execute_bulk(
            BulkJob::RegisterSparse {
                col,
                ids: all_ids,
                csr: merged,
            },
            members,
            BulkDone::Sparse { nnzs },
        );
    }

    /// Pop the leading run of same-collection `RegisterSparse`
    /// requests off one connection's queue into the fused CSR batch
    /// (indices/values concatenate; indptr re-offsets). Only the
    /// front run is taken, so program order within the connection
    /// is untouched.
    fn pull_register_sparse(
        &mut self,
        tok: usize,
        col: &Arc<Collection>,
        ids: &mut Vec<String>,
        merged: &mut CsrMatrix,
        members: &mut Vec<FuseMember>,
        nnzs: &mut Vec<u64>,
    ) {
        let name = &col.name;
        loop {
            if members.len() >= MAX_FUSE {
                return;
            }
            let gen = self.gens[tok];
            let Some(conn) = self.conns[tok].as_mut() else {
                return;
            };
            if conn.blocked > 0 {
                return;
            }
            let (rows, nnz) = match conn.queue.front() {
                Some(Pending::Req {
                    req: Request::RegisterSparse { ids, csr },
                    ..
                }) if name == DEFAULT_COLLECTION && ids.len() == csr.rows() => {
                    (csr.rows(), csr.nnz())
                }
                Some(Pending::Req {
                    req: Request::Scoped { collection, inner },
                    ..
                }) if collection == name => match inner.as_ref() {
                    Request::RegisterSparse { ids, csr } if ids.len() == csr.rows() => {
                        (csr.rows(), csr.nnz())
                    }
                    _ => return,
                },
                _ => return,
            };
            // Keep the fused batch inside the bulk guards the
            // members would individually never hit: the nnz budget
            // and the projected-output workspace.
            if merged.nnz() + nnz > MAX_BULK_CELLS
                || (merged.rows() + rows).saturating_mul(col.k) > MAX_BULK_CELLS
            {
                return;
            }
            let Some(Pending::Req { req, decode_us }) = conn.queue.pop_front() else {
                return;
            };
            let (scope, frame_ids, csr) = match req {
                Request::RegisterSparse { ids, csr } => (None, ids, csr),
                Request::Scoped { collection, inner } => match *inner {
                    Request::RegisterSparse { ids, csr } => (Some(collection), ids, csr),
                    other => {
                        conn.queue.push_front(Pending::Req {
                            req: Request::Scoped {
                                collection,
                                inner: Box::new(other),
                            },
                            decode_us,
                        });
                        return;
                    }
                },
                other => {
                    conn.queue.push_front(Pending::Req {
                        req: other,
                        decode_us,
                    });
                    return;
                }
            };
            let base = merged.nnz();
            merged.indices.extend_from_slice(&csr.indices);
            merged.values.extend_from_slice(&csr.values);
            merged.indptr.extend(csr.indptr.iter().skip(1).map(|&p| base + p));
            merged.cols = merged.cols.max(csr.cols);
            ids.extend(frame_ids);
            members.push(FuseMember {
                tok,
                gen,
                scope,
                decode_us,
                count: csr.rows(),
            });
            nnzs.push(csr.nnz() as u64);
        }
    }

    fn fuse_topk(
        &mut self,
        active: &[usize],
        tok: usize,
        scope: Option<String>,
        vectors: Vec<Vec<f32>>,
        n: u32,
        decode_us: u64,
    ) {
        let Some(col) = self.fuse_target(scope.as_deref()) else {
            self.respond_one(tok, rewrap(scope, Request::TopK { vectors, n }), decode_us);
            return;
        };
        let mut all = vectors;
        let count = all.len();
        let mut members = vec![self.member(tok, scope, decode_us, count)];
        self.pull_topk(tok, &col.name, n, &mut all, &mut members);
        for &other in active {
            if other != tok {
                self.pull_topk(other, &col.name, n, &mut all, &mut members);
            }
        }
        if members.len() == 1 {
            let m = members.pop().unwrap();
            let req = Request::TopK { vectors: all, n };
            self.respond_one(m.tok, rewrap(m.scope, req), m.decode_us);
            return;
        }
        self.execute_bulk(
            BulkJob::TopK {
                col,
                vectors: all,
                n,
            },
            members,
            BulkDone::TopK,
        );
    }

    /// Pop the leading run of same-`(collection, n)` `TopK`
    /// requests off one connection's queue into the fused sweep.
    fn pull_topk(
        &mut self,
        tok: usize,
        name: &str,
        n: u32,
        all: &mut Vec<Vec<f32>>,
        members: &mut Vec<FuseMember>,
    ) {
        loop {
            let gen = self.gens[tok];
            let Some(conn) = self.conns[tok].as_mut() else {
                return;
            };
            if conn.blocked > 0 {
                return;
            }
            let extra = match conn.queue.front() {
                Some(Pending::Req {
                    req: Request::TopK { vectors, n: n2 },
                    ..
                }) if name == DEFAULT_COLLECTION && *n2 == n => vectors.len(),
                Some(Pending::Req {
                    req: Request::Scoped { collection, inner },
                    ..
                }) if collection == name => match inner.as_ref() {
                    Request::TopK { vectors, n: n2 } if *n2 == n => vectors.len(),
                    _ => return,
                },
                _ => return,
            };
            if all.len() + extra > MAX_FUSE || members.len() >= MAX_FUSE {
                return;
            }
            let Some(Pending::Req { req, decode_us }) = conn.queue.pop_front() else {
                return;
            };
            let (scope, vectors) = match req {
                Request::TopK { vectors, .. } => (None, vectors),
                Request::Scoped { collection, inner } => match *inner {
                    Request::TopK { vectors, .. } => (Some(collection), vectors),
                    other => {
                        conn.queue.push_front(Pending::Req {
                            req: Request::Scoped {
                                collection,
                                inner: Box::new(other),
                            },
                            decode_us,
                        });
                        return;
                    }
                },
                other => {
                    conn.queue.push_front(Pending::Req {
                        req: other,
                        decode_us,
                    });
                    return;
                }
            };
            members.push(FuseMember {
                tok,
                gen,
                scope,
                decode_us,
                count: vectors.len(),
            });
            all.extend(vectors);
        }
    }
}
