//! The per-loop reactor core: one epoll loop owning one SO_REUSEPORT
//! listener and every connection the kernel hashed to it.
//!
//! Everything here is PR 8's single-loop machinery, unchanged per
//! connection — nonblocking accept, in-place frame parsing, pipelined
//! dispatch, gathered writes with high/low-water backpressure — plus
//! three additions for the sharded front-end:
//!
//! - **Completion drain.** When a worker-pool lane is attached, the
//!   lane's completion eventfd lives in this loop's epoll; offloaded
//!   fused runs complete through [`super::dispatch`] in submission
//!   order.
//! - **Idle sweep.** With `--conn-timeout-ms` set, `epoll_pwait` gets a
//!   finite timeout (a quarter of the timeout, clamped to 10..=250ms)
//!   and a coarse wheel sweep closes connections idle past the limit —
//!   tick granularity, zero allocation, no per-connection timers.
//! - **Stop flags.** A shared trip flag (set when a sibling loop
//!   errors) and an optional caller-provided shutdown flag end the loop
//!   cleanly: connections close, slots release, `run` returns `Ok`.
//!
//! Connection slots carry a generation counter so a completion for a
//! closed (and possibly re-used) slot is dropped instead of answering
//! the wrong peer.

use std::collections::VecDeque;
use std::io::{ErrorKind, Read, Write};
use std::net::{TcpListener, TcpStream};
use std::os::unix::io::AsRawFd;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

use super::dispatch::InFlight;
use super::{pool, sys};
use crate::coordinator::metrics::ReactorLoopMetrics;
use crate::coordinator::obs;
use crate::coordinator::protocol::{self, Request, Response};
use crate::coordinator::server::{observe_request, reject_connection, ServiceState};

/// Pending write bytes past which a connection's read interest is
/// dropped (the backpressure trigger).
pub(super) const HIGH_WATER: usize = 1 << 20;
/// Pending write bytes under which a paused connection resumes
/// reading (hysteresis against MOD churn at the boundary).
pub(super) const LOW_WATER: usize = 64 * 1024;
/// Stack chunk for socket reads (copied into the connection buffer;
/// `extend_from_slice` into existing capacity allocates nothing).
const READ_CHUNK: usize = 16 * 1024;
/// Per-connection read budget per tick: a firehose peer yields the
/// loop after this many bytes and level-triggered epoll re-arms it.
const MAX_TICK_READ: usize = 256 * 1024;
/// Readiness events drained per `epoll_wait`.
const MAX_EVENTS: usize = 1024;
/// The listener's epoll token; connections use their slab index.
const LISTENER_TOKEN: u64 = u64::MAX;
/// The worker-lane completion eventfd's token.
const COMPLETION_TOKEN: u64 = u64::MAX - 1;

/// One decoded-but-undispatched request (or its decode error).
pub(super) enum Pending {
    Req { req: Request, decode_us: u64 },
    Bad { message: String, decode_us: u64 },
}

pub(super) struct Conn {
    pub(super) stream: TcpStream,
    pub(super) peer: String,
    /// Read buffer; valid bytes are `rbuf[rpos..]`.
    pub(super) rbuf: Vec<u8>,
    pub(super) rpos: usize,
    /// Gathered response frames; unsent bytes are `wbuf[wpos..]`.
    pub(super) wbuf: Vec<u8>,
    pub(super) wpos: usize,
    /// Frames parsed this tick, awaiting dispatch.
    pub(super) queue: VecDeque<Pending>,
    /// Currently-registered epoll interest bits.
    pub(super) interest: u32,
    /// Read interest dropped by backpressure.
    pub(super) paused: bool,
    /// Offloaded fused runs this connection is a member of. While
    /// nonzero the queue stays parked (program order: the in-flight
    /// acks must be written first) and the connection is skipped as a
    /// fusion donor.
    pub(super) blocked: u32,
    /// Last byte-level activity (read or write progress), for the
    /// coarse idle sweep.
    pub(super) last_active: Instant,
}

impl Conn {
    pub(super) fn pending_write(&self) -> usize {
        self.wbuf.len() - self.wpos
    }
}

/// Per-loop configuration, fixed at spawn.
pub(super) struct LoopConfig {
    pub idx: usize,
    pub max_conns: usize,
    pub conn_timeout: Option<Duration>,
    /// Caller-provided clean-shutdown flag.
    pub external_stop: Option<Arc<AtomicBool>>,
    /// Shared trip flag: set by any loop that errors so siblings drain.
    pub trip: Arc<AtomicBool>,
    /// True only for the unsharded `--reactor-threads 0` loop with no
    /// stop flag and no timeout: keeps the exact PR-8 behavior of
    /// blocking indefinitely in `epoll_pwait`.
    pub block_forever: bool,
}

pub(super) struct Reactor {
    pub(super) epfd: i32,
    pub(super) listener: TcpListener,
    pub(super) state: Arc<ServiceState>,
    pub(super) cfg: LoopConfig,
    /// This loop's metric shard (labeled `reactor="idx"` in expo).
    pub(super) shard: Arc<ReactorLoopMetrics>,
    /// Worker-pool lane, when `--reactor-workers > 0`.
    pub(super) lane: Option<Arc<pool::LoopLane>>,
    pub(super) conns: Vec<Option<Conn>>,
    /// Slot generations: bumped on close so stale completions for a
    /// recycled slot are discarded.
    pub(super) gens: Vec<u64>,
    pub(super) free: Vec<usize>,
    /// Tokens freed mid-tick; recycled only at tick end so a stale
    /// queued event can never act on a just-accepted connection.
    pub(super) pending_free: Vec<usize>,
    /// Connections that parsed at least one frame this tick (or had an
    /// offload completion applied — either way they need dispatch and
    /// a flush).
    pub(super) active: Vec<usize>,
    pub(super) events: Vec<sys::EpollEvent>,
    /// Requests answered this tick (the dispatch-batch histogram
    /// sample).
    pub(super) tick_dispatched: u64,
    /// Offloaded runs awaiting completion, in submission order.
    pub(super) pending_bulk: VecDeque<InFlight>,
    pub(super) inflight: usize,
    pub(super) next_seq: u64,
    /// Next idle-sweep deadline (set iff `conn_timeout` is).
    next_sweep: Option<Instant>,
}

impl Drop for Reactor {
    fn drop(&mut self) {
        sys::close(self.epfd);
    }
}

/// Run one event loop to completion on the current thread. Returns
/// `Ok` only on a clean stop-flag shutdown; errors otherwise (and the
/// caller trips the shared flag so sibling loops drain too).
pub(super) fn run_loop(
    listener: TcpListener,
    state: Arc<ServiceState>,
    shard: Arc<ReactorLoopMetrics>,
    lane: Option<Arc<pool::LoopLane>>,
    cfg: LoopConfig,
) -> crate::Result<()> {
    listener.set_nonblocking(true)?;
    let epfd = sys::epoll_create1()?;
    let next_sweep = cfg.conn_timeout.map(|_| Instant::now());
    let mut r = Reactor {
        epfd,
        listener,
        state,
        cfg,
        shard,
        lane,
        conns: Vec::new(),
        gens: Vec::new(),
        free: Vec::new(),
        pending_free: Vec::new(),
        active: Vec::new(),
        events: vec![sys::EpollEvent::default(); MAX_EVENTS],
        tick_dispatched: 0,
        pending_bulk: VecDeque::new(),
        inflight: 0,
        next_seq: 0,
        next_sweep,
    };
    sys::epoll_ctl(
        r.epfd,
        sys::EPOLL_CTL_ADD,
        r.listener.as_raw_fd(),
        sys::EPOLLIN,
        LISTENER_TOKEN,
    )?;
    if let Some(lane) = &r.lane {
        sys::epoll_ctl(
            r.epfd,
            sys::EPOLL_CTL_ADD,
            lane.comp_wake.raw(),
            sys::EPOLLIN,
            COMPLETION_TOKEN,
        )?;
    }
    r.run()
}

impl Reactor {
    fn poll_timeout_ms(&self) -> i32 {
        if let Some(t) = self.cfg.conn_timeout {
            // A quarter of the idle timeout bounds sweep lag at 25%
            // of the configured limit; the clamp keeps ticks humane.
            (t.as_millis() as i64 / 4).clamp(10, 250) as i32
        } else if self.cfg.block_forever {
            -1
        } else {
            250
        }
    }

    fn should_stop(&self) -> bool {
        self.cfg.trip.load(Ordering::Relaxed)
            || self
                .cfg
                .external_stop
                .as_ref()
                .is_some_and(|f| f.load(Ordering::Relaxed))
    }

    fn run(&mut self) -> crate::Result<()> {
        loop {
            let timeout = self.poll_timeout_ms();
            let mut events = std::mem::take(&mut self.events);
            let n = sys::epoll_wait(self.epfd, &mut events, timeout)?;
            let m = &self.state.metrics;
            m.reactor_polls.fetch_add(1, Ordering::Relaxed);
            m.reactor_ready_events.fetch_add(n as u64, Ordering::Relaxed);
            self.shard.polls.fetch_add(1, Ordering::Relaxed);
            self.shard.ready_events.fetch_add(n as u64, Ordering::Relaxed);
            if self.should_stop() {
                self.events = events;
                self.close_all("server shutdown");
                return Ok(());
            }
            for ev in &events[..n] {
                let (bits, tok) = (ev.events, ev.data);
                match tok {
                    LISTENER_TOKEN => self.accept_ready(),
                    COMPLETION_TOKEN => self.drain_completions(),
                    _ => self.conn_event(tok as usize, bits),
                }
            }
            self.events = events;
            self.sweep_idle();
            self.dispatch();
            let active = std::mem::take(&mut self.active);
            for &t in &active {
                if self.conns.get(t).is_some_and(|c| c.is_some()) {
                    self.flush_writes(t);
                }
            }
            self.active = active;
            self.active.clear();
            self.free.append(&mut self.pending_free);
        }
    }

    fn accept_ready(&mut self) {
        loop {
            match self.listener.accept() {
                Ok((stream, addr)) => {
                    if self.cfg.max_conns > 0
                        && self.state.metrics.connections.load(Ordering::Relaxed)
                            >= self.cfg.max_conns as u64
                    {
                        // Accepted sockets are blocking (O_NONBLOCK
                        // does not inherit), so the thread-mode
                        // rejection path works unchanged.
                        let _ = reject_connection(stream, self.cfg.max_conns);
                        continue;
                    }
                    if self.register_conn(stream, addr.to_string()).is_err() {
                        continue;
                    }
                    self.state.metrics.connections.fetch_add(1, Ordering::Relaxed);
                    self.shard.connections.fetch_add(1, Ordering::Relaxed);
                }
                Err(e) if e.kind() == ErrorKind::WouldBlock => break,
                Err(e) if e.kind() == ErrorKind::Interrupted => continue,
                Err(e) => {
                    // Transient accept failure (EMFILE under fd
                    // pressure, aborted handshake): log and let the
                    // next readiness tick retry.
                    obs::log::warn("crp::server", "accept failed", &[("error", e.to_string())]);
                    break;
                }
            }
        }
    }

    fn register_conn(&mut self, stream: TcpStream, peer: String) -> crate::Result<()> {
        stream.set_nonblocking(true)?;
        stream.set_nodelay(true)?;
        let tok = match self.free.pop() {
            Some(t) => t,
            None => {
                self.conns.push(None);
                self.gens.push(0);
                self.conns.len() - 1
            }
        };
        let interest = sys::EPOLLIN | sys::EPOLLRDHUP;
        let fd = stream.as_raw_fd();
        let added = sys::epoll_ctl(self.epfd, sys::EPOLL_CTL_ADD, fd, interest, tok as u64);
        if let Err(e) = added {
            self.free.push(tok);
            return Err(e);
        }
        self.conns[tok] = Some(Conn {
            stream,
            peer,
            rbuf: Vec::new(),
            rpos: 0,
            wbuf: Vec::new(),
            wpos: 0,
            queue: VecDeque::new(),
            interest,
            paused: false,
            blocked: 0,
            last_active: Instant::now(),
        });
        Ok(())
    }

    fn conn_event(&mut self, tok: usize, bits: u32) {
        if !matches!(self.conns.get(tok), Some(Some(_))) {
            return; // closed earlier this tick
        }
        if bits & (sys::EPOLLERR | sys::EPOLLHUP) != 0 {
            self.close(tok, "socket error/hangup");
            return;
        }
        if bits & sys::EPOLLOUT != 0 && !self.flush_writes(tok) {
            return;
        }
        if bits & (sys::EPOLLIN | sys::EPOLLRDHUP) != 0 {
            self.read_ready(tok);
        }
    }

    fn read_ready(&mut self, tok: usize) {
        let mut tmp = [0u8; READ_CHUNK];
        let mut budget = MAX_TICK_READ;
        loop {
            let Some(conn) = self.conns[tok].as_mut() else {
                return;
            };
            match conn.stream.read(&mut tmp) {
                Ok(0) => {
                    self.close(tok, "peer closed");
                    return;
                }
                Ok(n) => {
                    conn.rbuf.extend_from_slice(&tmp[..n]);
                    conn.last_active = Instant::now();
                    budget = budget.saturating_sub(n);
                    if budget == 0 || n < tmp.len() {
                        break;
                    }
                }
                Err(e) if e.kind() == ErrorKind::WouldBlock => break,
                Err(e) if e.kind() == ErrorKind::Interrupted => continue,
                Err(e) => {
                    let reason = e.to_string();
                    self.close(tok, &reason);
                    return;
                }
            }
        }
        self.parse_frames(tok);
    }

    /// Decode every complete frame in the read buffer, in place.
    /// Pipelined clients land several per call.
    fn parse_frames(&mut self, tok: usize) {
        let Some(conn) = self.conns[tok].as_mut() else {
            return;
        };
        let mut newly = 0u64;
        let mut oversized = None;
        loop {
            let avail = conn.rbuf.len() - conn.rpos;
            if avail < 4 {
                break;
            }
            let len = u32::from_le_bytes(conn.rbuf[conn.rpos..conn.rpos + 4].try_into().unwrap());
            if len > protocol::MAX_FRAME {
                // Same contract as the blocking path's read_frame:
                // an impossible header ends the connection.
                oversized = Some(len);
                break;
            }
            let need = 4 + len as usize;
            if avail < need {
                break;
            }
            let t0 = Instant::now();
            let parsed = match Request::decode(&conn.rbuf[conn.rpos + 4..conn.rpos + need]) {
                Ok(req) => Pending::Req {
                    req,
                    decode_us: t0.elapsed().as_micros() as u64,
                },
                Err(e) => Pending::Bad {
                    message: format!("bad request: {e}"),
                    decode_us: t0.elapsed().as_micros() as u64,
                },
            };
            conn.rpos += need;
            conn.queue.push_back(parsed);
            newly += 1;
        }
        // Reclaim the consumed prefix; the buffer itself is kept.
        if conn.rpos > 0 {
            let len = conn.rbuf.len();
            if conn.rpos == len {
                conn.rbuf.clear();
            } else {
                conn.rbuf.copy_within(conn.rpos.., 0);
                conn.rbuf.truncate(len - conn.rpos);
            }
            conn.rpos = 0;
        }
        if newly > 0 {
            self.state
                .metrics
                .reactor_frames
                .fetch_add(newly, Ordering::Relaxed);
            self.shard.frames.fetch_add(newly, Ordering::Relaxed);
            self.mark_active(tok);
        }
        if let Some(len) = oversized {
            // Dispatch what decoded cleanly first (their responses
            // still flush), then hang up like thread mode does.
            let reason = format!("frame too large: {len}");
            self.dispatch();
            self.flush_writes(tok);
            self.close(tok, &reason);
        }
    }

    pub(super) fn mark_active(&mut self, tok: usize) {
        if !self.active.contains(&tok) {
            self.active.push(tok);
        }
    }

    /// Close connections idle past `--conn-timeout-ms`. Coarse by
    /// design: runs at most once per sweep tick (a quarter of the
    /// timeout), so a connection lives at most ~1.25× the configured
    /// limit. Connections that are mid-offload or still owe responses
    /// are not idle and are left alone.
    fn sweep_idle(&mut self) {
        let Some(timeout) = self.cfg.conn_timeout else {
            return;
        };
        let now = Instant::now();
        match self.next_sweep {
            Some(at) if now < at => return,
            _ => {}
        }
        self.next_sweep = Some(now + timeout / 4);
        for tok in 0..self.conns.len() {
            let idle = match &self.conns[tok] {
                Some(c) => {
                    c.blocked == 0
                        && c.queue.is_empty()
                        && c.pending_write() == 0
                        && now.duration_since(c.last_active) >= timeout
                }
                None => false,
            };
            if idle {
                self.close(tok, "idle timeout");
            }
        }
    }

    /// Route one request through the shared router (identical to a
    /// thread-mode request) and gather its response.
    pub(super) fn respond_one(&mut self, tok: usize, req: Request, decode_us: u64) {
        let h0 = Instant::now();
        let (resp, meta) = self.state.handle_traced(req);
        let handle_us = h0.elapsed().as_micros() as u64;
        self.push_response(tok, &resp, &meta, decode_us, handle_us);
    }

    pub(super) fn respond_bad(&mut self, tok: usize, message: String, decode_us: u64) {
        let resp = Response::Error { message };
        let meta = obs::ReqMeta {
            kind: obs::RequestKind::Admin,
            collection: None,
            candidates: None,
        };
        self.push_response(tok, &resp, &meta, decode_us, 0);
    }

    /// Encode one response into the connection's write buffer and
    /// record the request's full-path metrics (thread-mode parity:
    /// histogram, slow-query ring, sampled trace).
    pub(super) fn push_response(
        &mut self,
        tok: usize,
        resp: &Response,
        meta: &obs::ReqMeta,
        decode_us: u64,
        handle_us: u64,
    ) {
        let Some(conn) = self.conns[tok].as_mut() else {
            return;
        };
        let w0 = Instant::now();
        let appended = protocol::append_frame(&mut conn.wbuf, resp).is_ok();
        let write_us = w0.elapsed().as_micros() as u64;
        let pending = conn.pending_write() as u64;
        if !appended {
            // A response over the frame cap fails the write on the
            // blocking path too; the connection cannot continue.
            self.close(tok, "response frame too large");
            return;
        }
        self.tick_dispatched += 1;
        self.state
            .metrics
            .reactor_write_buffer_hwm
            .fetch_max(pending, Ordering::Relaxed);
        let total_us = (decode_us + handle_us + write_us).max(1);
        observe_request(&self.state, meta, total_us, decode_us, handle_us, write_us);
    }

    /// Flush as much of the write buffer as the socket accepts,
    /// then recompute epoll interest (write interest while bytes
    /// remain; read interest unless backpressured). Returns false
    /// if the connection closed.
    pub(super) fn flush_writes(&mut self, tok: usize) -> bool {
        loop {
            let Some(conn) = self.conns[tok].as_mut() else {
                return false;
            };
            if conn.pending_write() == 0 {
                break;
            }
            let wpos = conn.wpos;
            match conn.stream.write(&conn.wbuf[wpos..]) {
                Ok(0) => {
                    self.close(tok, "peer stopped accepting writes");
                    return false;
                }
                Ok(n) => {
                    conn.wpos += n;
                    conn.last_active = Instant::now();
                }
                Err(e) if e.kind() == ErrorKind::WouldBlock => break,
                Err(e) if e.kind() == ErrorKind::Interrupted => continue,
                Err(e) => {
                    let reason = e.to_string();
                    self.close(tok, &reason);
                    return false;
                }
            }
        }
        let Some(conn) = self.conns[tok].as_mut() else {
            return false;
        };
        // Reclaim sent bytes; the allocation is kept for reuse.
        if conn.wpos == conn.wbuf.len() {
            conn.wbuf.clear();
            conn.wpos = 0;
        } else if conn.wpos >= LOW_WATER {
            let len = conn.wbuf.len();
            conn.wbuf.copy_within(conn.wpos.., 0);
            conn.wbuf.truncate(len - conn.wpos);
            conn.wpos = 0;
        }
        self.update_interest(tok);
        true
    }

    fn update_interest(&mut self, tok: usize) {
        let epfd = self.epfd;
        let Some(conn) = self.conns[tok].as_mut() else {
            return;
        };
        let pending = conn.pending_write();
        // Hysteresis: pause reading at the high-water mark, resume
        // only once the peer has drained under the low-water mark.
        conn.paused = pending >= HIGH_WATER || (conn.paused && pending > LOW_WATER);
        let mut want = sys::EPOLLRDHUP;
        if !conn.paused {
            want |= sys::EPOLLIN;
        }
        if pending > 0 {
            want |= sys::EPOLLOUT;
        }
        if want != conn.interest
            && sys::epoll_ctl(
                epfd,
                sys::EPOLL_CTL_MOD,
                conn.stream.as_raw_fd(),
                want,
                tok as u64,
            )
            .is_ok()
        {
            conn.interest = want;
        }
    }

    pub(super) fn close(&mut self, tok: usize, reason: &str) {
        if let Some(conn) = self.conns[tok].take() {
            // A closed peer is the normal end of every connection —
            // debug, never warn (same contract as thread mode).
            obs::log::debug(
                "crp::server",
                "connection closed",
                &[("peer", conn.peer.clone()), ("reason", reason.to_string())],
            );
            self.state.metrics.connections.fetch_sub(1, Ordering::Relaxed);
            self.shard.connections.fetch_sub(1, Ordering::Relaxed);
            // Invalidate any in-flight offload membership for this
            // slot: a later completion finds the generation bumped and
            // drops the member instead of answering a recycled slot.
            self.gens[tok] += 1;
            self.pending_free.push(tok);
            // Dropping the stream closes the fd, which also removes
            // it from the epoll interest list.
            drop(conn);
        }
    }

    fn close_all(&mut self, reason: &str) {
        for tok in 0..self.conns.len() {
            if self.conns[tok].is_some() {
                self.close(tok, reason);
            }
        }
        self.free.append(&mut self.pending_free);
        obs::log::info(
            "crp::server",
            "reactor loop stopped",
            &[("reactor", self.cfg.idx.to_string())],
        );
    }
}

pub(super) fn rewrap(scope: Option<String>, inner: Request) -> Request {
    match scope {
        Some(collection) => Request::Scoped {
            collection,
            inner: Box::new(inner),
        },
        None => inner,
    }
}
