//! Bounded worker pool for off-loop execution of *fused* bulk work.
//!
//! Topology: loop `i` submits exclusively to worker `i % W`, so every
//! ring is strictly single-producer/single-consumer and a loop's jobs
//! execute in submission order with no cross-thread reordering — which
//! is what preserves per-connection program order and per-frame ack
//! order without any sequencing logic beyond a FIFO.
//!
//! One lane per loop:
//!
//! ```text
//!  loop i ── sub ring ──▶ worker (i % W)    wake: worker eventfd
//!  loop i ◀── comp ring ── worker (i % W)   wake: lane comp eventfd
//! ```
//!
//! The submission eventfd belongs to the *worker* (one blocking-read
//! wait fd per worker, shared by all its lanes); the completion eventfd
//! belongs to the *lane* and is registered in the owning loop's epoll,
//! so completions wake the loop exactly like socket readiness. Rings
//! are bounded: the loop never holds more than [`MAX_INFLIGHT`] jobs in
//! flight per lane (falling back to inline execution past that), so the
//! completion ring — sized [`RING_CAP`] ≥ `MAX_INFLIGHT` — can never
//! overflow.

use std::cell::UnsafeCell;
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::Instant;

use super::sys::EventFd;
use crate::coordinator::protocol::Response;
use crate::coordinator::registry::Collection;
use crate::data::sparse::CsrMatrix;

/// Ring capacity per direction, per lane.
pub(super) const RING_CAP: usize = 64;
/// Jobs a loop may have in flight per lane before it executes fused
/// runs inline instead (bounds completion-ring occupancy at half cap).
pub(super) const MAX_INFLIGHT: usize = 32;

/// A fixed-capacity single-producer/single-consumer ring. `push` is
/// only ever called from one thread and `pop` from one other; the
/// head/tail indices use acquire/release pairs so the consumer observes
/// fully-written slots and the producer observes fully-taken ones.
pub(super) struct Spsc<T> {
    slots: Box<[UnsafeCell<Option<T>>]>,
    /// Next slot to pop (consumer-owned; producer only loads).
    head: AtomicUsize,
    /// Next slot to push (producer-owned; consumer only loads).
    tail: AtomicUsize,
}

// Safety: the SPSC protocol gives each slot a single owner at any
// time — the producer owns `[tail, head+cap)`, the consumer owns
// `[head, tail)` — so the UnsafeCell accesses never race.
unsafe impl<T: Send> Send for Spsc<T> {}
unsafe impl<T: Send> Sync for Spsc<T> {}

impl<T> Spsc<T> {
    pub fn with_capacity(cap: usize) -> Self {
        Spsc {
            slots: (0..cap).map(|_| UnsafeCell::new(None)).collect(),
            head: AtomicUsize::new(0),
            tail: AtomicUsize::new(0),
        }
    }

    /// Producer side. Returns the value back when the ring is full.
    pub fn push(&self, v: T) -> Result<(), T> {
        let tail = self.tail.load(Ordering::Relaxed);
        let head = self.head.load(Ordering::Acquire);
        if tail.wrapping_sub(head) == self.slots.len() {
            return Err(v);
        }
        unsafe { *self.slots[tail % self.slots.len()].get() = Some(v) };
        self.tail.store(tail.wrapping_add(1), Ordering::Release);
        Ok(())
    }

    /// Consumer side.
    pub fn pop(&self) -> Option<T> {
        let head = self.head.load(Ordering::Relaxed);
        let tail = self.tail.load(Ordering::Acquire);
        if head == tail {
            return None;
        }
        let v = unsafe { (*self.slots[head % self.slots.len()].get()).take() };
        self.head.store(head.wrapping_add(1), Ordering::Release);
        v
    }
}

/// A fused bulk run, detached from the loop so a worker can execute it.
/// Runs exactly the calls the inline path would make.
pub(super) enum BulkJob {
    Register {
        col: Arc<Collection>,
        ids: Vec<String>,
        vecs: Vec<Vec<f32>>,
    },
    RegisterSparse {
        col: Arc<Collection>,
        ids: Vec<String>,
        csr: CsrMatrix,
    },
    TopK {
        col: Arc<Collection>,
        vectors: Vec<Vec<f32>>,
        n: u32,
    },
}

impl BulkJob {
    pub fn run(self) -> Response {
        match self {
            BulkJob::Register { col, ids, vecs } => col.register_batch(ids, vecs),
            BulkJob::RegisterSparse { col, ids, csr } => col.register_sparse(ids, csr),
            BulkJob::TopK { col, vectors, n } => col.topk(vectors, n),
        }
    }
}

pub(super) struct Submission {
    pub seq: u64,
    pub job: BulkJob,
}

pub(super) struct Completion {
    pub seq: u64,
    pub resp: Response,
    /// Worker-measured execution time for the whole fused run.
    pub handle_us: u64,
}

/// One loop's pair of rings plus wake fds. Shared (via `Arc`) between
/// the owning loop thread and its statically-assigned worker.
pub(super) struct LoopLane {
    pub sub: Spsc<Submission>,
    pub comp: Spsc<Completion>,
    /// The assigned worker's wait fd (blocking): signaled on submit.
    pub worker_wake: Arc<EventFd>,
    /// The loop's completion fd (nonblocking, epoll-registered):
    /// signaled by the worker after each completion push.
    pub comp_wake: EventFd,
}

/// The worker threads plus everything needed to join them.
pub(super) struct WorkerPool {
    stop: Arc<AtomicBool>,
    wakes: Vec<Arc<EventFd>>,
    handles: Vec<JoinHandle<()>>,
}

impl WorkerPool {
    /// Spawn `workers` threads serving `loops` lanes (lane `i` →
    /// worker `i % workers`). Returns the pool and the per-loop lanes.
    pub fn spawn(loops: usize, workers: usize) -> crate::Result<(WorkerPool, Vec<Arc<LoopLane>>)> {
        let stop = Arc::new(AtomicBool::new(false));
        let wakes: Vec<Arc<EventFd>> = (0..workers)
            .map(|_| EventFd::new(false).map(Arc::new))
            .collect::<crate::Result<_>>()?;
        let lanes: Vec<Arc<LoopLane>> = (0..loops)
            .map(|i| {
                Ok(Arc::new(LoopLane {
                    sub: Spsc::with_capacity(RING_CAP),
                    comp: Spsc::with_capacity(RING_CAP),
                    worker_wake: wakes[i % workers].clone(),
                    comp_wake: EventFd::new(true)?,
                }))
            })
            .collect::<crate::Result<_>>()?;
        let mut handles = Vec::with_capacity(workers);
        for w in 0..workers {
            let mine: Vec<Arc<LoopLane>> = lanes
                .iter()
                .enumerate()
                .filter(|(i, _)| i % workers == w)
                .map(|(_, l)| l.clone())
                .collect();
            let wake = wakes[w].clone();
            let stop = stop.clone();
            handles.push(
                std::thread::Builder::new()
                    .name(format!("crp-worker-{w}"))
                    .spawn(move || worker_main(&mine, &wake, &stop))?,
            );
        }
        Ok((
            WorkerPool {
                stop,
                wakes,
                handles,
            },
            lanes,
        ))
    }

    /// Stop and join every worker. In-flight jobs finish; queued jobs
    /// are drained and executed (their completions go unread — by the
    /// time this runs, every loop has already closed its connections).
    pub fn shutdown(mut self) {
        self.stop.store(true, Ordering::SeqCst);
        for w in &self.wakes {
            w.signal();
        }
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

fn worker_main(lanes: &[Arc<LoopLane>], wake: &EventFd, stop: &AtomicBool) {
    loop {
        wake.drain(); // blocks until a loop signals (or shutdown does)
        loop {
            let mut did = false;
            for lane in lanes {
                while let Some(sub) = lane.sub.pop() {
                    let t0 = Instant::now();
                    let resp = sub.job.run();
                    let handle_us = t0.elapsed().as_micros() as u64;
                    // Cannot fail: per-lane in-flight is capped at
                    // MAX_INFLIGHT < RING_CAP by the submitting loop.
                    let pushed = lane.comp.push(Completion {
                        seq: sub.seq,
                        resp,
                        handle_us,
                    });
                    debug_assert!(pushed.is_ok(), "completion ring overflow");
                    lane.comp_wake.signal();
                    did = true;
                }
            }
            if !did {
                break;
            }
        }
        if stop.load(Ordering::SeqCst) {
            return;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn spsc_ring_is_fifo_and_bounded() {
        let ring: Spsc<u32> = Spsc::with_capacity(4);
        assert_eq!(ring.pop(), None);
        for i in 0..4 {
            ring.push(i).unwrap();
        }
        assert_eq!(ring.push(99), Err(99), "full ring rejects");
        for i in 0..4 {
            assert_eq!(ring.pop(), Some(i), "FIFO order");
        }
        assert_eq!(ring.pop(), None);
        // Wraps: indices keep running past capacity.
        for round in 0..10u32 {
            ring.push(round).unwrap();
            assert_eq!(ring.pop(), Some(round));
        }
    }

    #[test]
    fn spsc_ring_survives_cross_thread_handoff() {
        let ring: Arc<Spsc<u64>> = Arc::new(Spsc::with_capacity(8));
        let n = 10_000u64;
        let producer = {
            let ring = ring.clone();
            std::thread::spawn(move || {
                for i in 0..n {
                    let mut v = i;
                    loop {
                        match ring.push(v) {
                            Ok(()) => break,
                            Err(back) => {
                                v = back;
                                std::hint::spin_loop();
                            }
                        }
                    }
                }
            })
        };
        let mut expect = 0u64;
        while expect < n {
            if let Some(v) = ring.pop() {
                assert_eq!(v, expect, "values arrive in order, none lost");
                expect += 1;
            } else {
                std::hint::spin_loop();
            }
        }
        producer.join().unwrap();
    }
}
