//! Minimal raw-syscall bindings for the reactor: epoll, rlimit,
//! SO_REUSEPORT socket setup, and eventfd. Numbers and ABI per
//! `asm/unistd_64.h` (x86_64) and the generic 64-bit table (aarch64);
//! both arches use `epoll_pwait` with a null sigmask so one 6-argument
//! entry point covers everything. No `libc`/`mio` in the dependency
//! budget.
//!
//! The socket syscalls exist because SO_REUSEPORT must be set *before*
//! `bind`, which `std::net::TcpListener` gives no hook for: the
//! multi-reactor front-end hand-builds each listening socket
//! (`socket` → `setsockopt` → `bind` → `listen`) and only then wraps
//! the fd in a std `TcpListener`. The eventfd is the worker-pool wake
//! primitive: loop threads signal their worker after pushing a
//! submission, workers signal the loop's epoll-registered completion
//! eventfd after pushing a result.

use std::arch::asm;
use std::net::{SocketAddr, TcpListener};
use std::os::unix::io::FromRawFd;

pub const EPOLLIN: u32 = 0x001;
pub const EPOLLOUT: u32 = 0x004;
pub const EPOLLERR: u32 = 0x008;
pub const EPOLLHUP: u32 = 0x010;
pub const EPOLLRDHUP: u32 = 0x2000;
pub const EPOLL_CTL_ADD: i32 = 1;
pub const EPOLL_CTL_MOD: i32 = 3;
const EPOLL_CLOEXEC: usize = 0x80000;
const EINTR: isize = -4;
const RLIMIT_NOFILE: usize = 7;

const AF_INET: u16 = 2;
const AF_INET6: u16 = 10;
const SOCK_STREAM: usize = 1;
const SOCK_CLOEXEC: usize = 0x80000;
const SOL_SOCKET: usize = 1;
const SO_REUSEADDR: usize = 2;
const SO_REUSEPORT: usize = 15;
const EFD_NONBLOCK: usize = 0x800;
const EFD_CLOEXEC: usize = 0x80000;
const LISTEN_BACKLOG: usize = 1024;

/// Kernel `struct epoll_event`: packed on x86_64 (the kernel ABI
/// has no padding there), naturally aligned elsewhere.
#[cfg_attr(target_arch = "x86_64", repr(C, packed))]
#[cfg_attr(not(target_arch = "x86_64"), repr(C))]
#[derive(Clone, Copy, Default)]
pub struct EpollEvent {
    pub events: u32,
    pub data: u64,
}

#[cfg(target_arch = "x86_64")]
mod nr {
    pub const READ: usize = 0;
    pub const WRITE: usize = 1;
    pub const CLOSE: usize = 3;
    pub const SOCKET: usize = 41;
    pub const BIND: usize = 49;
    pub const LISTEN: usize = 50;
    pub const SETSOCKOPT: usize = 54;
    pub const EPOLL_CTL: usize = 233;
    pub const EPOLL_PWAIT: usize = 281;
    pub const EVENTFD2: usize = 290;
    pub const EPOLL_CREATE1: usize = 291;
    pub const PRLIMIT64: usize = 302;
}
#[cfg(target_arch = "aarch64")]
mod nr {
    pub const EVENTFD2: usize = 19;
    pub const EPOLL_CREATE1: usize = 20;
    pub const EPOLL_CTL: usize = 21;
    pub const EPOLL_PWAIT: usize = 22;
    pub const CLOSE: usize = 57;
    pub const READ: usize = 63;
    pub const WRITE: usize = 64;
    pub const SOCKET: usize = 198;
    pub const BIND: usize = 200;
    pub const LISTEN: usize = 201;
    pub const SETSOCKOPT: usize = 208;
    pub const PRLIMIT64: usize = 261;
}

#[cfg(target_arch = "x86_64")]
#[inline]
unsafe fn syscall6(n: usize, a1: usize, a2: usize, a3: usize, a4: usize, a5: usize, a6: usize) -> isize {
    let ret: isize;
    asm!(
        "syscall",
        inlateout("rax") n as isize => ret,
        in("rdi") a1,
        in("rsi") a2,
        in("rdx") a3,
        in("r10") a4,
        in("r8") a5,
        in("r9") a6,
        lateout("rcx") _,
        lateout("r11") _,
        options(nostack, preserves_flags)
    );
    ret
}

#[cfg(target_arch = "aarch64")]
#[inline]
unsafe fn syscall6(n: usize, a1: usize, a2: usize, a3: usize, a4: usize, a5: usize, a6: usize) -> isize {
    let ret: isize;
    asm!(
        "svc 0",
        inlateout("x0") a1 as isize => ret,
        in("x1") a2,
        in("x2") a3,
        in("x3") a4,
        in("x4") a5,
        in("x5") a6,
        in("x8") n,
        options(nostack, preserves_flags)
    );
    ret
}

fn check(ret: isize, what: &str) -> crate::Result<usize> {
    anyhow::ensure!(ret >= 0, "{what} failed: errno {}", -ret);
    Ok(ret as usize)
}

pub fn epoll_create1() -> crate::Result<i32> {
    let r = unsafe { syscall6(nr::EPOLL_CREATE1, EPOLL_CLOEXEC, 0, 0, 0, 0, 0) };
    Ok(check(r, "epoll_create1")? as i32)
}

pub fn epoll_ctl(epfd: i32, op: i32, fd: i32, events: u32, data: u64) -> crate::Result<()> {
    let mut ev = EpollEvent { events, data };
    let r = unsafe {
        syscall6(
            nr::EPOLL_CTL,
            epfd as usize,
            op as usize,
            fd as usize,
            &mut ev as *mut EpollEvent as usize,
            0,
            0,
        )
    };
    check(r, "epoll_ctl")?;
    Ok(())
}

/// Wait for readiness; retries `EINTR` internally. `timeout_ms` -1
/// blocks indefinitely.
pub fn epoll_wait(epfd: i32, events: &mut [EpollEvent], timeout_ms: i32) -> crate::Result<usize> {
    loop {
        let r = unsafe {
            syscall6(
                nr::EPOLL_PWAIT,
                epfd as usize,
                events.as_mut_ptr() as usize,
                events.len(),
                timeout_ms as isize as usize,
                0, // null sigmask: plain epoll_wait semantics
                8,
            )
        };
        if r == EINTR {
            continue;
        }
        return check(r, "epoll_wait");
    }
}

pub fn close(fd: i32) {
    let _ = unsafe { syscall6(nr::CLOSE, fd as usize, 0, 0, 0, 0, 0) };
}

/// Encode a `SocketAddr` as the kernel's `sockaddr_in`/`sockaddr_in6`.
/// Returns the buffer and the populated length (16 or 28 bytes).
fn sockaddr_bytes(addr: &SocketAddr) -> ([u8; 28], usize) {
    let mut buf = [0u8; 28];
    match addr {
        SocketAddr::V4(v4) => {
            buf[0..2].copy_from_slice(&AF_INET.to_ne_bytes());
            buf[2..4].copy_from_slice(&v4.port().to_be_bytes());
            buf[4..8].copy_from_slice(&v4.ip().octets());
            (buf, 16)
        }
        SocketAddr::V6(v6) => {
            buf[0..2].copy_from_slice(&AF_INET6.to_ne_bytes());
            buf[2..4].copy_from_slice(&v6.port().to_be_bytes());
            buf[4..8].copy_from_slice(&v6.flowinfo().to_ne_bytes());
            buf[8..24].copy_from_slice(&v6.ip().octets());
            buf[24..28].copy_from_slice(&v6.scope_id().to_ne_bytes());
            (buf, 28)
        }
    }
}

fn socket(domain: u16, ty: usize) -> crate::Result<i32> {
    let r = unsafe { syscall6(nr::SOCKET, domain as usize, ty, 0, 0, 0, 0) };
    Ok(check(r, "socket")? as i32)
}

fn setsockopt_int(fd: i32, level: usize, opt: usize, val: i32) -> crate::Result<()> {
    let r = unsafe {
        syscall6(
            nr::SETSOCKOPT,
            fd as usize,
            level,
            opt,
            &val as *const i32 as usize,
            std::mem::size_of::<i32>(),
            0,
        )
    };
    check(r, "setsockopt")?;
    Ok(())
}

fn bind(fd: i32, addr: &SocketAddr) -> crate::Result<()> {
    let (buf, len) = sockaddr_bytes(addr);
    let r = unsafe { syscall6(nr::BIND, fd as usize, buf.as_ptr() as usize, len, 0, 0, 0) };
    check(r, "bind")?;
    Ok(())
}

fn listen(fd: i32) -> crate::Result<()> {
    let r = unsafe { syscall6(nr::LISTEN, fd as usize, LISTEN_BACKLOG, 0, 0, 0, 0) };
    check(r, "listen")?;
    Ok(())
}

/// Build one listening socket with SO_REUSEPORT set *before* bind —
/// the piece `std::net::TcpListener` cannot do — and hand it to std.
/// SO_REUSEADDR matches what std sets on its own listeners.
pub fn bind_reuseport(addr: &SocketAddr) -> crate::Result<TcpListener> {
    let domain = if addr.is_ipv4() { AF_INET } else { AF_INET6 };
    let fd = socket(domain, SOCK_STREAM | SOCK_CLOEXEC)?;
    let setup = (|| {
        setsockopt_int(fd, SOL_SOCKET, SO_REUSEADDR, 1)?;
        setsockopt_int(fd, SOL_SOCKET, SO_REUSEPORT, 1)?;
        bind(fd, addr)?;
        listen(fd)
    })();
    if let Err(e) = setup {
        close(fd);
        return Err(e);
    }
    Ok(unsafe { TcpListener::from_raw_fd(fd) })
}

/// Bind `n` SO_REUSEPORT listeners on one address. The first bind may
/// hit an ephemeral port (`:0`); siblings then pin its resolved port so
/// the kernel hashes incoming connections across all `n` accept queues.
pub fn bind_reuseport_group(addr: &str, n: usize) -> crate::Result<Vec<TcpListener>> {
    use std::net::ToSocketAddrs;
    anyhow::ensure!(n >= 1, "reuseport group needs at least one listener");
    let mut target = addr
        .to_socket_addrs()?
        .next()
        .ok_or_else(|| anyhow::anyhow!("cannot resolve listen address {addr:?}"))?;
    let mut out = Vec::with_capacity(n);
    for i in 0..n {
        let l = bind_reuseport(&target)?;
        if i == 0 {
            target = l.local_addr()?;
        }
        out.push(l);
    }
    Ok(out)
}

/// A kernel eventfd: an 8-byte counter usable both as a blocking wait
/// primitive (worker side) and as an epoll-registered wake fd (loop
/// side). Counting semantics: writes add, a read drains to zero.
#[derive(Debug)]
pub struct EventFd {
    fd: i32,
}

impl EventFd {
    pub fn new(nonblocking: bool) -> crate::Result<EventFd> {
        let flags = EFD_CLOEXEC | if nonblocking { EFD_NONBLOCK } else { 0 };
        let r = unsafe { syscall6(nr::EVENTFD2, 0, flags, 0, 0, 0, 0) };
        Ok(EventFd {
            fd: check(r, "eventfd2")? as i32,
        })
    }

    pub fn raw(&self) -> i32 {
        self.fd
    }

    /// Add 1 to the counter, waking any waiter. Failure is ignored: the
    /// only non-transient cause is a counter at `u64::MAX - 1`, which
    /// already has a wakeup pending.
    pub fn signal(&self) {
        let one = 1u64.to_ne_bytes();
        let _ = unsafe {
            syscall6(
                nr::WRITE,
                self.fd as usize,
                one.as_ptr() as usize,
                one.len(),
                0,
                0,
                0,
            )
        };
    }

    /// Read the counter (blocking fds wait for it to become nonzero;
    /// nonblocking fds return 0 immediately when unsignaled).
    pub fn drain(&self) -> u64 {
        let mut buf = [0u8; 8];
        let r = unsafe {
            syscall6(
                nr::READ,
                self.fd as usize,
                buf.as_mut_ptr() as usize,
                buf.len(),
                0,
                0,
                0,
            )
        };
        if r == 8 {
            u64::from_ne_bytes(buf)
        } else {
            0
        }
    }
}

impl Drop for EventFd {
    fn drop(&mut self) {
        close(self.fd);
    }
}

#[repr(C)]
struct Rlimit64 {
    cur: u64,
    max: u64,
}

/// Best-effort `RLIMIT_NOFILE` raise (soft → hard) so a single
/// process can hold thousands of sockets without root. Returns the
/// resulting soft limit, or `None` if even reading it failed.
pub fn raise_nofile_limit() -> Option<u64> {
    let mut old = Rlimit64 { cur: 0, max: 0 };
    let r = unsafe {
        syscall6(
            nr::PRLIMIT64,
            0,
            RLIMIT_NOFILE,
            0,
            &mut old as *mut Rlimit64 as usize,
            0,
            0,
        )
    };
    if r < 0 {
        return None;
    }
    if old.cur >= old.max {
        return Some(old.cur);
    }
    let new = Rlimit64 {
        cur: old.max,
        max: old.max,
    };
    let r = unsafe {
        syscall6(
            nr::PRLIMIT64,
            0,
            RLIMIT_NOFILE,
            &new as *const Rlimit64 as usize,
            0,
            0,
            0,
        )
    };
    Some(if r < 0 { old.cur } else { new.cur })
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::{Read, Write};
    use std::net::TcpStream;
    use std::os::unix::io::AsRawFd;

    /// The raw-syscall epoll layer drives real sockets: readiness
    /// surfaces for written data and MOD rewrites interest.
    #[test]
    fn epoll_syscalls_drive_socket_readiness() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let mut client = TcpStream::connect(addr).unwrap();
        let (server, _) = listener.accept().unwrap();

        let epfd = epoll_create1().unwrap();
        epoll_ctl(epfd, EPOLL_CTL_ADD, server.as_raw_fd(), EPOLLIN, 42).unwrap();
        let mut events = vec![EpollEvent::default(); 8];
        // Nothing written yet: a zero-timeout wait reports nothing.
        assert_eq!(epoll_wait(epfd, &mut events, 0).unwrap(), 0);
        client.write_all(b"ping").unwrap();
        let n = epoll_wait(epfd, &mut events, 1000).unwrap();
        assert_eq!(n, 1);
        // Copy packed fields out before asserting (no references
        // into a packed struct).
        let (bits, data) = (events[0].events, events[0].data);
        assert_eq!(data, 42);
        assert_ne!(bits & EPOLLIN, 0);
        // MOD to write-only interest: the pending read bytes no
        // longer wake the loop; an idle socket is writable.
        epoll_ctl(epfd, EPOLL_CTL_MOD, server.as_raw_fd(), EPOLLOUT, 7).unwrap();
        let n = epoll_wait(epfd, &mut events, 1000).unwrap();
        assert_eq!(n, 1);
        let (bits, data) = (events[0].events, events[0].data);
        assert_eq!(data, 7);
        assert_ne!(bits & EPOLLOUT, 0);
        assert_eq!(bits & EPOLLIN, 0);
        close(epfd);
    }

    #[test]
    fn nofile_limit_is_readable_and_raisable() {
        let lim = raise_nofile_limit().expect("prlimit64 works on linux");
        assert!(lim >= 1, "soft NOFILE limit {lim}");
        // Idempotent: a second call reports the same (now soft ==
        // hard) limit.
        assert_eq!(raise_nofile_limit(), Some(lim));
    }

    /// A SO_REUSEPORT group shares one port: every sibling reports the
    /// first listener's resolved address, and a connection is accepted
    /// by exactly one of them.
    #[test]
    fn reuseport_group_shares_one_port_and_accepts() {
        let group = bind_reuseport_group("127.0.0.1:0", 3).unwrap();
        let addr = group[0].local_addr().unwrap();
        assert_ne!(addr.port(), 0);
        for l in &group {
            assert_eq!(l.local_addr().unwrap(), addr);
            l.set_nonblocking(true).unwrap();
        }
        let mut client = TcpStream::connect(addr).unwrap();
        client.write_all(b"hi").unwrap();
        // The kernel routed the connection to exactly one accept queue.
        let deadline = std::time::Instant::now() + std::time::Duration::from_secs(5);
        let mut accepted = 0;
        while std::time::Instant::now() < deadline && accepted == 0 {
            for l in &group {
                match l.accept() {
                    Ok((mut s, _)) => {
                        let mut buf = [0u8; 2];
                        s.set_nonblocking(false).unwrap();
                        s.read_exact(&mut buf).unwrap();
                        assert_eq!(&buf, b"hi");
                        accepted += 1;
                    }
                    Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {}
                    Err(e) => panic!("accept failed: {e}"),
                }
            }
            std::thread::sleep(std::time::Duration::from_millis(1));
        }
        assert_eq!(accepted, 1);
    }

    /// eventfd signal/drain roundtrip, and the fd wakes epoll — the
    /// worker-pool completion path in miniature.
    #[test]
    fn eventfd_signals_accumulate_and_wake_epoll() {
        let efd = EventFd::new(true).unwrap();
        assert_eq!(efd.drain(), 0, "unsignaled nonblocking read is empty");
        efd.signal();
        efd.signal();
        assert_eq!(efd.drain(), 2, "counting semantics: writes add");
        assert_eq!(efd.drain(), 0, "read drained the counter");

        let epfd = epoll_create1().unwrap();
        epoll_ctl(epfd, EPOLL_CTL_ADD, efd.raw(), EPOLLIN, 99).unwrap();
        let mut events = vec![EpollEvent::default(); 4];
        assert_eq!(epoll_wait(epfd, &mut events, 0).unwrap(), 0);
        efd.signal();
        let n = epoll_wait(epfd, &mut events, 1000).unwrap();
        assert_eq!(n, 1);
        let (bits, data) = (events[0].events, events[0].data);
        assert_eq!(data, 99);
        assert_ne!(bits & EPOLLIN, 0);
        close(epfd);
    }
}
