//! Blocking client for the sketch service (used by examples, integration
//! tests, the CLI, and the load generator).

use std::io::{BufReader, BufWriter};
use std::net::TcpStream;

use super::protocol::{
    self, CollectionInfo, KnnHit, Request, Response, SlowQueryEntry, StatsSnapshot,
};
use super::replication::Backoff;
use crate::coding::Scheme;
use crate::data::sparse::CsrMatrix;
use crate::projection::MatrixKind;

/// Wrap `req` in a [`Request::Scoped`] frame when a collection is
/// named; `None` keeps the legacy no-namespace encoding (routes to
/// `default`).
fn scoped(collection: Option<&str>, req: Request) -> Request {
    match collection {
        Some(c) => Request::Scoped {
            collection: c.to_string(),
            inner: Box::new(req),
        },
        None => req,
    }
}

/// One `ReplSync` answer, as seen by a replica: either the next run of
/// WAL frames to apply or a snapshot image to rebuild from.
#[derive(Debug)]
pub enum ReplPull {
    Records {
        segment: u64,
        next_segment: u64,
        next_offset: u64,
        behind_bytes: u64,
        primary_records: u64,
        bytes: Vec<u8>,
    },
    Bootstrap {
        segment: u64,
        offset: u64,
        primary_records: u64,
        snapshot: Vec<u8>,
    },
}

/// A connected client. One in-flight request at a time per connection
/// (the protocol is strictly request/response).
pub struct SketchClient {
    reader: BufReader<TcpStream>,
    writer: BufWriter<TcpStream>,
    /// Response frames land here via `read_frame_into`, reusing one
    /// allocation across calls — the replication applier tails the
    /// primary's WAL through this client, so its steady-state pull
    /// loop stops allocating a fresh `Vec` per chunk too.
    recv_buf: Vec<u8>,
}

impl SketchClient {
    pub fn connect(addr: &str) -> crate::Result<Self> {
        let stream = TcpStream::connect(addr)?;
        stream.set_nodelay(true)?;
        Ok(SketchClient {
            reader: BufReader::new(stream.try_clone()?),
            writer: BufWriter::new(stream),
            recv_buf: Vec::new(),
        })
    }

    /// [`SketchClient::connect`] with bounded retry: up to `attempts`
    /// connection attempts separated by jittered exponential backoff
    /// (100ms doubling to 2s). Opt-in — rides out a server restart or
    /// a listen backlog reset without turning a genuinely absent
    /// server into a hang.
    pub fn connect_with_retry(addr: &str, attempts: u32) -> crate::Result<Self> {
        let mut backoff = Backoff::new(
            std::time::Duration::from_millis(100),
            std::time::Duration::from_secs(2),
        );
        let mut last = None;
        for attempt in 0..attempts.max(1) {
            if attempt > 0 {
                std::thread::sleep(backoff.next_delay());
            }
            match Self::connect(addr) {
                Ok(c) => return Ok(c),
                Err(e) => last = Some(e),
            }
        }
        Err(last.unwrap_or_else(|| anyhow::anyhow!("no connection attempts made")))
    }

    fn call(&mut self, req: &Request) -> crate::Result<Response> {
        protocol::write_frame(&mut self.writer, &req.encode())?;
        protocol::read_frame_into(&mut self.reader, &mut self.recv_buf)?;
        Response::decode(&self.recv_buf)
    }

    fn bail(resp: Response) -> anyhow::Error {
        match resp {
            Response::Error { message } => anyhow::anyhow!("server error: {message}"),
            other => anyhow::anyhow!("unexpected response: {other:?}"),
        }
    }

    pub fn ping(&mut self) -> crate::Result<()> {
        match self.call(&Request::Ping)? {
            Response::Pong => Ok(()),
            other => Err(Self::bail(other)),
        }
    }

    pub fn register(&mut self, id: &str, vector: Vec<f32>) -> crate::Result<()> {
        self.register_in(None, id, vector)
    }

    /// [`SketchClient::register`] into a named collection (`None` =
    /// `default`, sent as a legacy no-namespace frame).
    pub fn register_in(
        &mut self,
        collection: Option<&str>,
        id: &str,
        vector: Vec<f32>,
    ) -> crate::Result<()> {
        let req = scoped(
            collection,
            Request::Register {
                id: id.to_string(),
                vector,
            },
        );
        match self.call(&req)? {
            Response::Registered { .. } => Ok(()),
            other => Err(Self::bail(other)),
        }
    }

    /// Bulk register: `ids[i]` stores the sketch of `vectors[i]` via the
    /// server's fused project→encode→pack→ingest pass. Returns the
    /// number of sketches stored.
    pub fn register_batch(
        &mut self,
        ids: Vec<String>,
        vectors: Vec<Vec<f32>>,
    ) -> crate::Result<u64> {
        self.register_batch_in(None, ids, vectors)
    }

    /// [`SketchClient::register_batch`] into a named collection.
    pub fn register_batch_in(
        &mut self,
        collection: Option<&str>,
        ids: Vec<String>,
        vectors: Vec<Vec<f32>>,
    ) -> crate::Result<u64> {
        match self.call(&scoped(collection, Request::RegisterBatch { ids, vectors }))? {
            Response::RegisteredBatch { count } => Ok(count),
            other => Err(Self::bail(other)),
        }
    }

    /// Sparse bulk register: `ids[i]` stores the sketch of CSR row `i`,
    /// shipped as index/value triplets (O(nnz) wire bytes) and
    /// projected at O(nnz·k) through the server's gather kernel —
    /// byte-identical to registering the densified rows. Returns the
    /// number of sketches stored.
    pub fn register_sparse(&mut self, ids: Vec<String>, csr: CsrMatrix) -> crate::Result<u64> {
        self.register_sparse_in(None, ids, csr)
    }

    /// [`SketchClient::register_sparse`] into a named collection.
    pub fn register_sparse_in(
        &mut self,
        collection: Option<&str>,
        ids: Vec<String>,
        csr: CsrMatrix,
    ) -> crate::Result<u64> {
        match self.call(&scoped(collection, Request::RegisterSparse { ids, csr }))? {
            Response::RegisteredBatch { count } => Ok(count),
            other => Err(Self::bail(other)),
        }
    }

    /// Drop the sketch stored under `id`; returns whether it existed.
    pub fn remove(&mut self, id: &str) -> crate::Result<bool> {
        self.remove_in(None, id)
    }

    /// [`SketchClient::remove`] in a named collection.
    pub fn remove_in(&mut self, collection: Option<&str>, id: &str) -> crate::Result<bool> {
        let req = scoped(collection, Request::Remove { id: id.to_string() });
        match self.call(&req)? {
            Response::Removed { existed } => Ok(existed),
            other => Err(Self::bail(other)),
        }
    }

    /// Explicit durability checkpoint; returns `(rows snapshotted,
    /// WAL bytes retired)`. Errors when the server is not durable.
    /// Unscoped, this checkpoints every durable collection; scoped, one.
    pub fn persist(&mut self) -> crate::Result<(u64, u64)> {
        self.persist_in(None)
    }

    /// [`SketchClient::persist`] for a named collection.
    pub fn persist_in(&mut self, collection: Option<&str>) -> crate::Result<(u64, u64)> {
        match self.call(&scoped(collection, Request::Persist))? {
            Response::Persisted { rows, wal_bytes } => Ok((rows, wal_bytes)),
            other => Err(Self::bail(other)),
        }
    }

    /// Returns `(rho, std_err)`.
    pub fn estimate(&mut self, a: &str, b: &str) -> crate::Result<(f64, f64)> {
        self.estimate_in(None, a, b)
    }

    /// [`SketchClient::estimate`] within a named collection.
    pub fn estimate_in(
        &mut self,
        collection: Option<&str>,
        a: &str,
        b: &str,
    ) -> crate::Result<(f64, f64)> {
        let req = scoped(
            collection,
            Request::Estimate {
                a: a.to_string(),
                b: b.to_string(),
            },
        );
        match self.call(&req)? {
            Response::Estimate { rho, std_err, .. } => Ok((rho, std_err)),
            other => Err(Self::bail(other)),
        }
    }

    pub fn estimate_vec(&mut self, id: &str, vector: Vec<f32>) -> crate::Result<(f64, f64)> {
        self.estimate_vec_in(None, id, vector)
    }

    /// [`SketchClient::estimate_vec`] within a named collection.
    pub fn estimate_vec_in(
        &mut self,
        collection: Option<&str>,
        id: &str,
        vector: Vec<f32>,
    ) -> crate::Result<(f64, f64)> {
        let req = scoped(
            collection,
            Request::EstimateVec {
                id: id.to_string(),
                vector,
            },
        );
        match self.call(&req)? {
            Response::Estimate { rho, std_err, .. } => Ok((rho, std_err)),
            other => Err(Self::bail(other)),
        }
    }

    pub fn knn(&mut self, vector: Vec<f32>, n: u32) -> crate::Result<Vec<KnnHit>> {
        self.knn_in(None, vector, n)
    }

    /// [`SketchClient::knn`] within a named collection.
    pub fn knn_in(
        &mut self,
        collection: Option<&str>,
        vector: Vec<f32>,
        n: u32,
    ) -> crate::Result<Vec<KnnHit>> {
        match self.call(&scoped(collection, Request::Knn { vector, n }))? {
            Response::Knn { hits } => Ok(hits),
            other => Err(Self::bail(other)),
        }
    }

    /// Batched top-k: one result list per query vector, in order.
    pub fn topk(&mut self, vectors: Vec<Vec<f32>>, n: u32) -> crate::Result<Vec<Vec<KnnHit>>> {
        self.topk_in(None, vectors, n)
    }

    /// [`SketchClient::topk`] within a named collection.
    pub fn topk_in(
        &mut self,
        collection: Option<&str>,
        vectors: Vec<Vec<f32>>,
        n: u32,
    ) -> crate::Result<Vec<Vec<KnnHit>>> {
        match self.call(&scoped(collection, Request::TopK { vectors, n }))? {
            Response::TopK { results } => Ok(results),
            other => Err(Self::bail(other)),
        }
    }

    /// Approximate batched top-k through the server's banded code
    /// index: `probes` extra bucket probes per band (0 = the
    /// collection's default). Recall trades against candidate cost;
    /// results carry exact ρ̂ for every returned id.
    pub fn approx_topk(
        &mut self,
        vectors: Vec<Vec<f32>>,
        n: u32,
        probes: u32,
    ) -> crate::Result<Vec<Vec<KnnHit>>> {
        self.approx_topk_in(None, vectors, n, probes)
    }

    /// [`SketchClient::approx_topk`] within a named collection.
    pub fn approx_topk_in(
        &mut self,
        collection: Option<&str>,
        vectors: Vec<Vec<f32>>,
        n: u32,
        probes: u32,
    ) -> crate::Result<Vec<Vec<KnnHit>>> {
        match self.call(&scoped(collection, Request::ApproxTopK { vectors, n, probes }))? {
            Response::TopK { results } => Ok(results),
            other => Err(Self::bail(other)),
        }
    }

    /// Create a collection with its own coding choice. `bits` 0 derives
    /// the packed width from `(scheme, w)`; `checkpoint_every` 0 uses
    /// the server's global cadence.
    pub fn create_collection(
        &mut self,
        name: &str,
        scheme: Scheme,
        w: f64,
        k: u64,
        seed: u64,
        checkpoint_every: u64,
    ) -> crate::Result<()> {
        self.create_collection_with_kind(
            name,
            scheme,
            w,
            k,
            seed,
            checkpoint_every,
            MatrixKind::Gaussian,
        )
    }

    /// [`SketchClient::create_collection`] with an explicit projection
    /// matrix family (`MatrixKind::SignSparse` enables the O(nnz)
    /// matrix-free sign kernel). Gaussian frames stay byte-identical to
    /// the legacy encoding, so older servers accept them.
    #[allow(clippy::too_many_arguments)]
    pub fn create_collection_with_kind(
        &mut self,
        name: &str,
        scheme: Scheme,
        w: f64,
        k: u64,
        seed: u64,
        checkpoint_every: u64,
        kind: MatrixKind,
    ) -> crate::Result<()> {
        match self.call(&Request::CreateCollection {
            name: name.to_string(),
            scheme,
            w,
            bits: 0,
            k,
            seed,
            checkpoint_every,
            kind,
        })? {
            Response::CollectionCreated { .. } => Ok(()),
            other => Err(Self::bail(other)),
        }
    }

    /// Drop a collection (and its durable state); returns whether it
    /// existed.
    pub fn drop_collection(&mut self, name: &str) -> crate::Result<bool> {
        match self.call(&Request::DropCollection {
            name: name.to_string(),
        })? {
            Response::CollectionDropped { existed } => Ok(existed),
            other => Err(Self::bail(other)),
        }
    }

    /// Enumerate collections, sorted by name.
    pub fn list_collections(&mut self) -> crate::Result<Vec<CollectionInfo>> {
        match self.call(&Request::ListCollections)? {
            Response::Collections { collections } => Ok(collections),
            other => Err(Self::bail(other)),
        }
    }

    /// Aggregate service counters (the legacy frame — works against any
    /// server version; `per_collection` comes back empty).
    pub fn stats(&mut self) -> crate::Result<StatsSnapshot> {
        match self.call(&Request::Stats)? {
            Response::Stats(s) => Ok(s),
            other => Err(Self::bail(other)),
        }
    }

    /// [`SketchClient::stats`] plus the per-collection breakdown
    /// (rows, pending, WAL bytes, index buckets) and per-request-kind
    /// latency rows. Needs a server that understands `StatsDetailed`;
    /// older servers reject the frame. The reverse pairing also needs
    /// matching versions: clients older than the server cannot decode
    /// a detailed answer once it carries a section they predate (use
    /// plain [`SketchClient::stats`] for cross-version compatibility).
    pub fn stats_detailed(&mut self) -> crate::Result<StatsSnapshot> {
        match self.call(&Request::StatsDetailed)? {
            Response::Stats(s) => Ok(s),
            other => Err(Self::bail(other)),
        }
    }

    /// The full Prometheus-style exposition page (the same text
    /// `--metrics-addr` serves over HTTP). Needs a server that
    /// understands `MetricsText`; older servers reject the frame.
    pub fn metrics_text(&mut self) -> crate::Result<String> {
        match self.call(&Request::MetricsText)? {
            Response::MetricsText { text } => Ok(text),
            other => Err(Self::bail(other)),
        }
    }

    /// One replication pull: ask the primary for WAL records of
    /// `collection` past `(segment, offset)` — `segment` 0 requests a
    /// snapshot bootstrap instead. `replica` is this replica's stable
    /// id (keys the primary's segment-retention floor).
    pub fn repl_sync(
        &mut self,
        collection: &str,
        replica: &str,
        segment: u64,
        offset: u64,
    ) -> crate::Result<ReplPull> {
        let req = Request::ReplSync {
            collection: collection.to_string(),
            replica: replica.to_string(),
            segment,
            offset,
        };
        match self.call(&req)? {
            Response::ReplRecords {
                segment,
                next_segment,
                next_offset,
                behind_bytes,
                primary_records,
                bytes,
            } => Ok(ReplPull::Records {
                segment,
                next_segment,
                next_offset,
                behind_bytes,
                primary_records,
                bytes,
            }),
            Response::ReplBootstrap {
                segment,
                offset,
                primary_records,
                snapshot,
            } => Ok(ReplPull::Bootstrap {
                segment,
                offset,
                primary_records,
                snapshot,
            }),
            other => Err(Self::bail(other)),
        }
    }

    /// The server's slow-query ring, oldest first (`max` 0 = the whole
    /// ring).
    pub fn slow_queries(&mut self, max: u32) -> crate::Result<Vec<SlowQueryEntry>> {
        match self.call(&Request::SlowQueries { max })? {
            Response::SlowQueries { entries } => Ok(entries),
            other => Err(Self::bail(other)),
        }
    }

    /// Promote a replica into a standalone primary (idempotent; a
    /// server that never replicated reports `was_replica` false).
    pub fn promote(&mut self) -> crate::Result<bool> {
        match self.call(&Request::Promote)? {
            Response::Promoted { was_replica } => Ok(was_replica),
            other => Err(Self::bail(other)),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::server::{serve, ServerConfig};
    use crate::projection::{ProjectionConfig, Projector};
    use std::sync::Arc;

    /// Boot an ephemeral-port server and report its address. The server
    /// thread owns the ready channel; if it dies before binding (port
    /// exhaustion, bad addr), `recv` observes the dropped sender — that
    /// is surfaced as an error here instead of an opaque `unwrap` panic.
    fn spawn_server(k: usize) -> crate::Result<String> {
        let projector = Arc::new(Projector::new_cpu(ProjectionConfig {
            k,
            seed: 1,
            ..Default::default()
        }));
        let cfg = ServerConfig {
            addr: "127.0.0.1:0".to_string(),
            ..Default::default()
        };
        let (tx, rx) = std::sync::mpsc::channel();
        std::thread::spawn(move || {
            let _ = serve(projector, cfg, Some(tx));
        });
        let addr = rx.recv().map_err(|_| {
            anyhow::anyhow!("server thread exited before reporting its bound address")
        })?;
        Ok(addr.to_string())
    }

    #[test]
    fn end_to_end_over_tcp() -> crate::Result<()> {
        let addr = spawn_server(512)?;
        let mut c = SketchClient::connect(&addr)?;
        c.ping()?;
        let (u, v) = crate::data::pairs::unit_pair_with_rho(64, 0.8, 21);
        c.register("u", u.clone())?;
        c.register("v", v)?;
        let (rho, err) = c.estimate("u", "v")?;
        assert!((rho - 0.8).abs() < 4.0 * err + 0.05, "rho {rho} err {err}");
        let hits = c.knn(u.clone(), 2)?;
        assert_eq!(hits[0].id, "u"); // itself
        let results = c.topk(vec![u.clone()], 2)?;
        assert_eq!(results.len(), 1);
        assert_eq!(results[0], hits);
        // Bulk registration round-trips and lands in the same store.
        let n = c.register_batch(
            vec!["b0".into(), "b1".into()],
            vec![u.clone(), u],
        )?;
        assert_eq!(n, 2);
        let (rho_dup, _) = c.estimate("b0", "u")?;
        assert!(rho_dup > 0.999, "identical vectors: rho {rho_dup}");
        let stats = c.stats()?;
        assert_eq!(stats.registered, 4);
        assert_eq!(stats.knn_queries, 2);
        assert!(!stats.kernel.is_empty());
        // Remove round-trips; Persist errors on a non-durable server.
        assert!(c.remove("b1")?);
        assert!(!c.remove("b1")?);
        let stats = c.stats()?;
        assert_eq!(stats.wal_records, 0, "non-durable server logs nothing");
        assert!(c.persist().is_err());
        // The exposition page rides the same connection; by now every
        // request above has been recorded by the connection loop.
        let text = c.metrics_text()?;
        assert!(text.contains("crp_registered_total 4"), "{text}");
        assert!(text.contains("crp_requests_total{kind=\"register\"} 2"));
        assert!(text.contains("# TYPE crp_request_duration_us histogram"));
        // Detailed stats carry per-request latency rows for the kinds
        // this connection exercised.
        let detailed = c.stats_detailed()?;
        let kinds: Vec<&str> = detailed.per_request.iter().map(|r| r.kind.as_str()).collect();
        assert!(kinds.contains(&"register"), "{kinds:?}");
        assert!(kinds.contains(&"knn"), "{kinds:?}");
        for r in &detailed.per_request {
            assert!(r.count > 0);
            assert!(r.p99_us >= r.p50_us, "{}: p99 < p50", r.kind);
        }
        Ok(())
    }

    #[test]
    fn sparse_register_over_tcp_matches_dense() -> crate::Result<()> {
        let addr = spawn_server(128)?;
        let mut c = SketchClient::connect(&addr)?;
        let mut csr = CsrMatrix::with_capacity(2, 3, 50);
        csr.push_row(&[3, 17, 40], &[0.5, -1.0, 2.0]);
        csr.push_row(&[], &[]);
        let dense0 = csr.row_dense(0);
        let n = c.register_sparse(vec!["s0".into(), "s1".into()], csr)?;
        assert_eq!(n, 2);
        c.register("d0", dense0)?;
        // Identical sketches estimate ρ̂ = 1 — the CSR frame landed the
        // same packed codes the dense frame did.
        let (rho, _) = c.estimate("s0", "d0")?;
        assert!(rho > 0.999, "rho {rho}");
        // A sign-sparse collection is created over the wire and serves
        // the same sparse ingest path.
        c.create_collection_with_kind(
            "signs",
            Scheme::OneBit,
            0.0,
            64,
            9,
            0,
            MatrixKind::SignSparse { s: 4 },
        )?;
        let mut csr2 = CsrMatrix::with_capacity(1, 2, 50);
        csr2.push_row(&[1, 30], &[1.0, -2.0]);
        let dense = csr2.row_dense(0);
        assert_eq!(c.register_sparse_in(Some("signs"), vec!["a".into()], csr2)?, 1);
        c.register_in(Some("signs"), "b", dense)?;
        let (rho, _) = c.estimate_in(Some("signs"), "a", "b")?;
        assert!(rho > 0.999, "sign-sparse rho {rho}");
        // Mismatched id/row counts surface as a clean server error.
        assert!(c
            .register_sparse(vec!["x".into()], CsrMatrix::with_capacity(0, 0, 8))
            .is_err());
        Ok(())
    }

    #[test]
    fn server_error_propagates() -> crate::Result<()> {
        let addr = spawn_server(64)?;
        let mut c = SketchClient::connect(&addr)?;
        let e = c.estimate("ghost", "ghost2");
        assert!(e.is_err());
        Ok(())
    }

    #[test]
    fn dead_server_yields_error_not_panic() {
        // A listener that accepts one connection and immediately drops
        // it simulates a server dying mid-conversation: every later
        // call must surface an error — nothing unwraps internally.
        let listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        let server = std::thread::spawn(move || {
            let (stream, _) = listener.accept().unwrap();
            drop(stream);
        });
        let mut c = SketchClient::connect(&addr).unwrap();
        server.join().unwrap();
        assert!(c.ping().is_err());
        assert!(c.estimate("a", "b").is_err());
        // Connecting to a port nothing listens on errors cleanly too.
        assert!(SketchClient::connect("127.0.0.1:1").is_err());
    }

    #[test]
    fn connect_with_retry_rides_out_a_late_listener() {
        // Nothing listening and a bounded attempt budget: fails in
        // bounded time instead of hanging.
        let t0 = std::time::Instant::now();
        assert!(SketchClient::connect_with_retry("127.0.0.1:1", 2).is_err());
        assert!(t0.elapsed() < std::time::Duration::from_secs(5));

        // A listener that appears after the first refused attempt is
        // reached by a later one.
        let probe = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = probe.local_addr().unwrap().to_string();
        drop(probe); // port free now; reclaimed by the thread below
        let addr2 = addr.clone();
        let listener = std::thread::spawn(move || {
            std::thread::sleep(std::time::Duration::from_millis(150));
            let l = std::net::TcpListener::bind(&addr2).unwrap();
            let _ = l.accept();
        });
        // Generous budget: the backoff schedule crosses 150ms well
        // within 8 attempts.
        assert!(SketchClient::connect_with_retry(&addr, 8).is_ok());
        listener.join().unwrap();
    }

    #[test]
    fn concurrent_clients() -> crate::Result<()> {
        let addr = spawn_server(128)?;
        let mut handles = Vec::new();
        for t in 0..6 {
            let addr = addr.clone();
            handles.push(std::thread::spawn(move || {
                let mut c = SketchClient::connect(&addr).unwrap();
                for i in 0..10 {
                    let v: Vec<f32> = (0..32)
                        .map(|j| ((t * 100 + i * 10 + j) as f32).sin())
                        .collect();
                    c.register(&format!("t{t}-{i}"), v).unwrap();
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        let mut c = SketchClient::connect(&addr)?;
        let stats = c.stats()?;
        assert_eq!(stats.registered, 60);
        Ok(())
    }
}
