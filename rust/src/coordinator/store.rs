//! Sharded sketch store: `id → PackedCodes`. Only the coded sketches
//! live here — raw vectors are dropped after projection, which is the
//! paper's storage-compression story in operational form.
//!
//! Two storage modes:
//!
//! * **Map-only** ([`SketchStore::new`]) — the sharded `HashMap` alone;
//!   sketches of any shape.
//! * **Arena-backed** ([`SketchStore::with_arena`]) — every put/remove is
//!   mirrored into an [`EpochArena`] so `Knn`/`TopK` queries run as
//!   columnar scans ([`crate::scan`]) instead of pointer-chasing the
//!   map. All sketches must then share one `(k, bits)` shape.
//!
//! Writes in arena mode go through the epoch buffer: `put`/`remove`
//! take a shard write lock, a sealed *read* lock, and the small pending
//! mutex — never the arena write lock — so registration keeps flowing
//! while scans hold the read side. When the pending load crosses the
//! drain threshold, the writer that crossed it attempts a bulk fold
//! (outside its shard critical section) with a *try*-lock: under read
//! pressure the fold is skipped — the register path never waits on the
//! sealed write lock — and a later write retries once the scans finish.
//! One bounded exception: if sustained scans starve the fold until the
//! pending load reaches [`crate::scan::epoch::RELIEF_FACTOR`]× the
//! threshold, the crossing writer folds with a blocking acquisition so
//! pending memory cannot grow without bound.
//!
//! With [`SketchStore::delegate_drains`] (the serving configuration — a
//! [`crate::coordinator::maintenance`] thread owns fold duty), the
//! crossing writer only notifies a [`DrainSignal`] and keeps nothing
//! but the relief-cap backstop, so registers never pay for folds or
//! compaction at all.
//!
//! Consistency: for one id, the map and arena are updated under that
//! id's shard write lock, so per-id last-writer-wins holds across both
//! views. The bulk path ([`SketchStore::put_rows`]) updates the arena
//! first and the map after, without a covering lock — a concurrent
//! single `put` of the same id may interleave, which is the documented
//! tradeoff of bulk ingest.

use std::collections::HashMap;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex, OnceLock, RwLock};
use std::time::Duration;

use crate::coding::PackedCodes;
use crate::scan::{EpochArena, EpochConfig};

const N_SHARDS: usize = 16;

/// Wake-up channel from the store's writers to an external maintenance
/// thread that owns drains/compaction. Notifications coalesce: any
/// number of threshold crossings between waits wake the waiter once.
#[derive(Debug, Default)]
pub struct DrainSignal {
    armed: Mutex<bool>,
    cv: Condvar,
}

impl DrainSignal {
    pub fn notify(&self) {
        let mut armed = self.armed.lock().unwrap();
        *armed = true;
        self.cv.notify_one();
    }

    /// Block until notified or `timeout` elapses; returns whether a
    /// notification arrived (and consumes it).
    pub fn wait_timeout(&self, timeout: Duration) -> bool {
        let mut armed = self.armed.lock().unwrap();
        if !*armed {
            armed = self.cv.wait_timeout(armed, timeout).unwrap().0;
        }
        let was = *armed;
        *armed = false;
        was
    }
}

/// Thread-safe sharded map from string ids to packed code sketches.
#[derive(Debug)]
pub struct SketchStore {
    shards: Vec<RwLock<HashMap<String, PackedCodes>>>,
    /// Live sketch count, maintained on put/remove so `len` never has to
    /// sweep all shard locks (it sits on the metrics path).
    count: AtomicUsize,
    /// Columnar mirror for the scan engine (arena-backed mode only).
    arena: Option<EpochArena>,
    /// When set (see [`SketchStore::delegate_drains`]), threshold
    /// crossings notify this signal instead of folding on the writer.
    drain_signal: OnceLock<Arc<DrainSignal>>,
}

impl Default for SketchStore {
    fn default() -> Self {
        Self::new()
    }
}

impl SketchStore {
    /// Map-only store.
    pub fn new() -> Self {
        SketchStore {
            shards: (0..N_SHARDS).map(|_| RwLock::new(HashMap::new())).collect(),
            count: AtomicUsize::new(0),
            arena: None,
            drain_signal: OnceLock::new(),
        }
    }

    /// Arena-backed store for sketches of `k` codes at `bits` per code
    /// (rounded up to a supported packing width). Every sketch put into
    /// this store must match that shape.
    pub fn with_arena(k: usize, bits: u32) -> Self {
        Self::with_arena_config(k, bits, EpochConfig::default())
    }

    /// As [`SketchStore::with_arena`] with explicit drain/compaction
    /// policy.
    pub fn with_arena_config(k: usize, bits: u32, cfg: EpochConfig) -> Self {
        let mut s = Self::new();
        s.arena = Some(EpochArena::with_config(k, bits, cfg));
        s
    }

    /// As [`SketchStore::with_arena_config`], additionally maintaining
    /// the banded multi-probe candidate index
    /// ([`crate::lsh::CodeIndex`]) over the sealed arena so
    /// `ApproxTopK` queries run in bucket-bounded work. The index rides
    /// every drain; writers pay nothing extra on the put path.
    pub fn with_arena_index(
        k: usize,
        bits: u32,
        cfg: EpochConfig,
        index: crate::lsh::IndexConfig,
    ) -> Self {
        let mut s = Self::new();
        s.arena = Some(EpochArena::with_index_config(k, bits, cfg, index));
        s
    }

    /// The columnar mirror, when in arena-backed mode. Scans through it
    /// never block `put`/`remove` (epoch-buffered writes).
    pub fn arena(&self) -> Option<&EpochArena> {
        self.arena.as_ref()
    }

    /// Hand fold/compaction duty to an external maintenance thread:
    /// after this, a writer that crosses the drain threshold notifies
    /// `signal` instead of folding itself, and folds inline only past
    /// the relief cap ([`crate::scan::epoch::RELIEF_FACTOR`]× the
    /// threshold) — the hard bound on pending growth if the maintenance
    /// thread stalls. Set once; later calls are ignored.
    pub fn delegate_drains(&self, signal: Arc<DrainSignal>) {
        let _ = self.drain_signal.set(signal);
    }

    /// Post-write fold policy: fold on the writer (owner mode) or
    /// notify the maintenance thread (delegated mode).
    fn fold_or_notify(&self) {
        let Some(arena) = &self.arena else { return };
        match self.drain_signal.get() {
            Some(signal) => {
                signal.notify();
                if arena.overloaded() {
                    arena.drain();
                }
            }
            None => {
                arena.relieve();
            }
        }
    }

    fn shard(&self, id: &str) -> &RwLock<HashMap<String, PackedCodes>> {
        let mut h: u64 = 0xcbf29ce484222325;
        for b in id.as_bytes() {
            h ^= *b as u64;
            h = h.wrapping_mul(0x100000001b3);
        }
        &self.shards[(h as usize) % N_SHARDS]
    }

    /// Insert or replace a sketch. In arena mode this touches the shard
    /// lock, a sealed read lock, and the pending mutex — never the arena
    /// write lock — and opportunistically folds the epoch afterwards if
    /// this write armed the drain threshold (try-lock; skipped while
    /// scans hold the read side).
    pub fn put(&self, id: String, codes: PackedCodes) {
        let mut drain_due = false;
        {
            let mut guard = self.shard(&id).write().unwrap();
            if let Some(arena) = &self.arena {
                drain_due = arena.put(&id, &codes);
            }
            if guard.insert(id, codes).is_none() {
                self.count.fetch_add(1, Ordering::Relaxed);
            }
        }
        if drain_due {
            self.fold_or_notify();
        }
    }

    /// Bulk insert: `ids[i]`'s packed row is
    /// `words[i·stride..(i+1)·stride]` in arena layout — the fused
    /// encode pipeline's ingest. One pending-buffer lock round-trip for
    /// the whole batch; requires arena mode (the batch already has one
    /// fixed shape).
    pub fn put_rows(&self, ids: &[String], words: &[u64]) -> crate::Result<()> {
        let arena = self
            .arena
            .as_ref()
            .ok_or_else(|| anyhow::anyhow!("put_rows requires an arena-backed store"))?;
        let stride = arena.stride();
        anyhow::ensure!(
            words.len() == ids.len() * stride,
            "bulk buffer holds {} words for {} rows of stride {stride}",
            words.len(),
            ids.len()
        );
        let drain_due = arena.put_rows(ids, words);
        for (i, id) in ids.iter().enumerate() {
            let codes = PackedCodes::from_words(
                arena.bits(),
                arena.k(),
                words[i * stride..(i + 1) * stride].to_vec(),
            );
            let mut guard = self.shard(id).write().unwrap();
            if guard.insert(id.clone(), codes).is_none() {
                self.count.fetch_add(1, Ordering::Relaxed);
            }
        }
        if drain_due {
            self.fold_or_notify();
        }
        Ok(())
    }

    /// Fetch a clone of a sketch.
    pub fn get(&self, id: &str) -> Option<PackedCodes> {
        self.shard(id).read().unwrap().get(id).cloned()
    }

    pub fn contains(&self, id: &str) -> bool {
        self.shard(id).read().unwrap().contains_key(id)
    }

    pub fn remove(&self, id: &str) -> bool {
        let removed = {
            let mut guard = self.shard(id).write().unwrap();
            if let Some(arena) = &self.arena {
                arena.remove(id);
            }
            let removed = guard.remove(id).is_some();
            if removed {
                self.count.fetch_sub(1, Ordering::Relaxed);
            }
            removed
        };
        // Delete-heavy phases arm the drain threshold too — fold and
        // compact without waiting for a later put.
        if let Some(arena) = &self.arena {
            if removed && arena.drain_due() {
                self.fold_or_notify();
            }
        }
        removed
    }

    /// Live sketch count (lock-free; one atomic load).
    pub fn len(&self) -> usize {
        self.count.load(Ordering::Relaxed)
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Visit every `(id, sketch)` pair (tests and brute-force oracles;
    /// persistence serializes the sealed arena image instead, so it
    /// never holds shard locks across disk writes). The visitor runs
    /// under each shard's read lock in turn.
    pub fn for_each<F: FnMut(&str, &PackedCodes)>(&self, mut f: F) {
        for s in &self.shards {
            let guard = s.read().unwrap();
            for (id, codes) in guard.iter() {
                f(id, codes);
            }
        }
    }

    /// Total bytes of packed sketch storage.
    pub fn storage_bytes(&self) -> usize {
        let mut total = 0;
        self.for_each(|_, c| total += c.storage_bytes());
        total
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coding::pack_codes;

    fn sketch(seed: u16) -> PackedCodes {
        let codes: Vec<u16> = (0..64).map(|i| ((i as u16 + seed) % 4)).collect();
        pack_codes(&codes, 2)
    }

    #[test]
    fn put_get_remove() {
        let s = SketchStore::new();
        assert!(s.is_empty());
        s.put("a".into(), sketch(0));
        s.put("b".into(), sketch(1));
        assert_eq!(s.len(), 2);
        assert!(s.contains("a"));
        assert_eq!(s.get("a").unwrap(), sketch(0));
        assert!(s.get("zzz").is_none());
        assert!(s.remove("a"));
        assert!(!s.remove("a"));
        assert_eq!(s.len(), 1);
    }

    #[test]
    fn overwrite_replaces() {
        let s = SketchStore::new();
        s.put("x".into(), sketch(0));
        s.put("x".into(), sketch(9));
        assert_eq!(s.len(), 1);
        assert_eq!(s.get("x").unwrap(), sketch(9));
    }

    #[test]
    fn for_each_sees_all() {
        let s = SketchStore::new();
        for i in 0..100 {
            s.put(format!("id{i}"), sketch(i as u16));
        }
        let mut n = 0;
        s.for_each(|_, _| n += 1);
        assert_eq!(n, 100);
        assert!(s.storage_bytes() >= 100 * 16);
    }

    #[test]
    fn concurrent_access() {
        use std::sync::Arc;
        let s = Arc::new(SketchStore::new());
        let mut handles = Vec::new();
        for t in 0..8 {
            let s = s.clone();
            handles.push(std::thread::spawn(move || {
                for i in 0..50 {
                    s.put(format!("t{t}-{i}"), sketch(i));
                    let _ = s.get(&format!("t{t}-{i}"));
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(s.len(), 400);
    }

    #[test]
    fn arena_mode_mirrors_map() {
        let s = SketchStore::with_arena(64, 2);
        for i in 0..30 {
            s.put(format!("id{i}"), sketch(i));
        }
        s.put("id7".into(), sketch(99)); // overwrite
        assert!(s.remove("id3"));
        assert_eq!(s.len(), 29);
        let arena = s.arena().unwrap();
        assert_eq!(arena.len(), 29);
        assert_eq!(arena.get("id7").unwrap(), sketch(99));
        assert!(arena.get("id3").is_none());
        for i in [0u16, 1, 2, 4, 5, 28, 29] {
            assert_eq!(arena.get(&format!("id{i}")), s.get(&format!("id{i}")));
        }
        // The mirror stays exact across a drain.
        arena.drain();
        assert_eq!(arena.len(), 29);
        assert_eq!(arena.get("id7").unwrap(), sketch(99));
        assert!(arena.get("id3").is_none());
    }

    #[test]
    fn arena_mode_auto_drains_at_threshold() {
        let s = SketchStore::with_arena_config(
            64,
            2,
            EpochConfig {
                drain_threshold: 16,
                ..EpochConfig::default()
            },
        );
        for i in 0..100 {
            s.put(format!("id{i}"), sketch(i));
        }
        let arena = s.arena().unwrap();
        assert!(arena.drains() >= 5, "drains {}", arena.drains());
        assert!(arena.pending_load() < 16);
        assert_eq!(arena.len(), 100);
        // Delete-heavy phases fold too — removes arm the threshold.
        let drains_before = arena.drains();
        for i in 0..64 {
            assert!(s.remove(&format!("id{i}")));
        }
        assert!(
            arena.drains() > drains_before,
            "removes alone must trigger drains"
        );
        assert_eq!(arena.len(), 36);
        assert_eq!(s.len(), 36);
    }

    #[test]
    fn bulk_put_rows_matches_singles() {
        let s = SketchStore::with_arena(64, 2);
        let stride = s.arena().unwrap().stride();
        let ids: Vec<String> = (0..10).map(|i| format!("b{i}")).collect();
        let mut words = Vec::with_capacity(10 * stride);
        for i in 0..10u16 {
            words.extend_from_slice(sketch(i).words());
        }
        s.put_rows(&ids, &words).unwrap();
        assert_eq!(s.len(), 10);
        assert_eq!(s.arena().unwrap().len(), 10);
        for (i, id) in ids.iter().enumerate() {
            assert_eq!(s.get(id).unwrap(), sketch(i as u16), "{id}");
            assert_eq!(s.arena().unwrap().get(id).unwrap(), sketch(i as u16));
        }
        // Shape errors are reported, not panicked.
        assert!(s.put_rows(&ids, &words[..words.len() - 1]).is_err());
        assert!(SketchStore::new().put_rows(&ids, &words).is_err());
    }

    #[test]
    fn delegated_drains_notify_instead_of_folding() {
        let s = SketchStore::with_arena_config(
            64,
            2,
            EpochConfig {
                drain_threshold: 4,
                ..EpochConfig::default()
            },
        );
        let signal = std::sync::Arc::new(DrainSignal::default());
        s.delegate_drains(signal.clone());
        for i in 0..8 {
            s.put(format!("id{i}"), sketch(i));
        }
        let arena = s.arena().unwrap();
        // The writer crossed the threshold twice but folded zero times —
        // it only raised the signal.
        assert_eq!(arena.drains(), 0);
        assert!(arena.pending_load() >= 4);
        assert!(signal.wait_timeout(std::time::Duration::from_millis(1)));
        // Signal consumed; no new crossing, no new notification.
        assert!(!signal.wait_timeout(std::time::Duration::from_millis(1)));
        // Past the relief cap (RELIEF_FACTOR × 4 = 32) the writer folds
        // inline anyway, bounding pending growth.
        for i in 0..40 {
            s.put(format!("extra{i}"), sketch(i));
        }
        assert!(arena.drains() >= 1, "relief backstop must fold");
        assert_eq!(s.len(), 48);
        assert_eq!(arena.len(), 48);
    }

    #[test]
    fn concurrent_arena_mode_stays_consistent() {
        use std::sync::Arc;
        let s = Arc::new(SketchStore::with_arena_config(
            64,
            2,
            EpochConfig {
                drain_threshold: 32, // force mid-test drains
                ..EpochConfig::default()
            },
        ));
        let mut handles = Vec::new();
        for t in 0..4 {
            let s = s.clone();
            handles.push(std::thread::spawn(move || {
                for i in 0..40 {
                    s.put(format!("t{t}-{i}"), sketch(i));
                }
                for i in (0..40).step_by(3) {
                    s.remove(&format!("t{t}-{i}"));
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        let live = 4 * (40 - 14);
        assert_eq!(s.len(), live);
        assert_eq!(s.arena().unwrap().len(), live);
        s.arena().unwrap().drain();
        assert_eq!(s.arena().unwrap().len(), live);
    }
}
