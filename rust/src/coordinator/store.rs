//! Sharded sketch store: `id → PackedCodes`. Only the coded sketches
//! live here — raw vectors are dropped after projection, which is the
//! paper's storage-compression story in operational form.
//!
//! Two storage modes:
//!
//! * **Map-only** ([`SketchStore::new`]) — the sharded `HashMap` alone;
//!   sketches of any shape.
//! * **Arena-backed** ([`SketchStore::with_arena`]) — every put/remove is
//!   mirrored into a columnar [`CodeArena`] so `Knn`/`TopK` queries run
//!   as sequential scans ([`crate::scan`]) instead of pointer-chasing the
//!   map. All sketches must then share one `(k, bits)` shape.

use std::collections::HashMap;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::RwLock;

use crate::coding::PackedCodes;
use crate::scan::CodeArena;

const N_SHARDS: usize = 16;

/// Thread-safe sharded map from string ids to packed code sketches.
#[derive(Debug)]
pub struct SketchStore {
    shards: Vec<RwLock<HashMap<String, PackedCodes>>>,
    /// Live sketch count, maintained on put/remove so `len` never has to
    /// sweep all shard locks (it sits on the metrics path).
    count: AtomicUsize,
    /// Columnar mirror for the scan engine (arena-backed mode only).
    arena: Option<RwLock<CodeArena>>,
}

impl Default for SketchStore {
    fn default() -> Self {
        Self::new()
    }
}

impl SketchStore {
    /// Map-only store.
    pub fn new() -> Self {
        SketchStore {
            shards: (0..N_SHARDS).map(|_| RwLock::new(HashMap::new())).collect(),
            count: AtomicUsize::new(0),
            arena: None,
        }
    }

    /// Arena-backed store for sketches of `k` codes at `bits` per code
    /// (rounded up to a supported packing width). Every sketch put into
    /// this store must match that shape.
    pub fn with_arena(k: usize, bits: u32) -> Self {
        let mut s = Self::new();
        s.arena = Some(RwLock::new(CodeArena::new(k, bits)));
        s
    }

    /// The columnar mirror, when in arena-backed mode. Writers (`put`,
    /// `remove`) take the arena lock *before* any shard lock, so it is
    /// safe to call this store's read methods while holding the arena
    /// read lock; do not call `put`/`remove` while holding it.
    pub fn arena(&self) -> Option<&RwLock<CodeArena>> {
        self.arena.as_ref()
    }

    fn shard(&self, id: &str) -> &RwLock<HashMap<String, PackedCodes>> {
        let mut h: u64 = 0xcbf29ce484222325;
        for b in id.as_bytes() {
            h ^= *b as u64;
            h = h.wrapping_mul(0x100000001b3);
        }
        &self.shards[(h as usize) % N_SHARDS]
    }

    /// Insert or replace a sketch.
    pub fn put(&self, id: String, codes: PackedCodes) {
        // Lock order: arena (outer) before shard (inner). Shard locks
        // are only ever written under the arena write lock, so a caller
        // holding the arena *read* lock (from [`SketchStore::arena`])
        // may safely call any read method here without deadlocking, and
        // the two views stay consistent under concurrent writers.
        let mut arena_guard = self.arena.as_ref().map(|a| a.write().unwrap());
        let mut guard = self.shard(&id).write().unwrap();
        if let Some(arena) = arena_guard.as_deref_mut() {
            arena.insert(&id, &codes);
        }
        if guard.insert(id, codes).is_none() {
            self.count.fetch_add(1, Ordering::Relaxed);
        }
    }

    /// Fetch a clone of a sketch.
    pub fn get(&self, id: &str) -> Option<PackedCodes> {
        self.shard(id).read().unwrap().get(id).cloned()
    }

    pub fn contains(&self, id: &str) -> bool {
        self.shard(id).read().unwrap().contains_key(id)
    }

    pub fn remove(&self, id: &str) -> bool {
        // Same lock order as `put`: arena before shard.
        let mut arena_guard = self.arena.as_ref().map(|a| a.write().unwrap());
        let mut guard = self.shard(id).write().unwrap();
        if let Some(arena) = arena_guard.as_deref_mut() {
            arena.remove(id);
        }
        let removed = guard.remove(id).is_some();
        if removed {
            self.count.fetch_sub(1, Ordering::Relaxed);
        }
        removed
    }

    /// Live sketch count (lock-free; one atomic load).
    pub fn len(&self) -> usize {
        self.count.load(Ordering::Relaxed)
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Visit every `(id, sketch)` pair (used by the kNN scan). The
    /// visitor runs under each shard's read lock in turn.
    pub fn for_each<F: FnMut(&str, &PackedCodes)>(&self, mut f: F) {
        for s in &self.shards {
            let guard = s.read().unwrap();
            for (id, codes) in guard.iter() {
                f(id, codes);
            }
        }
    }

    /// Total bytes of packed sketch storage.
    pub fn storage_bytes(&self) -> usize {
        let mut total = 0;
        self.for_each(|_, c| total += c.storage_bytes());
        total
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coding::pack_codes;

    fn sketch(seed: u16) -> PackedCodes {
        let codes: Vec<u16> = (0..64).map(|i| ((i as u16 + seed) % 4)).collect();
        pack_codes(&codes, 2)
    }

    #[test]
    fn put_get_remove() {
        let s = SketchStore::new();
        assert!(s.is_empty());
        s.put("a".into(), sketch(0));
        s.put("b".into(), sketch(1));
        assert_eq!(s.len(), 2);
        assert!(s.contains("a"));
        assert_eq!(s.get("a").unwrap(), sketch(0));
        assert!(s.get("zzz").is_none());
        assert!(s.remove("a"));
        assert!(!s.remove("a"));
        assert_eq!(s.len(), 1);
    }

    #[test]
    fn overwrite_replaces() {
        let s = SketchStore::new();
        s.put("x".into(), sketch(0));
        s.put("x".into(), sketch(9));
        assert_eq!(s.len(), 1);
        assert_eq!(s.get("x").unwrap(), sketch(9));
    }

    #[test]
    fn for_each_sees_all() {
        let s = SketchStore::new();
        for i in 0..100 {
            s.put(format!("id{i}"), sketch(i as u16));
        }
        let mut n = 0;
        s.for_each(|_, _| n += 1);
        assert_eq!(n, 100);
        assert!(s.storage_bytes() >= 100 * 16);
    }

    #[test]
    fn concurrent_access() {
        use std::sync::Arc;
        let s = Arc::new(SketchStore::new());
        let mut handles = Vec::new();
        for t in 0..8 {
            let s = s.clone();
            handles.push(std::thread::spawn(move || {
                for i in 0..50 {
                    s.put(format!("t{t}-{i}"), sketch(i));
                    let _ = s.get(&format!("t{t}-{i}"));
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(s.len(), 400);
    }

    #[test]
    fn arena_mode_mirrors_map() {
        let s = SketchStore::with_arena(64, 2);
        for i in 0..30 {
            s.put(format!("id{i}"), sketch(i));
        }
        s.put("id7".into(), sketch(99)); // overwrite
        assert!(s.remove("id3"));
        assert_eq!(s.len(), 29);
        let arena = s.arena().unwrap().read().unwrap();
        assert_eq!(arena.len(), 29);
        assert_eq!(arena.get("id7").unwrap(), sketch(99));
        assert!(arena.get("id3").is_none());
        for i in [0u16, 1, 2, 4, 5, 28, 29] {
            assert_eq!(arena.get(&format!("id{i}")), s.get(&format!("id{i}")));
        }
    }

    #[test]
    fn concurrent_arena_mode_stays_consistent() {
        use std::sync::Arc;
        let s = Arc::new(SketchStore::with_arena(64, 2));
        let mut handles = Vec::new();
        for t in 0..4 {
            let s = s.clone();
            handles.push(std::thread::spawn(move || {
                for i in 0..40 {
                    s.put(format!("t{t}-{i}"), sketch(i));
                }
                for i in (0..40).step_by(3) {
                    s.remove(&format!("t{t}-{i}"));
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        let live = 4 * (40 - 14);
        assert_eq!(s.len(), live);
        assert_eq!(s.arena().unwrap().read().unwrap().len(), live);
    }
}
