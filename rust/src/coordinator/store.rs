//! Sharded sketch store: `id → PackedCodes`. Only the coded sketches
//! live here — raw vectors are dropped after projection, which is the
//! paper's storage-compression story in operational form.

use std::collections::HashMap;
use std::sync::RwLock;

use crate::coding::PackedCodes;

const N_SHARDS: usize = 16;

/// Thread-safe sharded map from string ids to packed code sketches.
#[derive(Debug)]
pub struct SketchStore {
    shards: Vec<RwLock<HashMap<String, PackedCodes>>>,
}

impl Default for SketchStore {
    fn default() -> Self {
        Self::new()
    }
}

impl SketchStore {
    pub fn new() -> Self {
        SketchStore {
            shards: (0..N_SHARDS).map(|_| RwLock::new(HashMap::new())).collect(),
        }
    }

    fn shard(&self, id: &str) -> &RwLock<HashMap<String, PackedCodes>> {
        let mut h: u64 = 0xcbf29ce484222325;
        for b in id.as_bytes() {
            h ^= *b as u64;
            h = h.wrapping_mul(0x100000001b3);
        }
        &self.shards[(h as usize) % N_SHARDS]
    }

    /// Insert or replace a sketch.
    pub fn put(&self, id: String, codes: PackedCodes) {
        self.shard(&id).write().unwrap().insert(id, codes);
    }

    /// Fetch a clone of a sketch.
    pub fn get(&self, id: &str) -> Option<PackedCodes> {
        self.shard(id).read().unwrap().get(id).cloned()
    }

    pub fn contains(&self, id: &str) -> bool {
        self.shard(id).read().unwrap().contains_key(id)
    }

    pub fn remove(&self, id: &str) -> bool {
        self.shard(id).write().unwrap().remove(id).is_some()
    }

    pub fn len(&self) -> usize {
        self.shards.iter().map(|s| s.read().unwrap().len()).sum()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Visit every `(id, sketch)` pair (used by the kNN scan). The
    /// visitor runs under each shard's read lock in turn.
    pub fn for_each<F: FnMut(&str, &PackedCodes)>(&self, mut f: F) {
        for s in &self.shards {
            let guard = s.read().unwrap();
            for (id, codes) in guard.iter() {
                f(id, codes);
            }
        }
    }

    /// Total bytes of packed sketch storage.
    pub fn storage_bytes(&self) -> usize {
        let mut total = 0;
        self.for_each(|_, c| total += c.storage_bytes());
        total
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coding::pack_codes;

    fn sketch(seed: u16) -> PackedCodes {
        let codes: Vec<u16> = (0..64).map(|i| ((i as u16 + seed) % 4)).collect();
        pack_codes(&codes, 2)
    }

    #[test]
    fn put_get_remove() {
        let s = SketchStore::new();
        assert!(s.is_empty());
        s.put("a".into(), sketch(0));
        s.put("b".into(), sketch(1));
        assert_eq!(s.len(), 2);
        assert!(s.contains("a"));
        assert_eq!(s.get("a").unwrap(), sketch(0));
        assert!(s.get("zzz").is_none());
        assert!(s.remove("a"));
        assert!(!s.remove("a"));
        assert_eq!(s.len(), 1);
    }

    #[test]
    fn overwrite_replaces() {
        let s = SketchStore::new();
        s.put("x".into(), sketch(0));
        s.put("x".into(), sketch(9));
        assert_eq!(s.len(), 1);
        assert_eq!(s.get("x").unwrap(), sketch(9));
    }

    #[test]
    fn for_each_sees_all() {
        let s = SketchStore::new();
        for i in 0..100 {
            s.put(format!("id{i}"), sketch(i as u16));
        }
        let mut n = 0;
        s.for_each(|_, _| n += 1);
        assert_eq!(n, 100);
        assert!(s.storage_bytes() >= 100 * 16);
    }

    #[test]
    fn concurrent_access() {
        use std::sync::Arc;
        let s = Arc::new(SketchStore::new());
        let mut handles = Vec::new();
        for t in 0..8 {
            let s = s.clone();
            handles.push(std::thread::spawn(move || {
                for i in 0..50 {
                    s.put(format!("t{t}-{i}"), sketch(i));
                    let _ = s.get(&format!("t{t}-{i}"));
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(s.len(), 400);
    }
}
