//! Dynamic batcher: groups incoming projection requests into batches of
//! up to `max_batch` vectors or `max_delay`, whichever comes first, then
//! executes one batched projection + encode per flush.
//!
//! This is the standard serving-system batching policy (vLLM-style
//! size-or-deadline): the AOT artifact has a fixed batch dimension, so
//! filling it amortizes dispatch overhead; the deadline bounds tail
//! latency when traffic is sparse. Implemented on std threads + channels
//! (no async runtime is vendored in this environment); each request
//! parks on its own rendezvous channel until the batch executes.

use std::sync::mpsc;
use std::sync::Arc;
use std::time::Duration;

use crate::coding::{BatchEncoder, CodingParams, PackedCodes};
use crate::coordinator::metrics::Metrics;
use crate::projection::Projector;

/// Batching policy.
#[derive(Clone, Debug)]
pub struct BatcherConfig {
    /// Flush when this many vectors are queued (align with the artifact
    /// batch dimension for best PJRT utilization).
    pub max_batch: usize,
    /// Flush after this long even if the batch is not full.
    pub max_delay: Duration,
    /// Opportunistic flush: if no new work arrives within this window,
    /// flush immediately instead of waiting out `max_delay`. Keeps lone
    /// clients at projection latency while bursts still coalesce.
    pub idle_flush: Duration,
}

impl Default for BatcherConfig {
    fn default() -> Self {
        BatcherConfig {
            max_batch: 64,
            max_delay: Duration::from_millis(2),
            idle_flush: Duration::from_micros(150),
        }
    }
}

/// One queued vector, in whichever form the caller holds it. Sparse
/// jobs skip densification entirely: they project at O(nnz·k) through
/// the gather kernel inside the same flush as their dense batchmates.
enum JobInput {
    Dense(Vec<f32>),
    Sparse { indices: Vec<u32>, values: Vec<f32> },
}

struct Job {
    input: JobInput,
    resp: mpsc::SyncSender<PackedCodes>,
}

/// Handle for submitting vectors to the batched sketch pipeline.
/// Clone-cheap; every clone feeds the same worker thread.
#[derive(Clone)]
pub struct SketchBatcher {
    tx: mpsc::Sender<Job>,
    pub coding: CodingParams,
    pub k: usize,
    /// Shared with the worker: `sketch` raises the queue-depth gauge
    /// before handing a job over, `flush` lowers it per executed batch.
    metrics: Arc<Metrics>,
}

impl SketchBatcher {
    /// Spawn the batcher worker thread.
    pub fn spawn(
        projector: Arc<Projector>,
        coding: CodingParams,
        cfg: BatcherConfig,
        metrics: Arc<Metrics>,
    ) -> Self {
        let (tx, rx) = mpsc::channel::<Job>();
        let k = projector.cfg.k;
        let coding_worker = coding.clone();
        let metrics_worker = metrics.clone();
        std::thread::Builder::new()
            .name("crp-batcher".into())
            .spawn(move || batch_loop(rx, projector, coding_worker, cfg, metrics_worker))
            .expect("spawn batcher thread");
        SketchBatcher {
            tx,
            coding,
            k,
            metrics,
        }
    }

    /// Submit a vector; blocks until its batch has been projected and
    /// coded. Dimension may vary per call (padded internally).
    pub fn sketch(&self, vector: Vec<f32>) -> crate::Result<PackedCodes> {
        self.submit(JobInput::Dense(vector))
    }

    /// Submit one sparse vector as sorted (indices, values) triplets;
    /// blocks like [`SketchBatcher::sketch`] and returns byte-identical
    /// codes to sketching the densified vector — the projection replays
    /// the dense kernel's operation sequence over the nonzeros only.
    pub fn sketch_sparse(&self, indices: Vec<u32>, values: Vec<f32>) -> crate::Result<PackedCodes> {
        anyhow::ensure!(
            indices.len() == values.len(),
            "indices {} != values {}",
            indices.len(),
            values.len()
        );
        anyhow::ensure!(
            indices.windows(2).all(|w| w[0] < w[1]),
            "sparse indices must be strictly increasing"
        );
        self.submit(JobInput::Sparse { indices, values })
    }

    fn submit(&self, input: JobInput) -> crate::Result<PackedCodes> {
        let (resp_tx, resp_rx) = mpsc::sync_channel(1);
        use std::sync::atomic::Ordering;
        self.metrics
            .batcher_queue_depth
            .fetch_add(1, Ordering::Relaxed);
        let sent = self.tx.send(Job {
            input,
            resp: resp_tx,
        });
        if sent.is_err() {
            self.metrics
                .batcher_queue_depth
                .fetch_sub(1, Ordering::Relaxed);
            anyhow::bail!("batcher worker gone");
        }
        resp_rx
            .recv()
            .map_err(|_| anyhow::anyhow!("batcher dropped job"))
    }
}

fn batch_loop(
    rx: mpsc::Receiver<Job>,
    projector: Arc<Projector>,
    coding: CodingParams,
    cfg: BatcherConfig,
    metrics: Arc<Metrics>,
) {
    let mut pending: Vec<Job> = Vec::with_capacity(cfg.max_batch);
    // Fused encode state lives across flushes: the `h_{w,q}` offsets are
    // computed once (they are part of the hash function) and the code
    // scratch is reused, instead of reallocating both per flush.
    let mut encoder = BatchEncoder::new(coding, projector.cfg.k);
    // Sparse-job scratch (projected row + gathered matrix rows), also
    // reused across flushes.
    let mut xrow = vec![0.0f32; projector.cfg.k];
    let mut gather = Vec::new();
    loop {
        // Wait for the first job of a batch.
        let first = match rx.recv() {
            Ok(j) => j,
            Err(_) => return,
        };
        pending.push(first);
        let deadline = std::time::Instant::now() + cfg.max_delay;
        // Fill until size, hard deadline, or an idle window with no new
        // arrivals (opportunistic early flush).
        while pending.len() < cfg.max_batch {
            let now = std::time::Instant::now();
            if now >= deadline {
                break;
            }
            let wait = cfg.idle_flush.min(deadline - now);
            match rx.recv_timeout(wait) {
                Ok(j) => pending.push(j),
                Err(mpsc::RecvTimeoutError::Timeout) => break, // idle
                Err(mpsc::RecvTimeoutError::Disconnected) => break,
            }
        }
        flush(
            &mut pending,
            &projector,
            &mut encoder,
            &mut xrow,
            &mut gather,
            &metrics,
        );
    }
}

/// Execute one batch synchronously. Dense members run through the
/// batched ragged projector; sparse members replay the same kernel
/// per-row over their nonzeros. Rows project independently (padding
/// and batchmates never change a row's bits), so a mixed batch is
/// byte-identical to an all-dense one.
fn flush(
    pending: &mut Vec<Job>,
    projector: &Projector,
    encoder: &mut BatchEncoder,
    xrow: &mut [f32],
    gather: &mut Vec<f32>,
    metrics: &Metrics,
) {
    if pending.is_empty() {
        return;
    }
    let b = pending.len();
    let k = encoder.k();
    let n_dense = pending
        .iter()
        .filter(|j| matches!(j.input, JobInput::Dense(_)))
        .count();
    let x = projector.project_ragged(
        pending.iter().filter_map(|j| match &j.input {
            JobInput::Dense(v) => Some(v.as_slice()),
            JobInput::Sparse { .. } => None,
        }),
        n_dense,
    );
    // Count the batch before releasing waiters so a client that reads
    // stats immediately after its response sees its own work reflected.
    metrics
        .batcher_queue_depth
        .fetch_sub(b as u64, std::sync::atomic::Ordering::Relaxed);
    metrics
        .batches_executed
        .fetch_add(1, std::sync::atomic::Ordering::Relaxed);
    metrics
        .vectors_projected
        .fetch_add(b as u64, std::sync::atomic::Ordering::Relaxed);
    let mut drow = 0usize;
    for job in pending.drain(..) {
        let packed = match job.input {
            JobInput::Dense(_) => {
                let p = encoder.encode_pack(&x[drow * k..(drow + 1) * k]);
                drow += 1;
                p
            }
            JobInput::Sparse { indices, values } => {
                xrow.fill(0.0);
                projector.project_csr_row_into(&indices, &values, gather, xrow);
                encoder.encode_pack(xrow)
            }
        };
        let _ = job.resp.send(packed);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coding::{pack_codes, Scheme};
    use crate::projection::ProjectionConfig;

    fn mk(k: usize, max_batch: usize, delay_ms: u64) -> (SketchBatcher, Arc<Metrics>) {
        let projector = Arc::new(Projector::new_cpu(ProjectionConfig {
            k,
            seed: 3,
            ..Default::default()
        }));
        let metrics = Arc::new(Metrics::default());
        let b = SketchBatcher::spawn(
            projector,
            CodingParams::new(Scheme::TwoBit, 0.75),
            BatcherConfig {
                max_batch,
                max_delay: Duration::from_millis(delay_ms),
                idle_flush: Duration::from_micros(500),
            },
            metrics.clone(),
        );
        (b, metrics)
    }

    #[test]
    fn single_job_flushes_on_deadline() {
        let (b, m) = mk(32, 64, 1);
        let codes = b.sketch(vec![0.5; 100]).unwrap();
        assert_eq!(codes.len, 32);
        assert_eq!(
            m.batches_executed
                .load(std::sync::atomic::Ordering::Relaxed),
            1
        );
    }

    #[test]
    fn batch_fills_up() {
        let (b, m) = mk(16, 8, 100);
        let mut handles = Vec::new();
        for i in 0..8 {
            let b = b.clone();
            handles.push(std::thread::spawn(move || {
                b.sketch(vec![i as f32 * 0.1; 64]).unwrap()
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        // All 8 should have flown in a small number of batches.
        let batches = m
            .batches_executed
            .load(std::sync::atomic::Ordering::Relaxed);
        assert!(batches <= 3, "batches {batches}");
        assert_eq!(
            m.vectors_projected
                .load(std::sync::atomic::Ordering::Relaxed),
            8
        );
    }

    #[test]
    fn batched_result_matches_direct_projection() {
        let (b, _) = mk(24, 4, 1);
        let v: Vec<f32> = (0..80).map(|i| (i as f32) * 0.01 - 0.4).collect();
        let got = b.sketch(v.clone()).unwrap();
        // Direct: same projector config + coding.
        let projector = Projector::new_cpu(ProjectionConfig {
            k: 24,
            seed: 3,
            ..Default::default()
        });
        let coding = CodingParams::new(Scheme::TwoBit, 0.75);
        let x = projector.project_dense(&v);
        let want = pack_codes(&coding.encode(&x), coding.bits_per_code());
        assert_eq!(got, want);
    }

    #[test]
    fn mixed_dimensions_in_one_batch() {
        let (b, _) = mk(16, 4, 30);
        let b1 = b.clone();
        let h1 = std::thread::spawn(move || b1.sketch(vec![1.0; 10]).unwrap());
        let b2 = b.clone();
        let h2 = std::thread::spawn(move || b2.sketch(vec![1.0; 200]).unwrap());
        let (a, c) = (h1.join().unwrap(), h2.join().unwrap());
        // Short vector padded with zeros ≡ projecting it alone.
        let projector = Projector::new_cpu(ProjectionConfig {
            k: 16,
            seed: 3,
            ..Default::default()
        });
        let coding = CodingParams::new(Scheme::TwoBit, 0.75);
        let want_a = pack_codes(
            &coding.encode(&projector.project_dense(&vec![1.0; 10])),
            coding.bits_per_code(),
        );
        let want_c = pack_codes(
            &coding.encode(&projector.project_dense(&vec![1.0; 200])),
            coding.bits_per_code(),
        );
        assert_eq!(a, want_a);
        assert_eq!(c, want_c);
    }

    #[test]
    fn sparse_job_matches_densified_dense_job() {
        let (b, _) = mk(24, 4, 30);
        let indices = vec![2u32, 7, 90];
        let values = vec![0.5f32, -1.25, 2.0];
        let mut dense = vec![0.0f32; 91];
        for (&i, &v) in indices.iter().zip(&values) {
            dense[i as usize] = v;
        }
        // Submit both concurrently so they share one mixed flush.
        let b1 = b.clone();
        let (i2, v2) = (indices.clone(), values.clone());
        let h1 = std::thread::spawn(move || b1.sketch_sparse(i2, v2).unwrap());
        let b2 = b.clone();
        let h2 = std::thread::spawn(move || b2.sketch(dense).unwrap());
        let (sparse, densified) = (h1.join().unwrap(), h2.join().unwrap());
        assert_eq!(sparse, densified);
        // An all-zero sparse vector is fine (projects to zeros).
        let empty = b.sketch_sparse(vec![], vec![]).unwrap();
        assert_eq!(empty, b.sketch(vec![]).unwrap());
        // Bad shapes are rejected before queueing.
        assert!(b.sketch_sparse(vec![3, 1], vec![1.0, 2.0]).is_err());
        assert!(b.sketch_sparse(vec![1], vec![]).is_err());
    }

    #[test]
    fn empty_vector_ok() {
        let (b, _) = mk(8, 2, 1);
        let codes = b.sketch(vec![]).unwrap();
        assert_eq!(codes.len, 8);
    }
}
