//! Service metrics: lock-free counters and a fixed-bucket latency
//! histogram (microsecond resolution, powers-of-two buckets).

use std::sync::atomic::{AtomicU64, Ordering};

/// Latency histogram with 32 power-of-two microsecond buckets
/// (`[1us, 2us) ... [2^31 us, ∞)`), plus count/sum for means.
#[derive(Debug, Default)]
pub struct LatencyHistogram {
    buckets: [AtomicU64; 32],
    count: AtomicU64,
    sum_us: AtomicU64,
}

impl LatencyHistogram {
    pub fn record(&self, micros: u64) {
        self.record_n(micros, 1);
    }

    /// Record `n` samples of the same latency in O(1) — bulk paths
    /// amortize one timing across a batch without under-weighting the
    /// percentiles against per-request samples.
    pub fn record_n(&self, micros: u64, n: u64) {
        if n == 0 {
            return;
        }
        let b = (64 - micros.max(1).leading_zeros() as usize - 1).min(31);
        self.buckets[b].fetch_add(n, Ordering::Relaxed);
        self.count.fetch_add(n, Ordering::Relaxed);
        self.sum_us
            .fetch_add(micros.saturating_mul(n), Ordering::Relaxed);
    }

    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    pub fn mean_us(&self) -> f64 {
        let c = self.count();
        if c == 0 {
            0.0
        } else {
            self.sum_us.load(Ordering::Relaxed) as f64 / c as f64
        }
    }

    /// Approximate percentile (upper bucket bound). The final bucket
    /// is unbounded, so a percentile landing there saturates to its
    /// *lower* bound (`2^31` µs ≈ 36 min) — the last finite boundary —
    /// rather than fabricating a `2^32` "upper bound" that no sample
    /// is known to respect.
    pub fn percentile_us(&self, p: f64) -> u64 {
        let total = self.count();
        if total == 0 {
            return 0;
        }
        let target = ((total as f64) * p.clamp(0.0, 1.0)).ceil() as u64;
        let mut seen = 0u64;
        for (i, b) in self.buckets.iter().enumerate() {
            seen += b.load(Ordering::Relaxed);
            if seen >= target {
                return if i == 31 { 1u64 << 31 } else { 1u64 << (i + 1) };
            }
        }
        u64::MAX
    }

    /// Total of all recorded samples in µs (pairs with
    /// [`LatencyHistogram::count`] for exposition `_sum`/`_count`).
    pub fn sum_us(&self) -> u64 {
        self.sum_us.load(Ordering::Relaxed)
    }

    /// Raw per-bucket counts (bucket `i` covers `[2^i, 2^(i+1))` µs;
    /// the last is unbounded) — the exposition renderer's input.
    pub fn bucket_counts(&self) -> [u64; 32] {
        std::array::from_fn(|i| self.buckets[i].load(Ordering::Relaxed))
    }
}

/// All service counters.
#[derive(Debug, Default)]
pub struct Metrics {
    pub registered: AtomicU64,
    pub estimates: AtomicU64,
    pub knn_queries: AtomicU64,
    pub batches_executed: AtomicU64,
    pub vectors_projected: AtomicU64,
    /// Times the background maintenance thread woke (tick or drain
    /// notification) to fold epochs / checkpoint.
    pub maintenance_wakeups: AtomicU64,
    /// Open client connections right now (gauge: the accept loop
    /// increments, each connection thread decrements on exit; rejected
    /// over-limit connections are never counted).
    pub connections: AtomicU64,
    pub register_latency: LatencyHistogram,
    /// Requests that crossed the server's `--slow-query-us` threshold
    /// (each also emitted one structured slow-query log line).
    pub slow_queries: AtomicU64,
    /// Full-path latency per request kind, recorded once per request
    /// by the connection loop (decode → handle → encode+write).
    pub requests: super::obs::RequestHistograms,
    /// Vectors currently queued at the sketch batcher (gauge: `sketch`
    /// increments before handing work to the batch thread, `flush`
    /// decrements per executed job). Nonzero under concurrent register
    /// load in either serve mode.
    pub batcher_queue_depth: AtomicU64,
    /// Reactor front-end (all zero in thread mode): epoll_wait returns.
    pub reactor_polls: AtomicU64,
    /// Readiness events delivered across all reactor ticks.
    pub reactor_ready_events: AtomicU64,
    /// Frames parsed out of reactor read buffers (≥ requests answered:
    /// pipelined clients land several frames per readiness event).
    pub reactor_frames: AtomicU64,
    /// Register/TopK groups the reactor fused into one bulk call.
    pub reactor_coalesced_batches: AtomicU64,
    /// Requests dispatched per reactor tick (power-of-two buckets, a
    /// count histogram — the "µs" of [`LatencyHistogram`] reads as
    /// "requests" here), recorded only for ticks that dispatched work.
    pub reactor_dispatch_batch: LatencyHistogram,
    /// High-water mark of any reactor connection's pending write
    /// buffer, bytes (the backpressure trigger; updated via
    /// `fetch_max`).
    pub reactor_write_buffer_hwm: AtomicU64,
    /// Fused runs handed to the worker pool instead of executing
    /// inline on the loop thread (`--reactor-workers > 0` only; always
    /// ≤ `reactor_coalesced_batches`).
    pub reactor_offloaded_batches: AtomicU64,
    /// Offloaded runs currently in flight across all loops (gauge:
    /// incremented at submission, decremented when the completion is
    /// applied).
    pub reactor_worker_queue_depth: AtomicU64,
    /// Per-loop metric shards, installed by the reactor front-end at
    /// startup (empty in thread mode). Loops update their shard *and*
    /// the unlabeled aggregates above, so existing series are unbroken.
    reactor_loops: std::sync::Mutex<Vec<std::sync::Arc<ReactorLoopMetrics>>>,
}

/// One reactor loop's share of the front-end counters, exported as
/// `crp_reactor_*{reactor="i"}` and as the `per_loop` rows of
/// `StatsDetailed`'s reactor section.
#[derive(Debug, Default)]
pub struct ReactorLoopMetrics {
    pub ready_events: AtomicU64,
    pub polls: AtomicU64,
    pub frames: AtomicU64,
    pub coalesced_batches: AtomicU64,
    pub offloaded_batches: AtomicU64,
    /// Connections currently owned by this loop (gauge).
    pub connections: AtomicU64,
}

impl Metrics {
    /// Counter-only snapshot. The scan-engine fields (`pending_rows`,
    /// `drains`, `tombstones`, `kernel`) live in each collection's
    /// epoch arena and the durability fields (`wal_records`,
    /// `wal_bytes`, `last_checkpoint_rows`) in each WAL engine; the
    /// server aggregates those across the registry (plus the
    /// `collections` count) before answering `Stats`.
    pub fn snapshot(&self) -> super::protocol::StatsSnapshot {
        let batches = self.batches_executed.load(Ordering::Relaxed);
        let vectors = self.vectors_projected.load(Ordering::Relaxed);
        super::protocol::StatsSnapshot {
            registered: self.registered.load(Ordering::Relaxed),
            estimates: self.estimates.load(Ordering::Relaxed),
            knn_queries: self.knn_queries.load(Ordering::Relaxed),
            batches_executed: batches,
            vectors_projected: vectors,
            mean_batch_size: if batches == 0 {
                0.0
            } else {
                vectors as f64 / batches as f64
            },
            p50_register_us: self.register_latency.percentile_us(0.50),
            p99_register_us: self.register_latency.percentile_us(0.99),
            maintenance_wakeups: self.maintenance_wakeups.load(Ordering::Relaxed),
            connections: self.connections.load(Ordering::Relaxed),
            ..Default::default()
        }
    }

    /// Install `n` per-loop metric shards for the reactor front-end
    /// and return them in loop order. Called once at reactor startup;
    /// thread mode never calls it, keeping `StatsDetailed`'s reactor
    /// section in its legacy byte-pinned shape there.
    pub fn install_reactor_loops(
        &self,
        n: usize,
    ) -> Vec<std::sync::Arc<ReactorLoopMetrics>> {
        let shards: Vec<_> = (0..n)
            .map(|_| std::sync::Arc::new(ReactorLoopMetrics::default()))
            .collect();
        *self.reactor_loops.lock().unwrap() = shards.clone();
        shards
    }

    /// The installed per-loop shards, in loop order (empty in thread
    /// mode). Cloned `Arc`s: cheap, and safe to read off-thread.
    pub fn reactor_loop_shards(&self) -> Vec<std::sync::Arc<ReactorLoopMetrics>> {
        self.reactor_loops.lock().unwrap().clone()
    }

    /// The reactor/batcher section for `StatsDetailed` — filled in
    /// both serve modes (thread mode reports zero reactor counters but
    /// a live batcher queue depth, keeping the PR-6 follow-up series
    /// observable everywhere).
    pub fn reactor_stats(&self) -> super::protocol::ReactorStats {
        let per_loop = self
            .reactor_loop_shards()
            .iter()
            .map(|s| super::protocol::ReactorLoopStats {
                ready_events: s.ready_events.load(Ordering::Relaxed),
                polls: s.polls.load(Ordering::Relaxed),
                frames: s.frames.load(Ordering::Relaxed),
                coalesced_batches: s.coalesced_batches.load(Ordering::Relaxed),
                offloaded_batches: s.offloaded_batches.load(Ordering::Relaxed),
                connections: s.connections.load(Ordering::Relaxed),
            })
            .collect();
        super::protocol::ReactorStats {
            ready_events: self.reactor_ready_events.load(Ordering::Relaxed),
            polls: self.reactor_polls.load(Ordering::Relaxed),
            frames: self.reactor_frames.load(Ordering::Relaxed),
            coalesced_batches: self.reactor_coalesced_batches.load(Ordering::Relaxed),
            p50_dispatch: self.reactor_dispatch_batch.percentile_us(0.50),
            p99_dispatch: self.reactor_dispatch_batch.percentile_us(0.99),
            write_buffer_hwm: self.reactor_write_buffer_hwm.load(Ordering::Relaxed),
            batcher_queue_depth: self.batcher_queue_depth.load(Ordering::Relaxed),
            offloaded_batches: self.reactor_offloaded_batches.load(Ordering::Relaxed),
            worker_queue_depth: self.reactor_worker_queue_depth.load(Ordering::Relaxed),
            per_loop,
        }
    }

    /// Per-request-kind latency rows for `StatsDetailed`, in kind
    /// order, skipping kinds with no traffic yet (the wire section
    /// stays empty — hence absent — on an idle server).
    pub fn per_request(&self) -> Vec<super::protocol::RequestLatency> {
        super::obs::REQUEST_KINDS
            .iter()
            .filter_map(|&kind| {
                let h = self.requests.hist(kind);
                let count = h.count();
                (count > 0).then(|| super::protocol::RequestLatency {
                    kind: kind.label().to_string(),
                    count,
                    mean_us: h.mean_us(),
                    p50_us: h.percentile_us(0.50),
                    p99_us: h.percentile_us(0.99),
                })
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn histogram_percentiles() {
        let h = LatencyHistogram::default();
        for us in [10u64, 20, 30, 40, 1000] {
            h.record(us);
        }
        assert_eq!(h.count(), 5);
        assert!(h.mean_us() > 0.0);
        let p50 = h.percentile_us(0.5);
        assert!((16..=64).contains(&p50), "p50 bucket {p50}");
        let p99 = h.percentile_us(0.99);
        assert!(p99 >= 1024, "p99 bucket {p99}");
    }

    #[test]
    fn record_n_weights_bulk_samples() {
        let h = LatencyHistogram::default();
        h.record_n(10, 5);
        h.record(1000);
        assert_eq!(h.count(), 6);
        // The five bulk samples dominate the median, not the lone slow one.
        let p50 = h.percentile_us(0.5);
        assert!((8..=32).contains(&p50), "p50 {p50}");
        h.record_n(10, 0); // no-op
        assert_eq!(h.count(), 6);
    }

    #[test]
    fn empty_histogram() {
        let h = LatencyHistogram::default();
        assert_eq!(h.percentile_us(0.99), 0);
        assert_eq!(h.mean_us(), 0.0);
    }

    /// Satellite pin: a percentile landing in the final (unbounded)
    /// bucket reports that bucket's lower bound `2^31`, not the bogus
    /// `2^32` "upper bound" the pre-fix code fabricated.
    #[test]
    fn percentile_saturates_in_final_bucket() {
        let h = LatencyHistogram::default();
        h.record(u64::MAX);
        assert_eq!(h.percentile_us(1.0), 1u64 << 31);
        assert_ne!(h.percentile_us(1.0), 1u64 << 32);
        // Any sample ≥ 2^31 µs lands there, not only u64::MAX.
        let h = LatencyHistogram::default();
        h.record(3_000_000_000);
        assert_eq!(h.percentile_us(0.5), 1u64 << 31);
        // The penultimate bucket still reports its upper bound.
        let h = LatencyHistogram::default();
        h.record((1u64 << 30) + 1);
        assert_eq!(h.percentile_us(1.0), 1u64 << 31);
        assert_eq!(h.bucket_counts()[30], 1);
    }

    #[test]
    fn bucket_counts_and_sum_expose_raw_state() {
        let h = LatencyHistogram::default();
        h.record(1);
        h.record_n(10, 3);
        let b = h.bucket_counts();
        assert_eq!(b[0], 1);
        assert_eq!(b[3], 3, "10µs lands in [8, 16)");
        assert_eq!(b.iter().sum::<u64>(), h.count());
        assert_eq!(h.sum_us(), 31);
    }

    #[test]
    fn per_request_skips_idle_kinds() {
        use crate::coordinator::obs::RequestKind;
        let m = Metrics::default();
        assert!(m.per_request().is_empty());
        m.requests.hist(RequestKind::Knn).record(100);
        m.requests.hist(RequestKind::Knn).record(300);
        m.requests.hist(RequestKind::Persist).record(50_000);
        let rows = m.per_request();
        assert_eq!(rows.len(), 2);
        assert_eq!(rows[0].kind, "knn");
        assert_eq!(rows[0].count, 2);
        assert!((rows[0].mean_us - 200.0).abs() < 1e-9);
        assert!(rows[0].p50_us >= 128 && rows[0].p99_us >= 256);
        assert_eq!(rows[1].kind, "persist");
    }

    #[test]
    fn snapshot_mean_batch() {
        let m = Metrics::default();
        m.batches_executed.store(4, Ordering::Relaxed);
        m.vectors_projected.store(100, Ordering::Relaxed);
        assert!((m.snapshot().mean_batch_size - 25.0).abs() < 1e-9);
    }

    /// Per-loop shards: absent until installed (thread mode keeps the
    /// legacy reactor section), then surfaced per loop in order in
    /// `reactor_stats`.
    #[test]
    fn reactor_loop_shards_surface_in_stats() {
        let m = Metrics::default();
        assert!(m.reactor_loop_shards().is_empty());
        assert!(m.reactor_stats().per_loop.is_empty());
        let shards = m.install_reactor_loops(3);
        assert_eq!(shards.len(), 3);
        shards[1].frames.fetch_add(7, Ordering::Relaxed);
        shards[2].offloaded_batches.fetch_add(2, Ordering::Relaxed);
        m.reactor_offloaded_batches.fetch_add(2, Ordering::Relaxed);
        let st = m.reactor_stats();
        assert_eq!(st.per_loop.len(), 3);
        assert_eq!(st.per_loop[0].frames, 0);
        assert_eq!(st.per_loop[1].frames, 7);
        assert_eq!(st.per_loop[2].offloaded_batches, 2);
        assert_eq!(st.offloaded_batches, 2);
        // Re-install replaces the shard set (fresh server, fresh loops).
        assert_eq!(m.install_reactor_loops(1).len(), 1);
        assert_eq!(m.reactor_stats().per_loop.len(), 1);
    }
}
