//! Event-driven serving front-end: one epoll loop that holds every
//! connection in a single thread.
//!
//! The thread-per-connection path in `server.rs` is the oracle — this
//! module exists so fan-in stops being bounded by OS threads. Design:
//!
//! - **Nonblocking accept + epoll readiness.** The kernel interface is
//!   raw `epoll_pwait`/`epoll_ctl` syscalls (`std::arch::asm!`, gated to
//!   linux x86_64/aarch64 — no `libc`/`mio` in the dependency budget);
//!   socket reads and writes go through the std `TcpStream` in
//!   nonblocking mode.
//! - **Zero-copy framing.** Each connection owns a grow-only read
//!   buffer; frames are parsed in place (`Request::decode` takes
//!   `&[u8]`) and the consumed prefix is reclaimed with `copy_within` —
//!   no per-request `Vec`. Responses encode straight into the
//!   connection's write buffer via [`protocol::append_frame`]. At
//!   steady state a fixed-size request (e.g. `Ping`) costs zero heap
//!   allocations end to end.
//! - **Pipelining.** Every complete frame in the buffer is decoded and
//!   dispatched in one tick; responses are appended in arrival order,
//!   so per-connection request/response order matches the blocking path
//!   exactly.
//! - **Coalescing.** `Register` (and scoped `Register`) requests that
//!   arrive in the same tick for the same collection fuse into one
//!   [`Collection::register_batch`] call — one projection, one WAL
//!   record — and each member still receives its own `Registered{id}`
//!   frame. `RegisterSparse` runs fuse the same way: CSR frames for the
//!   same collection concatenate into one
//!   [`Collection::register_sparse`] call and each member gets its own
//!   `RegisteredBatch` frame with its own row count. `TopK` requests
//!   with the same `(collection, n)` fuse into one `scan_topk_batch`
//!   sweep and the results are split back.
//!   Fusion only ever consumes the *front* run of each connection's
//!   queue, so per-connection program order (and therefore state) is
//!   preserved. Aggregate counters (`batches_executed`,
//!   `mean_batch_size`) legitimately differ from thread mode; response
//!   bytes do not.
//! - **Backpressure.** Responses gather in a per-connection write
//!   buffer flushed on writability. Past [`HIGH_WATER`] pending bytes
//!   the connection's read interest is dropped (a slow reader stops
//!   generating new work); reads resume under [`LOW_WATER`].
//! - **Limits.** `--max-conns` is enforced exactly like thread mode
//!   (one clean `Error` frame, then close). `--conn-timeout` is a
//!   blocking-path feature: the reactor's defense against idle/slow
//!   peers is backpressure plus the connection cap, not per-socket
//!   timeouts.
//!
//! Error-path caveat, documented rather than papered over: if a *fused*
//! bulk register fails (WAL I/O error mid-batch), every member receives
//! the batch error frame, whose message differs from the per-request
//! error thread mode would produce. Healthy-path responses are pinned
//! byte-identical across modes by `tests/serve.rs`.

#[cfg(all(target_os = "linux", any(target_arch = "x86_64", target_arch = "aarch64")))]
mod sys {
    //! Minimal raw-syscall epoll + rlimit bindings. Numbers and ABI per
    //! `asm/unistd_64.h` (x86_64) and the generic 64-bit table
    //! (aarch64); both arches use `epoll_pwait` with a null sigmask so
    //! one 6-argument entry point covers everything.

    use std::arch::asm;

    pub const EPOLLIN: u32 = 0x001;
    pub const EPOLLOUT: u32 = 0x004;
    pub const EPOLLERR: u32 = 0x008;
    pub const EPOLLHUP: u32 = 0x010;
    pub const EPOLLRDHUP: u32 = 0x2000;
    pub const EPOLL_CTL_ADD: i32 = 1;
    pub const EPOLL_CTL_MOD: i32 = 3;
    const EPOLL_CLOEXEC: usize = 0x80000;
    const EINTR: isize = -4;
    const RLIMIT_NOFILE: usize = 7;

    /// Kernel `struct epoll_event`: packed on x86_64 (the kernel ABI
    /// has no padding there), naturally aligned elsewhere.
    #[cfg_attr(target_arch = "x86_64", repr(C, packed))]
    #[cfg_attr(not(target_arch = "x86_64"), repr(C))]
    #[derive(Clone, Copy, Default)]
    pub struct EpollEvent {
        pub events: u32,
        pub data: u64,
    }

    #[cfg(target_arch = "x86_64")]
    mod nr {
        pub const CLOSE: usize = 3;
        pub const EPOLL_CTL: usize = 233;
        pub const EPOLL_PWAIT: usize = 281;
        pub const EPOLL_CREATE1: usize = 291;
        pub const PRLIMIT64: usize = 302;
    }
    #[cfg(target_arch = "aarch64")]
    mod nr {
        pub const EPOLL_CREATE1: usize = 20;
        pub const EPOLL_CTL: usize = 21;
        pub const EPOLL_PWAIT: usize = 22;
        pub const CLOSE: usize = 57;
        pub const PRLIMIT64: usize = 261;
    }

    #[cfg(target_arch = "x86_64")]
    #[inline]
    unsafe fn syscall6(
        n: usize,
        a1: usize,
        a2: usize,
        a3: usize,
        a4: usize,
        a5: usize,
        a6: usize,
    ) -> isize {
        let ret: isize;
        asm!(
            "syscall",
            inlateout("rax") n as isize => ret,
            in("rdi") a1,
            in("rsi") a2,
            in("rdx") a3,
            in("r10") a4,
            in("r8") a5,
            in("r9") a6,
            lateout("rcx") _,
            lateout("r11") _,
            options(nostack, preserves_flags)
        );
        ret
    }

    #[cfg(target_arch = "aarch64")]
    #[inline]
    unsafe fn syscall6(
        n: usize,
        a1: usize,
        a2: usize,
        a3: usize,
        a4: usize,
        a5: usize,
        a6: usize,
    ) -> isize {
        let ret: isize;
        asm!(
            "svc 0",
            inlateout("x0") a1 as isize => ret,
            in("x1") a2,
            in("x2") a3,
            in("x3") a4,
            in("x4") a5,
            in("x5") a6,
            in("x8") n,
            options(nostack, preserves_flags)
        );
        ret
    }

    fn check(ret: isize, what: &str) -> crate::Result<usize> {
        anyhow::ensure!(ret >= 0, "{what} failed: errno {}", -ret);
        Ok(ret as usize)
    }

    pub fn epoll_create1() -> crate::Result<i32> {
        let r = unsafe { syscall6(nr::EPOLL_CREATE1, EPOLL_CLOEXEC, 0, 0, 0, 0, 0) };
        Ok(check(r, "epoll_create1")? as i32)
    }

    pub fn epoll_ctl(epfd: i32, op: i32, fd: i32, events: u32, data: u64) -> crate::Result<()> {
        let mut ev = EpollEvent { events, data };
        let r = unsafe {
            syscall6(
                nr::EPOLL_CTL,
                epfd as usize,
                op as usize,
                fd as usize,
                &mut ev as *mut EpollEvent as usize,
                0,
                0,
            )
        };
        check(r, "epoll_ctl")?;
        Ok(())
    }

    /// Wait for readiness; retries `EINTR` internally. `timeout_ms` -1
    /// blocks indefinitely.
    pub fn epoll_wait(
        epfd: i32,
        events: &mut [EpollEvent],
        timeout_ms: i32,
    ) -> crate::Result<usize> {
        loop {
            let r = unsafe {
                syscall6(
                    nr::EPOLL_PWAIT,
                    epfd as usize,
                    events.as_mut_ptr() as usize,
                    events.len(),
                    timeout_ms as isize as usize,
                    0, // null sigmask: plain epoll_wait semantics
                    8,
                )
            };
            if r == EINTR {
                continue;
            }
            return check(r, "epoll_wait");
        }
    }

    pub fn close(fd: i32) {
        let _ = unsafe { syscall6(nr::CLOSE, fd as usize, 0, 0, 0, 0, 0) };
    }

    #[repr(C)]
    struct Rlimit64 {
        cur: u64,
        max: u64,
    }

    /// Best-effort `RLIMIT_NOFILE` raise (soft → hard) so a single
    /// process can hold thousands of sockets without root. Returns the
    /// resulting soft limit, or `None` if even reading it failed.
    pub fn raise_nofile_limit() -> Option<u64> {
        let mut old = Rlimit64 { cur: 0, max: 0 };
        let r = unsafe {
            syscall6(
                nr::PRLIMIT64,
                0,
                RLIMIT_NOFILE,
                0,
                &mut old as *mut Rlimit64 as usize,
                0,
                0,
            )
        };
        if r < 0 {
            return None;
        }
        if old.cur >= old.max {
            return Some(old.cur);
        }
        let new = Rlimit64 {
            cur: old.max,
            max: old.max,
        };
        let r = unsafe {
            syscall6(
                nr::PRLIMIT64,
                0,
                RLIMIT_NOFILE,
                &new as *const Rlimit64 as usize,
                0,
                0,
                0,
            )
        };
        Some(if r < 0 { old.cur } else { new.cur })
    }
}

#[cfg(all(target_os = "linux", any(target_arch = "x86_64", target_arch = "aarch64")))]
mod imp {
    use std::collections::VecDeque;
    use std::io::{ErrorKind, Read, Write};
    use std::net::{TcpListener, TcpStream};
    use std::os::unix::io::AsRawFd;
    use std::sync::atomic::Ordering;
    use std::sync::Arc;
    use std::time::Instant;

    use super::sys;
    use crate::coordinator::obs;
    use crate::coordinator::protocol::{self, Request, Response};
    use crate::coordinator::registry::{Collection, DEFAULT_COLLECTION, MAX_BULK_CELLS};
    use crate::coordinator::server::{observe_request, reject_connection, ServiceState};
    use crate::data::sparse::CsrMatrix;

    /// Pending write bytes past which a connection's read interest is
    /// dropped (the backpressure trigger).
    const HIGH_WATER: usize = 1 << 20;
    /// Pending write bytes under which a paused connection resumes
    /// reading (hysteresis against MOD churn at the boundary).
    const LOW_WATER: usize = 64 * 1024;
    /// Stack chunk for socket reads (copied into the connection buffer;
    /// `extend_from_slice` into existing capacity allocates nothing).
    const READ_CHUNK: usize = 16 * 1024;
    /// Per-connection read budget per tick: a firehose peer yields the
    /// loop after this many bytes and level-triggered epoll re-arms it.
    const MAX_TICK_READ: usize = 256 * 1024;
    /// Readiness events drained per `epoll_wait`.
    const MAX_EVENTS: usize = 1024;
    /// Fused-group member cap (also the fused-TopK total-query cap).
    const MAX_FUSE: usize = 256;
    /// The listener's epoll token; connections use their slab index.
    const LISTENER_TOKEN: u64 = u64::MAX;

    /// One decoded-but-undispatched request (or its decode error).
    enum Pending {
        Req { req: Request, decode_us: u64 },
        Bad { message: String, decode_us: u64 },
    }

    struct Conn {
        stream: TcpStream,
        peer: String,
        /// Read buffer; valid bytes are `rbuf[rpos..]`.
        rbuf: Vec<u8>,
        rpos: usize,
        /// Gathered response frames; unsent bytes are `wbuf[wpos..]`.
        wbuf: Vec<u8>,
        wpos: usize,
        /// Frames parsed this tick, awaiting dispatch.
        queue: VecDeque<Pending>,
        /// Currently-registered epoll interest bits.
        interest: u32,
        /// Read interest dropped by backpressure.
        paused: bool,
    }

    impl Conn {
        fn pending_write(&self) -> usize {
            self.wbuf.len() - self.wpos
        }
    }

    /// A fused-group member: which connection it came from, how it was
    /// scoped (meta parity with thread mode), and its share of the
    /// fused work.
    struct FuseMember {
        tok: usize,
        scope: Option<String>,
        decode_us: u64,
        /// Work items contributed: queries for TopK fusion, CSR rows
        /// for RegisterSparse fusion, always 1 for Register.
        count: usize,
    }

    struct Reactor {
        epfd: i32,
        listener: TcpListener,
        state: Arc<ServiceState>,
        max_conns: usize,
        conns: Vec<Option<Conn>>,
        free: Vec<usize>,
        /// Tokens freed mid-tick; recycled only at tick end so a stale
        /// queued event can never act on a just-accepted connection.
        pending_free: Vec<usize>,
        /// Connections that parsed at least one frame this tick.
        active: Vec<usize>,
        events: Vec<sys::EpollEvent>,
        /// Requests answered this tick (the dispatch-batch histogram
        /// sample).
        tick_dispatched: u64,
    }

    impl Drop for Reactor {
        fn drop(&mut self) {
            sys::close(self.epfd);
        }
    }

    /// Run the reactor until the epoll loop errors. Mirrors the thread
    /// mode contract: never returns in healthy operation.
    pub(crate) fn serve_reactor(
        listener: TcpListener,
        state: Arc<ServiceState>,
        max_conns: usize,
    ) -> crate::Result<()> {
        listener.set_nonblocking(true)?;
        let epfd = sys::epoll_create1()?;
        let mut r = Reactor {
            epfd,
            listener,
            state,
            max_conns,
            conns: Vec::new(),
            free: Vec::new(),
            pending_free: Vec::new(),
            active: Vec::new(),
            events: vec![sys::EpollEvent::default(); MAX_EVENTS],
            tick_dispatched: 0,
        };
        sys::epoll_ctl(
            r.epfd,
            sys::EPOLL_CTL_ADD,
            r.listener.as_raw_fd(),
            sys::EPOLLIN,
            LISTENER_TOKEN,
        )?;
        obs::log::info(
            "crp::server",
            "reactor front-end up",
            &[("max_conns", r.max_conns.to_string())],
        );
        r.run()
    }

    impl Reactor {
        fn run(&mut self) -> crate::Result<()> {
            loop {
                let mut events = std::mem::take(&mut self.events);
                let n = sys::epoll_wait(self.epfd, &mut events, -1)?;
                self.state.metrics.reactor_polls.fetch_add(1, Ordering::Relaxed);
                self.state
                    .metrics
                    .reactor_ready_events
                    .fetch_add(n as u64, Ordering::Relaxed);
                for ev in &events[..n] {
                    let (bits, tok) = (ev.events, ev.data);
                    if tok == LISTENER_TOKEN {
                        self.accept_ready();
                    } else {
                        self.conn_event(tok as usize, bits);
                    }
                }
                self.events = events;
                self.dispatch();
                let active = std::mem::take(&mut self.active);
                for &t in &active {
                    if self.conns.get(t).is_some_and(|c| c.is_some()) {
                        self.flush_writes(t);
                    }
                }
                self.active = active;
                self.active.clear();
                self.free.append(&mut self.pending_free);
            }
        }

        fn accept_ready(&mut self) {
            loop {
                match self.listener.accept() {
                    Ok((stream, addr)) => {
                        if self.max_conns > 0
                            && self.state.metrics.connections.load(Ordering::Relaxed)
                                >= self.max_conns as u64
                        {
                            // Accepted sockets are blocking (O_NONBLOCK
                            // does not inherit), so the thread-mode
                            // rejection path works unchanged.
                            let _ = reject_connection(stream, self.max_conns);
                            continue;
                        }
                        if self.register_conn(stream, addr.to_string()).is_err() {
                            continue;
                        }
                        self.state.metrics.connections.fetch_add(1, Ordering::Relaxed);
                    }
                    Err(e) if e.kind() == ErrorKind::WouldBlock => break,
                    Err(e) if e.kind() == ErrorKind::Interrupted => continue,
                    Err(e) => {
                        // Transient accept failure (EMFILE under fd
                        // pressure, aborted handshake): log and let the
                        // next readiness tick retry.
                        obs::log::warn("crp::server", "accept failed", &[("error", e.to_string())]);
                        break;
                    }
                }
            }
        }

        fn register_conn(&mut self, stream: TcpStream, peer: String) -> crate::Result<()> {
            stream.set_nonblocking(true)?;
            stream.set_nodelay(true)?;
            let tok = match self.free.pop() {
                Some(t) => t,
                None => {
                    self.conns.push(None);
                    self.conns.len() - 1
                }
            };
            let interest = sys::EPOLLIN | sys::EPOLLRDHUP;
            let fd = stream.as_raw_fd();
            let added = sys::epoll_ctl(self.epfd, sys::EPOLL_CTL_ADD, fd, interest, tok as u64);
            if let Err(e) = added {
                self.free.push(tok);
                return Err(e);
            }
            self.conns[tok] = Some(Conn {
                stream,
                peer,
                rbuf: Vec::new(),
                rpos: 0,
                wbuf: Vec::new(),
                wpos: 0,
                queue: VecDeque::new(),
                interest,
                paused: false,
            });
            Ok(())
        }

        fn conn_event(&mut self, tok: usize, bits: u32) {
            if !matches!(self.conns.get(tok), Some(Some(_))) {
                return; // closed earlier this tick
            }
            if bits & (sys::EPOLLERR | sys::EPOLLHUP) != 0 {
                self.close(tok, "socket error/hangup");
                return;
            }
            if bits & sys::EPOLLOUT != 0 && !self.flush_writes(tok) {
                return;
            }
            if bits & (sys::EPOLLIN | sys::EPOLLRDHUP) != 0 {
                self.read_ready(tok);
            }
        }

        fn read_ready(&mut self, tok: usize) {
            let mut tmp = [0u8; READ_CHUNK];
            let mut budget = MAX_TICK_READ;
            loop {
                let Some(conn) = self.conns[tok].as_mut() else {
                    return;
                };
                match conn.stream.read(&mut tmp) {
                    Ok(0) => {
                        self.close(tok, "peer closed");
                        return;
                    }
                    Ok(n) => {
                        conn.rbuf.extend_from_slice(&tmp[..n]);
                        budget = budget.saturating_sub(n);
                        if budget == 0 || n < tmp.len() {
                            break;
                        }
                    }
                    Err(e) if e.kind() == ErrorKind::WouldBlock => break,
                    Err(e) if e.kind() == ErrorKind::Interrupted => continue,
                    Err(e) => {
                        let reason = e.to_string();
                        self.close(tok, &reason);
                        return;
                    }
                }
            }
            self.parse_frames(tok);
        }

        /// Decode every complete frame in the read buffer, in place.
        /// Pipelined clients land several per call.
        fn parse_frames(&mut self, tok: usize) {
            let Some(conn) = self.conns[tok].as_mut() else {
                return;
            };
            let mut newly = 0u64;
            let mut oversized = None;
            loop {
                let avail = conn.rbuf.len() - conn.rpos;
                if avail < 4 {
                    break;
                }
                let len =
                    u32::from_le_bytes(conn.rbuf[conn.rpos..conn.rpos + 4].try_into().unwrap());
                if len > protocol::MAX_FRAME {
                    // Same contract as the blocking path's read_frame:
                    // an impossible header ends the connection.
                    oversized = Some(len);
                    break;
                }
                let need = 4 + len as usize;
                if avail < need {
                    break;
                }
                let t0 = Instant::now();
                let parsed = match Request::decode(&conn.rbuf[conn.rpos + 4..conn.rpos + need]) {
                    Ok(req) => Pending::Req {
                        req,
                        decode_us: t0.elapsed().as_micros() as u64,
                    },
                    Err(e) => Pending::Bad {
                        message: format!("bad request: {e}"),
                        decode_us: t0.elapsed().as_micros() as u64,
                    },
                };
                conn.rpos += need;
                conn.queue.push_back(parsed);
                newly += 1;
            }
            // Reclaim the consumed prefix; the buffer itself is kept.
            if conn.rpos > 0 {
                let len = conn.rbuf.len();
                if conn.rpos == len {
                    conn.rbuf.clear();
                } else {
                    conn.rbuf.copy_within(conn.rpos.., 0);
                    conn.rbuf.truncate(len - conn.rpos);
                }
                conn.rpos = 0;
            }
            if newly > 0 {
                self.state
                    .metrics
                    .reactor_frames
                    .fetch_add(newly, Ordering::Relaxed);
                if !self.active.contains(&tok) {
                    self.active.push(tok);
                }
            }
            if let Some(len) = oversized {
                // Dispatch what decoded cleanly first (their responses
                // still flush), then hang up like thread mode does.
                let reason = format!("frame too large: {len}");
                self.dispatch();
                self.flush_writes(tok);
                self.close(tok, &reason);
            }
        }

        /// Drain every connection's parsed-request queue, fusing
        /// same-collection `Register` runs and same-`(collection, n)`
        /// `TopK` runs across connections into the bulk paths.
        fn dispatch(&mut self) {
            let replica_active = self
                .state
                .replica
                .as_ref()
                .is_some_and(|r| r.is_active());
            let active = std::mem::take(&mut self.active);
            for &tok in &active {
                loop {
                    let Some(head) = self.conns[tok].as_mut().and_then(|c| c.queue.pop_front())
                    else {
                        break;
                    };
                    match head {
                        Pending::Bad { message, decode_us } => {
                            self.respond_bad(tok, message, decode_us)
                        }
                        Pending::Req { req, decode_us } => match req {
                            // Register fusion is a write: on an active
                            // replica route through the router so every
                            // member gets the exact redirect error.
                            Request::Register { id, vector } if !replica_active => {
                                self.fuse_register(&active, tok, None, id, vector, decode_us)
                            }
                            Request::Scoped { collection, inner }
                                if !replica_active
                                    && matches!(*inner, Request::Register { .. }) =>
                            {
                                if let Request::Register { id, vector } = *inner {
                                    self.fuse_register(
                                        &active,
                                        tok,
                                        Some(collection),
                                        id,
                                        vector,
                                        decode_us,
                                    );
                                }
                            }
                            // Sparse bulk ingest fuses like Register:
                            // CSR frames concatenate into one call.
                            Request::RegisterSparse { ids, csr } if !replica_active => {
                                self.fuse_register_sparse(&active, tok, None, ids, csr, decode_us)
                            }
                            Request::Scoped { collection, inner }
                                if !replica_active
                                    && matches!(*inner, Request::RegisterSparse { .. }) =>
                            {
                                if let Request::RegisterSparse { ids, csr } = *inner {
                                    self.fuse_register_sparse(
                                        &active,
                                        tok,
                                        Some(collection),
                                        ids,
                                        csr,
                                        decode_us,
                                    );
                                }
                            }
                            Request::TopK { vectors, n } => {
                                self.fuse_topk(&active, tok, None, vectors, n, decode_us)
                            }
                            Request::Scoped { collection, inner }
                                if matches!(*inner, Request::TopK { .. }) =>
                            {
                                if let Request::TopK { vectors, n } = *inner {
                                    self.fuse_topk(
                                        &active,
                                        tok,
                                        Some(collection),
                                        vectors,
                                        n,
                                        decode_us,
                                    );
                                }
                            }
                            other => self.respond_one(tok, other, decode_us),
                        },
                    }
                }
            }
            self.active = active;
            if self.tick_dispatched > 0 {
                // Count histogram: the "µs" axis reads as requests/tick.
                self.state
                    .metrics
                    .reactor_dispatch_batch
                    .record(self.tick_dispatched);
                self.tick_dispatched = 0;
            }
        }

        /// Route one request through the shared router (identical to a
        /// thread-mode request) and gather its response.
        fn respond_one(&mut self, tok: usize, req: Request, decode_us: u64) {
            let h0 = Instant::now();
            let (resp, meta) = self.state.handle_traced(req);
            let handle_us = h0.elapsed().as_micros() as u64;
            self.push_response(tok, &resp, &meta, decode_us, handle_us);
        }

        fn respond_bad(&mut self, tok: usize, message: String, decode_us: u64) {
            let resp = Response::Error { message };
            let meta = obs::ReqMeta {
                kind: obs::RequestKind::Admin,
                collection: None,
                candidates: None,
            };
            self.push_response(tok, &resp, &meta, decode_us, 0);
        }

        /// Encode one response into the connection's write buffer and
        /// record the request's full-path metrics (thread-mode parity:
        /// histogram, slow-query ring, sampled trace).
        fn push_response(
            &mut self,
            tok: usize,
            resp: &Response,
            meta: &obs::ReqMeta,
            decode_us: u64,
            handle_us: u64,
        ) {
            let Some(conn) = self.conns[tok].as_mut() else {
                return;
            };
            let w0 = Instant::now();
            let appended = protocol::append_frame(&mut conn.wbuf, resp).is_ok();
            let write_us = w0.elapsed().as_micros() as u64;
            let pending = conn.pending_write() as u64;
            if !appended {
                // A response over the frame cap fails the write on the
                // blocking path too; the connection cannot continue.
                self.close(tok, "response frame too large");
                return;
            }
            self.tick_dispatched += 1;
            self.state
                .metrics
                .reactor_write_buffer_hwm
                .fetch_max(pending, Ordering::Relaxed);
            let total_us = (decode_us + handle_us + write_us).max(1);
            observe_request(&self.state, meta, total_us, decode_us, handle_us, write_us);
        }

        /// Resolve a fusion target; `None` means the collection is
        /// unknown and the caller must replay through the router for
        /// the exact per-request error bytes.
        fn fuse_target(&self, scope: Option<&str>) -> Option<Arc<Collection>> {
            self.state
                .registry
                .get(scope.unwrap_or(DEFAULT_COLLECTION))
        }

        fn fuse_register(
            &mut self,
            active: &[usize],
            tok: usize,
            scope: Option<String>,
            id: String,
            vector: Vec<f32>,
            decode_us: u64,
        ) {
            let Some(col) = self.fuse_target(scope.as_deref()) else {
                self.respond_one(tok, rewrap(scope, Request::Register { id, vector }), decode_us);
                return;
            };
            let mut ids = Vec::new();
            let mut vecs = Vec::new();
            let mut members = Vec::new();
            let mut maxd = vector.len().max(1);
            ids.push(id);
            vecs.push(vector);
            members.push(FuseMember {
                tok,
                scope,
                decode_us,
                count: 1,
            });
            self.pull_registers(tok, &col.name, &mut ids, &mut vecs, &mut members, &mut maxd);
            for &other in active {
                if other != tok {
                    let name = &col.name;
                    self.pull_registers(other, name, &mut ids, &mut vecs, &mut members, &mut maxd);
                }
            }
            if members.len() == 1 {
                // Nothing to fuse with this tick: the per-request path
                // keeps single-register metrics identical to thread mode.
                let m = members.pop().unwrap();
                let req = Request::Register {
                    id: ids.pop().unwrap(),
                    vector: vecs.pop().unwrap(),
                };
                self.respond_one(m.tok, rewrap(m.scope, req), m.decode_us);
                return;
            }
            let b = members.len() as u64;
            let echo_ids = ids.clone();
            let h0 = Instant::now();
            let resp = col.register_batch(ids, vecs);
            let handle_each = (h0.elapsed().as_micros() as u64 / b).max(1);
            self.state
                .metrics
                .reactor_coalesced_batches
                .fetch_add(1, Ordering::Relaxed);
            let fused_ok = matches!(resp, Response::RegisteredBatch { .. });
            for (m, id) in members.into_iter().zip(echo_ids) {
                let meta = obs::ReqMeta {
                    kind: obs::RequestKind::Register,
                    collection: m.scope,
                    candidates: None,
                };
                if fused_ok {
                    let one = Response::Registered { id };
                    self.push_response(m.tok, &one, &meta, m.decode_us, handle_each);
                } else {
                    self.push_response(m.tok, &resp, &meta, m.decode_us, handle_each);
                }
            }
        }

        /// Pop the leading run of same-collection `Register` requests
        /// off one connection's queue into the fused batch. Only the
        /// front run is taken, so program order within the connection
        /// is untouched.
        fn pull_registers(
            &mut self,
            tok: usize,
            name: &str,
            ids: &mut Vec<String>,
            vecs: &mut Vec<Vec<f32>>,
            members: &mut Vec<FuseMember>,
            maxd: &mut usize,
        ) {
            loop {
                if members.len() >= MAX_FUSE {
                    return;
                }
                let Some(conn) = self.conns[tok].as_mut() else {
                    return;
                };
                let dim = match conn.queue.front() {
                    Some(Pending::Req {
                        req: Request::Register { vector, .. },
                        ..
                    }) if name == DEFAULT_COLLECTION => vector.len().max(1),
                    Some(Pending::Req {
                        req: Request::Scoped { collection, inner },
                        ..
                    }) if collection == name => match inner.as_ref() {
                        Request::Register { vector, .. } => vector.len().max(1),
                        _ => return,
                    },
                    _ => return,
                };
                // Keep the fused batch inside the bulk workspace the
                // members would individually never hit.
                if (members.len() + 1) * dim.max(*maxd) > MAX_BULK_CELLS {
                    return;
                }
                let Some(Pending::Req { req, decode_us }) = conn.queue.pop_front() else {
                    return;
                };
                let (scope, id, vector) = match req {
                    Request::Register { id, vector } => (None, id, vector),
                    Request::Scoped { collection, inner } => match *inner {
                        Request::Register { id, vector } => (Some(collection), id, vector),
                        other => {
                            // Defensive: restore anything unexpected.
                            conn.queue.push_front(Pending::Req {
                                req: Request::Scoped {
                                    collection,
                                    inner: Box::new(other),
                                },
                                decode_us,
                            });
                            return;
                        }
                    },
                    other => {
                        conn.queue.push_front(Pending::Req {
                            req: other,
                            decode_us,
                        });
                        return;
                    }
                };
                *maxd = (*maxd).max(vector.len().max(1));
                ids.push(id);
                vecs.push(vector);
                members.push(FuseMember {
                    tok,
                    scope,
                    decode_us,
                    count: 1,
                });
            }
        }

        fn fuse_register_sparse(
            &mut self,
            active: &[usize],
            tok: usize,
            scope: Option<String>,
            ids: Vec<String>,
            csr: CsrMatrix,
            decode_us: u64,
        ) {
            let Some(col) = self.fuse_target(scope.as_deref()) else {
                let req = Request::RegisterSparse { ids, csr };
                self.respond_one(tok, rewrap(scope, req), decode_us);
                return;
            };
            if ids.len() != csr.rows() {
                // A malformed frame replays through the router for the
                // exact per-request error instead of poisoning a fuse.
                let req = Request::RegisterSparse { ids, csr };
                self.respond_one(tok, rewrap(scope, req), decode_us);
                return;
            }
            let mut all_ids = ids;
            let mut merged = csr;
            let mut members = vec![FuseMember {
                tok,
                scope,
                decode_us,
                count: merged.rows(),
            }];
            // Per-frame nnz, parallel to `members` (each member's
            // slow-query candidates magnitude — thread-mode parity).
            let mut nnzs = vec![merged.nnz() as u64];
            self.pull_register_sparse(tok, &col, &mut all_ids, &mut merged, &mut members, &mut nnzs);
            for &other in active {
                if other != tok {
                    self.pull_register_sparse(
                        other, &col, &mut all_ids, &mut merged, &mut members, &mut nnzs,
                    );
                }
            }
            if members.len() == 1 {
                let m = members.pop().unwrap();
                let req = Request::RegisterSparse {
                    ids: all_ids,
                    csr: merged,
                };
                self.respond_one(m.tok, rewrap(m.scope, req), m.decode_us);
                return;
            }
            let b = members.len() as u64;
            let h0 = Instant::now();
            let resp = col.register_sparse(all_ids, merged);
            let handle_each = (h0.elapsed().as_micros() as u64 / b).max(1);
            self.state
                .metrics
                .reactor_coalesced_batches
                .fetch_add(1, Ordering::Relaxed);
            let fused_ok = matches!(resp, Response::RegisteredBatch { .. });
            for (m, nnz) in members.into_iter().zip(nnzs) {
                let meta = obs::ReqMeta {
                    kind: obs::RequestKind::RegisterSparse,
                    collection: m.scope,
                    candidates: Some(nnz),
                };
                if fused_ok {
                    let one = Response::RegisteredBatch {
                        count: m.count as u64,
                    };
                    self.push_response(m.tok, &one, &meta, m.decode_us, handle_each);
                } else {
                    self.push_response(m.tok, &resp, &meta, m.decode_us, handle_each);
                }
            }
        }

        /// Pop the leading run of same-collection `RegisterSparse`
        /// requests off one connection's queue into the fused CSR batch
        /// (indices/values concatenate; indptr re-offsets). Only the
        /// front run is taken, so program order within the connection
        /// is untouched.
        fn pull_register_sparse(
            &mut self,
            tok: usize,
            col: &Arc<Collection>,
            ids: &mut Vec<String>,
            merged: &mut CsrMatrix,
            members: &mut Vec<FuseMember>,
            nnzs: &mut Vec<u64>,
        ) {
            let name = &col.name;
            loop {
                if members.len() >= MAX_FUSE {
                    return;
                }
                let Some(conn) = self.conns[tok].as_mut() else {
                    return;
                };
                let (rows, nnz) = match conn.queue.front() {
                    Some(Pending::Req {
                        req: Request::RegisterSparse { ids, csr },
                        ..
                    }) if name == DEFAULT_COLLECTION && ids.len() == csr.rows() => {
                        (csr.rows(), csr.nnz())
                    }
                    Some(Pending::Req {
                        req: Request::Scoped { collection, inner },
                        ..
                    }) if collection == name => match inner.as_ref() {
                        Request::RegisterSparse { ids, csr } if ids.len() == csr.rows() => {
                            (csr.rows(), csr.nnz())
                        }
                        _ => return,
                    },
                    _ => return,
                };
                // Keep the fused batch inside the bulk guards the
                // members would individually never hit: the nnz budget
                // and the projected-output workspace.
                if merged.nnz() + nnz > MAX_BULK_CELLS
                    || (merged.rows() + rows).saturating_mul(col.k) > MAX_BULK_CELLS
                {
                    return;
                }
                let Some(Pending::Req { req, decode_us }) = conn.queue.pop_front() else {
                    return;
                };
                let (scope, frame_ids, csr) = match req {
                    Request::RegisterSparse { ids, csr } => (None, ids, csr),
                    Request::Scoped { collection, inner } => match *inner {
                        Request::RegisterSparse { ids, csr } => (Some(collection), ids, csr),
                        other => {
                            conn.queue.push_front(Pending::Req {
                                req: Request::Scoped {
                                    collection,
                                    inner: Box::new(other),
                                },
                                decode_us,
                            });
                            return;
                        }
                    },
                    other => {
                        conn.queue.push_front(Pending::Req {
                            req: other,
                            decode_us,
                        });
                        return;
                    }
                };
                let base = merged.nnz();
                merged.indices.extend_from_slice(&csr.indices);
                merged.values.extend_from_slice(&csr.values);
                merged.indptr.extend(csr.indptr.iter().skip(1).map(|&p| base + p));
                merged.cols = merged.cols.max(csr.cols);
                ids.extend(frame_ids);
                members.push(FuseMember {
                    tok,
                    scope,
                    decode_us,
                    count: csr.rows(),
                });
                nnzs.push(csr.nnz() as u64);
            }
        }

        fn fuse_topk(
            &mut self,
            active: &[usize],
            tok: usize,
            scope: Option<String>,
            vectors: Vec<Vec<f32>>,
            n: u32,
            decode_us: u64,
        ) {
            let Some(col) = self.fuse_target(scope.as_deref()) else {
                self.respond_one(tok, rewrap(scope, Request::TopK { vectors, n }), decode_us);
                return;
            };
            let mut all = vectors;
            let mut members = vec![FuseMember {
                tok,
                scope,
                decode_us,
                count: all.len(),
            }];
            self.pull_topk(tok, &col.name, n, &mut all, &mut members);
            for &other in active {
                if other != tok {
                    self.pull_topk(other, &col.name, n, &mut all, &mut members);
                }
            }
            if members.len() == 1 {
                let m = members.pop().unwrap();
                let req = Request::TopK { vectors: all, n };
                self.respond_one(m.tok, rewrap(m.scope, req), m.decode_us);
                return;
            }
            let b = members.len() as u64;
            let h0 = Instant::now();
            let resp = col.topk(all, n);
            let handle_each = (h0.elapsed().as_micros() as u64 / b).max(1);
            self.state
                .metrics
                .reactor_coalesced_batches
                .fetch_add(1, Ordering::Relaxed);
            match resp {
                Response::TopK { results } => {
                    let mut it = results.into_iter();
                    for m in members {
                        let chunk: Vec<_> = it.by_ref().take(m.count).collect();
                        let meta = obs::ReqMeta {
                            kind: obs::RequestKind::TopK,
                            collection: m.scope,
                            candidates: None,
                        };
                        let one = Response::TopK { results: chunk };
                        self.push_response(m.tok, &one, &meta, m.decode_us, handle_each);
                    }
                }
                err => {
                    // A sketch failure surfaces the same
                    // `sketch failed: ...` message per-request topk
                    // would produce (the failing vector may belong to
                    // another member; the message text is identical).
                    for m in members {
                        let meta = obs::ReqMeta {
                            kind: obs::RequestKind::TopK,
                            collection: m.scope,
                            candidates: None,
                        };
                        self.push_response(m.tok, &err, &meta, m.decode_us, handle_each);
                    }
                }
            }
        }

        /// Pop the leading run of same-`(collection, n)` `TopK`
        /// requests off one connection's queue into the fused sweep.
        fn pull_topk(
            &mut self,
            tok: usize,
            name: &str,
            n: u32,
            all: &mut Vec<Vec<f32>>,
            members: &mut Vec<FuseMember>,
        ) {
            loop {
                let Some(conn) = self.conns[tok].as_mut() else {
                    return;
                };
                let extra = match conn.queue.front() {
                    Some(Pending::Req {
                        req: Request::TopK { vectors, n: n2 },
                        ..
                    }) if name == DEFAULT_COLLECTION && *n2 == n => vectors.len(),
                    Some(Pending::Req {
                        req: Request::Scoped { collection, inner },
                        ..
                    }) if collection == name => match inner.as_ref() {
                        Request::TopK { vectors, n: n2 } if *n2 == n => vectors.len(),
                        _ => return,
                    },
                    _ => return,
                };
                if all.len() + extra > MAX_FUSE || members.len() >= MAX_FUSE {
                    return;
                }
                let Some(Pending::Req { req, decode_us }) = conn.queue.pop_front() else {
                    return;
                };
                let (scope, vectors) = match req {
                    Request::TopK { vectors, .. } => (None, vectors),
                    Request::Scoped { collection, inner } => match *inner {
                        Request::TopK { vectors, .. } => (Some(collection), vectors),
                        other => {
                            conn.queue.push_front(Pending::Req {
                                req: Request::Scoped {
                                    collection,
                                    inner: Box::new(other),
                                },
                                decode_us,
                            });
                            return;
                        }
                    },
                    other => {
                        conn.queue.push_front(Pending::Req {
                            req: other,
                            decode_us,
                        });
                        return;
                    }
                };
                members.push(FuseMember {
                    tok,
                    scope,
                    decode_us,
                    count: vectors.len(),
                });
                all.extend(vectors);
            }
        }

        /// Flush as much of the write buffer as the socket accepts,
        /// then recompute epoll interest (write interest while bytes
        /// remain; read interest unless backpressured). Returns false
        /// if the connection closed.
        fn flush_writes(&mut self, tok: usize) -> bool {
            loop {
                let Some(conn) = self.conns[tok].as_mut() else {
                    return false;
                };
                if conn.pending_write() == 0 {
                    break;
                }
                let wpos = conn.wpos;
                match conn.stream.write(&conn.wbuf[wpos..]) {
                    Ok(0) => {
                        self.close(tok, "peer stopped accepting writes");
                        return false;
                    }
                    Ok(n) => conn.wpos += n,
                    Err(e) if e.kind() == ErrorKind::WouldBlock => break,
                    Err(e) if e.kind() == ErrorKind::Interrupted => continue,
                    Err(e) => {
                        let reason = e.to_string();
                        self.close(tok, &reason);
                        return false;
                    }
                }
            }
            let Some(conn) = self.conns[tok].as_mut() else {
                return false;
            };
            // Reclaim sent bytes; the allocation is kept for reuse.
            if conn.wpos == conn.wbuf.len() {
                conn.wbuf.clear();
                conn.wpos = 0;
            } else if conn.wpos >= LOW_WATER {
                let len = conn.wbuf.len();
                conn.wbuf.copy_within(conn.wpos.., 0);
                conn.wbuf.truncate(len - conn.wpos);
                conn.wpos = 0;
            }
            self.update_interest(tok);
            true
        }

        fn update_interest(&mut self, tok: usize) {
            let epfd = self.epfd;
            let Some(conn) = self.conns[tok].as_mut() else {
                return;
            };
            let pending = conn.pending_write();
            // Hysteresis: pause reading at the high-water mark, resume
            // only once the peer has drained under the low-water mark.
            conn.paused = pending >= HIGH_WATER || (conn.paused && pending > LOW_WATER);
            let mut want = sys::EPOLLRDHUP;
            if !conn.paused {
                want |= sys::EPOLLIN;
            }
            if pending > 0 {
                want |= sys::EPOLLOUT;
            }
            if want != conn.interest
                && sys::epoll_ctl(
                    epfd,
                    sys::EPOLL_CTL_MOD,
                    conn.stream.as_raw_fd(),
                    want,
                    tok as u64,
                )
                .is_ok()
            {
                conn.interest = want;
            }
        }

        fn close(&mut self, tok: usize, reason: &str) {
            if let Some(conn) = self.conns[tok].take() {
                // A closed peer is the normal end of every connection —
                // debug, never warn (same contract as thread mode).
                obs::log::debug(
                    "crp::server",
                    "connection closed",
                    &[("peer", conn.peer.clone()), ("reason", reason.to_string())],
                );
                self.state.metrics.connections.fetch_sub(1, Ordering::Relaxed);
                self.pending_free.push(tok);
                // Dropping the stream closes the fd, which also removes
                // it from the epoll interest list.
                drop(conn);
            }
        }
    }

    fn rewrap(scope: Option<String>, inner: Request) -> Request {
        match scope {
            Some(collection) => Request::Scoped {
                collection,
                inner: Box::new(inner),
            },
            None => inner,
        }
    }

    #[cfg(test)]
    mod tests {
        use super::*;

        /// The raw-syscall epoll layer drives real sockets: readiness
        /// surfaces for written data and MOD rewrites interest.
        #[test]
        fn epoll_syscalls_drive_socket_readiness() {
            let listener = TcpListener::bind("127.0.0.1:0").unwrap();
            let addr = listener.local_addr().unwrap();
            let mut client = TcpStream::connect(addr).unwrap();
            let (server, _) = listener.accept().unwrap();

            let epfd = sys::epoll_create1().unwrap();
            sys::epoll_ctl(epfd, sys::EPOLL_CTL_ADD, server.as_raw_fd(), sys::EPOLLIN, 42).unwrap();
            let mut events = vec![sys::EpollEvent::default(); 8];
            // Nothing written yet: a zero-timeout wait reports nothing.
            assert_eq!(sys::epoll_wait(epfd, &mut events, 0).unwrap(), 0);
            client.write_all(b"ping").unwrap();
            let n = sys::epoll_wait(epfd, &mut events, 1000).unwrap();
            assert_eq!(n, 1);
            // Copy packed fields out before asserting (no references
            // into a packed struct).
            let (bits, data) = (events[0].events, events[0].data);
            assert_eq!(data, 42);
            assert_ne!(bits & sys::EPOLLIN, 0);
            // MOD to write-only interest: the pending read bytes no
            // longer wake the loop; an idle socket is writable.
            sys::epoll_ctl(epfd, sys::EPOLL_CTL_MOD, server.as_raw_fd(), sys::EPOLLOUT, 7).unwrap();
            let n = sys::epoll_wait(epfd, &mut events, 1000).unwrap();
            assert_eq!(n, 1);
            let (bits, data) = (events[0].events, events[0].data);
            assert_eq!(data, 7);
            assert_ne!(bits & sys::EPOLLOUT, 0);
            assert_eq!(bits & sys::EPOLLIN, 0);
            sys::close(epfd);
        }

        #[test]
        fn nofile_limit_is_readable_and_raisable() {
            let lim = sys::raise_nofile_limit().expect("prlimit64 works on linux");
            assert!(lim >= 1, "soft NOFILE limit {lim}");
            // Idempotent: a second call reports the same (now soft ==
            // hard) limit.
            assert_eq!(sys::raise_nofile_limit(), Some(lim));
        }
    }
}

#[cfg(all(target_os = "linux", any(target_arch = "x86_64", target_arch = "aarch64")))]
pub(crate) use imp::serve_reactor;

#[cfg(all(target_os = "linux", any(target_arch = "x86_64", target_arch = "aarch64")))]
pub use sys::raise_nofile_limit;

/// `--server-mode reactor` needs epoll; everywhere else the flag fails
/// fast with a clear error instead of a degraded emulation.
#[cfg(not(all(target_os = "linux", any(target_arch = "x86_64", target_arch = "aarch64"))))]
pub(crate) fn serve_reactor(
    _listener: std::net::TcpListener,
    _state: std::sync::Arc<crate::coordinator::server::ServiceState>,
    _max_conns: usize,
) -> crate::Result<()> {
    anyhow::bail!(
        "--server-mode reactor requires linux on x86_64/aarch64 (epoll); \
         use --server-mode threads"
    )
}

/// No-op off linux: the connection-scaling bench degrades gracefully.
#[cfg(not(all(target_os = "linux", any(target_arch = "x86_64", target_arch = "aarch64"))))]
pub fn raise_nofile_limit() -> Option<u64> {
    None
}
