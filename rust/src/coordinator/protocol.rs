//! Wire protocol: length-prefixed binary frames over TCP.
//!
//! Frame = `u32` little-endian payload length + payload. Payloads are a
//! compact hand-rolled binary encoding (this environment vendors no
//! serde): a one-byte message tag followed by fields in declaration
//! order. Strings are `u32`-length-prefixed UTF-8; `Vec<f32>` is a
//! `u32` count + raw little-endian f32s. Round-trip tests pin the format.
//!
//! ## Collections
//!
//! Data-path requests are namespaced by wrapping them in
//! [`Request::Scoped`] (tag 13): the collection name followed by the
//! inner request's own encoding. Legacy no-namespace frames (tags 0–9)
//! are untouched — they decode exactly as before and the server routes
//! them to the `default` collection, so pre-namespace clients keep
//! working byte-identically. Collection admin travels on its own tags
//! ([`Request::CreateCollection`] / [`Request::DropCollection`] /
//! [`Request::ListCollections`]).

use std::io::{Read, Write};

use crate::coding::Scheme;
use crate::data::sparse::CsrMatrix;
use crate::projection::MatrixKind;

/// Maximum accepted frame size (guards the server against bad clients).
pub const MAX_FRAME: u32 = 64 * 1024 * 1024;

/// Client → server requests.
#[derive(Clone, Debug, PartialEq)]
pub enum Request {
    /// Store a vector's sketch under `id` (vector is projected, coded,
    /// and discarded — only the codes are kept).
    Register { id: String, vector: Vec<f32> },
    /// Estimate similarity between two registered ids.
    Estimate { a: String, b: String },
    /// Estimate similarity between a query vector and a registered id.
    EstimateVec { id: String, vector: Vec<f32> },
    /// Top-n most similar registered ids to the query vector.
    Knn { vector: Vec<f32>, n: u32 },
    /// Batched top-n: one scan fan-out over the code arena per query
    /// vector, answered in request order.
    TopK { vectors: Vec<Vec<f32>>, n: u32 },
    /// Approximate batched top-n through the banded code index:
    /// bucket candidates (multi-probe expanded by `probes` low-order
    /// band-bit flips; 0 = the collection's default) reranked through
    /// the exact collision kernels. Same response shape as `TopK`;
    /// recall governed by the collection's index config + `probes`.
    ApproxTopK {
        vectors: Vec<Vec<f32>>,
        n: u32,
        probes: u32,
    },
    /// Bulk registration: `ids[i]` stores the sketch of `vectors[i]`,
    /// via one fused project→quantize→pack pass and one bulk arena
    /// ingest (no per-vector batching round-trip).
    RegisterBatch {
        ids: Vec<String>,
        vectors: Vec<Vec<f32>>,
    },
    /// Bulk sparse registration: `ids[i]` stores the sketch of row `i`
    /// of the CSR batch. The server projects each row at O(nnz·k)
    /// through the gather kernel, producing codes byte-identical to
    /// densifying the rows and sending `RegisterBatch` — the sparse
    /// frame is a transport + compute optimization, never a semantic
    /// one. The CSR structure is validated at the decode boundary
    /// ([`crate::data::sparse::CsrMatrix::validate`]), so a crafted
    /// frame errors cleanly instead of panicking downstream.
    RegisterSparse { ids: Vec<String>, csr: CsrMatrix },
    /// Drop the sketch stored under `id` (logged to the WAL like any
    /// other mutation when durability is enabled).
    Remove { id: String },
    /// Explicit durability checkpoint: snapshot the sealed arena and
    /// truncate the WAL. Errors when the server runs without
    /// durability.
    Persist,
    /// Service statistics (aggregates only — the frame a pre-breakdown
    /// client can still decode).
    Stats,
    /// Service statistics with the per-collection breakdown appended.
    /// Rides tag 4 with a one-byte tail, so the bare legacy `Stats`
    /// frame stays byte-identical; old servers reject the tail frame
    /// cleanly instead of silently dropping the section.
    ///
    /// Compatibility contract: detailed answers grow new trailing
    /// sections over time (per-collection in PR 5, per-request in
    /// PR 6), so a client must be at least as new as the server to
    /// decode a non-idle `StatsDetailed` answer — older clients error
    /// on the extra tail instead of silently missing data. Plain
    /// `Stats` answers never carry a section and stay decodable by
    /// every client version.
    StatsDetailed,
    /// Health check.
    Ping,
    /// Create a named collection with its own coding choice. `bits` is
    /// a cross-check: 0 derives it from `(scheme, w)`, a nonzero value
    /// must match what the scheme packs or the create is rejected.
    /// `checkpoint_every` sets the collection's own checkpoint cadence
    /// (0 = the server's global `--checkpoint-every`); it rides as an
    /// optional frame tail, so pre-cadence client frames still decode.
    /// `kind` picks the projection matrix family; non-Gaussian kinds
    /// ride as a second optional tail after `checkpoint_every`, so a
    /// Gaussian create stays byte-identical to the pre-sparse frame.
    CreateCollection {
        name: String,
        scheme: Scheme,
        w: f64,
        bits: u32,
        k: u64,
        seed: u64,
        checkpoint_every: u64,
        kind: MatrixKind,
    },
    /// Drop a named collection (its durable state is deleted).
    DropCollection { name: String },
    /// Enumerate collections with their coding configs and row counts.
    ListCollections,
    /// Namespace wrapper: route `inner` (any data-path request) to the
    /// named collection instead of `default`. Never nests.
    Scoped {
        collection: String,
        inner: Box<Request>,
    },
    /// Full metrics in Prometheus text exposition format — the same
    /// body `crp serve --metrics-addr` serves over HTTP, fetched over
    /// the native protocol (`crp metrics`).
    MetricsText,
    /// Replication pull: a replica asking the primary for the next
    /// window of WAL records of `collection`, starting at its last
    /// applied `(segment, offset)` position. `segment == 0` (WAL
    /// segment numbering starts at 1) means "bootstrap me" — the
    /// primary answers with a snapshot image plus a resume position.
    /// `replica` is a stable self-chosen id the primary uses to track
    /// the retention floor per attached replica. Carries its own
    /// collection field rather than riding `Scoped`, so the replication
    /// path stays out of the data-path namespace machinery.
    ReplSync {
        collection: String,
        replica: String,
        segment: u64,
        offset: u64,
    },
    /// Fetch the most recent entries of the server's slow-query ring
    /// (newest last, at most `max`; 0 = the whole ring).
    SlowQueries { max: u32 },
    /// Promote a replica: stop the applier, start accepting writes.
    /// Idempotent — a primary (or an already-promoted replica) answers
    /// `was_replica: false`.
    Promote,
}

/// Server → client responses.
#[derive(Clone, Debug, PartialEq)]
pub enum Response {
    Registered { id: String },
    RegisteredBatch { count: u64 },
    Estimate { rho: f64, std_err: f64, p_hat: f64 },
    Knn { hits: Vec<KnnHit> },
    TopK { results: Vec<Vec<KnnHit>> },
    Removed { existed: bool },
    /// Checkpoint result: live rows snapshotted + WAL bytes retired.
    Persisted { rows: u64, wal_bytes: u64 },
    Stats(StatsSnapshot),
    Pong,
    Error { message: String },
    /// `ListCollections` result, sorted by name.
    Collections { collections: Vec<CollectionInfo> },
    CollectionCreated { name: String },
    CollectionDropped { existed: bool },
    /// `MetricsText` result: the rendered exposition body.
    MetricsText { text: String },
    /// `ReplSync` answer on the steady-state path: `bytes` is a run of
    /// complete CRC-framed `CRPWAL1` records copied verbatim from
    /// segment `segment` (possibly empty when the replica is caught
    /// up). The replica verifies every frame CRC before applying any
    /// of them, then resumes from `(next_segment, next_offset)`.
    /// `behind_bytes` is the primary-computed backlog remaining after
    /// this chunk; `primary_records` the primary's lifetime record
    /// count for lag-in-records accounting.
    ReplRecords {
        segment: u64,
        next_segment: u64,
        next_offset: u64,
        behind_bytes: u64,
        primary_records: u64,
        bytes: Vec<u8>,
    },
    /// `ReplSync` answer when the replica must (re)bootstrap: a full
    /// `CRPSNAP2` image plus the WAL position the stream resumes from.
    ReplBootstrap {
        segment: u64,
        offset: u64,
        primary_records: u64,
        snapshot: Vec<u8>,
    },
    /// `SlowQueries` answer: ring entries, oldest first.
    SlowQueries { entries: Vec<SlowQueryEntry> },
    /// `Promote` answer.
    Promoted { was_replica: bool },
}

#[derive(Clone, Debug, PartialEq)]
pub struct KnnHit {
    pub id: String,
    pub rho: f64,
}

/// Wire-facing summary of one collection.
#[derive(Clone, Debug, PartialEq)]
pub struct CollectionInfo {
    pub name: String,
    pub scheme: Scheme,
    pub w: f64,
    /// Bits per packed code (derived from `scheme` + `w`).
    pub bits: u32,
    pub k: u64,
    pub seed: u64,
    /// Live sketches currently stored.
    pub rows: u64,
    /// Whether the collection persists (snapshot + WAL).
    pub durable: bool,
}

/// Per-collection slice of the stats breakdown. Only a
/// [`Request::StatsDetailed`] answer carries these; the section is
/// appended after every aggregate field and omitted entirely when
/// empty, so a plain `Stats` response stays byte-identical to the
/// pre-breakdown format.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct CollectionStats {
    pub name: String,
    /// Live sketches stored.
    pub rows: u64,
    /// Rows buffered in the current ingest epoch.
    pub pending_rows: u64,
    /// WAL bytes appended since start (0 without durability).
    pub wal_bytes: u64,
    /// Occupied banded-index buckets (0 without an index).
    pub index_buckets: u64,
}

/// Per-request-kind latency row of the stats breakdown. Like
/// [`CollectionStats`], only a [`Request::StatsDetailed`] answer
/// carries these; the section rides after the per-collection one and
/// is omitted when empty.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct RequestLatency {
    /// Request-kind label (`register`, `knn`, `approx_topk`, …) —
    /// identical to the `kind` label on the `/metrics` endpoint.
    pub kind: String,
    /// Requests of this kind handled so far.
    pub count: u64,
    pub mean_us: f64,
    pub p50_us: u64,
    pub p99_us: u64,
}

/// One captured slow query, as served by [`Request::SlowQueries`].
#[derive(Clone, Debug, Default, PartialEq)]
pub struct SlowQueryEntry {
    /// Monotone capture sequence number (gaps mean ring eviction).
    pub seq: u64,
    /// Request-kind label, as on `/metrics`.
    pub kind: String,
    pub collection: String,
    pub total_us: u64,
    /// Candidate rows examined (0 when the kind records none).
    pub candidates: u64,
}

/// Replication posture of a replica, as carried in the third optional
/// `StatsDetailed` section (never present on a primary).
#[derive(Clone, Debug, Default, PartialEq)]
pub struct ReplicationStats {
    /// Address the applier pulls from.
    pub primary: String,
    /// False once promoted (the section survives promotion so lag at
    /// the moment of failover stays observable).
    pub active: bool,
    pub lag_bytes: u64,
    pub lag_records: u64,
    pub lag_seconds: f64,
    /// Snapshot bootstraps performed (1 = initial only).
    pub bootstraps: u64,
    /// Stream reconnects after loss.
    pub reconnects: u64,
}

/// Reactor front-end counters, as carried in the fourth optional
/// `StatsDetailed` section. Unlike the earlier sections this one is
/// introduced by [`REACTOR_SECTION_SENTINEL`] rather than position
/// alone, because the replication section before it has no count or
/// presence prefix of its own (it opens with a length-prefixed string,
/// and the sentinel can never be a valid string length inside a frame
/// bounded by [`MAX_FRAME`]).
#[derive(Clone, Debug, Default, PartialEq)]
pub struct ReactorStats {
    /// epoll readiness events delivered to the reactor so far.
    pub ready_events: u64,
    /// epoll_wait returns (reactor ticks).
    pub polls: u64,
    /// Frames parsed out of reactor read buffers (≥ requests answered:
    /// pipelined clients land several frames per readiness event).
    pub frames: u64,
    /// Register/TopK groups the reactor fused into one bulk call.
    pub coalesced_batches: u64,
    /// Requests dispatched per tick, p50/p99 over non-idle ticks
    /// (power-of-two buckets, like every histogram here).
    pub p50_dispatch: u64,
    pub p99_dispatch: u64,
    /// High-water mark of any connection's pending write buffer, bytes
    /// (the backpressure trigger).
    pub write_buffer_hwm: u64,
    /// Vectors currently queued at the sketch batcher (gauge; nonzero
    /// in both serve modes — the PR-6 follow-up series).
    pub batcher_queue_depth: u64,
    /// Fused bulk runs handed to the worker pool instead of executing
    /// on the loop (0 with `--reactor-workers 0`).
    pub offloaded_batches: u64,
    /// Jobs currently queued or running in the worker pool (gauge).
    pub worker_queue_depth: u64,
    /// Per-event-loop breakdown, loop index order. Empty in thread
    /// mode and on pre-PR-10 servers; its presence (or a nonzero
    /// offload counter) adds the extension block after the eight
    /// legacy counters — see the encoder for the layout rule.
    pub per_loop: Vec<ReactorLoopStats>,
}

/// One event loop's share of the reactor counters (PR 10: the reactor
/// is sharded across `--reactor-threads` SO_REUSEPORT loops). Carried
/// inside the [`ReactorStats`] extension block; the aggregate fields
/// above remain the cross-loop sums, so old clients lose nothing.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct ReactorLoopStats {
    pub ready_events: u64,
    pub polls: u64,
    pub frames: u64,
    pub coalesced_batches: u64,
    pub offloaded_batches: u64,
    /// Open connections on this loop right now (gauge).
    pub connections: u64,
}

/// Introduces the reactor section of a `Stats` frame. `u32::MAX` is
/// impossible as the string length that would otherwise sit at this
/// position (the replication section's `primary` field), since string
/// lengths are validated against the payload size and no payload
/// reaches 4 GiB under [`MAX_FRAME`].
pub const REACTOR_SECTION_SENTINEL: u32 = u32::MAX;

#[derive(Clone, Debug, Default, PartialEq)]
pub struct StatsSnapshot {
    pub registered: u64,
    pub estimates: u64,
    pub knn_queries: u64,
    pub batches_executed: u64,
    pub vectors_projected: u64,
    pub mean_batch_size: f64,
    pub p50_register_us: u64,
    pub p99_register_us: u64,
    /// Rows buffered in the current ingest epoch (arena mode).
    pub pending_rows: u64,
    /// Epoch drains executed so far.
    pub drains: u64,
    /// Sealed-arena tombstones plus this epoch's masked rows.
    pub tombstones: u64,
    /// Collision-kernel tier serving scans (`avx2`/`sse2`/`swar`).
    pub kernel: String,
    /// WAL records appended since start (0 without durability).
    pub wal_records: u64,
    /// WAL bytes appended since start (0 without durability).
    pub wal_bytes: u64,
    /// Live rows written by the most recent checkpoint.
    pub last_checkpoint_rows: u64,
    /// Background maintenance thread wake-ups (drains/checkpoints).
    pub maintenance_wakeups: u64,
    /// Open client connections right now (gauge; bounded by
    /// `--max-conns`).
    pub connections: u64,
    /// Collections served by this process.
    pub collections: u64,
    /// Per-collection breakdown, sorted by name. Populated only for
    /// `StatsDetailed`; rides as an optional section after the
    /// aggregates and is omitted from the frame when empty (plain
    /// `Stats` responses stay byte-identical to pre-breakdown ones).
    pub per_collection: Vec<CollectionStats>,
    /// Full-path latency per request kind, in kind order, kinds with
    /// no traffic skipped. Populated only for `StatsDetailed`; rides
    /// as a second optional section after `per_collection` (see the
    /// encoder for the tail layout rules). Clients predating this
    /// section cannot decode a `StatsDetailed` answer that carries it
    /// — a deliberate break, same tradeoff as `per_collection` in the
    /// prior PR (see [`Request::StatsDetailed`]).
    pub per_request: Vec<RequestLatency>,
    /// Replication posture — `Some` only on replicas answering
    /// `StatsDetailed`. Rides as a third positional section after
    /// `per_request`; its presence forces the earlier sections onto
    /// the wire (as zero counts if need be). Primaries never carry it,
    /// so their `StatsDetailed` frames stay byte-identical to PR 6.
    pub replication: Option<ReplicationStats>,
    /// Reactor front-end counters — `Some` only on `StatsDetailed`
    /// answers from PR 8+ servers. Rides as a fourth section after
    /// `replication`, introduced by [`REACTOR_SECTION_SENTINEL`] so
    /// the decoder can tell it apart from a replication tail; its
    /// presence forces the per-collection/per-request sections onto
    /// the wire (as zero counts), but never fabricates a replication
    /// section. Plain `Stats` answers never carry it.
    pub reactor: Option<ReactorStats>,
}

// ---- encoding primitives ----------------------------------------------

/// Byte sink for payload encoding. Borrows the caller's buffer so the
/// reactor's write path can append frame after frame into one reused
/// allocation; `encode()` hands it a fresh `Vec` and keeps its old
/// signature.
struct Enc<'a>(&'a mut Vec<u8>);

impl Enc<'_> {
    fn tag(&mut self, t: u8) {
        self.0.push(t);
    }
    fn u8(&mut self, v: u8) {
        self.0.push(v);
    }
    fn u32(&mut self, v: u32) {
        self.0.extend_from_slice(&v.to_le_bytes());
    }
    fn u64(&mut self, v: u64) {
        self.0.extend_from_slice(&v.to_le_bytes());
    }
    fn f64(&mut self, v: f64) {
        self.0.extend_from_slice(&v.to_le_bytes());
    }
    fn str(&mut self, s: &str) {
        self.u32(s.len() as u32);
        self.0.extend_from_slice(s.as_bytes());
    }
    fn f32s(&mut self, v: &[f32]) {
        self.u32(v.len() as u32);
        for x in v {
            self.0.extend_from_slice(&x.to_le_bytes());
        }
    }
    fn u32s(&mut self, v: &[u32]) {
        self.u32(v.len() as u32);
        for x in v {
            self.0.extend_from_slice(&x.to_le_bytes());
        }
    }
    fn bytes(&mut self, b: &[u8]) {
        self.u32(b.len() as u32);
        self.0.extend_from_slice(b);
    }
}

struct Dec<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Dec<'a> {
    fn new(buf: &'a [u8]) -> Self {
        Dec { buf, pos: 0 }
    }
    fn take(&mut self, n: usize) -> crate::Result<&'a [u8]> {
        anyhow::ensure!(self.pos + n <= self.buf.len(), "truncated message");
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }
    fn u8(&mut self) -> crate::Result<u8> {
        Ok(self.take(1)?[0])
    }
    fn u32(&mut self) -> crate::Result<u32> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }
    fn u64(&mut self) -> crate::Result<u64> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }
    fn f64(&mut self) -> crate::Result<f64> {
        Ok(f64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }
    fn str(&mut self) -> crate::Result<String> {
        let n = self.u32()? as usize;
        anyhow::ensure!(n <= self.buf.len(), "bad string length");
        Ok(String::from_utf8(self.take(n)?.to_vec())?)
    }
    fn f32s(&mut self) -> crate::Result<Vec<f32>> {
        let n = self.u32()? as usize;
        anyhow::ensure!(n * 4 <= self.buf.len(), "bad vector length");
        let raw = self.take(n * 4)?;
        Ok(raw
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
            .collect())
    }
    fn u32s(&mut self) -> crate::Result<Vec<u32>> {
        let n = self.u32()? as usize;
        anyhow::ensure!(n * 4 <= self.buf.len(), "bad u32-array length");
        let raw = self.take(n * 4)?;
        Ok(raw
            .chunks_exact(4)
            .map(|c| u32::from_le_bytes(c.try_into().unwrap()))
            .collect())
    }
    fn bytes(&mut self) -> crate::Result<Vec<u8>> {
        let n = self.u32()? as usize;
        anyhow::ensure!(n <= self.buf.len(), "bad byte-blob length");
        Ok(self.take(n)?.to_vec())
    }
    fn done(&self) -> crate::Result<()> {
        anyhow::ensure!(self.pos == self.buf.len(), "trailing bytes");
        Ok(())
    }
}

impl Request {
    pub fn encode(&self) -> Vec<u8> {
        let mut out = Vec::new();
        self.encode_into(&mut out);
        out
    }

    /// Append this request's payload encoding (no length prefix) to
    /// `out`, reusing its allocation. `encode` delegates here.
    pub fn encode_into(&self, out: &mut Vec<u8>) {
        let mut e = Enc(out);
        match self {
            Request::Register { id, vector } => {
                e.tag(0);
                e.str(id);
                e.f32s(vector);
            }
            Request::Estimate { a, b } => {
                e.tag(1);
                e.str(a);
                e.str(b);
            }
            Request::EstimateVec { id, vector } => {
                e.tag(2);
                e.str(id);
                e.f32s(vector);
            }
            Request::Knn { vector, n } => {
                e.tag(3);
                e.f32s(vector);
                e.u32(*n);
            }
            Request::Stats => e.tag(4),
            Request::StatsDetailed => {
                e.tag(4);
                e.u8(1);
            }
            Request::Ping => e.tag(5),
            Request::TopK { vectors, n } => {
                e.tag(6);
                e.u32(vectors.len() as u32);
                for v in vectors {
                    e.f32s(v);
                }
                e.u32(*n);
            }
            Request::RegisterBatch { ids, vectors } => {
                e.tag(7);
                e.u32(ids.len() as u32);
                for id in ids {
                    e.str(id);
                }
                e.u32(vectors.len() as u32);
                for v in vectors {
                    e.f32s(v);
                }
            }
            Request::Remove { id } => {
                e.tag(8);
                e.str(id);
            }
            Request::Persist => e.tag(9),
            Request::CreateCollection {
                name,
                scheme,
                w,
                bits,
                k,
                seed,
                checkpoint_every,
                kind,
            } => {
                e.tag(10);
                e.str(name);
                e.u8(scheme.wire_code());
                e.f64(*w);
                e.u32(*bits);
                e.u64(*k);
                e.u64(*seed);
                e.u64(*checkpoint_every);
                // Optional tail: Gaussian (the default) is omitted so
                // pre-sparse create frames stay byte-identical.
                if *kind != MatrixKind::Gaussian {
                    e.u8(kind.code());
                    e.u32(kind.param());
                }
            }
            Request::DropCollection { name } => {
                e.tag(11);
                e.str(name);
            }
            Request::ListCollections => e.tag(12),
            Request::Scoped { collection, inner } => {
                e.tag(13);
                e.str(collection);
                inner.encode_into(e.0);
            }
            Request::ApproxTopK { vectors, n, probes } => {
                e.tag(14);
                e.u32(vectors.len() as u32);
                for v in vectors {
                    e.f32s(v);
                }
                e.u32(*n);
                e.u32(*probes);
            }
            Request::MetricsText => e.tag(15),
            Request::ReplSync {
                collection,
                replica,
                segment,
                offset,
            } => {
                e.tag(16);
                e.str(collection);
                e.str(replica);
                e.u64(*segment);
                e.u64(*offset);
            }
            Request::SlowQueries { max } => {
                e.tag(17);
                e.u32(*max);
            }
            Request::Promote => e.tag(18),
            Request::RegisterSparse { ids, csr } => {
                e.tag(19);
                e.u32(ids.len() as u32);
                for id in ids {
                    e.str(id);
                }
                e.u64(csr.cols as u64);
                e.u32(csr.indptr.len() as u32);
                for &p in &csr.indptr {
                    e.u32(p as u32);
                }
                e.u32s(&csr.indices);
                e.f32s(&csr.values);
            }
        }
    }

    pub fn decode(buf: &[u8]) -> crate::Result<Self> {
        Self::decode_depth(buf, true)
    }

    /// `allow_scoped` is false when already inside a `Scoped` wrapper:
    /// nesting is rejected *before* recursing, so a frame of stacked
    /// tag-13 headers can never overflow the connection thread's stack.
    fn decode_depth(buf: &[u8], allow_scoped: bool) -> crate::Result<Self> {
        let mut d = Dec::new(buf);
        let tag = d.u8()?;
        let req = match tag {
            0 => Request::Register {
                id: d.str()?,
                vector: d.f32s()?,
            },
            1 => Request::Estimate {
                a: d.str()?,
                b: d.str()?,
            },
            2 => Request::EstimateVec {
                id: d.str()?,
                vector: d.f32s()?,
            },
            3 => Request::Knn {
                vector: d.f32s()?,
                n: d.u32()?,
            },
            4 => {
                // Optional one-byte tail: bare [4] is the legacy
                // aggregates-only Stats; [4, 1] asks for the
                // per-collection breakdown.
                if d.pos < buf.len() {
                    let v = d.u8()?;
                    anyhow::ensure!(v == 1, "bad stats detail byte {v}");
                    Request::StatsDetailed
                } else {
                    Request::Stats
                }
            }
            5 => Request::Ping,
            6 => {
                let n_vecs = d.u32()? as usize;
                anyhow::ensure!(n_vecs * 4 <= buf.len(), "bad batch size");
                let mut vectors = Vec::with_capacity(n_vecs);
                for _ in 0..n_vecs {
                    vectors.push(d.f32s()?);
                }
                Request::TopK {
                    vectors,
                    n: d.u32()?,
                }
            }
            7 => {
                let n_ids = d.u32()? as usize;
                anyhow::ensure!(n_ids * 4 <= buf.len(), "bad id count");
                let mut ids = Vec::with_capacity(n_ids);
                for _ in 0..n_ids {
                    ids.push(d.str()?);
                }
                let n_vecs = d.u32()? as usize;
                anyhow::ensure!(n_vecs * 4 <= buf.len(), "bad batch size");
                let mut vectors = Vec::with_capacity(n_vecs);
                for _ in 0..n_vecs {
                    vectors.push(d.f32s()?);
                }
                Request::RegisterBatch { ids, vectors }
            }
            8 => Request::Remove { id: d.str()? },
            9 => Request::Persist,
            10 => {
                let name = d.str()?;
                let code = d.u8()?;
                let scheme = Scheme::from_wire_code(code)
                    .ok_or_else(|| anyhow::anyhow!("unknown scheme code {code}"))?;
                let (w, bits, k, seed) = (d.f64()?, d.u32()?, d.u64()?, d.u64()?);
                // Optional tail: frames from pre-cadence clients end at
                // `seed` and mean "use the server's global cadence".
                let checkpoint_every = if d.pos < buf.len() { d.u64()? } else { 0 };
                // Second optional tail: pre-sparse frames (and Gaussian
                // creates from new clients) end here.
                let kind = if d.pos < buf.len() {
                    let code = d.u8()?;
                    let param = d.u32()?;
                    MatrixKind::from_wire(code, param)?
                } else {
                    MatrixKind::Gaussian
                };
                Request::CreateCollection {
                    name,
                    scheme,
                    w,
                    bits,
                    k,
                    seed,
                    checkpoint_every,
                    kind,
                }
            }
            11 => Request::DropCollection { name: d.str()? },
            12 => Request::ListCollections,
            13 => {
                anyhow::ensure!(allow_scoped, "nested Scoped request");
                let collection = d.str()?;
                // The tail is the inner request's own encoding; its
                // decoder enforces its own completeness.
                let inner = Request::decode_depth(&buf[d.pos..], false)?;
                d.pos = buf.len();
                Request::Scoped {
                    collection,
                    inner: Box::new(inner),
                }
            }
            14 => {
                let n_vecs = d.u32()? as usize;
                anyhow::ensure!(n_vecs * 4 <= buf.len(), "bad batch size");
                let mut vectors = Vec::with_capacity(n_vecs);
                for _ in 0..n_vecs {
                    vectors.push(d.f32s()?);
                }
                Request::ApproxTopK {
                    vectors,
                    n: d.u32()?,
                    probes: d.u32()?,
                }
            }
            15 => Request::MetricsText,
            16 => Request::ReplSync {
                collection: d.str()?,
                replica: d.str()?,
                segment: d.u64()?,
                offset: d.u64()?,
            },
            17 => Request::SlowQueries { max: d.u32()? },
            18 => Request::Promote,
            19 => {
                let n_ids = d.u32()? as usize;
                anyhow::ensure!(n_ids * 4 <= buf.len(), "bad id count");
                let mut ids = Vec::with_capacity(n_ids);
                for _ in 0..n_ids {
                    ids.push(d.str()?);
                }
                let cols = d.u64()? as usize;
                let indptr: Vec<usize> = d.u32s()?.into_iter().map(|p| p as usize).collect();
                let indices = d.u32s()?;
                let values = d.f32s()?;
                let csr = CsrMatrix {
                    indptr,
                    indices,
                    values,
                    cols,
                };
                // Decode-boundary validation: a crafted frame errors
                // here instead of panicking on slice indexing later.
                csr.validate()?;
                anyhow::ensure!(
                    ids.len() == csr.rows(),
                    "ids {} != rows {}",
                    ids.len(),
                    csr.rows()
                );
                Request::RegisterSparse { ids, csr }
            }
            t => anyhow::bail!("unknown request tag {t}"),
        };
        d.done()?;
        Ok(req)
    }
}

impl Response {
    pub fn encode(&self) -> Vec<u8> {
        let mut out = Vec::new();
        self.encode_into(&mut out);
        out
    }

    /// Append this response's payload encoding (no length prefix) to
    /// `out`, reusing its allocation — the reactor encodes every
    /// response this way, straight into the connection's write buffer
    /// (see [`append_frame`]). `encode` delegates here.
    pub fn encode_into(&self, out: &mut Vec<u8>) {
        let mut e = Enc(out);
        match self {
            Response::Registered { id } => {
                e.tag(0);
                e.str(id);
            }
            Response::Estimate {
                rho,
                std_err,
                p_hat,
            } => {
                e.tag(1);
                e.f64(*rho);
                e.f64(*std_err);
                e.f64(*p_hat);
            }
            Response::Knn { hits } => {
                e.tag(2);
                e.u32(hits.len() as u32);
                for h in hits {
                    e.str(&h.id);
                    e.f64(h.rho);
                }
            }
            Response::Stats(s) => {
                e.tag(3);
                e.u64(s.registered);
                e.u64(s.estimates);
                e.u64(s.knn_queries);
                e.u64(s.batches_executed);
                e.u64(s.vectors_projected);
                e.f64(s.mean_batch_size);
                e.u64(s.p50_register_us);
                e.u64(s.p99_register_us);
                e.u64(s.pending_rows);
                e.u64(s.drains);
                e.u64(s.tombstones);
                e.str(&s.kernel);
                e.u64(s.wal_records);
                e.u64(s.wal_bytes);
                e.u64(s.last_checkpoint_rows);
                e.u64(s.maintenance_wakeups);
                e.u64(s.connections);
                e.u64(s.collections);
                // Optional sections — appended after every aggregate
                // field, and omitted entirely when empty so a plain
                // `Stats` answer is byte-identical to the pre-breakdown
                // format (old clients keep decoding it). The decoder
                // reads trailing sections positionally, so a non-empty
                // per-request section forces the per-collection one to
                // be present too (as a zero count if need be).
                //
                // Only plain `Stats` is compatible both directions.
                // A `StatsDetailed` answer with traffic recorded is
                // NOT decodable by clients predating a section it
                // carries (their `done()` rejects the extra tail) —
                // an accepted break; see Request::StatsDetailed.
                let has_tail = s.replication.is_some() || s.reactor.is_some();
                if !s.per_collection.is_empty() || !s.per_request.is_empty() || has_tail {
                    e.u32(s.per_collection.len() as u32);
                    for c in &s.per_collection {
                        e.str(&c.name);
                        e.u64(c.rows);
                        e.u64(c.pending_rows);
                        e.u64(c.wal_bytes);
                        e.u64(c.index_buckets);
                    }
                }
                if !s.per_request.is_empty() || has_tail {
                    e.u32(s.per_request.len() as u32);
                    for r in &s.per_request {
                        e.str(&r.kind);
                        e.u64(r.count);
                        e.f64(r.mean_us);
                        e.u64(r.p50_us);
                        e.u64(r.p99_us);
                    }
                }
                if let Some(r) = &s.replication {
                    e.str(&r.primary);
                    e.u8(u8::from(r.active));
                    e.u64(r.lag_bytes);
                    e.u64(r.lag_records);
                    e.f64(r.lag_seconds);
                    e.u64(r.bootstraps);
                    e.u64(r.reconnects);
                }
                if let Some(r) = &s.reactor {
                    // Sentinel first: the decoder peeks it to tell this
                    // section from a replication tail (see ReactorStats).
                    e.u32(REACTOR_SECTION_SENTINEL);
                    e.u64(r.ready_events);
                    e.u64(r.polls);
                    e.u64(r.frames);
                    e.u64(r.coalesced_batches);
                    e.u64(r.p50_dispatch);
                    e.u64(r.p99_dispatch);
                    e.u64(r.write_buffer_hwm);
                    e.u64(r.batcher_queue_depth);
                    // PR 10 extension: worker-pool counters plus the
                    // per-loop breakdown. Omitted entirely when empty
                    // so a single-loop, no-worker server (and thread
                    // mode, which never fills these) stays
                    // byte-identical to the PR 8 section — decoders
                    // detect it purely by frame length, since this is
                    // the final section.
                    let has_ext = r.offloaded_batches > 0
                        || r.worker_queue_depth > 0
                        || !r.per_loop.is_empty();
                    if has_ext {
                        e.u64(r.offloaded_batches);
                        e.u64(r.worker_queue_depth);
                        e.u32(r.per_loop.len() as u32);
                        for l in &r.per_loop {
                            e.u64(l.ready_events);
                            e.u64(l.polls);
                            e.u64(l.frames);
                            e.u64(l.coalesced_batches);
                            e.u64(l.offloaded_batches);
                            e.u64(l.connections);
                        }
                    }
                }
            }
            Response::Pong => e.tag(4),
            Response::Error { message } => {
                e.tag(5);
                e.str(message);
            }
            Response::RegisteredBatch { count } => {
                e.tag(7);
                e.u64(*count);
            }
            Response::Removed { existed } => {
                e.tag(8);
                e.u8(u8::from(*existed));
            }
            Response::Persisted { rows, wal_bytes } => {
                e.tag(9);
                e.u64(*rows);
                e.u64(*wal_bytes);
            }
            Response::TopK { results } => {
                e.tag(6);
                e.u32(results.len() as u32);
                for hits in results {
                    e.u32(hits.len() as u32);
                    for h in hits {
                        e.str(&h.id);
                        e.f64(h.rho);
                    }
                }
            }
            Response::Collections { collections } => {
                e.tag(10);
                e.u32(collections.len() as u32);
                for c in collections {
                    e.str(&c.name);
                    e.u8(c.scheme.wire_code());
                    e.f64(c.w);
                    e.u32(c.bits);
                    e.u64(c.k);
                    e.u64(c.seed);
                    e.u64(c.rows);
                    e.u8(u8::from(c.durable));
                }
            }
            Response::CollectionCreated { name } => {
                e.tag(11);
                e.str(name);
            }
            Response::CollectionDropped { existed } => {
                e.tag(12);
                e.u8(u8::from(*existed));
            }
            Response::MetricsText { text } => {
                e.tag(13);
                e.str(text);
            }
            Response::ReplRecords {
                segment,
                next_segment,
                next_offset,
                behind_bytes,
                primary_records,
                bytes,
            } => {
                e.tag(14);
                e.u64(*segment);
                e.u64(*next_segment);
                e.u64(*next_offset);
                e.u64(*behind_bytes);
                e.u64(*primary_records);
                e.bytes(bytes);
            }
            Response::ReplBootstrap {
                segment,
                offset,
                primary_records,
                snapshot,
            } => {
                e.tag(15);
                e.u64(*segment);
                e.u64(*offset);
                e.u64(*primary_records);
                e.bytes(snapshot);
            }
            Response::SlowQueries { entries } => {
                e.tag(16);
                e.u32(entries.len() as u32);
                for q in entries {
                    e.u64(q.seq);
                    e.str(&q.kind);
                    e.str(&q.collection);
                    e.u64(q.total_us);
                    e.u64(q.candidates);
                }
            }
            Response::Promoted { was_replica } => {
                e.tag(17);
                e.u8(u8::from(*was_replica));
            }
        }
    }

    pub fn decode(buf: &[u8]) -> crate::Result<Self> {
        let mut d = Dec::new(buf);
        let tag = d.u8()?;
        let resp = match tag {
            0 => Response::Registered { id: d.str()? },
            1 => Response::Estimate {
                rho: d.f64()?,
                std_err: d.f64()?,
                p_hat: d.f64()?,
            },
            2 => {
                let n = d.u32()? as usize;
                let mut hits = Vec::with_capacity(n.min(1 << 20));
                for _ in 0..n {
                    hits.push(KnnHit {
                        id: d.str()?,
                        rho: d.f64()?,
                    });
                }
                Response::Knn { hits }
            }
            3 => {
                let mut s = StatsSnapshot {
                    registered: d.u64()?,
                    estimates: d.u64()?,
                    knn_queries: d.u64()?,
                    batches_executed: d.u64()?,
                    vectors_projected: d.u64()?,
                    mean_batch_size: d.f64()?,
                    p50_register_us: d.u64()?,
                    p99_register_us: d.u64()?,
                    pending_rows: d.u64()?,
                    drains: d.u64()?,
                    tombstones: d.u64()?,
                    kernel: d.str()?,
                    wal_records: d.u64()?,
                    wal_bytes: d.u64()?,
                    last_checkpoint_rows: d.u64()?,
                    maintenance_wakeups: d.u64()?,
                    connections: d.u64()?,
                    collections: d.u64()?,
                    per_collection: Vec::new(),
                    per_request: Vec::new(),
                    replication: None,
                    reactor: None,
                };
                // Optional per-collection section: absent in frames
                // from pre-breakdown servers.
                if d.pos < buf.len() {
                    let n = d.u32()? as usize;
                    anyhow::ensure!(n * 36 <= buf.len(), "bad collection stat count");
                    for _ in 0..n {
                        s.per_collection.push(CollectionStats {
                            name: d.str()?,
                            rows: d.u64()?,
                            pending_rows: d.u64()?,
                            wal_bytes: d.u64()?,
                            index_buckets: d.u64()?,
                        });
                    }
                }
                // Optional per-request section: absent in frames from
                // pre-observability servers.
                if d.pos < buf.len() {
                    let n = d.u32()? as usize;
                    anyhow::ensure!(n * 36 <= buf.len(), "bad request stat count");
                    for _ in 0..n {
                        s.per_request.push(RequestLatency {
                            kind: d.str()?,
                            count: d.u64()?,
                            mean_us: d.f64()?,
                            p50_us: d.u64()?,
                            p99_us: d.u64()?,
                        });
                    }
                }
                // Optional replication section: present only in
                // `StatsDetailed` frames from replicas. The reactor
                // section behind it opens with REACTOR_SECTION_SENTINEL
                // — impossible as the string length that starts a
                // replication section — so one peeked u32 tells the
                // tails apart (a primary's frame can carry the reactor
                // section without fabricating a replication one).
                let at_sentinel = |d: &Dec| {
                    buf.len() - d.pos >= 4
                        && buf[d.pos..d.pos + 4] == REACTOR_SECTION_SENTINEL.to_le_bytes()
                };
                if d.pos < buf.len() && !at_sentinel(&d) {
                    let primary = d.str()?;
                    let active = d.u8()?;
                    anyhow::ensure!(active <= 1, "bad bool byte {active}");
                    s.replication = Some(ReplicationStats {
                        primary,
                        active: active == 1,
                        lag_bytes: d.u64()?,
                        lag_records: d.u64()?,
                        lag_seconds: d.f64()?,
                        bootstraps: d.u64()?,
                        reconnects: d.u64()?,
                    });
                }
                // Optional reactor section: sentinel-introduced (PR 8).
                if d.pos < buf.len() {
                    let sent = d.u32()?;
                    anyhow::ensure!(
                        sent == REACTOR_SECTION_SENTINEL,
                        "bad reactor section sentinel {sent:#x}"
                    );
                    let mut r = ReactorStats {
                        ready_events: d.u64()?,
                        polls: d.u64()?,
                        frames: d.u64()?,
                        coalesced_batches: d.u64()?,
                        p50_dispatch: d.u64()?,
                        p99_dispatch: d.u64()?,
                        write_buffer_hwm: d.u64()?,
                        batcher_queue_depth: d.u64()?,
                        ..Default::default()
                    };
                    // PR 10 extension, detected by leftover bytes: the
                    // reactor section is always last, so a PR 8 frame
                    // ends exactly here.
                    if d.pos < buf.len() {
                        r.offloaded_batches = d.u64()?;
                        r.worker_queue_depth = d.u64()?;
                        let n_loops = d.u32()? as usize;
                        anyhow::ensure!(n_loops * 8 <= buf.len(), "bad loop count");
                        let mut per_loop = Vec::with_capacity(n_loops);
                        for _ in 0..n_loops {
                            per_loop.push(ReactorLoopStats {
                                ready_events: d.u64()?,
                                polls: d.u64()?,
                                frames: d.u64()?,
                                coalesced_batches: d.u64()?,
                                offloaded_batches: d.u64()?,
                                connections: d.u64()?,
                            });
                        }
                        r.per_loop = per_loop;
                    }
                    s.reactor = Some(r);
                }
                Response::Stats(s)
            }
            4 => Response::Pong,
            5 => Response::Error { message: d.str()? },
            6 => {
                let n_results = d.u32()? as usize;
                anyhow::ensure!(n_results * 4 <= buf.len(), "bad result count");
                let mut results = Vec::with_capacity(n_results);
                for _ in 0..n_results {
                    let n_hits = d.u32()? as usize;
                    anyhow::ensure!(n_hits * 12 <= buf.len(), "bad hit count");
                    let mut hits = Vec::with_capacity(n_hits);
                    for _ in 0..n_hits {
                        hits.push(KnnHit {
                            id: d.str()?,
                            rho: d.f64()?,
                        });
                    }
                    results.push(hits);
                }
                Response::TopK { results }
            }
            7 => Response::RegisteredBatch { count: d.u64()? },
            8 => {
                let v = d.u8()?;
                anyhow::ensure!(v <= 1, "bad bool byte {v}");
                Response::Removed { existed: v == 1 }
            }
            9 => Response::Persisted {
                rows: d.u64()?,
                wal_bytes: d.u64()?,
            },
            10 => {
                let n = d.u32()? as usize;
                anyhow::ensure!(n * 30 <= buf.len(), "bad collection count");
                let mut collections = Vec::with_capacity(n);
                for _ in 0..n {
                    let name = d.str()?;
                    let code = d.u8()?;
                    let scheme = Scheme::from_wire_code(code)
                        .ok_or_else(|| anyhow::anyhow!("unknown scheme code {code}"))?;
                    let w = d.f64()?;
                    let bits = d.u32()?;
                    let k = d.u64()?;
                    let seed = d.u64()?;
                    let rows = d.u64()?;
                    let durable = d.u8()?;
                    anyhow::ensure!(durable <= 1, "bad bool byte {durable}");
                    collections.push(CollectionInfo {
                        name,
                        scheme,
                        w,
                        bits,
                        k,
                        seed,
                        rows,
                        durable: durable == 1,
                    });
                }
                Response::Collections { collections }
            }
            11 => Response::CollectionCreated { name: d.str()? },
            12 => {
                let v = d.u8()?;
                anyhow::ensure!(v <= 1, "bad bool byte {v}");
                Response::CollectionDropped { existed: v == 1 }
            }
            13 => Response::MetricsText { text: d.str()? },
            14 => Response::ReplRecords {
                segment: d.u64()?,
                next_segment: d.u64()?,
                next_offset: d.u64()?,
                behind_bytes: d.u64()?,
                primary_records: d.u64()?,
                bytes: d.bytes()?,
            },
            15 => Response::ReplBootstrap {
                segment: d.u64()?,
                offset: d.u64()?,
                primary_records: d.u64()?,
                snapshot: d.bytes()?,
            },
            16 => {
                let n = d.u32()? as usize;
                anyhow::ensure!(n * 40 <= buf.len(), "bad slow-query count");
                let mut entries = Vec::with_capacity(n);
                for _ in 0..n {
                    entries.push(SlowQueryEntry {
                        seq: d.u64()?,
                        kind: d.str()?,
                        collection: d.str()?,
                        total_us: d.u64()?,
                        candidates: d.u64()?,
                    });
                }
                Response::SlowQueries { entries }
            }
            17 => {
                let v = d.u8()?;
                anyhow::ensure!(v <= 1, "bad bool byte {v}");
                Response::Promoted { was_replica: v == 1 }
            }
            t => anyhow::bail!("unknown response tag {t}"),
        };
        d.done()?;
        Ok(resp)
    }
}

// ---- framing ------------------------------------------------------------

/// Read one frame from a blocking reader.
pub fn read_frame<R: Read>(r: &mut R) -> crate::Result<Vec<u8>> {
    let mut buf = Vec::new();
    read_frame_into(r, &mut buf)?;
    Ok(buf)
}

/// [`read_frame`] into a caller-owned buffer, reusing its allocation
/// across requests (the per-request `Vec` was measurable at fan-in).
/// The buffer is cleared first; on success it holds exactly the
/// payload. Steady state costs zero allocations once the buffer has
/// grown to the connection's largest frame.
pub fn read_frame_into<R: Read>(r: &mut R, buf: &mut Vec<u8>) -> crate::Result<()> {
    let mut len_buf = [0u8; 4];
    r.read_exact(&mut len_buf)?;
    let len = u32::from_le_bytes(len_buf);
    anyhow::ensure!(len <= MAX_FRAME, "frame too large: {len}");
    buf.clear();
    buf.resize(len as usize, 0);
    r.read_exact(buf)?;
    Ok(())
}

/// Append `resp` to `out` as one length-prefixed frame: reserve the
/// 4-byte header, encode the payload in place, patch the length. The
/// reactor's gathered-write path — no intermediate payload `Vec`, no
/// flush; `out` accumulates frames until the socket drains it.
pub fn append_frame(out: &mut Vec<u8>, resp: &Response) -> crate::Result<()> {
    let start = out.len();
    out.extend_from_slice(&[0u8; 4]);
    resp.encode_into(out);
    let payload = out.len() - start - 4;
    if payload > MAX_FRAME as usize {
        out.truncate(start);
        anyhow::bail!("frame too large: {payload}");
    }
    out[start..start + 4].copy_from_slice(&(payload as u32).to_le_bytes());
    Ok(())
}

/// Write one frame.
pub fn write_frame<W: Write>(w: &mut W, payload: &[u8]) -> crate::Result<()> {
    anyhow::ensure!(payload.len() <= MAX_FRAME as usize, "frame too large");
    w.write_all(&(payload.len() as u32).to_le_bytes())?;
    w.write_all(payload)?;
    w.flush()?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn roundtrip_req(r: Request) {
        let enc = r.encode();
        let back = Request::decode(&enc).unwrap();
        assert_eq!(r, back);
    }

    /// A small well-formed CSR batch: 3 rows over 10 columns.
    fn sample_csr() -> CsrMatrix {
        let mut m = CsrMatrix::with_capacity(3, 5, 10);
        m.push_row(&[0, 3, 7], &[1.0, -2.0, 0.5]);
        m.push_row(&[9], &[4.0]);
        m.push_row(&[], &[]);
        m
    }

    fn roundtrip_resp(r: Response) {
        let enc = r.encode();
        let back = Response::decode(&enc).unwrap();
        assert_eq!(r, back);
    }

    #[test]
    fn request_roundtrips() {
        roundtrip_req(Request::Register {
            id: "vec-α".into(),
            vector: vec![0.1, -0.5, f32::MIN_POSITIVE],
        });
        roundtrip_req(Request::Estimate {
            a: "a".into(),
            b: "b".into(),
        });
        roundtrip_req(Request::EstimateVec {
            id: "q".into(),
            vector: vec![],
        });
        roundtrip_req(Request::Knn {
            vector: vec![1.0; 100],
            n: 5,
        });
        roundtrip_req(Request::TopK {
            vectors: vec![vec![0.5; 16], vec![], vec![-1.0, 2.0]],
            n: 7,
        });
        roundtrip_req(Request::TopK {
            vectors: vec![],
            n: 0,
        });
        roundtrip_req(Request::RegisterBatch {
            ids: vec!["a".into(), "β".into()],
            vectors: vec![vec![1.0, -2.0], vec![]],
        });
        roundtrip_req(Request::RegisterBatch {
            ids: vec![],
            vectors: vec![],
        });
        roundtrip_req(Request::Remove { id: "gone".into() });
        roundtrip_req(Request::Persist);
        roundtrip_req(Request::Stats);
        roundtrip_req(Request::StatsDetailed);
        roundtrip_req(Request::Ping);
        roundtrip_req(Request::ApproxTopK {
            vectors: vec![vec![0.5; 16], vec![], vec![-1.0, 2.0]],
            n: 7,
            probes: 3,
        });
        roundtrip_req(Request::ApproxTopK {
            vectors: vec![],
            n: 0,
            probes: 0,
        });
        roundtrip_req(Request::CreateCollection {
            name: "web-embeddings".into(),
            scheme: Scheme::Uniform,
            w: 1.0,
            bits: 4,
            k: 1024,
            seed: 42,
            checkpoint_every: 50_000,
            kind: MatrixKind::Gaussian,
        });
        roundtrip_req(Request::CreateCollection {
            name: "sparse-text".into(),
            scheme: Scheme::OneBit,
            w: 0.0,
            bits: 1,
            k: 256,
            seed: 7,
            checkpoint_every: 0,
            kind: MatrixKind::SignSparse { s: 100 },
        });
        roundtrip_req(Request::RegisterSparse {
            ids: vec!["a".into(), "β".into(), "c".into()],
            csr: sample_csr(),
        });
        roundtrip_req(Request::RegisterSparse {
            ids: vec![],
            csr: CsrMatrix::with_capacity(0, 0, 0),
        });
        roundtrip_req(Request::DropCollection { name: "old".into() });
        roundtrip_req(Request::ListCollections);
        roundtrip_req(Request::ReplSync {
            collection: "default".into(),
            replica: "r-1234".into(),
            segment: 7,
            offset: 4096,
        });
        roundtrip_req(Request::ReplSync {
            collection: "web".into(),
            replica: "r".into(),
            segment: 0,
            offset: 0,
        });
        roundtrip_req(Request::SlowQueries { max: 0 });
        roundtrip_req(Request::SlowQueries { max: 32 });
        roundtrip_req(Request::Promote);
        for inner in [
            Request::Register {
                id: "x".into(),
                vector: vec![0.5, -0.5],
            },
            Request::Estimate {
                a: "a".into(),
                b: "b".into(),
            },
            Request::EstimateVec {
                id: "q".into(),
                vector: vec![1.0],
            },
            Request::Knn {
                vector: vec![0.0; 8],
                n: 3,
            },
            Request::TopK {
                vectors: vec![vec![1.0], vec![]],
                n: 2,
            },
            Request::ApproxTopK {
                vectors: vec![vec![1.0], vec![]],
                n: 2,
                probes: 4,
            },
            Request::RegisterBatch {
                ids: vec!["a".into()],
                vectors: vec![vec![2.0]],
            },
            Request::RegisterSparse {
                ids: vec!["a".into(), "b".into(), "c".into()],
                csr: sample_csr(),
            },
            Request::Remove { id: "x".into() },
            Request::Persist,
        ] {
            roundtrip_req(Request::Scoped {
                collection: "two-bit-075".into(),
                inner: Box::new(inner),
            });
        }
    }

    /// Satellite pin: pre-namespace frames are untouched. The exact
    /// bytes old clients send still decode to the same requests (the
    /// server routes them to the `default` collection), and encoding
    /// those requests reproduces the same bytes — no re-tagging.
    #[test]
    fn legacy_frames_decode_and_encode_byte_identically() {
        // Hand-built tag-0 Register frame, as a pre-namespace client
        // would emit it: tag | u32 id_len | id | u32 n | f32s.
        let mut legacy_register = vec![0u8];
        legacy_register.extend_from_slice(&2u32.to_le_bytes());
        legacy_register.extend_from_slice(b"ab");
        legacy_register.extend_from_slice(&2u32.to_le_bytes());
        legacy_register.extend_from_slice(&0.5f32.to_le_bytes());
        legacy_register.extend_from_slice(&(-1.5f32).to_le_bytes());
        let want = Request::Register {
            id: "ab".into(),
            vector: vec![0.5, -1.5],
        };
        assert_eq!(Request::decode(&legacy_register).unwrap(), want);
        assert_eq!(want.encode(), legacy_register);

        // Tag-8 Remove and tag-9 Persist frames likewise.
        let mut legacy_remove = vec![8u8];
        legacy_remove.extend_from_slice(&1u32.to_le_bytes());
        legacy_remove.push(b'x');
        let want = Request::Remove { id: "x".into() };
        assert_eq!(Request::decode(&legacy_remove).unwrap(), want);
        assert_eq!(want.encode(), legacy_remove);
        assert_eq!(Request::decode(&[9u8]).unwrap(), Request::Persist);
        assert_eq!(Request::Persist.encode(), vec![9u8]);

        // Every legacy tag still owns its number: encoding the
        // un-namespaced requests emits tags 0–9, never the new ones.
        for (req, tag) in [
            (
                Request::Register {
                    id: "i".into(),
                    vector: vec![],
                },
                0u8,
            ),
            (
                Request::Estimate {
                    a: "a".into(),
                    b: "b".into(),
                },
                1,
            ),
            (
                Request::EstimateVec {
                    id: "i".into(),
                    vector: vec![],
                },
                2,
            ),
            (
                Request::Knn {
                    vector: vec![],
                    n: 1,
                },
                3,
            ),
            (Request::Stats, 4),
            (Request::Ping, 5),
            (
                Request::TopK {
                    vectors: vec![],
                    n: 1,
                },
                6,
            ),
            (
                Request::RegisterBatch {
                    ids: vec![],
                    vectors: vec![],
                },
                7,
            ),
            (Request::Remove { id: "i".into() }, 8),
            (Request::Persist, 9),
        ] {
            assert_eq!(req.encode()[0], tag, "{req:?}");
        }
        // Namespaced requests ride the Scoped wrapper (tag 13), leaving
        // the legacy tags untouched.
        let scoped = Request::Scoped {
            collection: "c".into(),
            inner: Box::new(Request::Ping),
        };
        assert_eq!(scoped.encode()[0], 13);
        // Nested Scoped is rejected at decode.
        let nested = Request::Scoped {
            collection: "outer".into(),
            inner: Box::new(scoped),
        };
        assert!(Request::decode(&nested.encode()).is_err());
        // ...including a hand-built frame of 100k stacked tag-13
        // headers: rejected at depth 2, before any recursion could
        // touch the connection thread's stack.
        let mut deep = Vec::with_capacity(100_000 * 6 + 1);
        for _ in 0..100_000 {
            deep.push(13u8);
            deep.extend_from_slice(&1u32.to_le_bytes());
            deep.push(b'c');
        }
        deep.push(5); // innermost Ping
        assert!(Request::decode(&deep).is_err());
    }

    /// Optional-tail back-compat pins: a pre-cadence CreateCollection
    /// frame (no trailing `checkpoint_every`) still decodes, and a
    /// pre-breakdown Stats frame (no per-collection section) still
    /// decodes — new fields default instead of erroring.
    #[test]
    fn optional_tails_tolerate_old_frames() {
        let with_tail = Request::CreateCollection {
            name: "c".into(),
            scheme: Scheme::TwoBit,
            w: 0.75,
            bits: 2,
            k: 64,
            seed: 9,
            checkpoint_every: 0,
            kind: MatrixKind::Gaussian,
        };
        let mut old_frame = with_tail.encode();
        assert_eq!(old_frame[0], 10);
        old_frame.truncate(old_frame.len() - 8); // strip the tail
        assert_eq!(Request::decode(&old_frame).unwrap(), with_tail);
        // A *partial* tail is still a truncated frame, not a default.
        let mut torn = with_tail.encode();
        torn.truncate(torn.len() - 3);
        assert!(Request::decode(&torn).is_err());

        // A Stats response without a breakdown emits NO section at all
        // — byte-identical to the pre-breakdown format, so pre-PR5
        // clients (whose decoder rejects trailing bytes) keep working —
        // and still round-trips through the tolerant new decoder.
        let stats = Response::Stats(StatsSnapshot {
            registered: 7,
            kernel: "swar".into(),
            ..Default::default()
        });
        let bytes = stats.encode();
        assert_eq!(Response::decode(&bytes).unwrap(), stats);
        let mut with_section = bytes.clone();
        with_section.extend_from_slice(&0u32.to_le_bytes());
        assert_eq!(
            bytes.len() + 4,
            with_section.len(),
            "empty sections must be omitted, not encoded as a zero count"
        );
        assert_eq!(Response::decode(&with_section).unwrap(), stats);

        // Stats request: bare legacy [4] vs the [4, 1] detail tail.
        assert_eq!(Request::Stats.encode(), vec![4u8]);
        assert_eq!(Request::StatsDetailed.encode(), vec![4u8, 1]);
        assert_eq!(Request::decode(&[4u8]).unwrap(), Request::Stats);
        assert_eq!(Request::decode(&[4u8, 1]).unwrap(), Request::StatsDetailed);
        assert!(Request::decode(&[4u8, 9]).is_err());
    }

    /// PR6 wire pins: the `MetricsText` frames and the per-request
    /// latency tail on `Stats`, plus proof the new tail never disturbs
    /// the pre-observability byte layouts pinned above (plain `Stats`
    /// answers only — a `StatsDetailed` answer carrying the tail needs
    /// a PR6+ client to decode, by design).
    #[test]
    fn metrics_text_and_per_request_frames() {
        // Request tag 15 is a bare byte, like Persist/Ping.
        assert_eq!(Request::MetricsText.encode(), vec![15u8]);
        roundtrip_req(Request::MetricsText);

        // Response tag 13: length-prefixed exposition text.
        roundtrip_resp(Response::MetricsText {
            text: "# TYPE crp_requests_total counter\n".into(),
        });
        roundtrip_resp(Response::MetricsText { text: String::new() });

        // Stats with per-request rows but no per-collection rows: the
        // decoder reads trailing sections positionally, so the encoder
        // must emit a zero-count per-collection section first.
        let stats = Response::Stats(StatsSnapshot {
            registered: 3,
            kernel: "swar".into(),
            per_request: vec![
                RequestLatency {
                    kind: "knn".into(),
                    count: 2,
                    mean_us: 150.5,
                    p50_us: 128,
                    p99_us: 256,
                },
                RequestLatency {
                    kind: "persist".into(),
                    count: 1,
                    mean_us: 50_000.0,
                    p50_us: 65_536,
                    p99_us: 65_536,
                },
            ],
            ..Default::default()
        });
        let bytes = stats.encode();
        assert_eq!(Response::decode(&bytes).unwrap(), stats);
        let bare = Response::Stats(StatsSnapshot {
            registered: 3,
            kernel: "swar".into(),
            ..Default::default()
        })
        .encode();
        // The leading zero-count per-collection section is present.
        assert_eq!(&bytes[bare.len()..bare.len() + 4], &0u32.to_le_bytes());

        // Both sections together round-trip too.
        let both = Response::Stats(StatsSnapshot {
            kernel: "avx2".into(),
            per_collection: vec![CollectionStats {
                name: "web".into(),
                rows: 9,
                pending_rows: 1,
                wal_bytes: 64,
                index_buckets: 3,
            }],
            per_request: vec![RequestLatency {
                kind: "estimate".into(),
                count: 40,
                mean_us: 12.0,
                p50_us: 8,
                p99_us: 32,
            }],
            ..Default::default()
        });
        assert_eq!(Response::decode(&both.encode()).unwrap(), both);

        // A pre-observability frame (per-collection section only, no
        // per-request tail) still decodes with the field defaulting.
        let old = Response::Stats(StatsSnapshot {
            kernel: "avx2".into(),
            per_collection: vec![CollectionStats {
                name: "web".into(),
                rows: 9,
                pending_rows: 1,
                wal_bytes: 64,
                index_buckets: 3,
            }],
            ..Default::default()
        });
        assert_eq!(Response::decode(&old.encode()).unwrap(), old);
    }

    /// PR7 wire pins: the replication / slow-query / promote frames own
    /// tags the legacy map never used (requests 16–18, responses
    /// 14–17), and the replication stats tail rides as a third
    /// positional section that forces the earlier ones onto the wire —
    /// while frames without it (every primary) stay byte-identical to
    /// the PR 6 layout.
    #[test]
    fn replication_frames_and_stats_tail() {
        // New request tags, pinned.
        let sync = Request::ReplSync {
            collection: "default".into(),
            replica: "r1".into(),
            segment: 3,
            offset: 16,
        };
        assert_eq!(sync.encode()[0], 16);
        assert_eq!(Request::SlowQueries { max: 5 }.encode()[0], 17);
        assert_eq!(Request::Promote.encode(), vec![18u8]);

        // New response tags, pinned + roundtripped (including raw WAL
        // payload bytes that must come back verbatim).
        let records = Response::ReplRecords {
            segment: 3,
            next_segment: 4,
            next_offset: 16,
            behind_bytes: 1024,
            primary_records: 99,
            bytes: vec![0xde, 0xad, 0xbe, 0xef, 0x00, 0x01],
        };
        assert_eq!(records.encode()[0], 14);
        roundtrip_resp(records);
        roundtrip_resp(Response::ReplRecords {
            segment: 1,
            next_segment: 1,
            next_offset: 16,
            behind_bytes: 0,
            primary_records: 0,
            bytes: vec![],
        });
        let boot = Response::ReplBootstrap {
            segment: 5,
            offset: 16,
            primary_records: 42,
            snapshot: vec![7u8; 129],
        };
        assert_eq!(boot.encode()[0], 15);
        roundtrip_resp(boot);
        let slow = Response::SlowQueries {
            entries: vec![SlowQueryEntry {
                seq: 9,
                kind: "knn".into(),
                collection: "default".into(),
                total_us: 125_000,
                candidates: 4096,
            }],
        };
        assert_eq!(slow.encode()[0], 16);
        roundtrip_resp(slow);
        roundtrip_resp(Response::SlowQueries { entries: vec![] });
        assert_eq!(Response::Promoted { was_replica: true }.encode(), vec![17u8, 1]);
        roundtrip_resp(Response::Promoted { was_replica: false });

        // Replication tail alone forces zero-count earlier sections so
        // the positional decoder finds it in the right place.
        let repl = Response::Stats(StatsSnapshot {
            kernel: "swar".into(),
            replication: Some(ReplicationStats {
                primary: "127.0.0.1:4100".into(),
                active: true,
                lag_bytes: 2048,
                lag_records: 17,
                lag_seconds: 0.25,
                bootstraps: 1,
                reconnects: 3,
            }),
            ..Default::default()
        });
        let bytes = repl.encode();
        assert_eq!(Response::decode(&bytes).unwrap(), repl);
        let bare = Response::Stats(StatsSnapshot {
            kernel: "swar".into(),
            ..Default::default()
        })
        .encode();
        // Two zero-count section headers precede the replication tail.
        assert_eq!(&bytes[bare.len()..bare.len() + 4], &0u32.to_le_bytes());
        assert_eq!(&bytes[bare.len() + 4..bare.len() + 8], &0u32.to_le_bytes());

        // All three sections together roundtrip.
        let full = Response::Stats(StatsSnapshot {
            kernel: "avx2".into(),
            per_collection: vec![CollectionStats {
                name: "web".into(),
                rows: 9,
                ..Default::default()
            }],
            per_request: vec![RequestLatency {
                kind: "knn".into(),
                count: 2,
                mean_us: 10.0,
                p50_us: 8,
                p99_us: 32,
            }],
            replication: Some(ReplicationStats {
                primary: "p:1".into(),
                active: false,
                lag_bytes: 0,
                lag_records: 0,
                lag_seconds: 0.0,
                bootstraps: 2,
                reconnects: 0,
            }),
            ..Default::default()
        });
        assert_eq!(Response::decode(&full.encode()).unwrap(), full);

        // No-replication frames are byte-identical to the PR 6 layout:
        // the tail adds nothing when absent (pinned above via `bare`).
        let pr6 = Response::Stats(StatsSnapshot {
            kernel: "swar".into(),
            per_request: vec![RequestLatency {
                kind: "knn".into(),
                count: 1,
                mean_us: 1.0,
                p50_us: 1,
                p99_us: 1,
            }],
            ..Default::default()
        });
        let enc = pr6.encode();
        assert_eq!(Response::decode(&enc).unwrap(), pr6);
        assert!(!enc.is_empty());
    }

    /// PR8 wire pins: the sentinel-introduced reactor stats tail.
    /// Frames without it stay byte-identical to the PR 7 layout; with
    /// it, the decoder must find it after any combination of the three
    /// earlier sections — including the replication-less primary case
    /// the sentinel exists for.
    #[test]
    fn reactor_stats_tail() {
        let reactor = ReactorStats {
            ready_events: 1000,
            polls: 400,
            frames: 1200,
            coalesced_batches: 37,
            p50_dispatch: 4,
            p99_dispatch: 32,
            write_buffer_hwm: 1 << 20,
            batcher_queue_depth: 5,
            ..Default::default()
        };
        // Reactor tail alone (a primary): zero-count per-collection and
        // per-request sections, NO replication section, then the
        // sentinel.
        let stats = Response::Stats(StatsSnapshot {
            kernel: "swar".into(),
            reactor: Some(reactor.clone()),
            ..Default::default()
        });
        let bytes = stats.encode();
        assert_eq!(Response::decode(&bytes).unwrap(), stats);
        let bare = Response::Stats(StatsSnapshot {
            kernel: "swar".into(),
            ..Default::default()
        })
        .encode();
        assert_eq!(&bytes[bare.len()..bare.len() + 4], &0u32.to_le_bytes());
        assert_eq!(&bytes[bare.len() + 4..bare.len() + 8], &0u32.to_le_bytes());
        assert_eq!(&bytes[bare.len() + 8..bare.len() + 12], &[0xFF; 4]);
        // Exactly sentinel + 8 u64s follow — no hidden replication
        // section was fabricated.
        assert_eq!(bytes.len(), bare.len() + 8 + 4 + 8 * 8);

        // All four sections together (a replica) round-trip.
        let full = Response::Stats(StatsSnapshot {
            kernel: "avx2".into(),
            per_collection: vec![CollectionStats {
                name: "web".into(),
                rows: 9,
                ..Default::default()
            }],
            per_request: vec![RequestLatency {
                kind: "knn".into(),
                count: 2,
                mean_us: 10.0,
                p50_us: 8,
                p99_us: 32,
            }],
            replication: Some(ReplicationStats {
                primary: "p:1".into(),
                active: true,
                lag_bytes: 64,
                lag_records: 1,
                lag_seconds: 0.5,
                bootstraps: 1,
                reconnects: 0,
            }),
            reactor: Some(reactor),
            ..Default::default()
        });
        assert_eq!(Response::decode(&full.encode()).unwrap(), full);

        // PR 7 shapes are untouched: no reactor field → no sentinel,
        // and old replication-tail frames still decode (pinned again
        // here against the new peek logic).
        let pr7 = Response::Stats(StatsSnapshot {
            kernel: "swar".into(),
            replication: Some(ReplicationStats {
                primary: "127.0.0.1:4100".into(),
                active: true,
                lag_bytes: 2048,
                lag_records: 17,
                lag_seconds: 0.25,
                bootstraps: 1,
                reconnects: 3,
            }),
            ..Default::default()
        });
        let enc = pr7.encode();
        assert!(!enc.windows(4).any(|w| w == [0xFF; 4]), "no sentinel");
        assert_eq!(Response::decode(&enc).unwrap(), pr7);

        // A truncated reactor section is a truncated frame, not a
        // default.
        let mut torn = stats.encode();
        torn.truncate(torn.len() - 3);
        assert!(Response::decode(&torn).is_err());
    }

    /// PR10 wire pins: the reactor section's multi-loop extension
    /// (worker-pool counters + per-loop breakdown) rides after the
    /// eight PR 8 counters, detected by frame length alone. A reactor
    /// snapshot with no offload and no loop shards must stay
    /// byte-identical to the PR 8 encoding.
    #[test]
    fn reactor_multi_loop_extension() {
        let legacy = ReactorStats {
            ready_events: 10,
            polls: 5,
            frames: 12,
            coalesced_batches: 2,
            ..Default::default()
        };
        let extended = ReactorStats {
            offloaded_batches: 7,
            worker_queue_depth: 1,
            per_loop: vec![
                ReactorLoopStats {
                    ready_events: 6,
                    polls: 3,
                    frames: 8,
                    coalesced_batches: 2,
                    offloaded_batches: 7,
                    connections: 4,
                },
                ReactorLoopStats::default(),
            ],
            ..legacy.clone()
        };
        let snap = |r: ReactorStats| {
            Response::Stats(StatsSnapshot {
                kernel: "swar".into(),
                reactor: Some(r),
                ..Default::default()
            })
        };

        // Legacy shape: extension absent, PR 8 length pin still holds.
        let legacy_bytes = snap(legacy.clone()).encode();
        let bare = Response::Stats(StatsSnapshot {
            kernel: "swar".into(),
            ..Default::default()
        })
        .encode();
        assert_eq!(legacy_bytes.len(), bare.len() + 8 + 4 + 8 * 8);
        assert_eq!(Response::decode(&legacy_bytes).unwrap(), snap(legacy.clone()));

        // Extended shape: legacy prefix byte-identical, extension
        // appended (2 u64s + count + 2 loops × 6 u64s), round-trips.
        let ext_bytes = snap(extended.clone()).encode();
        assert_eq!(&ext_bytes[..legacy_bytes.len()], &legacy_bytes[..]);
        assert_eq!(
            ext_bytes.len(),
            legacy_bytes.len() + 8 + 8 + 4 + 2 * 6 * 8
        );
        assert_eq!(Response::decode(&ext_bytes).unwrap(), snap(extended));

        // A nonzero offload counter alone forces the extension even
        // with no per-loop shards (single loop + workers).
        let off_only = ReactorStats {
            offloaded_batches: 3,
            ..legacy
        };
        let off_bytes = snap(off_only.clone()).encode();
        assert_eq!(off_bytes.len(), legacy_bytes.len() + 8 + 8 + 4);
        assert_eq!(Response::decode(&off_bytes).unwrap(), snap(off_only));

        // A truncated extension is a truncated frame.
        let mut torn = ext_bytes;
        torn.truncate(torn.len() - 5);
        assert!(Response::decode(&torn).is_err());
    }

    /// PR9 wire pins: the sparse-ingest frame owns tag 19, a Gaussian
    /// `CreateCollection` stays byte-identical to the pre-sparse
    /// layout (the kind tail is omitted, not zero-encoded), and a
    /// malformed CSR frame errors at decode instead of panicking
    /// downstream.
    #[test]
    fn sparse_frames_and_matrix_kind_tail() {
        let sparse = Request::RegisterSparse {
            ids: vec!["a".into(), "b".into(), "c".into()],
            csr: sample_csr(),
        };
        assert_eq!(sparse.encode()[0], 19);

        // Gaussian create: frame ends right after `checkpoint_every` —
        // tag | str name | u8 scheme | f64 w | u32 bits | u64 k |
        // u64 seed | u64 cadence. No kind tail.
        let gaussian = Request::CreateCollection {
            name: "c".into(),
            scheme: Scheme::TwoBit,
            w: 0.75,
            bits: 2,
            k: 64,
            seed: 9,
            checkpoint_every: 10,
            kind: MatrixKind::Gaussian,
        };
        let genc = gaussian.encode();
        assert_eq!(genc.len(), 1 + (4 + 1) + 1 + 8 + 4 + 8 + 8 + 8);
        // A sign-sparse create appends exactly u8 code + u32 s.
        let signed = Request::CreateCollection {
            name: "c".into(),
            scheme: Scheme::TwoBit,
            w: 0.75,
            bits: 2,
            k: 64,
            seed: 9,
            checkpoint_every: 10,
            kind: MatrixKind::SignSparse { s: 64 },
        };
        let senc = signed.encode();
        assert_eq!(senc.len(), genc.len() + 5);
        assert_eq!(&senc[..genc.len()], genc.as_slice());
        // Unknown kind code / degenerate s reject at decode.
        let mut bad = senc.clone();
        bad[genc.len()] = 9;
        assert!(Request::decode(&bad).is_err());
        let mut bad = senc.clone();
        bad[genc.len() + 1..].copy_from_slice(&0u32.to_le_bytes());
        assert!(Request::decode(&bad).is_err());
        // A partial kind tail is a truncated frame, not a default.
        let mut torn = senc.clone();
        torn.truncate(torn.len() - 2);
        assert!(Request::decode(&torn).is_err());

        // Malformed CSR payloads: every corruption errors cleanly.
        let good = sparse.encode();
        assert!(Request::decode(&good).is_ok());
        // ids count disagreeing with the row count.
        let mismatched = Request::RegisterSparse {
            ids: vec!["only-one".into()],
            csr: sample_csr(),
        };
        assert!(Request::decode(&mismatched.encode()).is_err());
        // Out-of-range column index.
        let mut csr = sample_csr();
        csr.indices[1] = 10;
        let oob = Request::RegisterSparse {
            ids: vec!["a".into(), "b".into(), "c".into()],
            csr,
        };
        assert!(Request::decode(&oob.encode()).is_err());
        // Unsorted indices within a row.
        let mut csr = sample_csr();
        csr.indices[1] = 0;
        let unsorted = Request::RegisterSparse {
            ids: vec!["a".into(), "b".into(), "c".into()],
            csr,
        };
        assert!(Request::decode(&unsorted.encode()).is_err());
        // indptr end disagreeing with nnz.
        let mut csr = sample_csr();
        *csr.indptr.last_mut().unwrap() = 2;
        let torn_ptr = Request::RegisterSparse {
            ids: vec!["a".into(), "b".into(), "c".into()],
            csr,
        };
        assert!(Request::decode(&torn_ptr.encode()).is_err());
    }

    /// Satellite pins: the buffer-reusing framing variants are
    /// byte-identical to their allocating originals, and `encode_into`
    /// appends (never clobbers) so frames can be gathered.
    #[test]
    fn frame_reuse_variants_match_originals() {
        let resp = Response::Knn {
            hits: vec![KnnHit {
                id: "a".into(),
                rho: 0.5,
            }],
        };
        // encode_into ≡ encode, appended after existing bytes.
        let mut out = vec![9u8, 9];
        resp.encode_into(&mut out);
        assert_eq!(&out[..2], &[9, 9]);
        assert_eq!(&out[2..], resp.encode().as_slice());
        let req = Request::Scoped {
            collection: "c".into(),
            inner: Box::new(Request::Knn {
                vector: vec![1.0, 2.0],
                n: 3,
            }),
        };
        let mut rout = Vec::new();
        req.encode_into(&mut rout);
        assert_eq!(rout, req.encode());

        // append_frame ≡ write_frame, and gathers back-to-back frames
        // that read_frame_into consumes one at a time with one reused
        // buffer.
        let mut gathered = Vec::new();
        append_frame(&mut gathered, &resp).unwrap();
        append_frame(&mut gathered, &Response::Pong).unwrap();
        let mut expect = Vec::new();
        write_frame(&mut expect, &resp.encode()).unwrap();
        write_frame(&mut expect, &Response::Pong.encode()).unwrap();
        assert_eq!(gathered, expect);
        let mut cursor = std::io::Cursor::new(gathered);
        let mut buf = vec![0xAAu8; 3]; // stale content must be cleared
        read_frame_into(&mut cursor, &mut buf).unwrap();
        assert_eq!(Response::decode(&buf).unwrap(), resp);
        read_frame_into(&mut cursor, &mut buf).unwrap();
        assert_eq!(Response::decode(&buf).unwrap(), Response::Pong);
        // Oversized header rejected through the _into path too.
        let mut cursor = std::io::Cursor::new(u32::MAX.to_le_bytes().to_vec());
        assert!(read_frame_into(&mut cursor, &mut buf).is_err());
    }

    #[test]
    fn response_roundtrips() {
        roundtrip_resp(Response::Registered { id: "x".into() });
        roundtrip_resp(Response::Estimate {
            rho: 0.87,
            std_err: 0.01,
            p_hat: 0.9,
        });
        roundtrip_resp(Response::Knn {
            hits: vec![
                KnnHit {
                    id: "a".into(),
                    rho: 0.9,
                },
                KnnHit {
                    id: "b".into(),
                    rho: 0.1,
                },
            ],
        });
        roundtrip_resp(Response::TopK {
            results: vec![
                vec![
                    KnnHit {
                        id: "x".into(),
                        rho: 0.99,
                    },
                    KnnHit {
                        id: "y".into(),
                        rho: 0.42,
                    },
                ],
                vec![],
            ],
        });
        roundtrip_resp(Response::Stats(StatsSnapshot {
            registered: 10,
            mean_batch_size: 3.5,
            pending_rows: 17,
            drains: 3,
            tombstones: 2,
            kernel: "avx2".into(),
            wal_records: 1234,
            wal_bytes: 98765,
            last_checkpoint_rows: 10,
            maintenance_wakeups: 77,
            connections: 12,
            collections: 3,
            per_collection: vec![
                CollectionStats {
                    name: "default".into(),
                    rows: 10,
                    pending_rows: 2,
                    wal_bytes: 4096,
                    index_buckets: 321,
                },
                CollectionStats {
                    name: "web".into(),
                    rows: 0,
                    pending_rows: 0,
                    wal_bytes: 0,
                    index_buckets: 0,
                },
            ],
            ..Default::default()
        }));
        roundtrip_resp(Response::Collections {
            collections: vec![
                CollectionInfo {
                    name: "default".into(),
                    scheme: Scheme::TwoBit,
                    w: 0.75,
                    bits: 2,
                    k: 256,
                    seed: 0,
                    rows: 1_000_000,
                    durable: true,
                },
                CollectionInfo {
                    name: "uni4".into(),
                    scheme: Scheme::Uniform,
                    w: 1.0,
                    bits: 4,
                    k: 128,
                    seed: 11,
                    rows: 0,
                    durable: false,
                },
            ],
        });
        roundtrip_resp(Response::Collections {
            collections: vec![],
        });
        roundtrip_resp(Response::CollectionCreated { name: "c".into() });
        roundtrip_resp(Response::CollectionDropped { existed: true });
        roundtrip_resp(Response::CollectionDropped { existed: false });
        roundtrip_resp(Response::RegisteredBatch { count: 512 });
        roundtrip_resp(Response::Removed { existed: true });
        roundtrip_resp(Response::Removed { existed: false });
        roundtrip_resp(Response::Persisted {
            rows: 100_000,
            wal_bytes: 1 << 30,
        });
        roundtrip_resp(Response::Pong);
        roundtrip_resp(Response::Error {
            message: "boom".into(),
        });
    }

    #[test]
    fn garbage_rejected() {
        assert!(Request::decode(&[]).is_err());
        assert!(Request::decode(&[200]).is_err());
        assert!(Request::decode(&[0, 1, 0, 0]).is_err()); // truncated string
        // Trailing bytes rejected.
        let mut enc = Request::Ping.encode();
        enc.push(0);
        assert!(Request::decode(&enc).is_err());
    }

    #[test]
    fn frame_roundtrip_in_memory() {
        let mut buf = Vec::new();
        write_frame(&mut buf, b"hello").unwrap();
        let mut cursor = std::io::Cursor::new(buf);
        let got = read_frame(&mut cursor).unwrap();
        assert_eq!(got, b"hello");
    }

    #[test]
    fn oversized_frame_rejected() {
        let hdr = u32::MAX.to_le_bytes();
        let mut cursor = std::io::Cursor::new(hdr.to_vec());
        assert!(read_frame(&mut cursor).is_err());
    }
}
