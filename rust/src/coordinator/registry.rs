//! The collection registry: one server process, many named collections,
//! each with the coding that fits its workload.
//!
//! The paper's central operational claim is that the coding scheme is a
//! *per-workload* choice — uniform `h_w` beats `h_{w,q}`, and the 2-bit
//! non-uniform code wins when bits are scarce. A [`Collection`] bundles
//! everything one such choice needs: a [`Projector`] (its own `k` and
//! seed), a dynamic [`SketchBatcher`], a fused bulk-ingest
//! [`BatchEncoder`], an arena-backed [`SketchStore`], a
//! [`CollisionEstimator`], and optionally a [`Durability`] engine. The
//! [`Registry`] owns the named set, creates/drops collections at
//! runtime, and hands all of their stores one shared [`DrainSignal`] so
//! a single maintenance thread multiplexes drains, compaction, and
//! checkpoints across every collection.
//!
//! ## Durable layout
//!
//! With a root directory (`crp serve --data-dir`), each collection
//! persists under its own subdirectory and a CRC-checked `MANIFEST`
//! records the full coding config of every collection, so a restart
//! rebuilds the whole registry — projector seeds included — without any
//! flags beyond `--data-dir`:
//!
//! ```text
//! <root>/MANIFEST                         registry of (name, scheme, w, bits, k, seed)
//! <root>/<collection>/snap/snapshot.bin   CRPSNAP2 arena image
//! <root>/<collection>/wal/wal.*.log       CRPWAL1 epoch segments
//! ```
//!
//! The `default` collection always exists (it serves every legacy
//! no-namespace request) and is recorded in the MANIFEST like any
//! other; restarting with flags that contradict the MANIFEST is an
//! error, not silent data corruption. Dropping a collection removes it
//! from the MANIFEST *first*, then deletes its directory — a crash
//! between the two leaves an orphan directory that the next `create`
//! of that name clears before reuse, so recreate never replays stale
//! state.

use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex, RwLock};
use std::time::Instant;

use crate::coding::{BatchEncoder, CodingParams, PackedCodes, Scheme};
use crate::coordinator::batcher::{BatcherConfig, SketchBatcher};
use crate::coordinator::durability::{crc32_update, Durability, DurabilityConfig, FsyncPolicy};
use crate::coordinator::metrics::{LatencyHistogram, Metrics};
use crate::coordinator::protocol::{CollectionInfo, KnnHit, Response};
use crate::coordinator::store::{DrainSignal, SketchStore};
use crate::data::sparse::CsrMatrix;
use crate::estimator::CollisionEstimator;
use crate::lsh::IndexConfig;
use crate::projection::{MatrixKind, ProjectionConfig, Projector};
use crate::scan::EpochConfig;

/// Name of the implicit collection legacy (no-namespace) frames route to.
pub const DEFAULT_COLLECTION: &str = "default";

/// Registry MANIFEST file magic (version in the name: `CRPMANI3` adds
/// the projection matrix kind — family code + parameter — per entry).
pub const MANIFEST_MAGIC: &[u8; 8] = b"CRPMANI3";

/// The PR-5 MANIFEST magic; still readable (entries carry options but
/// no matrix kind, which defaults to Gaussian).
pub const MANIFEST_MAGIC_V2: &[u8; 8] = b"CRPMANI2";

/// The PR-4 MANIFEST magic; still readable (entries carry no options,
/// which default from the spec).
pub const MANIFEST_MAGIC_V1: &[u8; 8] = b"CRPMANI1";

/// Upper bound on collection-name bytes (also a directory name).
const MAX_NAME: usize = 64;

/// Upper bound on the padded projection workspace (`b·d` f32 cells) one
/// `RegisterBatch` may demand. Vectors are padded to the batch's max
/// dimension, so without this cap a frame mixing one huge vector with
/// many tiny ones would force an allocation quadratic in frame size.
pub(crate) const MAX_BULK_CELLS: usize = 1 << 24; // 64 MiB of f32 workspace

/// The coding configuration a collection is created with — everything
/// recorded in the MANIFEST and needed to rebuild it from disk.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct CollectionSpec {
    pub scheme: Scheme,
    /// Bin width `w` (ignored by `OneBit`).
    pub w: f64,
    /// Projections per sketch.
    pub k: usize,
    /// Seed of the collection's virtual projection matrix.
    pub seed: u64,
    /// Projection matrix family (Gaussian or very-sparse ±1). Part of
    /// the coding identity — two collections differing only in kind
    /// produce incomparable sketches — so it is MANIFEST-recorded and
    /// drift-checked like scheme/w/k/seed.
    pub kind: MatrixKind,
}

impl CollectionSpec {
    pub fn coding(&self) -> CodingParams {
        CodingParams::new(self.scheme, self.w)
    }

    /// Bits per packed code this spec produces.
    pub fn bits(&self) -> u32 {
        self.coding().bits_per_code()
    }

    /// Reject shapes the serving stack cannot hold: `k` outside
    /// `[1, 2^20]`, or a lattice bin width outside `[1e-3, 1e3]` (tiny
    /// `w` explodes the bin count past what a `u16` code can index).
    pub fn validate(&self) -> crate::Result<()> {
        anyhow::ensure!(
            self.k >= 1 && self.k <= 1 << 20,
            "collection k {} outside [1, {}]",
            self.k,
            1usize << 20
        );
        match self.scheme {
            Scheme::OneBit => {}
            _ => anyhow::ensure!(
                self.w.is_finite() && (1e-3..=1e3).contains(&self.w),
                "scheme {} needs a bin width w in [1e-3, 1e3], got {}",
                self.scheme.label(),
                self.w
            ),
        }
        if let MatrixKind::SignSparse { s } = self.kind {
            anyhow::ensure!(s >= 1, "sign-sparse density parameter s must be >= 1");
        }
        Ok(())
    }

    /// Exact equality for MANIFEST validation (`w` compared bitwise).
    fn matches(&self, other: &CollectionSpec) -> bool {
        self.scheme == other.scheme
            && self.w.to_bits() == other.w.to_bits()
            && self.k == other.k
            && self.seed == other.seed
            && self.kind == other.kind
    }
}

/// Per-collection serving options — everything beyond the coding
/// identity: checkpoint cadence and the banded-index shape. Recorded in
/// the MANIFEST next to the spec so a restart reproduces both.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct CollectionOptions {
    /// Logged rows between automatic checkpoints for this collection;
    /// 0 falls back to the server's global `--checkpoint-every`.
    pub checkpoint_every: u64,
    /// Banded multi-probe index shape serving `ApproxTopK`.
    pub index: IndexConfig,
}

impl CollectionOptions {
    /// Defaults for a spec: global checkpoint cadence, index shape
    /// derived from the sketch shape.
    pub fn for_spec(spec: &CollectionSpec) -> CollectionOptions {
        CollectionOptions {
            checkpoint_every: 0,
            index: IndexConfig::for_shape(spec.k, spec.bits()),
        }
    }

    fn validate(&self, spec: &CollectionSpec) -> crate::Result<()> {
        self.index
            .validate(spec.k, crate::coding::supported_width(spec.bits()))
    }
}

/// Fused bulk-ingest state: one encoder (cached offsets + scratch) and
/// one word buffer, reused across `RegisterBatch` requests.
struct BulkIngest {
    encoder: BatchEncoder,
    words: Vec<u64>,
}

/// One named collection: projector + batcher + estimator + arena-backed
/// store (+ durability), all sharing one `(scheme, w, k, seed)` choice.
pub struct Collection {
    pub name: String,
    pub spec: CollectionSpec,
    pub options: CollectionOptions,
    pub k: usize,
    pub store: Arc<SketchStore>,
    pub estimator: CollisionEstimator,
    pub batcher: SketchBatcher,
    pub durability: Option<Arc<Durability>>,
    /// Nonzeros per sparse-ingested row (a count histogram — the "µs"
    /// of [`LatencyHistogram`] reads as "nonzeros" here). Only
    /// `RegisterSparse` traffic lands in it.
    pub ingest_nnz: LatencyHistogram,
    projector: Arc<Projector>,
    bulk: Mutex<BulkIngest>,
    metrics: Arc<Metrics>,
    /// Set when the collection is dropped from the registry; gates
    /// maintenance and checkpoints so a dropped collection can never
    /// resurrect files inside a directory its replacement now owns.
    dropped: AtomicBool,
}

impl Collection {
    #[allow(clippy::too_many_arguments)]
    fn open(
        name: &str,
        spec: CollectionSpec,
        options: CollectionOptions,
        projector: Arc<Projector>,
        epoch: EpochConfig,
        batcher_cfg: BatcherConfig,
        durability_cfg: Option<DurabilityConfig>,
        metrics: Arc<Metrics>,
        signal: Arc<DrainSignal>,
    ) -> crate::Result<Arc<Collection>> {
        spec.validate()?;
        options.validate(&spec)?;
        anyhow::ensure!(
            projector.cfg.k == spec.k && projector.cfg.seed == spec.seed,
            "projector shape (k={}, seed={}) does not match collection spec (k={}, seed={})",
            projector.cfg.k,
            projector.cfg.seed,
            spec.k,
            spec.seed
        );
        anyhow::ensure!(
            projector.cfg.kind == spec.kind,
            "projector matrix kind {} does not match collection spec kind {}",
            projector.cfg.kind,
            spec.kind
        );
        let coding = spec.coding();
        let batcher = SketchBatcher::spawn(
            projector.clone(),
            coding.clone(),
            batcher_cfg,
            metrics.clone(),
        );
        let bits = coding.bits_per_code();
        let store = Arc::new(SketchStore::with_arena_index(
            spec.k,
            bits,
            epoch,
            options.index,
        ));
        store.delegate_drains(signal);
        let durability = match durability_cfg {
            Some(dcfg) => {
                let (d, stats) = Durability::open(dcfg, &store)?;
                metrics.registered.fetch_add(stats.live, Ordering::Relaxed);
                Some(Arc::new(d))
            }
            None => None,
        };
        Ok(Arc::new(Collection {
            name: name.to_string(),
            spec,
            options,
            k: spec.k,
            estimator: CollisionEstimator::new(coding.clone()),
            batcher,
            store,
            durability,
            ingest_nnz: LatencyHistogram::default(),
            projector,
            bulk: Mutex::new(BulkIngest {
                encoder: BatchEncoder::new(coding, spec.k),
                words: Vec::new(),
            }),
            metrics,
            dropped: AtomicBool::new(false),
        }))
    }

    /// Whether this collection has been dropped from its registry.
    pub fn is_dropped(&self) -> bool {
        self.dropped.load(Ordering::Relaxed)
    }

    /// Wire-facing summary of this collection.
    pub fn info(&self) -> CollectionInfo {
        CollectionInfo {
            name: self.name.clone(),
            scheme: self.spec.scheme,
            w: self.spec.w,
            bits: self.spec.bits(),
            k: self.spec.k as u64,
            seed: self.spec.seed,
            rows: self.store.len() as u64,
            durable: self.durability.is_some(),
        }
    }

    /// Run the snapshot-then-truncate checkpoint if this collection is
    /// durable and still live. `Ok(None)` means nothing to checkpoint
    /// (in-memory or dropped).
    pub fn checkpoint(&self) -> crate::Result<Option<(u64, u64)>> {
        if self.is_dropped() {
            return Ok(None);
        }
        match &self.durability {
            Some(d) => d.checkpoint(&self.store).map(Some),
            None => Ok(None),
        }
    }

    fn estimate_response(&self, collisions: usize) -> Response {
        let rho = self.estimator.estimate_from_count(collisions, self.k);
        let v = self
            .estimator
            .params
            .scheme
            .variance_factor(rho.min(0.999), self.estimator.params.w);
        Response::Estimate {
            rho,
            std_err: (v / self.k as f64).sqrt(),
            p_hat: collisions as f64 / self.k as f64,
        }
    }

    /// Map scan results to wire hits (ρ̂ from the collision count).
    fn to_knn_hits(&self, hits: Vec<crate::scan::ScanHit>) -> Vec<KnnHit> {
        hits.into_iter()
            .map(|h| KnnHit {
                id: h.id,
                rho: self.estimator.estimate_from_count(h.collisions, self.k),
            })
            .collect()
    }

    /// Exact top-`n` hits for one query sketch, ranked
    /// `(collisions desc, id asc)`. Collection stores are always
    /// arena-backed, so the scan engine is the one ranking path.
    fn topk_hits(&self, q: &PackedCodes, n: usize) -> Vec<KnnHit> {
        let arena = self.store.arena().expect("collection store is arena-backed");
        self.to_knn_hits(arena.scan_topk(q, n, 0))
    }

    /// Store one sketch, WAL-first when durable: the record is flushed
    /// before the store mutates, so an acknowledged `Register` survives
    /// `kill -9`. An `Err` means nothing was applied.
    fn durable_put(&self, id: &str, codes: PackedCodes) -> crate::Result<()> {
        match &self.durability {
            Some(d) => d.log_put(id, &codes, || self.store.put(id.to_string(), codes.clone())),
            None => {
                self.store.put(id.to_string(), codes);
                Ok(())
            }
        }
    }

    pub(crate) fn register(&self, id: String, vector: Vec<f32>) -> Response {
        let t0 = Instant::now();
        match self.batcher.sketch(vector) {
            Ok(codes) => match self.durable_put(&id, codes) {
                Ok(()) => {
                    self.metrics.registered.fetch_add(1, Ordering::Relaxed);
                    let us = t0.elapsed().as_micros() as u64;
                    self.metrics.register_latency.record(us);
                    Response::Registered { id }
                }
                Err(e) => Response::Error {
                    message: format!("register failed: {e}"),
                },
            },
            Err(e) => Response::Error {
                message: format!("sketch failed: {e}"),
            },
        }
    }

    pub(crate) fn remove(&self, id: String) -> Response {
        let result = match &self.durability {
            Some(d) => d.log_remove(&id, || self.store.remove(&id)),
            None => Ok(self.store.remove(&id)),
        };
        match result {
            Ok(existed) => Response::Removed { existed },
            Err(e) => Response::Error {
                message: format!("remove failed: {e}"),
            },
        }
    }

    pub(crate) fn estimate(&self, a: String, b: String) -> Response {
        let (sa, sb) = (self.store.get(&a), self.store.get(&b));
        match (sa, sb) {
            (Some(sa), Some(sb)) => {
                self.metrics.estimates.fetch_add(1, Ordering::Relaxed);
                let collisions = crate::coding::collision_count_packed(&sa, &sb);
                self.estimate_response(collisions)
            }
            (None, _) => Response::Error {
                message: format!("unknown id {a:?}"),
            },
            (_, None) => Response::Error {
                message: format!("unknown id {b:?}"),
            },
        }
    }

    pub(crate) fn estimate_vec(&self, id: String, vector: Vec<f32>) -> Response {
        let Some(stored) = self.store.get(&id) else {
            return Response::Error {
                message: format!("unknown id {id:?}"),
            };
        };
        match self.batcher.sketch(vector) {
            Ok(q) => {
                self.metrics.estimates.fetch_add(1, Ordering::Relaxed);
                let collisions = crate::coding::collision_count_packed(&q, &stored);
                self.estimate_response(collisions)
            }
            Err(e) => Response::Error {
                message: format!("sketch failed: {e}"),
            },
        }
    }

    pub(crate) fn knn(&self, vector: Vec<f32>, n: u32) -> Response {
        match self.batcher.sketch(vector) {
            Ok(q) => {
                self.metrics.knn_queries.fetch_add(1, Ordering::Relaxed);
                Response::Knn {
                    hits: self.topk_hits(&q, n as usize),
                }
            }
            Err(e) => Response::Error {
                message: format!("sketch failed: {e}"),
            },
        }
    }

    pub(crate) fn topk(&self, vectors: Vec<Vec<f32>>, n: u32) -> Response {
        let mut queries = Vec::with_capacity(vectors.len());
        for vector in vectors {
            match self.batcher.sketch(vector) {
                Ok(q) => queries.push(q),
                Err(e) => {
                    return Response::Error {
                        message: format!("sketch failed: {e}"),
                    }
                }
            }
        }
        self.metrics
            .knn_queries
            .fetch_add(queries.len() as u64, Ordering::Relaxed);
        let arena = self.store.arena().expect("collection store is arena-backed");
        let results = arena
            .scan_topk_batch(&queries, n as usize, 0)
            .into_iter()
            .map(|hits| self.to_knn_hits(hits))
            .collect();
        Response::TopK { results }
    }

    /// Approximate batched top-k through the banded index: bucket
    /// candidates reranked through the exact kernels, pending rows
    /// swept exactly (see [`crate::scan::EpochArena::scan_topk_approx`]).
    /// `probes` 0 uses the collection's configured default.
    ///
    /// Also returns the total candidate rows reranked across the batch
    /// (0 when the exact fallback served it) so the connection loop can
    /// tag slow-query lines without re-deriving it.
    pub(crate) fn approx_topk(
        &self,
        vectors: Vec<Vec<f32>>,
        n: u32,
        probes: u32,
    ) -> (Response, u64) {
        let mut queries = Vec::with_capacity(vectors.len());
        for vector in vectors {
            match self.batcher.sketch(vector) {
                Ok(q) => queries.push(q),
                Err(e) => {
                    return (
                        Response::Error {
                            message: format!("sketch failed: {e}"),
                        },
                        0,
                    )
                }
            }
        }
        self.metrics
            .knn_queries
            .fetch_add(queries.len() as u64, Ordering::Relaxed);
        let probes = if probes == 0 {
            self.options.index.probes
        } else {
            probes as usize
        };
        let arena = self.store.arena().expect("collection store is arena-backed");
        let (batch, candidates) =
            arena.scan_topk_approx_batch_counted(&queries, n as usize, probes);
        let results = batch
            .into_iter()
            .map(|hits| self.to_knn_hits(hits))
            .collect();
        (Response::TopK { results }, candidates)
    }

    /// This collection's slice of the stats breakdown.
    pub fn stats(&self) -> crate::coordinator::protocol::CollectionStats {
        let arena = self.store.arena();
        crate::coordinator::protocol::CollectionStats {
            name: self.name.clone(),
            rows: self.store.len() as u64,
            pending_rows: arena.map(|a| a.pending_rows() as u64).unwrap_or(0),
            wal_bytes: self.durability.as_ref().map(|d| d.wal_bytes()).unwrap_or(0),
            index_buckets: arena.map(|a| a.index_buckets() as u64).unwrap_or(0),
        }
    }

    pub(crate) fn persist(&self) -> Response {
        match self.checkpoint() {
            Ok(Some((rows, wal_bytes))) => Response::Persisted { rows, wal_bytes },
            Ok(None) => Response::Error {
                message: format!(
                    "durability is not enabled for collection {:?} \
                     (serve with --data-dir or --snapshot/--wal-dir)",
                    self.name
                ),
            },
            Err(e) => Response::Error {
                message: format!("checkpoint failed: {e}"),
            },
        }
    }

    /// The fused bulk-ingest path: one batched projection, one
    /// encode+pack pass into a reused word buffer, one bulk arena
    /// insert. Sketches are byte-identical to per-vector `Register`
    /// (same projector, same coding, same packing).
    pub(crate) fn register_batch(&self, ids: Vec<String>, vectors: Vec<Vec<f32>>) -> Response {
        if ids.len() != vectors.len() {
            return Response::Error {
                message: format!(
                    "ids/vectors length mismatch ({} vs {})",
                    ids.len(),
                    vectors.len()
                ),
            };
        }
        if ids.is_empty() {
            return Response::RegisteredBatch { count: 0 };
        }
        let t0 = Instant::now();
        let b = vectors.len();
        let d = vectors.iter().map(|v| v.len()).max().unwrap_or(1).max(1);
        if b.saturating_mul(d) > MAX_BULK_CELLS {
            return Response::Error {
                message: format!(
                    "batch of {b} vectors padded to dim {d} exceeds the bulk \
                     workspace limit of {MAX_BULK_CELLS} cells"
                ),
            };
        }
        let x = self
            .projector
            .project_ragged(vectors.iter().map(|v| v.as_slice()), b);
        let stored = {
            let mut bulk = self.bulk.lock().unwrap();
            let BulkIngest { encoder, words } = &mut *bulk;
            encoder.encode_pack_batch_into(&x, b, words);
            let words: &[u64] = words;
            match &self.durability {
                // One WAL record, one flush, for the whole batch.
                Some(d) => d.log_put_rows(&ids, words, || self.store.put_rows(&ids, words)),
                None => self.store.put_rows(&ids, words),
            }
        };
        match stored {
            Ok(()) => {
                self.metrics.registered.fetch_add(b as u64, Ordering::Relaxed);
                self.metrics.batches_executed.fetch_add(1, Ordering::Relaxed);
                self.metrics.vectors_projected.fetch_add(b as u64, Ordering::Relaxed);
                // One amortized sample per vector, so the percentiles
                // weight bulk and per-request registrations equally.
                self.metrics
                    .register_latency
                    .record_n((t0.elapsed().as_micros() as u64 / b as u64).max(1), b as u64);
                Response::RegisteredBatch { count: b as u64 }
            }
            Err(e) => Response::Error {
                message: format!("bulk register failed: {e}"),
            },
        }
    }

    /// The sparse bulk-ingest path: each CSR row is projected at
    /// O(nnz·k) through the gather kernel (never densified), encoded,
    /// and packed into the same reused word buffer as
    /// [`Collection::register_batch`] — one WAL record, one bulk arena
    /// insert. Sketches are byte-identical to densifying the rows and
    /// calling `register_batch` (pinned by the sparse proptests).
    pub(crate) fn register_sparse(&self, ids: Vec<String>, csr: CsrMatrix) -> Response {
        if ids.len() != csr.rows() {
            return Response::Error {
                message: format!(
                    "ids/rows length mismatch ({} vs {})",
                    ids.len(),
                    csr.rows()
                ),
            };
        }
        if ids.is_empty() {
            return Response::RegisteredBatch { count: 0 };
        }
        let t0 = Instant::now();
        let b = csr.rows();
        // The sparse analogue of the dense workspace cap: the frame's
        // own size bounds nnz, but the projected output is b·k cells
        // regardless of sparsity, so both terms are guarded.
        if csr.nnz() > MAX_BULK_CELLS || b.saturating_mul(self.k) > MAX_BULK_CELLS {
            return Response::Error {
                message: format!(
                    "sparse batch of {b} rows / {} nonzeros exceeds the bulk \
                     workspace limit of {MAX_BULK_CELLS} cells",
                    csr.nnz()
                ),
            };
        }
        let stored = {
            let mut bulk = self.bulk.lock().unwrap();
            let BulkIngest { encoder, words } = &mut *bulk;
            encoder.encode_csr(&self.projector, &csr, words);
            let words: &[u64] = words;
            match &self.durability {
                Some(d) => d.log_put_rows(&ids, words, || self.store.put_rows(&ids, words)),
                None => self.store.put_rows(&ids, words),
            }
        };
        match stored {
            Ok(()) => {
                self.metrics.registered.fetch_add(b as u64, Ordering::Relaxed);
                self.metrics.batches_executed.fetch_add(1, Ordering::Relaxed);
                self.metrics.vectors_projected.fetch_add(b as u64, Ordering::Relaxed);
                self.metrics
                    .register_latency
                    .record_n((t0.elapsed().as_micros() as u64 / b as u64).max(1), b as u64);
                for row in 0..b {
                    let (idx, _) = csr.row(row);
                    self.ingest_nnz.record(idx.len() as u64);
                }
                Response::RegisteredBatch { count: b as u64 }
            }
            Err(e) => Response::Error {
                message: format!("sparse register failed: {e}"),
            },
        }
    }
}

/// How the registry builds its collections.
#[derive(Clone, Debug)]
pub struct RegistryConfig {
    /// Durable root (`<root>/MANIFEST` + per-collection directories);
    /// `None` keeps every collection in memory unless a legacy
    /// single-collection [`DurabilityConfig`] is supplied for `default`.
    pub root: Option<PathBuf>,
    /// Ingest-epoch drain/compaction policy for every collection arena.
    pub epoch: EpochConfig,
    /// Dynamic batching policy for every collection.
    pub batcher: BatcherConfig,
    /// Logged rows between automatic checkpoints (root mode).
    pub checkpoint_every: u64,
    /// WAL fsync policy (root mode).
    pub fsync: FsyncPolicy,
}

/// Named collections under one server process.
pub struct Registry {
    cfg: RegistryConfig,
    collections: RwLock<HashMap<String, Arc<Collection>>>,
    /// Serializes create/drop and every MANIFEST rewrite.
    admin_mu: Mutex<()>,
    signal: Arc<DrainSignal>,
    metrics: Arc<Metrics>,
}

impl Registry {
    /// Build the registry and its `default` collection. In root mode
    /// the MANIFEST is read first: an existing `default` entry must
    /// match the server's flags (scheme/w/k/seed drift would silently
    /// corrupt estimates), and every other recorded collection is
    /// rebuilt from its own snapshot + WAL.
    pub fn open(
        cfg: RegistryConfig,
        metrics: Arc<Metrics>,
        default_projector: Arc<Projector>,
        default_coding: CodingParams,
        legacy_durability: Option<DurabilityConfig>,
    ) -> crate::Result<Arc<Registry>> {
        anyhow::ensure!(
            cfg.root.is_none() || legacy_durability.is_none(),
            "--data-dir and legacy --snapshot/--wal-dir are mutually exclusive"
        );
        let default_spec = CollectionSpec {
            scheme: default_coding.scheme,
            w: default_coding.w,
            k: default_projector.cfg.k,
            seed: default_projector.cfg.seed,
            kind: default_projector.cfg.kind,
        };
        default_spec.validate()?;
        let reg = Arc::new(Registry {
            cfg,
            collections: RwLock::new(HashMap::new()),
            admin_mu: Mutex::new(()),
            signal: Arc::new(DrainSignal::default()),
            metrics,
        });
        let _admin = reg.admin_mu.lock().unwrap();
        match reg.cfg.root.clone() {
            Some(root) => {
                std::fs::create_dir_all(&root)?;
                let manifest = read_manifest(&manifest_path(&root))?;
                let mut default_opts = CollectionOptions::for_spec(&default_spec);
                if let Some((_, disk, opts)) =
                    manifest.iter().find(|(n, _, _)| n == DEFAULT_COLLECTION)
                {
                    anyhow::ensure!(
                        disk.matches(&default_spec),
                        "collection \"default\" on disk was created with \
                         scheme={} w={} k={} seed={}, but the server was started with \
                         scheme={} w={} k={} seed={} — restart with matching flags \
                         or use a fresh --data-dir",
                        disk.scheme.label(),
                        disk.w,
                        disk.k,
                        disk.seed,
                        default_spec.scheme.label(),
                        default_spec.w,
                        default_spec.k,
                        default_spec.seed
                    );
                    default_opts = *opts;
                }
                reg.install(
                    DEFAULT_COLLECTION,
                    default_spec,
                    default_opts,
                    Some(default_projector),
                )?;
                for (name, spec, opts) in manifest {
                    if name != DEFAULT_COLLECTION {
                        reg.install(&name, spec, opts, None)?;
                    }
                }
                // Records a freshly-minted default entry; a no-op
                // rewrite otherwise.
                reg.write_manifest_locked()?;
            }
            None => {
                let c = Collection::open(
                    DEFAULT_COLLECTION,
                    default_spec,
                    CollectionOptions::for_spec(&default_spec),
                    default_projector,
                    reg.cfg.epoch.clone(),
                    reg.cfg.batcher.clone(),
                    legacy_durability,
                    reg.metrics.clone(),
                    reg.signal.clone(),
                )?;
                let mut map = reg.collections.write().unwrap();
                map.insert(DEFAULT_COLLECTION.to_string(), c);
            }
        }
        drop(_admin);
        Ok(reg)
    }

    /// The drain signal shared by every collection store (the
    /// maintenance thread waits on it).
    pub fn signal(&self) -> Arc<DrainSignal> {
        self.signal.clone()
    }

    /// Durability config for `name` in root mode, `None` otherwise.
    /// A nonzero per-collection cadence overrides the global one.
    fn durability_for(&self, name: &str, opts: &CollectionOptions) -> Option<DurabilityConfig> {
        let every = if opts.checkpoint_every > 0 {
            opts.checkpoint_every
        } else {
            self.cfg.checkpoint_every
        };
        self.cfg.root.as_ref().map(|root| DurabilityConfig {
            snapshot: root.join(name).join("snap").join("snapshot.bin"),
            wal_dir: root.join(name).join("wal"),
            checkpoint_every: every,
            fsync: self.cfg.fsync,
        })
    }

    /// Build a collection and insert it (admin lock must be held).
    /// `projector` is `None` for collections that own a fresh CPU
    /// projector derived from their spec (everything but `default`).
    fn install(
        &self,
        name: &str,
        spec: CollectionSpec,
        options: CollectionOptions,
        projector: Option<Arc<Projector>>,
    ) -> crate::Result<Arc<Collection>> {
        let projector = match projector {
            Some(p) => p,
            None => Arc::new(Projector::new_cpu(ProjectionConfig {
                k: spec.k,
                seed: spec.seed,
                kind: spec.kind,
                ..Default::default()
            })),
        };
        let c = Collection::open(
            name,
            spec,
            options,
            projector,
            self.cfg.epoch.clone(),
            self.cfg.batcher.clone(),
            self.durability_for(name, &options),
            self.metrics.clone(),
            self.signal.clone(),
        )?;
        let mut map = self.collections.write().unwrap();
        map.insert(name.to_string(), c.clone());
        Ok(c)
    }

    /// Create a collection at runtime. In root mode any orphan
    /// directory left by a crashed drop is cleared first, the
    /// collection opens durable, and the MANIFEST is rewritten before
    /// the create is acknowledged.
    pub fn create(
        &self,
        name: &str,
        spec: CollectionSpec,
        options: CollectionOptions,
    ) -> crate::Result<Arc<Collection>> {
        validate_name(name)?;
        spec.validate()?;
        options.validate(&spec)?;
        let _admin = self.admin_mu.lock().unwrap();
        anyhow::ensure!(
            !self.collections.read().unwrap().contains_key(name),
            "collection {name:?} already exists"
        );
        if let Some(root) = &self.cfg.root {
            // Not in the registry, so anything on disk under this name
            // is garbage from a crashed drop — never replay it.
            let dir = root.join(name);
            if dir.exists() {
                std::fs::remove_dir_all(&dir)?;
            }
        }
        let c = self.install(name, spec, options, None)?;
        if let Err(e) = self.write_manifest_locked() {
            // Roll back: an unrecorded durable collection would collide
            // with a future create of the same name.
            c.dropped.store(true, Ordering::Relaxed);
            self.collections.write().unwrap().remove(name);
            if let Some(root) = &self.cfg.root {
                let _ = std::fs::remove_dir_all(root.join(name));
            }
            return Err(e);
        }
        Ok(c)
    }

    /// Drop a collection: unregister it (MANIFEST first), then delete
    /// its directory. Returns whether it existed. The `default`
    /// collection cannot be dropped.
    pub fn drop_collection(&self, name: &str) -> crate::Result<bool> {
        anyhow::ensure!(
            name != DEFAULT_COLLECTION,
            "the {DEFAULT_COLLECTION:?} collection cannot be dropped"
        );
        let _admin = self.admin_mu.lock().unwrap();
        let Some(c) = self.collections.write().unwrap().remove(name) else {
            return Ok(false);
        };
        c.dropped.store(true, Ordering::Relaxed);
        if self.cfg.root.is_some() {
            self.write_manifest_locked()?;
            // After this point a crash leaves at most an orphan
            // directory, cleared by the next create of this name.
            let dir = self.cfg.root.as_ref().unwrap().join(name);
            if dir.exists() {
                std::fs::remove_dir_all(&dir)?;
            }
        }
        Ok(true)
    }

    /// Rebuild a collection empty, in place — the replica-side
    /// re-bootstrap path (a forced snapshot reload must not replay on
    /// top of stale rows). The entry is swapped under the admin lock;
    /// requests that already resolved the old `Arc` finish against it
    /// (it is marked dropped so its background machinery stands down),
    /// and the rebuilt collection reuses the same spec, options, and
    /// projector. Refused in root mode: replicas are in-memory by
    /// construction, and resetting a durable collection would replay
    /// its own WAL straight back in.
    pub(crate) fn reset_collection(&self, name: &str) -> crate::Result<Arc<Collection>> {
        anyhow::ensure!(
            self.cfg.root.is_none(),
            "reset_collection is for in-memory replicas, not durable collections"
        );
        let _admin = self.admin_mu.lock().unwrap();
        let Some(old) = self.collections.read().unwrap().get(name).cloned() else {
            anyhow::bail!("collection {name:?} does not exist");
        };
        old.dropped.store(true, Ordering::Relaxed);
        self.install(name, old.spec, old.options, Some(old.projector.clone()))
    }

    pub fn get(&self, name: &str) -> Option<Arc<Collection>> {
        self.collections.read().unwrap().get(name).cloned()
    }

    /// All collections, sorted by name.
    pub fn list(&self) -> Vec<Arc<Collection>> {
        let mut out: Vec<Arc<Collection>> =
            self.collections.read().unwrap().values().cloned().collect();
        out.sort_by(|a, b| a.name.cmp(&b.name));
        out
    }

    pub fn len(&self) -> usize {
        self.collections.read().unwrap().len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Checkpoint every durable collection; `None` when no collection
    /// is durable. Sums `(rows, wal bytes retired)` — the legacy
    /// whole-server `Persist` semantics.
    pub fn checkpoint_all(&self) -> crate::Result<Option<(u64, u64)>> {
        let mut any = false;
        let (mut rows, mut bytes) = (0u64, 0u64);
        for c in self.list() {
            if let Some((r, b)) = c.checkpoint()? {
                any = true;
                rows += r;
                bytes += b;
            }
        }
        Ok(any.then_some((rows, bytes)))
    }

    /// Rewrite `<root>/MANIFEST` from the current collection set
    /// (admin lock must be held). No-op without a root.
    fn write_manifest_locked(&self) -> crate::Result<()> {
        let Some(root) = &self.cfg.root else {
            return Ok(());
        };
        let entries: Vec<(String, CollectionSpec, CollectionOptions)> = self
            .list()
            .iter()
            .map(|c| (c.name.clone(), c.spec, c.options))
            .collect();
        write_manifest(&manifest_path(root), &entries)
    }
}

/// Collection names double as directory names: restrict to a safe
/// charset and refuse path-meaningful or reserved spellings.
pub fn validate_name(name: &str) -> crate::Result<()> {
    anyhow::ensure!(!name.is_empty(), "collection name must not be empty");
    anyhow::ensure!(
        name.len() <= MAX_NAME,
        "collection name of {} bytes exceeds the {MAX_NAME}-byte cap",
        name.len()
    );
    anyhow::ensure!(
        name.bytes()
            .all(|b| b.is_ascii_alphanumeric() || b == b'_' || b == b'-' || b == b'.'),
        "collection name {name:?} has characters outside [A-Za-z0-9._-]"
    );
    anyhow::ensure!(
        name != "." && name != ".." && name != "MANIFEST",
        "collection name {name:?} is reserved"
    );
    Ok(())
}

fn manifest_path(root: &Path) -> PathBuf {
    root.join("MANIFEST")
}

/// Serialize the MANIFEST payload (entries sorted by name for
/// deterministic bytes):
///
/// ```text
/// magic "CRPMANI3" | u32 n |
///   n × ( u32 name_len | name | u8 scheme | f64 w | u32 bits | u64 k | u64 seed
///         | u64 checkpoint_every | u32 bands | u32 band_bits | u32 probes
///         | u8 kind | u32 kind_param )
/// | u32 crc32 (everything after the magic)
/// ```
///
/// `CRPMANI2` files (no matrix kind; defaults to Gaussian) and
/// `CRPMANI1` files (no per-entry options either) are still read.
fn write_manifest(
    path: &Path,
    entries: &[(String, CollectionSpec, CollectionOptions)],
) -> crate::Result<()> {
    let mut sorted: Vec<&(String, CollectionSpec, CollectionOptions)> = entries.iter().collect();
    sorted.sort_by(|a, b| a.0.cmp(&b.0));
    let mut payload = Vec::with_capacity(16 + entries.len() * 73);
    payload.extend_from_slice(&(sorted.len() as u32).to_le_bytes());
    for (name, spec, opts) in sorted {
        payload.extend_from_slice(&(name.len() as u32).to_le_bytes());
        payload.extend_from_slice(name.as_bytes());
        payload.push(spec.scheme.wire_code());
        payload.extend_from_slice(&spec.w.to_le_bytes());
        payload.extend_from_slice(&spec.bits().to_le_bytes());
        payload.extend_from_slice(&(spec.k as u64).to_le_bytes());
        payload.extend_from_slice(&spec.seed.to_le_bytes());
        payload.extend_from_slice(&opts.checkpoint_every.to_le_bytes());
        payload.extend_from_slice(&(opts.index.bands as u32).to_le_bytes());
        payload.extend_from_slice(&opts.index.band_bits.to_le_bytes());
        payload.extend_from_slice(&(opts.index.probes as u32).to_le_bytes());
        payload.push(spec.kind.code());
        payload.extend_from_slice(&spec.kind.param().to_le_bytes());
    }
    let mut bytes = Vec::with_capacity(12 + payload.len());
    bytes.extend_from_slice(MANIFEST_MAGIC);
    bytes.extend_from_slice(&payload);
    bytes.extend_from_slice(&crc32_update(0, &payload).to_le_bytes());
    let tmp = path.with_extension("tmp");
    std::fs::write(&tmp, &bytes)?;
    let f = std::fs::File::open(&tmp)?;
    f.sync_all()?;
    drop(f);
    std::fs::rename(&tmp, path)?;
    Ok(())
}

/// Read and CRC-check a MANIFEST (either version). A missing file is an
/// empty registry; a corrupt one is an error (silently dropping
/// collections would lose acknowledged data).
fn read_manifest(
    path: &Path,
) -> crate::Result<Vec<(String, CollectionSpec, CollectionOptions)>> {
    if !path.is_file() {
        return Ok(Vec::new());
    }
    let bytes = std::fs::read(path)?;
    anyhow::ensure!(
        bytes.len() >= MANIFEST_MAGIC.len() + 8
            && (&bytes[..8] == MANIFEST_MAGIC
                || &bytes[..8] == MANIFEST_MAGIC_V2
                || &bytes[..8] == MANIFEST_MAGIC_V1),
        "not a CRP registry MANIFEST: {}",
        path.display()
    );
    let v3 = &bytes[..8] == MANIFEST_MAGIC;
    let v2 = v3 || &bytes[..8] == MANIFEST_MAGIC_V2;
    let payload = &bytes[8..bytes.len() - 4];
    let want = u32::from_le_bytes(bytes[bytes.len() - 4..].try_into().unwrap());
    anyhow::ensure!(
        crc32_update(0, payload) == want,
        "MANIFEST checksum mismatch: {}",
        path.display()
    );
    struct Cur<'a> {
        buf: &'a [u8],
        pos: usize,
    }
    impl<'a> Cur<'a> {
        fn take(&mut self, n: usize) -> crate::Result<&'a [u8]> {
            anyhow::ensure!(self.pos + n <= self.buf.len(), "truncated MANIFEST");
            let s = &self.buf[self.pos..self.pos + n];
            self.pos += n;
            Ok(s)
        }
        fn u32(&mut self) -> crate::Result<u32> {
            Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
        }
        fn u64(&mut self) -> crate::Result<u64> {
            Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
        }
        fn f64(&mut self) -> crate::Result<f64> {
            Ok(f64::from_le_bytes(self.take(8)?.try_into().unwrap()))
        }
    }
    let mut c = Cur {
        buf: payload,
        pos: 0,
    };
    let n = c.u32()? as usize;
    anyhow::ensure!(n <= 1 << 16, "implausible MANIFEST entry count {n}");
    let mut out = Vec::with_capacity(n);
    for _ in 0..n {
        let name_len = c.u32()? as usize;
        anyhow::ensure!(name_len <= MAX_NAME, "implausible MANIFEST name length");
        let name = String::from_utf8(c.take(name_len)?.to_vec())?;
        let scheme_code = c.take(1)?[0];
        let scheme = Scheme::from_wire_code(scheme_code)
            .ok_or_else(|| anyhow::anyhow!("unknown MANIFEST scheme code {scheme_code}"))?;
        let w = c.f64()?;
        let bits = c.u32()?;
        let k = c.u64()? as usize;
        let seed = c.u64()?;
        let raw_opts = if v2 {
            Some((
                c.u64()?,
                IndexConfig {
                    bands: c.u32()? as usize,
                    band_bits: c.u32()?,
                    probes: c.u32()? as usize,
                },
            ))
        } else {
            None
        };
        let kind = if v3 {
            let code = c.take(1)?[0];
            let param = c.u32()?;
            MatrixKind::from_wire(code, param)?
        } else {
            MatrixKind::Gaussian
        };
        let spec = CollectionSpec {
            scheme,
            w,
            k,
            seed,
            kind,
        };
        spec.validate()?;
        anyhow::ensure!(
            bits == spec.bits(),
            "MANIFEST entry {name:?} records {bits} bit(s)/code but its scheme packs {}",
            spec.bits()
        );
        let opts = match raw_opts {
            Some((checkpoint_every, index)) => CollectionOptions {
                checkpoint_every,
                index,
            },
            None => CollectionOptions::for_spec(&spec),
        };
        opts.validate(&spec)?;
        out.push((name, spec, opts));
    }
    anyhow::ensure!(c.pos == payload.len(), "trailing MANIFEST bytes");
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn spec(scheme: Scheme, w: f64, k: usize, seed: u64) -> CollectionSpec {
        CollectionSpec {
            scheme,
            w,
            k,
            seed,
            kind: MatrixKind::Gaussian,
        }
    }

    fn temp_dir(tag: &str) -> PathBuf {
        let d = std::env::temp_dir().join(format!("crp_registry_{tag}_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&d);
        std::fs::create_dir_all(&d).unwrap();
        d
    }

    #[test]
    fn manifest_roundtrips_and_checks_crc() {
        let dir = temp_dir("manifest");
        let path = dir.join("MANIFEST");
        let custom_opts = CollectionOptions {
            checkpoint_every: 12_345,
            index: IndexConfig {
                bands: 8,
                band_bits: 16,
                probes: 4,
            },
        };
        let entries = vec![
            (
                "default".to_string(),
                spec(Scheme::TwoBit, 0.75, 256, 0),
                CollectionOptions::for_spec(&spec(Scheme::TwoBit, 0.75, 256, 0)),
            ),
            (
                "uni4".to_string(),
                spec(Scheme::Uniform, 1.0, 128, 11),
                custom_opts,
            ),
            (
                "signs".to_string(),
                spec(Scheme::OneBit, 0.0, 512, 7),
                CollectionOptions::for_spec(&spec(Scheme::OneBit, 0.0, 512, 7)),
            ),
            (
                "sparse-text".to_string(),
                CollectionSpec {
                    kind: MatrixKind::SignSparse { s: 128 },
                    ..spec(Scheme::TwoBit, 0.75, 64, 5)
                },
                CollectionOptions::for_spec(&spec(Scheme::TwoBit, 0.75, 64, 5)),
            ),
        ];
        write_manifest(&path, &entries).unwrap();
        let mut back = read_manifest(&path).unwrap();
        back.sort_by(|a, b| a.0.cmp(&b.0));
        let mut want = entries.clone();
        want.sort_by(|a, b| a.0.cmp(&b.0));
        assert_eq!(back.len(), 4);
        for ((bn, bs, bo), (wn, ws, wo)) in back.iter().zip(&want) {
            assert_eq!(bn, wn);
            assert!(bs.matches(ws), "{bn}");
            assert_eq!(bo, wo, "{bn}: options must round-trip");
        }
        // Missing file = empty registry, not an error.
        assert!(read_manifest(&dir.join("nope")).unwrap().is_empty());
        // A flipped byte is caught by the CRC.
        let mut bytes = std::fs::read(&path).unwrap();
        let mid = bytes.len() / 2;
        bytes[mid] ^= 0x10;
        std::fs::write(&path, &bytes).unwrap();
        assert!(read_manifest(&path).is_err());
        // Garbage is rejected by the magic.
        std::fs::write(&path, b"not a manifest").unwrap();
        assert!(read_manifest(&path).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }

    /// A PR-5..8 era `CRPMANI2` file (options but no matrix kind)
    /// still reads; the kind defaults to Gaussian.
    #[test]
    fn manifest_v2_files_still_read() {
        let dir = temp_dir("manifest_v2");
        let path = dir.join("MANIFEST");
        let s = spec(Scheme::Uniform, 1.0, 128, 11);
        let opts = CollectionOptions {
            checkpoint_every: 12_345,
            ..CollectionOptions::for_spec(&s)
        };
        let mut payload = Vec::new();
        payload.extend_from_slice(&1u32.to_le_bytes());
        payload.extend_from_slice(&4u32.to_le_bytes());
        payload.extend_from_slice(b"uni4");
        payload.push(s.scheme.wire_code());
        payload.extend_from_slice(&s.w.to_le_bytes());
        payload.extend_from_slice(&s.bits().to_le_bytes());
        payload.extend_from_slice(&(s.k as u64).to_le_bytes());
        payload.extend_from_slice(&s.seed.to_le_bytes());
        payload.extend_from_slice(&opts.checkpoint_every.to_le_bytes());
        payload.extend_from_slice(&(opts.index.bands as u32).to_le_bytes());
        payload.extend_from_slice(&opts.index.band_bits.to_le_bytes());
        payload.extend_from_slice(&(opts.index.probes as u32).to_le_bytes());
        let mut bytes = Vec::new();
        bytes.extend_from_slice(MANIFEST_MAGIC_V2);
        bytes.extend_from_slice(&payload);
        bytes.extend_from_slice(&crc32_update(0, &payload).to_le_bytes());
        std::fs::write(&path, &bytes).unwrap();
        let back = read_manifest(&path).unwrap();
        assert_eq!(back.len(), 1);
        assert_eq!(back[0].0, "uni4");
        assert!(back[0].1.matches(&s), "kind must default to Gaussian");
        assert_eq!(back[0].2, opts);
        std::fs::remove_dir_all(&dir).ok();
    }

    /// A PR-4 era `CRPMANI1` file (no per-entry options) still reads;
    /// options default from each entry's spec.
    #[test]
    fn manifest_v1_files_still_read() {
        let dir = temp_dir("manifest_v1");
        let path = dir.join("MANIFEST");
        let s = spec(Scheme::TwoBit, 0.75, 96, 3);
        let mut payload = Vec::new();
        payload.extend_from_slice(&1u32.to_le_bytes());
        payload.extend_from_slice(&4u32.to_le_bytes());
        payload.extend_from_slice(b"two2");
        payload.push(s.scheme.wire_code());
        payload.extend_from_slice(&s.w.to_le_bytes());
        payload.extend_from_slice(&s.bits().to_le_bytes());
        payload.extend_from_slice(&(s.k as u64).to_le_bytes());
        payload.extend_from_slice(&s.seed.to_le_bytes());
        let mut bytes = Vec::new();
        bytes.extend_from_slice(MANIFEST_MAGIC_V1);
        bytes.extend_from_slice(&payload);
        bytes.extend_from_slice(&crc32_update(0, &payload).to_le_bytes());
        std::fs::write(&path, &bytes).unwrap();
        let back = read_manifest(&path).unwrap();
        assert_eq!(back.len(), 1);
        assert_eq!(back[0].0, "two2");
        assert!(back[0].1.matches(&s));
        assert_eq!(back[0].2, CollectionOptions::for_spec(&s));
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn collection_names_are_validated() {
        for ok in ["a", "web-embeddings", "tier_2", "v1.3", "A9"] {
            validate_name(ok).unwrap_or_else(|e| panic!("{ok:?}: {e}"));
        }
        for bad in ["", ".", "..", "MANIFEST", "a/b", "a b", "ü", "x\0"] {
            assert!(validate_name(bad).is_err(), "{bad:?} must be rejected");
        }
        assert!(validate_name(&"n".repeat(MAX_NAME)).is_ok());
        assert!(validate_name(&"n".repeat(MAX_NAME + 1)).is_err());
    }

    #[test]
    fn spec_validation_bounds_shapes() {
        assert!(spec(Scheme::TwoBit, 0.75, 256, 0).validate().is_ok());
        assert!(spec(Scheme::OneBit, 0.0, 1, 0).validate().is_ok());
        assert!(spec(Scheme::Uniform, 1.0, 0, 0).validate().is_err());
        assert!(spec(Scheme::Uniform, 1.0, (1 << 20) + 1, 0).validate().is_err());
        assert!(spec(Scheme::Uniform, 0.0, 64, 0).validate().is_err());
        assert!(spec(Scheme::Uniform, f64::NAN, 64, 0).validate().is_err());
        assert!(spec(Scheme::WindowOffset, 1e-4, 64, 0).validate().is_err());
        assert!(spec(Scheme::TwoBit, 1e4, 64, 0).validate().is_err());
    }

    #[test]
    fn registry_create_drop_and_isolation_in_memory() {
        let metrics = Arc::new(Metrics::default());
        let projector = Arc::new(Projector::new_cpu(ProjectionConfig {
            k: 64,
            seed: 3,
            ..Default::default()
        }));
        let reg = Registry::open(
            RegistryConfig {
                root: None,
                epoch: EpochConfig::default(),
                batcher: BatcherConfig::default(),
                checkpoint_every: 0,
                fsync: FsyncPolicy::Os,
            },
            metrics,
            projector,
            CodingParams::new(Scheme::TwoBit, 0.75),
            None,
        )
        .unwrap();
        assert_eq!(reg.len(), 1);
        let s4 = spec(Scheme::Uniform, 1.0, 48, 9);
        let o4 = CollectionOptions::for_spec(&s4);
        let c = reg.create("uni4", s4, o4).unwrap();
        assert_eq!(c.spec.bits(), 4);
        assert!(c.store.arena().unwrap().has_index());
        assert!(reg.create("uni4", s4, o4).is_err());
        let s1 = spec(Scheme::OneBit, 0.0, 8, 0);
        assert!(reg
            .create("bad/name", s1, CollectionOptions::for_spec(&s1))
            .is_err());
        // An index shape that doesn't fit the sketch is rejected too.
        let bad_opts = CollectionOptions {
            checkpoint_every: 0,
            index: IndexConfig {
                bands: 64,
                band_bits: 12,
                probes: 2,
            },
        };
        assert!(reg.create("badidx", s4, bad_opts).is_err());
        assert!(reg.drop_collection(DEFAULT_COLLECTION).is_err());

        // Same id in two collections: fully isolated rows.
        let default = reg.get(DEFAULT_COLLECTION).unwrap();
        let uni4 = reg.get("uni4").unwrap();
        default.register("x".into(), vec![1.0; 16]);
        uni4.register("x".into(), vec![-1.0; 16]);
        assert_eq!(default.store.len(), 1);
        assert_eq!(uni4.store.len(), 1);
        uni4.remove("x".into());
        assert_eq!(default.store.len(), 1, "remove must not cross collections");
        assert!(default.store.get("x").is_some());

        assert!(reg.drop_collection("uni4").unwrap());
        assert!(!reg.drop_collection("uni4").unwrap());
        assert!(uni4.is_dropped());
        assert_eq!(reg.len(), 1);
        // In-memory registries have nothing to checkpoint.
        assert!(reg.checkpoint_all().unwrap().is_none());
    }

    /// Sparse ingest stores the exact words dense ingest would, the
    /// per-row nnz histogram fills, and the guards reject malformed or
    /// oversized batches.
    #[test]
    fn register_sparse_matches_dense_and_guards() {
        let metrics = Arc::new(Metrics::default());
        let projector = Arc::new(Projector::new_cpu(ProjectionConfig {
            k: 64,
            seed: 3,
            ..Default::default()
        }));
        let reg = Registry::open(
            RegistryConfig {
                root: None,
                epoch: EpochConfig::default(),
                batcher: BatcherConfig::default(),
                checkpoint_every: 0,
                fsync: FsyncPolicy::Os,
            },
            metrics,
            projector,
            CodingParams::new(Scheme::TwoBit, 0.75),
            None,
        )
        .unwrap();
        let c = reg.get(DEFAULT_COLLECTION).unwrap();
        let mut csr = CsrMatrix::with_capacity(2, 4, 50);
        csr.push_row(&[0, 7, 49], &[1.0, -2.0, 0.5]);
        csr.push_row(&[3], &[4.0]);
        let dense: Vec<Vec<f32>> = (0..2).map(|r| csr.row_dense(r)).collect();
        let r = c.register_sparse(vec!["s0".into(), "s1".into()], csr.clone());
        assert_eq!(r, Response::RegisteredBatch { count: 2 });
        let r = c.register_batch(vec!["d0".into(), "d1".into()], dense);
        assert_eq!(r, Response::RegisteredBatch { count: 2 });
        for (s, d) in [("s0", "d0"), ("s1", "d1")] {
            assert_eq!(c.store.get(s), c.store.get(d), "{s} vs {d}");
        }
        assert_eq!(c.ingest_nnz.count(), 2);
        // ids/rows mismatch errors; an empty batch is a zero-count ack.
        assert!(matches!(
            c.register_sparse(vec!["x".into()], csr),
            Response::Error { .. }
        ));
        assert_eq!(
            c.register_sparse(vec![], CsrMatrix::with_capacity(0, 0, 10)),
            Response::RegisteredBatch { count: 0 }
        );
        // A sign-sparse collection serves the same path end to end.
        let ss = CollectionSpec {
            kind: MatrixKind::SignSparse { s: 4 },
            ..spec(Scheme::OneBit, 0.0, 32, 9)
        };
        let sc = reg.create("signs", ss, CollectionOptions::for_spec(&ss)).unwrap();
        let mut m = CsrMatrix::with_capacity(1, 2, 20);
        m.push_row(&[2, 19], &[1.0, -1.0]);
        let densified = vec![m.row_dense(0)];
        sc.register_sparse(vec!["a".into()], m);
        sc.register_batch(vec!["b".into()], densified);
        assert_eq!(sc.store.get("a"), sc.store.get("b"));
    }
}
