//! Minimal hand-rolled HTTP endpoint for `GET /metrics`.
//!
//! Same spirit as the frame protocol: no HTTP crate, just enough of
//! HTTP/1.1 for Prometheus-style scrapers — read the request line,
//! drain headers, answer `200` with the rendered exposition text (or
//! `404` for any other path) and close. The listener polls a
//! nonblocking accept so [`MetricsEndpoint`] can be dropped cleanly
//! (tests, server shutdown) without a stray blocking thread.

use std::io::{BufRead, BufReader, ErrorKind, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::Duration;

use super::log;

/// Renders the exposition body on each scrape (a closure over the
/// server's metrics + registry, so scrapes always see live state).
pub type RenderFn = Arc<dyn Fn() -> String + Send + Sync>;

/// A background `/metrics` listener; dropping it stops the thread.
pub struct MetricsEndpoint {
    addr: SocketAddr,
    stop: Arc<AtomicBool>,
    handle: Option<JoinHandle<()>>,
}

impl MetricsEndpoint {
    /// Bind `addr` (e.g. `127.0.0.1:0`) and serve scrapes until drop.
    pub fn spawn(addr: &str, render: RenderFn) -> crate::Result<MetricsEndpoint> {
        let listener = TcpListener::bind(addr)?;
        listener.set_nonblocking(true)?;
        let addr = listener.local_addr()?;
        let stop = Arc::new(AtomicBool::new(false));
        let stop_thread = stop.clone();
        let handle = std::thread::Builder::new()
            .name("crp-metrics".into())
            .spawn(move || {
                while !stop_thread.load(Ordering::Relaxed) {
                    match listener.accept() {
                        Ok((stream, _)) => {
                            if let Err(e) = serve_one(stream, &render) {
                                log::debug(
                                    "crp::obs::http",
                                    "metrics scrape failed",
                                    &[("error", e.to_string())],
                                );
                            }
                        }
                        Err(e) if e.kind() == ErrorKind::WouldBlock => {
                            std::thread::sleep(Duration::from_millis(25));
                        }
                        Err(e) => {
                            log::warn(
                                "crp::obs::http",
                                "metrics accept failed",
                                &[("error", e.to_string())],
                            );
                            std::thread::sleep(Duration::from_millis(25));
                        }
                    }
                }
            })?;
        Ok(MetricsEndpoint {
            addr,
            stop,
            handle: Some(handle),
        })
    }

    /// The bound address (resolves `:0` to the chosen port).
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }
}

impl Drop for MetricsEndpoint {
    fn drop(&mut self) {
        self.stop.store(true, Ordering::Relaxed);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

/// Answer one scrape connection and close it.
fn serve_one(stream: TcpStream, render: &RenderFn) -> crate::Result<()> {
    // The listener is nonblocking; accepted sockets inherit that on
    // some platforms, so switch back and bound slow scrapers.
    stream.set_nonblocking(false)?;
    stream.set_read_timeout(Some(Duration::from_secs(2)))?;
    // A scraper that stops reading must not pin the accept thread (or the
    // shutdown join in Drop) on a blocked write_all.
    stream.set_write_timeout(Some(Duration::from_secs(2)))?;
    let mut reader = BufReader::new(stream);
    let mut request_line = String::new();
    reader.read_line(&mut request_line)?;
    // Drain headers until the blank line; their contents don't matter.
    loop {
        let mut header = String::new();
        if reader.read_line(&mut header)? == 0 || header == "\r\n" || header == "\n" {
            break;
        }
    }
    let mut parts = request_line.split_whitespace();
    let method = parts.next().unwrap_or("");
    let path = parts.next().unwrap_or("");
    let mut stream = reader.into_inner();
    if method == "GET" && (path == "/metrics" || path == "/metrics/") {
        let body = render();
        write!(
            stream,
            "HTTP/1.1 200 OK\r\nContent-Type: text/plain; version=0.0.4; charset=utf-8\r\n\
             Content-Length: {}\r\nConnection: close\r\n\r\n",
            body.len()
        )?;
        stream.write_all(body.as_bytes())?;
    } else {
        let body = "not found; scrape GET /metrics\n";
        write!(
            stream,
            "HTTP/1.1 404 Not Found\r\nContent-Type: text/plain; charset=utf-8\r\n\
             Content-Length: {}\r\nConnection: close\r\n\r\n{}",
            body.len(),
            body
        )?;
    }
    stream.flush()?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Read;

    fn scrape(addr: SocketAddr, path: &str) -> String {
        let mut s = TcpStream::connect(addr).unwrap();
        write!(s, "GET {path} HTTP/1.1\r\nHost: x\r\n\r\n").unwrap();
        let mut out = String::new();
        s.read_to_string(&mut out).unwrap();
        out
    }

    #[test]
    fn serves_metrics_and_404() {
        let render: RenderFn = Arc::new(|| "crp_up 1\n".to_string());
        let ep = MetricsEndpoint::spawn("127.0.0.1:0", render).unwrap();
        let addr = ep.addr();

        let ok = scrape(addr, "/metrics");
        assert!(ok.starts_with("HTTP/1.1 200 OK\r\n"), "{ok}");
        assert!(ok.contains("text/plain; version=0.0.4"), "{ok}");
        assert!(ok.ends_with("crp_up 1\n"), "{ok}");

        let missing = scrape(addr, "/other");
        assert!(missing.starts_with("HTTP/1.1 404"), "{missing}");

        // Drop must join the listener thread promptly (the accept loop
        // polls); a hang here fails the test by timeout.
        drop(ep);
    }

    #[test]
    fn renders_live_state_per_scrape() {
        use std::sync::atomic::AtomicU64;
        let n = Arc::new(AtomicU64::new(0));
        let n2 = n.clone();
        let render: RenderFn =
            Arc::new(move || format!("scrapes {}\n", n2.fetch_add(1, Ordering::Relaxed)));
        let ep = MetricsEndpoint::spawn("127.0.0.1:0", render).unwrap();
        assert!(scrape(ep.addr(), "/metrics").ends_with("scrapes 0\n"));
        assert!(scrape(ep.addr(), "/metrics").ends_with("scrapes 1\n"));
    }
}
