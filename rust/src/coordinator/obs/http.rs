//! Minimal hand-rolled HTTP endpoint for `GET /metrics`, plus the
//! `GET /healthz` / `GET /readyz` probes load balancers point at.
//!
//! Same spirit as the frame protocol: no HTTP crate, just enough of
//! HTTP/1.1 for Prometheus-style scrapers — read the request line,
//! drain headers, answer `200` with the rendered exposition text (or
//! `404` for any other path) and close. `/healthz` is liveness (always
//! `200` once the listener is up); `/readyz` asks the server's health
//! closure — `503` until recovery finishes and, on a replica, while
//! replication lag sits over the cap. The listener polls a nonblocking
//! accept so [`MetricsEndpoint`] can be dropped cleanly (tests, server
//! shutdown) without a stray blocking thread.

use std::io::{BufRead, BufReader, ErrorKind, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::Duration;

use super::log;

/// Renders the exposition body on each scrape (a closure over the
/// server's metrics + registry, so scrapes always see live state).
pub type RenderFn = Arc<dyn Fn() -> String + Send + Sync>;

/// Answers each `GET /readyz` probe: `(ready, detail)`. The detail
/// string becomes the response body either way, so `kubectl`-style
/// probing shows *why* a replica is not ready (still bootstrapping,
/// lag over cap), not just the 503.
pub type HealthFn = Arc<dyn Fn() -> (bool, String) + Send + Sync>;

/// A background `/metrics` listener; dropping it stops the thread.
pub struct MetricsEndpoint {
    addr: SocketAddr,
    stop: Arc<AtomicBool>,
    handle: Option<JoinHandle<()>>,
}

impl MetricsEndpoint {
    /// Bind `addr` (e.g. `127.0.0.1:0`) and serve scrapes + health
    /// probes until drop.
    pub fn spawn(addr: &str, render: RenderFn, health: HealthFn) -> crate::Result<MetricsEndpoint> {
        let listener = TcpListener::bind(addr)?;
        listener.set_nonblocking(true)?;
        let addr = listener.local_addr()?;
        let stop = Arc::new(AtomicBool::new(false));
        let stop_thread = stop.clone();
        let handle = std::thread::Builder::new()
            .name("crp-metrics".into())
            .spawn(move || {
                while !stop_thread.load(Ordering::Relaxed) {
                    match listener.accept() {
                        Ok((stream, _)) => {
                            if let Err(e) = serve_one(stream, &render, &health) {
                                log::debug(
                                    "crp::obs::http",
                                    "metrics scrape failed",
                                    &[("error", e.to_string())],
                                );
                            }
                        }
                        Err(e) if e.kind() == ErrorKind::WouldBlock => {
                            std::thread::sleep(Duration::from_millis(25));
                        }
                        Err(e) => {
                            log::warn(
                                "crp::obs::http",
                                "metrics accept failed",
                                &[("error", e.to_string())],
                            );
                            std::thread::sleep(Duration::from_millis(25));
                        }
                    }
                }
            })?;
        Ok(MetricsEndpoint {
            addr,
            stop,
            handle: Some(handle),
        })
    }

    /// The bound address (resolves `:0` to the chosen port).
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }
}

impl Drop for MetricsEndpoint {
    fn drop(&mut self) {
        self.stop.store(true, Ordering::Relaxed);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

fn plain(stream: &mut TcpStream, status: &str, body: &str) -> crate::Result<()> {
    write!(
        stream,
        "HTTP/1.1 {status}\r\nContent-Type: text/plain; charset=utf-8\r\n\
         Content-Length: {}\r\nConnection: close\r\n\r\n{}",
        body.len(),
        body
    )?;
    Ok(())
}

/// Answer one scrape connection and close it.
fn serve_one(stream: TcpStream, render: &RenderFn, health: &HealthFn) -> crate::Result<()> {
    // The listener is nonblocking; accepted sockets inherit that on
    // some platforms, so switch back and bound slow scrapers.
    stream.set_nonblocking(false)?;
    stream.set_read_timeout(Some(Duration::from_secs(2)))?;
    // A scraper that stops reading must not pin the accept thread (or the
    // shutdown join in Drop) on a blocked write_all.
    stream.set_write_timeout(Some(Duration::from_secs(2)))?;
    let mut reader = BufReader::new(stream);
    let mut request_line = String::new();
    reader.read_line(&mut request_line)?;
    // Drain headers until the blank line; their contents don't matter.
    loop {
        let mut header = String::new();
        if reader.read_line(&mut header)? == 0 || header == "\r\n" || header == "\n" {
            break;
        }
    }
    let mut parts = request_line.split_whitespace();
    let method = parts.next().unwrap_or("");
    let path = parts.next().unwrap_or("");
    let mut stream = reader.into_inner();
    if method == "GET" && (path == "/metrics" || path == "/metrics/") {
        let body = render();
        write!(
            stream,
            "HTTP/1.1 200 OK\r\nContent-Type: text/plain; version=0.0.4; charset=utf-8\r\n\
             Content-Length: {}\r\nConnection: close\r\n\r\n",
            body.len()
        )?;
        stream.write_all(body.as_bytes())?;
    } else if method == "GET" && (path == "/healthz" || path == "/healthz/") {
        // Liveness: reaching this code means the process accepts and
        // answers — unconditionally alive.
        plain(&mut stream, "200 OK", "ok\n")?;
    } else if method == "GET" && (path == "/readyz" || path == "/readyz/") {
        let (ready, detail) = health();
        let status = if ready { "200 OK" } else { "503 Service Unavailable" };
        plain(&mut stream, status, &format!("{detail}\n"))?;
    } else {
        plain(
            &mut stream,
            "404 Not Found",
            "not found; GET /metrics, /healthz, or /readyz\n",
        )?;
    }
    stream.flush()?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Read;

    fn scrape(addr: SocketAddr, path: &str) -> String {
        let mut s = TcpStream::connect(addr).unwrap();
        write!(s, "GET {path} HTTP/1.1\r\nHost: x\r\n\r\n").unwrap();
        let mut out = String::new();
        s.read_to_string(&mut out).unwrap();
        out
    }

    fn always_ready() -> HealthFn {
        Arc::new(|| (true, "ready".to_string()))
    }

    #[test]
    fn serves_metrics_and_404() {
        let render: RenderFn = Arc::new(|| "crp_up 1\n".to_string());
        let ep = MetricsEndpoint::spawn("127.0.0.1:0", render, always_ready()).unwrap();
        let addr = ep.addr();

        let ok = scrape(addr, "/metrics");
        assert!(ok.starts_with("HTTP/1.1 200 OK\r\n"), "{ok}");
        assert!(ok.contains("text/plain; version=0.0.4"), "{ok}");
        assert!(ok.ends_with("crp_up 1\n"), "{ok}");

        let missing = scrape(addr, "/other");
        assert!(missing.starts_with("HTTP/1.1 404"), "{missing}");

        // Drop must join the listener thread promptly (the accept loop
        // polls); a hang here fails the test by timeout.
        drop(ep);
    }

    #[test]
    fn renders_live_state_per_scrape() {
        use std::sync::atomic::AtomicU64;
        let n = Arc::new(AtomicU64::new(0));
        let n2 = n.clone();
        let render: RenderFn =
            Arc::new(move || format!("scrapes {}\n", n2.fetch_add(1, Ordering::Relaxed)));
        let ep = MetricsEndpoint::spawn("127.0.0.1:0", render, always_ready()).unwrap();
        assert!(scrape(ep.addr(), "/metrics").ends_with("scrapes 0\n"));
        assert!(scrape(ep.addr(), "/metrics").ends_with("scrapes 1\n"));
    }

    #[test]
    fn health_probes_track_the_closure() {
        let ready = Arc::new(AtomicBool::new(false));
        let r2 = ready.clone();
        let health: HealthFn = Arc::new(move || {
            if r2.load(Ordering::Relaxed) {
                (true, "ready".to_string())
            } else {
                (false, "replication lag over cap".to_string())
            }
        });
        let render: RenderFn = Arc::new(|| String::new());
        let ep = MetricsEndpoint::spawn("127.0.0.1:0", render, health).unwrap();

        // Liveness never depends on readiness.
        let live = scrape(ep.addr(), "/healthz");
        assert!(live.starts_with("HTTP/1.1 200 OK"), "{live}");

        // Not ready: 503 with the reason in the body.
        let not_ready = scrape(ep.addr(), "/readyz");
        assert!(not_ready.starts_with("HTTP/1.1 503"), "{not_ready}");
        assert!(not_ready.ends_with("replication lag over cap\n"), "{not_ready}");

        // Each probe re-asks the closure — flipping the state flips the
        // answer without restarting the endpoint.
        ready.store(true, Ordering::Relaxed);
        let now_ready = scrape(ep.addr(), "/readyz");
        assert!(now_ready.starts_with("HTTP/1.1 200 OK"), "{now_ready}");
        assert!(now_ready.ends_with("ready\n"), "{now_ready}");
    }
}
