//! Observability: full-path request histograms, Prometheus-style
//! exposition, structured logging, and slow-query tracing.
//!
//! Zero new dependencies, in the same hand-rolled spirit as the frame
//! protocol. Three submodules:
//!
//! * [`log`] — leveled `key=value` stderr lines behind one atomic gate
//!   (`--log-level` / `CRP_LOG`); replaces ad-hoc `eprintln!`s.
//! * [`expo`] — renders every counter, gauge, and histogram (global +
//!   per-collection, straight off the registry) in Prometheus text
//!   exposition format.
//! * [`http`] — a minimal `GET /metrics` listener serving that text
//!   (`crp serve --metrics-addr`).
//!
//! This module holds the shared request-side vocabulary: the
//! [`RequestKind`] classification, one [`LatencyHistogram`] per kind
//! ([`RequestHistograms`], recorded by the connection loop around the
//! whole decode→handle→write path), the routing metadata the server
//! hands back per request ([`ReqMeta`]), and the slow-query / trace
//! sampling knobs ([`ObsConfig`]). Instrumentation rides outside every
//! existing lock: recording is a handful of relaxed atomic adds after
//! the response is on the wire.

pub mod expo;
pub mod http;
pub mod log;

use std::sync::atomic::{AtomicU64, Ordering};

use super::metrics::LatencyHistogram;
use super::protocol::Request;

/// Request classification for per-kind latency histograms and log
/// lines. Data-path kinds get their own bucket; introspection and
/// collection admin share `Admin` (rare, never latency-critical).
#[derive(Clone, Copy, Debug, Eq, PartialEq)]
pub enum RequestKind {
    Register,
    RegisterBatch,
    RegisterSparse,
    Remove,
    Estimate,
    Knn,
    TopK,
    ApproxTopK,
    Persist,
    Admin,
}

/// Every kind, in exposition order.
pub const REQUEST_KINDS: [RequestKind; 10] = [
    RequestKind::Register,
    RequestKind::RegisterBatch,
    RequestKind::RegisterSparse,
    RequestKind::Remove,
    RequestKind::Estimate,
    RequestKind::Knn,
    RequestKind::TopK,
    RequestKind::ApproxTopK,
    RequestKind::Persist,
    RequestKind::Admin,
];

impl RequestKind {
    /// Stable label, shared by `/metrics` series, `StatsDetailed`
    /// per-request rows, and log lines.
    pub fn label(self) -> &'static str {
        match self {
            RequestKind::Register => "register",
            RequestKind::RegisterBatch => "register_batch",
            RequestKind::RegisterSparse => "register_sparse",
            RequestKind::Remove => "remove",
            RequestKind::Estimate => "estimate",
            RequestKind::Knn => "knn",
            RequestKind::TopK => "topk",
            RequestKind::ApproxTopK => "approx_topk",
            RequestKind::Persist => "persist",
            RequestKind::Admin => "admin",
        }
    }

    /// Classify a request. `Scoped` classifies as its inner request
    /// (the wrapper is routing, not work); `Estimate`/`EstimateVec`
    /// share a bucket (same code path, one id resolved differently).
    pub fn of(req: &Request) -> RequestKind {
        match req {
            Request::Scoped { inner, .. } => RequestKind::of(inner),
            Request::Register { .. } => RequestKind::Register,
            Request::RegisterBatch { .. } => RequestKind::RegisterBatch,
            Request::RegisterSparse { .. } => RequestKind::RegisterSparse,
            Request::Remove { .. } => RequestKind::Remove,
            Request::Estimate { .. } | Request::EstimateVec { .. } => RequestKind::Estimate,
            Request::Knn { .. } => RequestKind::Knn,
            Request::TopK { .. } => RequestKind::TopK,
            Request::ApproxTopK { .. } => RequestKind::ApproxTopK,
            Request::Persist => RequestKind::Persist,
            Request::Stats
            | Request::StatsDetailed
            | Request::Ping
            | Request::CreateCollection { .. }
            | Request::DropCollection { .. }
            | Request::ListCollections
            | Request::MetricsText
            | Request::ReplSync { .. }
            | Request::SlowQueries { .. }
            | Request::Promote => RequestKind::Admin,
        }
    }
}

/// One latency histogram per request kind: the full client-visible
/// path (frame decode → routing/handling → response encode + write),
/// recorded once per request by the connection loop.
#[derive(Debug, Default)]
pub struct RequestHistograms {
    register: LatencyHistogram,
    register_batch: LatencyHistogram,
    register_sparse: LatencyHistogram,
    remove: LatencyHistogram,
    estimate: LatencyHistogram,
    knn: LatencyHistogram,
    topk: LatencyHistogram,
    approx_topk: LatencyHistogram,
    persist: LatencyHistogram,
    admin: LatencyHistogram,
}

impl RequestHistograms {
    pub fn hist(&self, kind: RequestKind) -> &LatencyHistogram {
        match kind {
            RequestKind::Register => &self.register,
            RequestKind::RegisterBatch => &self.register_batch,
            RequestKind::RegisterSparse => &self.register_sparse,
            RequestKind::Remove => &self.remove,
            RequestKind::Estimate => &self.estimate,
            RequestKind::Knn => &self.knn,
            RequestKind::TopK => &self.topk,
            RequestKind::ApproxTopK => &self.approx_topk,
            RequestKind::Persist => &self.persist,
            RequestKind::Admin => &self.admin,
        }
    }
}

/// What routing learned about one request — inputs for the connection
/// loop's recording, slow-query, and trace decisions.
#[derive(Debug)]
pub struct ReqMeta {
    pub kind: RequestKind,
    /// Explicit collection of a `Scoped` request; `None` for legacy
    /// frames (routed to `default`).
    pub collection: Option<String>,
    /// Candidate rows reranked by an `ApproxTopK` request, summed over
    /// its query batch (0 when the exact fallback served it; `None`
    /// for every other kind).
    pub candidates: Option<u64>,
}

/// Per-server slow-query / trace knobs. Sampling costs one relaxed
/// `fetch_add` when tracing is on and nothing when off.
#[derive(Debug)]
pub struct ObsConfig {
    /// Requests at least this slow end-to-end (µs) emit one structured
    /// slow-query line; 0 disables.
    pub slow_query_us: u64,
    /// Every Nth request emits a trace line; 0 disables.
    pub trace_sample: u64,
    seq: AtomicU64,
}

impl ObsConfig {
    pub fn new(slow_query_us: u64, trace_sample: u64) -> ObsConfig {
        ObsConfig {
            slow_query_us,
            trace_sample,
            seq: AtomicU64::new(0),
        }
    }

    /// Trace-sampling decision: true for the first request and every
    /// `trace_sample`-th after it.
    pub fn should_trace(&self) -> bool {
        if self.trace_sample == 0 {
            return false;
        }
        self.seq.fetch_add(1, Ordering::Relaxed) % self.trace_sample == 0
    }
}

/// The shared field list for slow-query and trace lines: identity plus
/// the decode→handle→write stage breakdown the connection loop timed.
pub fn stage_fields(
    meta: &ReqMeta,
    total_us: u64,
    decode_us: u64,
    handle_us: u64,
    write_us: u64,
) -> Vec<(&'static str, String)> {
    let mut fields = vec![
        ("kind", meta.kind.label().to_string()),
        (
            "collection",
            meta.collection.clone().unwrap_or_else(|| "default".into()),
        ),
        ("total_us", total_us.to_string()),
        ("decode_us", decode_us.to_string()),
        ("handle_us", handle_us.to_string()),
        ("write_us", write_us.to_string()),
    ];
    if let Some(c) = meta.candidates {
        fields.push(("candidates", c.to_string()));
    }
    fields
}

/// Entries retained by the slow-query ring before the oldest is
/// evicted — small enough to serve over the wire in one frame, large
/// enough to hold a burst.
pub const SLOW_RING_CAP: usize = 128;

/// A bounded ring of the most recent slow queries, retained in memory
/// so `crp slow` can fetch them over the protocol after the stderr
/// lines have scrolled away. Pushes happen on the connection loop's
/// slow path only (the query already blew the threshold), so one short
/// mutex hold is lost in the noise; readers copy the entries out under
/// the same lock — a snapshot can never observe a half-written entry.
#[derive(Debug, Default)]
pub struct SlowQueryRing {
    seq: AtomicU64,
    entries: std::sync::Mutex<std::collections::VecDeque<super::protocol::SlowQueryEntry>>,
}

impl SlowQueryRing {
    /// Record one slow query; evicts the oldest entry past
    /// [`SLOW_RING_CAP`]. Returns the entry's ring sequence number
    /// (monotone across evictions).
    pub fn push(&self, kind: RequestKind, collection: &str, total_us: u64, candidates: u64) -> u64 {
        let mut ring = self.entries.lock().unwrap();
        // Seq allocation happens under the lock so a snapshot's entries
        // are always strictly ordered by seq, even under racing pushers.
        let seq = self.seq.fetch_add(1, Ordering::Relaxed);
        if ring.len() >= SLOW_RING_CAP {
            ring.pop_front();
        }
        ring.push_back(super::protocol::SlowQueryEntry {
            seq,
            kind: kind.label().to_string(),
            collection: collection.to_string(),
            total_us,
            candidates,
        });
        seq
    }

    /// The most recent `max` entries, oldest first (`max` 0 = all).
    pub fn entries(&self, max: u32) -> Vec<super::protocol::SlowQueryEntry> {
        let ring = self.entries.lock().unwrap();
        let skip = if max == 0 {
            0
        } else {
            ring.len().saturating_sub(max as usize)
        };
        ring.iter().skip(skip).cloned().collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn classification_covers_every_kind() {
        assert_eq!(
            RequestKind::of(&Request::Register {
                id: "x".into(),
                vector: vec![]
            }),
            RequestKind::Register
        );
        assert_eq!(
            RequestKind::of(&Request::RegisterBatch {
                ids: vec![],
                vectors: vec![]
            }),
            RequestKind::RegisterBatch
        );
        assert_eq!(
            RequestKind::of(&Request::RegisterSparse {
                ids: vec![],
                csr: crate::data::sparse::CsrMatrix::with_capacity(0, 0, 4)
            }),
            RequestKind::RegisterSparse
        );
        assert_eq!(
            RequestKind::of(&Request::Remove { id: "x".into() }),
            RequestKind::Remove
        );
        assert_eq!(
            RequestKind::of(&Request::Estimate {
                a: "a".into(),
                b: "b".into()
            }),
            RequestKind::Estimate
        );
        assert_eq!(
            RequestKind::of(&Request::EstimateVec {
                id: "a".into(),
                vector: vec![]
            }),
            RequestKind::Estimate
        );
        assert_eq!(
            RequestKind::of(&Request::Knn {
                vector: vec![],
                n: 1
            }),
            RequestKind::Knn
        );
        assert_eq!(
            RequestKind::of(&Request::TopK {
                vectors: vec![],
                n: 1
            }),
            RequestKind::TopK
        );
        assert_eq!(
            RequestKind::of(&Request::ApproxTopK {
                vectors: vec![],
                n: 1,
                probes: 0
            }),
            RequestKind::ApproxTopK
        );
        assert_eq!(RequestKind::of(&Request::Persist), RequestKind::Persist);
        for admin in [
            Request::Stats,
            Request::StatsDetailed,
            Request::Ping,
            Request::ListCollections,
            Request::MetricsText,
            Request::DropCollection { name: "c".into() },
            Request::ReplSync {
                collection: "c".into(),
                replica: "r".into(),
                segment: 1,
                offset: 16,
            },
            Request::SlowQueries { max: 0 },
            Request::Promote,
        ] {
            assert_eq!(RequestKind::of(&admin), RequestKind::Admin, "{admin:?}");
        }
    }

    #[test]
    fn slow_ring_bounds_orders_and_trims() {
        let ring = SlowQueryRing::default();
        for i in 0..(SLOW_RING_CAP as u64 + 10) {
            ring.push(RequestKind::Knn, "default", 1000 + i, 0);
        }
        let all = ring.entries(0);
        assert_eq!(all.len(), SLOW_RING_CAP, "oldest entries evicted");
        // Oldest-first and contiguous: eviction dropped exactly the
        // first 10 sequence numbers.
        assert_eq!(all[0].seq, 10);
        assert!(all.windows(2).all(|w| w[1].seq == w[0].seq + 1));
        // A bounded fetch returns the most recent tail, still oldest
        // first.
        let tail = ring.entries(3);
        assert_eq!(tail.len(), 3);
        assert_eq!(tail[2].seq, all.last().unwrap().seq);
        assert!(ring.entries(9999).len() == SLOW_RING_CAP);
    }

    #[test]
    fn scoped_classifies_as_inner() {
        let scoped = Request::Scoped {
            collection: "c".into(),
            inner: Box::new(Request::Knn {
                vector: vec![],
                n: 3,
            }),
        };
        assert_eq!(RequestKind::of(&scoped), RequestKind::Knn);
    }

    #[test]
    fn histograms_are_per_kind() {
        let h = RequestHistograms::default();
        h.hist(RequestKind::Knn).record(100);
        h.hist(RequestKind::Knn).record(200);
        h.hist(RequestKind::Persist).record(5_000_000);
        assert_eq!(h.hist(RequestKind::Knn).count(), 2);
        assert_eq!(h.hist(RequestKind::Persist).count(), 1);
        assert_eq!(h.hist(RequestKind::TopK).count(), 0);
        // Labels are unique (they name exposition series).
        let mut labels: Vec<_> = REQUEST_KINDS.iter().map(|k| k.label()).collect();
        labels.sort_unstable();
        labels.dedup();
        assert_eq!(labels.len(), REQUEST_KINDS.len());
    }

    #[test]
    fn trace_sampling() {
        let off = ObsConfig::new(0, 0);
        assert!(!off.should_trace());

        let every = ObsConfig::new(0, 1);
        assert!((0..10).all(|_| every.should_trace()));

        let third = ObsConfig::new(0, 3);
        let hits: Vec<bool> = (0..9).map(|_| third.should_trace()).collect();
        assert_eq!(
            hits,
            [true, false, false, true, false, false, true, false, false]
        );
    }

    #[test]
    fn stage_fields_include_candidates_only_when_known() {
        let meta = ReqMeta {
            kind: RequestKind::ApproxTopK,
            collection: Some("web".into()),
            candidates: Some(42),
        };
        let fields = stage_fields(&meta, 100, 1, 98, 1);
        assert!(fields.contains(&("kind", "approx_topk".into())));
        assert!(fields.contains(&("collection", "web".into())));
        assert!(fields.contains(&("candidates", "42".into())));

        let meta = ReqMeta {
            kind: RequestKind::Knn,
            collection: None,
            candidates: None,
        };
        let fields = stage_fields(&meta, 100, 1, 98, 1);
        assert!(fields.contains(&("collection", "default".into())));
        assert!(!fields.iter().any(|(k, _)| *k == "candidates"));
    }
}
