//! Leveled, structured, lock-cheap logging.
//!
//! One line per event on stderr, machine-parsable `key=value` fields:
//!
//! ```text
//! ts_us=1754650000123456 level=warn target=crp::server msg="slow query" kind=topk total_us=125000
//! ```
//!
//! The level gate is a single relaxed atomic load, so disabled levels
//! cost one branch on the hot path. Values that are not bare tokens are
//! quoted with `\"`/`\\`/`\n`/`\r` escapes, so a line always splits on
//! spaces outside quotes. Level comes from `--log-level` (wins) or the
//! `CRP_LOG` env var via [`init_from_env`]; default `info`.
//!
//! The threshold is **process-global** (one static, like stderr
//! itself): every server and connection thread in the process shares
//! it, and the last [`set_level`] wins. Library embedders running
//! several servers in one process should configure the level once at
//! startup rather than per [`ServerConfig`](super::super::server::ServerConfig);
//! in-process tests that pass `log_level` only steer stderr noise and
//! must not assert on another server's emission.

use std::io::Write;
use std::sync::atomic::{AtomicU8, Ordering};
use std::time::{SystemTime, UNIX_EPOCH};

/// Severity, ordered most- to least-severe. `enabled` admits a level
/// iff it is at or above the global threshold.
#[derive(Clone, Copy, Debug, Eq, Ord, PartialEq, PartialOrd)]
pub enum Level {
    Error = 0,
    Warn = 1,
    Info = 2,
    Debug = 3,
}

impl Level {
    pub fn label(self) -> &'static str {
        match self {
            Level::Error => "error",
            Level::Warn => "warn",
            Level::Info => "info",
            Level::Debug => "debug",
        }
    }

    pub fn parse(s: &str) -> crate::Result<Level> {
        Ok(match s.to_ascii_lowercase().as_str() {
            "error" => Level::Error,
            "warn" | "warning" => Level::Warn,
            "info" => Level::Info,
            "debug" => Level::Debug,
            other => anyhow::bail!("unknown log level {other:?} (error|warn|info|debug)"),
        })
    }
}

/// Global threshold; `info` until [`set_level`] / [`init_from_env`].
static LEVEL: AtomicU8 = AtomicU8::new(Level::Info as u8);

/// Set the process-global threshold. Shared by every server in the
/// process — last writer wins (see the module docs).
pub fn set_level(level: Level) {
    LEVEL.store(level as u8, Ordering::Relaxed);
}

pub fn level() -> Level {
    match LEVEL.load(Ordering::Relaxed) {
        0 => Level::Error,
        1 => Level::Warn,
        2 => Level::Info,
        _ => Level::Debug,
    }
}

/// Whether lines at `level` are currently emitted.
pub fn enabled(level: Level) -> bool {
    (level as u8) <= LEVEL.load(Ordering::Relaxed)
}

/// Set the global level from an explicit flag value (wins) or the
/// `CRP_LOG` env var; leaves the default in place when neither is set.
pub fn init_from_env(flag: Option<&str>) -> crate::Result<()> {
    let chosen = match flag {
        Some(s) => Some(Level::parse(s)?),
        None => match std::env::var("CRP_LOG") {
            Ok(s) => Some(Level::parse(&s)?),
            Err(_) => None,
        },
    };
    if let Some(l) = chosen {
        set_level(l);
    }
    Ok(())
}

pub fn error(target: &str, msg: &str, fields: &[(&str, String)]) {
    emit(Level::Error, target, msg, fields);
}

pub fn warn(target: &str, msg: &str, fields: &[(&str, String)]) {
    emit(Level::Warn, target, msg, fields);
}

pub fn info(target: &str, msg: &str, fields: &[(&str, String)]) {
    emit(Level::Info, target, msg, fields);
}

pub fn debug(target: &str, msg: &str, fields: &[(&str, String)]) {
    emit(Level::Debug, target, msg, fields);
}

fn emit(level: Level, target: &str, msg: &str, fields: &[(&str, String)]) {
    if !enabled(level) {
        return;
    }
    let ts_us = SystemTime::now()
        .duration_since(UNIX_EPOCH)
        .map(|d| d.as_micros())
        .unwrap_or(0);
    let line = format_line(level, target, msg, fields, ts_us);
    // One locked write per line keeps concurrent connection threads
    // from interleaving fields.
    let mut err = std::io::stderr().lock();
    let _ = writeln!(err, "{line}");
}

/// Pure formatter (separated from `emit` so tests never race the
/// global level or capture stderr).
pub fn format_line(
    level: Level,
    target: &str,
    msg: &str,
    fields: &[(&str, String)],
    ts_us: u128,
) -> String {
    let mut out = String::with_capacity(96 + 24 * fields.len());
    out.push_str("ts_us=");
    out.push_str(&ts_us.to_string());
    out.push_str(" level=");
    out.push_str(level.label());
    out.push_str(" target=");
    out.push_str(target);
    out.push_str(" msg=");
    out.push_str(&quote(msg));
    for (k, v) in fields {
        out.push(' ');
        out.push_str(k);
        out.push('=');
        out.push_str(&quote(v));
    }
    out
}

/// Bare tokens pass through; anything else is quoted with
/// backslash-escaped `"` `\` and newlines, so consumers can split a
/// line on spaces outside quotes.
pub fn quote(s: &str) -> String {
    let bare = !s.is_empty()
        && s.bytes().all(|b| {
            b.is_ascii_alphanumeric()
                || matches!(b, b'.' | b'_' | b':' | b'/' | b'+' | b'-' | b',' | b'%' | b'#')
        });
    if bare {
        return s.to_string();
    }
    let mut out = String::with_capacity(s.len() + 2);
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            _ => out.push(c),
        }
    }
    out.push('"');
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_levels() {
        assert_eq!(Level::parse("error").unwrap(), Level::Error);
        assert_eq!(Level::parse("WARN").unwrap(), Level::Warn);
        assert_eq!(Level::parse("warning").unwrap(), Level::Warn);
        assert_eq!(Level::parse("Info").unwrap(), Level::Info);
        assert_eq!(Level::parse("debug").unwrap(), Level::Debug);
        assert!(Level::parse("trace").is_err());
        assert!(Level::parse("").is_err());
    }

    #[test]
    fn severity_orders() {
        assert!(Level::Error < Level::Warn);
        assert!(Level::Warn < Level::Info);
        assert!(Level::Info < Level::Debug);
    }

    #[test]
    fn quoting() {
        assert_eq!(
            quote("bare_token-1.2:3/x+y,z%p#q"),
            "bare_token-1.2:3/x+y,z%p#q"
        );
        assert_eq!(quote(""), "\"\"");
        assert_eq!(quote("two words"), "\"two words\"");
        assert_eq!(quote("a\"b"), "\"a\\\"b\"");
        assert_eq!(quote("a\\b"), "\"a\\\\b\"");
        assert_eq!(quote("a\nb"), "\"a\\nb\"");
        assert_eq!(quote("a\rb"), "\"a\\rb\"");
        assert_eq!(quote("résumé"), "\"résumé\"");
    }

    #[test]
    fn line_format() {
        let line = format_line(
            Level::Warn,
            "crp::server",
            "slow query",
            &[
                ("kind", "topk".to_string()),
                ("total_us", "125000".to_string()),
            ],
            42,
        );
        assert_eq!(
            line,
            "ts_us=42 level=warn target=crp::server msg=\"slow query\" kind=topk total_us=125000"
        );
    }

    #[test]
    fn line_format_no_fields() {
        let line = format_line(Level::Info, "crp", "up", &[], 7);
        assert_eq!(line, "ts_us=7 level=info target=crp msg=up");
    }
}
