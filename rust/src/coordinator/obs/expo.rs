//! Prometheus text exposition, rendered on demand.
//!
//! [`render`] walks the live [`Metrics`] and [`Registry`] and prints
//! every counter, gauge, and histogram in the text format Prometheus
//! scrapes (`text/plain; version=0.0.4`): `# TYPE` headers, cumulative
//! `_bucket{le="..."}` series ending at `+Inf`, and `_sum`/`_count`
//! pairs. Rendering takes no engine lock beyond the registry's brief
//! read-lock for the collection list — every number is a relaxed
//! atomic load off state the hot paths were already maintaining.
//!
//! Histogram buckets mirror [`LatencyHistogram`]: bucket `i` covers
//! `[2^i, 2^(i+1))` µs, so the exported `le` bounds are the powers of
//! two `2, 4, 8, ...` up to `2^31`, then `+Inf` for the unbounded tail.

use std::fmt::Write as _;
use std::sync::atomic::Ordering;

use crate::coordinator::metrics::{LatencyHistogram, Metrics};
use crate::coordinator::registry::Registry;
use crate::coordinator::replication::ReplicaState;
use crate::scan::EngineHist;

use super::REQUEST_KINDS;

/// One fully-labeled histogram block: cumulative buckets, `_sum`,
/// `_count`. `labels` is the rendered label set without braces
/// (`collection="web"`), empty for a bare series.
fn hist_block(out: &mut String, name: &str, labels: &str, counts: &[u64; 32], sum: u64) {
    let sep = if labels.is_empty() { "" } else { "," };
    let mut cum = 0u64;
    for (i, c) in counts.iter().enumerate().take(31) {
        cum += c;
        let le = 1u64 << (i + 1);
        let _ = writeln!(out, "{name}_bucket{{{labels}{sep}le=\"{le}\"}} {cum}");
    }
    cum += counts[31];
    let _ = writeln!(out, "{name}_bucket{{{labels}{sep}le=\"+Inf\"}} {cum}");
    gauge(out, &format!("{name}_sum"), labels, sum);
    gauge(out, &format!("{name}_count"), labels, cum);
}

fn type_line(out: &mut String, name: &str, kind: &str) {
    let _ = writeln!(out, "# TYPE {name} {kind}");
}

fn latency_hist(out: &mut String, name: &str, labels: &str, h: &LatencyHistogram) {
    hist_block(out, name, labels, &h.bucket_counts(), h.sum_us());
}

fn engine_hist(out: &mut String, name: &str, labels: &str, h: &EngineHist) {
    hist_block(out, name, labels, &h.bucket_counts(), h.sum());
}

fn gauge(out: &mut String, name: &str, labels: &str, v: u64) {
    if labels.is_empty() {
        let _ = writeln!(out, "{name} {v}");
    } else {
        let _ = writeln!(out, "{name}{{{labels}}} {v}");
    }
}

/// Render the full exposition page. Called per scrape (`GET /metrics`)
/// and per `MetricsText` protocol request. `replica` adds the
/// replication-lag series on a replicating server (`None` on a
/// primary: the series are absent, not zero, so dashboards can tell
/// "caught up" from "not a replica").
pub fn render(metrics: &Metrics, registry: &Registry, replica: Option<&ReplicaState>) -> String {
    let mut out = String::with_capacity(16 * 1024);

    if let Some(r) = replica {
        for (name, v) in [
            ("crp_replication_lag_bytes", r.lag_bytes()),
            ("crp_replication_lag_records", r.lag_records()),
            ("crp_replication_active", u64::from(r.is_active())),
        ] {
            type_line(&mut out, name, "gauge");
            gauge(&mut out, name, "", v);
        }
        type_line(&mut out, "crp_replication_lag_seconds", "gauge");
        let _ = writeln!(out, "crp_replication_lag_seconds {:.6}", r.lag_seconds());
        for (name, v) in [
            ("crp_replication_bootstraps_total", r.bootstraps()),
            ("crp_replication_reconnects_total", r.reconnects()),
        ] {
            type_line(&mut out, name, "counter");
            gauge(&mut out, name, "", v);
        }
    }

    // Global counters.
    for (name, v) in [
        ("crp_registered_total", &metrics.registered),
        ("crp_estimates_total", &metrics.estimates),
        ("crp_knn_queries_total", &metrics.knn_queries),
        ("crp_batches_executed_total", &metrics.batches_executed),
        ("crp_vectors_projected_total", &metrics.vectors_projected),
        ("crp_maintenance_wakeups_total", &metrics.maintenance_wakeups),
        ("crp_slow_queries_total", &metrics.slow_queries),
    ] {
        type_line(&mut out, name, "counter");
        gauge(&mut out, name, "", v.load(Ordering::Relaxed));
    }

    // Global gauges.
    type_line(&mut out, "crp_connections", "gauge");
    gauge(
        &mut out,
        "crp_connections",
        "",
        metrics.connections.load(Ordering::Relaxed),
    );
    type_line(&mut out, "crp_collections", "gauge");
    gauge(&mut out, "crp_collections", "", registry.len() as u64);

    // Reactor front-end + batcher pressure. Counters stay zero under
    // `--server-mode threads`; the batcher queue depth is live in both
    // modes. All are exported unconditionally so dashboards keep one
    // query across modes. With `--reactor-threads N` the unlabeled
    // series stay the cross-loop aggregates, and each loop's shard
    // adds a `{reactor="i"}`-labeled breakdown under the same TYPE
    // header (absent in thread/single-loop mode, not zero).
    let shards = metrics.reactor_loop_shards();
    for (name, v, per) in [
        (
            "crp_reactor_polls",
            &metrics.reactor_polls,
            (|s: &crate::coordinator::metrics::ReactorLoopMetrics| &s.polls)
                as fn(&crate::coordinator::metrics::ReactorLoopMetrics) -> &std::sync::atomic::AtomicU64,
        ),
        ("crp_reactor_ready_events", &metrics.reactor_ready_events, |s| {
            &s.ready_events
        }),
        ("crp_reactor_frames", &metrics.reactor_frames, |s| &s.frames),
        ("crp_reactor_coalesced_batches", &metrics.reactor_coalesced_batches, |s| {
            &s.coalesced_batches
        }),
        ("crp_reactor_offloaded_batches", &metrics.reactor_offloaded_batches, |s| {
            &s.offloaded_batches
        }),
    ] {
        type_line(&mut out, name, "counter");
        gauge(&mut out, name, "", v.load(Ordering::Relaxed));
        for (i, s) in shards.iter().enumerate() {
            gauge(
                &mut out,
                name,
                &format!("reactor=\"{i}\""),
                per(s).load(Ordering::Relaxed),
            );
        }
    }
    for (name, v) in [
        ("crp_reactor_write_buffer_hwm", &metrics.reactor_write_buffer_hwm),
        ("crp_reactor_worker_queue_depth", &metrics.reactor_worker_queue_depth),
        ("crp_batcher_queue_depth", &metrics.batcher_queue_depth),
    ] {
        type_line(&mut out, name, "gauge");
        gauge(&mut out, name, "", v.load(Ordering::Relaxed));
    }
    // Per-loop connection gauge: meaningful only when sharded, so the
    // series (TYPE line included) appears only with installed shards.
    if !shards.is_empty() {
        type_line(&mut out, "crp_reactor_connections", "gauge");
        for (i, s) in shards.iter().enumerate() {
            gauge(
                &mut out,
                "crp_reactor_connections",
                &format!("reactor=\"{i}\""),
                s.connections.load(Ordering::Relaxed),
            );
        }
    }
    // Dispatch batch size per reactor tick (a count histogram on the
    // same power-of-two buckets the latency series use).
    type_line(&mut out, "crp_reactor_dispatch_batch_size", "histogram");
    latency_hist(
        &mut out,
        "crp_reactor_dispatch_batch_size",
        "",
        &metrics.reactor_dispatch_batch,
    );

    // Per-kind request counters + full-path latency histograms. The
    // counter duplicates each histogram's `_count` under the name
    // dashboards expect for rate() queries.
    type_line(&mut out, "crp_requests_total", "counter");
    for kind in REQUEST_KINDS {
        let labels = format!("kind=\"{}\"", kind.label());
        gauge(
            &mut out,
            "crp_requests_total",
            &labels,
            metrics.requests.hist(kind).count(),
        );
    }
    type_line(&mut out, "crp_request_duration_us", "histogram");
    for kind in REQUEST_KINDS {
        let labels = format!("kind=\"{}\"", kind.label());
        latency_hist(
            &mut out,
            "crp_request_duration_us",
            &labels,
            metrics.requests.hist(kind),
        );
    }

    // Ingest-side latency (one amortized sample per registered vector).
    type_line(&mut out, "crp_register_latency_us", "histogram");
    latency_hist(&mut out, "crp_register_latency_us", "", &metrics.register_latency);

    // Per-collection engine state, straight off the registry. `list()`
    // is sorted by name, so scrapes are stable.
    let collections = registry.list();

    // Sparse-ingest row weight: nonzeros per CSR row, per collection (a
    // count histogram — the power-of-two buckets read as nnz, not µs).
    type_line(&mut out, "crp_ingest_nnz", "histogram");
    for c in &collections {
        latency_hist(
            &mut out,
            "crp_ingest_nnz",
            &format!("collection=\"{}\"", c.name),
            &c.ingest_nnz,
        );
    }
    for (name, kind, get) in [
        (
            "crp_collection_rows",
            "gauge",
            (|c| c.store.len() as u64) as fn(&crate::coordinator::registry::Collection) -> u64,
        ),
        ("crp_collection_pending_rows", "gauge", |c| {
            c.store.arena().map(|a| a.pending_rows() as u64).unwrap_or(0)
        }),
        ("crp_collection_tombstones", "gauge", |c| {
            c.store.arena().map(|a| a.tombstones() as u64).unwrap_or(0)
        }),
        ("crp_collection_storage_bytes", "gauge", |c| {
            c.store.arena().map(|a| a.storage_bytes() as u64).unwrap_or(0)
        }),
        ("crp_collection_index_buckets", "gauge", |c| {
            c.store.arena().map(|a| a.index_buckets() as u64).unwrap_or(0)
        }),
        ("crp_collection_index_max_bucket", "gauge", |c| {
            c.store.arena().map(|a| a.index_max_bucket() as u64).unwrap_or(0)
        }),
        ("crp_collection_drains_total", "counter", |c| {
            c.store.arena().map(|a| a.drains()).unwrap_or(0)
        }),
        ("crp_collection_wal_records_total", "counter", |c| {
            c.durability.as_ref().map(|d| d.wal_records()).unwrap_or(0)
        }),
        ("crp_collection_wal_bytes_total", "counter", |c| {
            c.durability.as_ref().map(|d| d.wal_bytes()).unwrap_or(0)
        }),
        ("crp_collection_last_checkpoint_rows", "gauge", |c| {
            c.durability.as_ref().map(|d| d.last_checkpoint_rows()).unwrap_or(0)
        }),
        ("crp_collection_snapshot_bytes", "gauge", |c| {
            c.durability.as_ref().map(|d| d.snapshot_bytes()).unwrap_or(0)
        }),
    ] {
        type_line(&mut out, name, kind);
        for c in &collections {
            gauge(&mut out, name, &format!("collection=\"{}\"", c.name), get(c));
        }
    }

    // Per-collection engine histograms (drain/fold, compaction, and the
    // ApproxTopK candidate/probe distributions).
    for (name, get) in [
        (
            "crp_drain_fold_us",
            (|o| &o.fold_us) as fn(&crate::scan::ArenaObs) -> &EngineHist,
        ),
        ("crp_compact_us", |o| &o.compact_us),
        ("crp_approx_candidates", |o| &o.approx_candidates),
        ("crp_approx_probes", |o| &o.approx_probes),
    ] {
        type_line(&mut out, name, "histogram");
        for c in &collections {
            if let Some(arena) = c.store.arena() {
                engine_hist(
                    &mut out,
                    name,
                    &format!("collection=\"{}\"", c.name),
                    get(arena.obs()),
                );
            }
        }
    }

    // Durability histograms. WAL appends carry the fsync discipline as
    // a label, so p99 jumps are attributable to the policy in force.
    type_line(&mut out, "crp_wal_append_us", "histogram");
    for c in &collections {
        if let Some(d) = &c.durability {
            let labels = format!(
                "collection=\"{}\",fsync=\"{}\"",
                c.name,
                d.fsync_policy().label()
            );
            latency_hist(&mut out, "crp_wal_append_us", &labels, d.wal_append_hist());
        }
    }
    type_line(&mut out, "crp_snapshot_write_us", "histogram");
    for c in &collections {
        if let Some(d) = &c.durability {
            let labels = format!("collection=\"{}\"", c.name);
            latency_hist(&mut out, "crp_snapshot_write_us", &labels, d.snapshot_write_hist());
        }
    }

    out
}

#[cfg(test)]
mod tests {
    use std::sync::Arc;

    use super::*;
    use crate::coding::{CodingParams, Scheme};
    use crate::coordinator::batcher::BatcherConfig;
    use crate::coordinator::durability::FsyncPolicy;
    use crate::coordinator::obs::RequestKind;
    use crate::coordinator::registry::{Registry, RegistryConfig};
    use crate::projection::{ProjectionConfig, Projector};
    use crate::scan::EpochConfig;

    fn mem_registry(metrics: Arc<Metrics>) -> Arc<Registry> {
        let projector = Arc::new(Projector::new_cpu(ProjectionConfig {
            k: 64,
            seed: 3,
            ..Default::default()
        }));
        Registry::open(
            RegistryConfig {
                root: None,
                epoch: EpochConfig::default(),
                batcher: BatcherConfig::default(),
                checkpoint_every: 0,
                fsync: FsyncPolicy::Os,
            },
            metrics,
            projector,
            CodingParams::new(Scheme::TwoBit, 0.75),
            None,
        )
        .unwrap()
    }

    #[test]
    fn renders_counters_gauges_and_request_histograms() {
        let metrics = Arc::new(Metrics::default());
        let reg = mem_registry(metrics.clone());
        metrics
            .knn_queries
            .fetch_add(7, std::sync::atomic::Ordering::Relaxed);
        metrics.requests.hist(RequestKind::Knn).record(100);
        metrics.requests.hist(RequestKind::Knn).record(5_000);

        let text = render(&metrics, &reg, None);
        assert!(text.contains("# TYPE crp_knn_queries_total counter"));
        assert!(text.contains("crp_knn_queries_total 7"));
        assert!(text.contains("crp_collections 1"));
        assert!(text.contains("crp_requests_total{kind=\"knn\"} 2"));
        // Every request kind renders a series even when idle.
        for kind in REQUEST_KINDS {
            assert!(
                text.contains(&format!("crp_requests_total{{kind=\"{}\"}}", kind.label())),
                "{}",
                kind.label()
            );
        }
        // Reactor + batcher series render (zeroed) even in thread mode.
        assert!(text.contains("# TYPE crp_reactor_ready_events counter"));
        assert!(text.contains("crp_reactor_ready_events 0"));
        assert!(text.contains("crp_reactor_write_buffer_hwm 0"));
        assert!(text.contains("crp_reactor_offloaded_batches 0"));
        assert!(text.contains("crp_reactor_worker_queue_depth 0"));
        assert!(text.contains("crp_batcher_queue_depth 0"));
        // No shards installed → no per-loop labels, and the per-loop
        // connections gauge is absent entirely (not zero).
        assert!(!text.contains("reactor=\""));
        assert!(!text.contains("crp_reactor_connections"));
        assert!(text.contains("# TYPE crp_reactor_dispatch_batch_size histogram"));
        assert!(text.contains("crp_reactor_dispatch_batch_size_count 0"));
        assert!(text.contains("# TYPE crp_request_duration_us histogram"));
        assert!(text.contains("crp_request_duration_us_count{kind=\"knn\"} 2"));
        assert!(text.contains("crp_request_duration_us_sum{kind=\"knn\"} 5100"));
        // The in-memory default collection renders its gauges.
        assert!(text.contains("crp_collection_rows{collection=\"default\"} 0"));
        // Sparse ingest renders per collection, zeroed before any
        // RegisterSparse traffic.
        assert!(text.contains("# TYPE crp_ingest_nnz histogram"));
        assert!(text.contains("crp_ingest_nnz_count{collection=\"default\"} 0"));
        // No durability → no WAL series body, but the TYPE line stays.
        assert!(text.contains("# TYPE crp_wal_append_us histogram"));
        assert!(!text.contains("crp_wal_append_us_count"));
    }

    #[test]
    fn histogram_buckets_are_cumulative_and_end_at_inf() {
        let metrics = Arc::new(Metrics::default());
        let reg = mem_registry(metrics.clone());
        // 100µs → bucket [64,128); 5000µs → [4096,8192).
        metrics.requests.hist(RequestKind::TopK).record(100);
        metrics.requests.hist(RequestKind::TopK).record(5_000);
        let text = render(&metrics, &reg, None);

        let bucket = |le: &str| -> u64 {
            let needle = format!("crp_request_duration_us_bucket{{kind=\"topk\",le=\"{le}\"}} ");
            let line = text
                .lines()
                .find(|l| l.starts_with(&needle))
                .unwrap_or_else(|| panic!("missing le={le}"));
            line.rsplit(' ').next().unwrap().parse().unwrap()
        };
        assert_eq!(bucket("64"), 0);
        assert_eq!(bucket("128"), 1);
        assert_eq!(bucket("4096"), 1);
        assert_eq!(bucket("8192"), 2);
        assert_eq!(bucket("+Inf"), 2, "+Inf bucket equals _count");
        // Monotone in `le` across the whole series.
        let mut last = 0u64;
        for le in (1..=31).map(|i| (1u64 << i).to_string()).chain(["+Inf".into()]) {
            let v = bucket(&le);
            assert!(v >= last, "bucket le={le} regressed: {v} < {last}");
            last = v;
        }
        assert!(text.contains("crp_request_duration_us_count{kind=\"topk\"} 2"));
    }

    #[test]
    fn engine_activity_reaches_collection_series() {
        let metrics = Arc::new(Metrics::default());
        let reg = mem_registry(metrics.clone());
        let c = reg.get("default").unwrap();
        let ids: Vec<String> = (0..8).map(|i| format!("v{i}")).collect();
        let vectors: Vec<Vec<f32>> = (0..8)
            .map(|i| (0..16).map(|j| ((i * 16 + j) as f32).sin()).collect())
            .collect();
        match c.register_batch(ids, vectors) {
            crate::coordinator::protocol::Response::RegisteredBatch { count } => {
                assert_eq!(count, 8)
            }
            other => panic!("unexpected response {other:?}"),
        }
        let arena = c.store.arena().unwrap();
        arena.drain();

        let text = render(&metrics, &reg, None);
        assert!(text.contains("crp_collection_rows{collection=\"default\"} 8"));
        assert!(text.contains("crp_collection_pending_rows{collection=\"default\"} 0"));
        assert!(text.contains("crp_collection_drains_total{collection=\"default\"} 1"));
        assert!(text.contains("crp_drain_fold_us_count{collection=\"default\"} 1"));
        assert!(text.contains("# TYPE crp_approx_candidates histogram"));
    }

    #[test]
    fn reactor_shards_render_labeled_series_next_to_aggregates() {
        let metrics = Arc::new(Metrics::default());
        let reg = mem_registry(metrics.clone());
        let shards = metrics.install_reactor_loops(2);
        shards[0]
            .frames
            .fetch_add(5, std::sync::atomic::Ordering::Relaxed);
        shards[1]
            .frames
            .fetch_add(3, std::sync::atomic::Ordering::Relaxed);
        shards[1]
            .connections
            .fetch_add(4, std::sync::atomic::Ordering::Relaxed);
        shards[0]
            .offloaded_batches
            .fetch_add(2, std::sync::atomic::Ordering::Relaxed);
        // Loops also bump the unlabeled aggregate on the hot path.
        metrics
            .reactor_frames
            .fetch_add(8, std::sync::atomic::Ordering::Relaxed);

        let text = render(&metrics, &reg, None);
        // Aggregate stays unlabeled; shard rows ride under it.
        assert!(text.contains("crp_reactor_frames 8"));
        assert!(text.contains("crp_reactor_frames{reactor=\"0\"} 5"));
        assert!(text.contains("crp_reactor_frames{reactor=\"1\"} 3"));
        assert!(text.contains("crp_reactor_offloaded_batches{reactor=\"0\"} 2"));
        // Per-loop connections gauge appears once sharded.
        assert!(text.contains("# TYPE crp_reactor_connections gauge"));
        assert!(text.contains("crp_reactor_connections{reactor=\"0\"} 0"));
        assert!(text.contains("crp_reactor_connections{reactor=\"1\"} 4"));
        // Exactly one TYPE header per series, labeled rows included.
        assert_eq!(
            text.matches("# TYPE crp_reactor_frames counter").count(),
            1
        );
    }

    #[test]
    fn replication_series_render_only_on_replicas() {
        let metrics = Arc::new(Metrics::default());
        let reg = mem_registry(metrics.clone());

        // Primary (no replica state): the series are absent entirely.
        let text = render(&metrics, &reg, None);
        assert!(!text.contains("crp_replication_"), "{text}");

        // Replica: lag gauges and lifecycle counters lead the page.
        let replica = ReplicaState::new("127.0.0.1:9999".into(), 1 << 20);
        let text = render(&metrics, &reg, Some(&replica));
        assert!(text.contains("# TYPE crp_replication_lag_bytes gauge"));
        assert!(text.contains("crp_replication_lag_bytes 0"));
        assert!(text.contains("crp_replication_lag_records 0"));
        assert!(text.contains("crp_replication_active 1"));
        assert!(text.contains("# TYPE crp_replication_lag_seconds gauge"));
        assert!(text.contains("crp_replication_bootstraps_total 0"));
        assert!(text.contains("crp_replication_reconnects_total 0"));
        // The lag-seconds value is a well-formed float on its own line.
        let line = text
            .lines()
            .find(|l| l.starts_with("crp_replication_lag_seconds "))
            .unwrap();
        let v: f64 = line.rsplit(' ').next().unwrap().parse().unwrap();
        assert!(v >= 0.0);

        // Promotion flips the active gauge but keeps the series.
        replica.promote();
        let text = render(&metrics, &reg, Some(&replica));
        assert!(text.contains("crp_replication_active 0"));
    }
}
