//! WAL-shipping replication: a replica-side applier that pulls the
//! primary's op log over the framed protocol and replays it locally.
//!
//! The stream is pull-based — the protocol is strictly
//! request/response, so the replica drives: each `ReplSync` names its
//! last applied `(segment, offset)` position and the primary answers
//! with either the next run of CRC-framed WAL records (shipped
//! verbatim; the replica re-verifies every checksum before any record
//! touches a store) or, when the replica is too far behind to chase
//! the log (position `0`, a retired segment, or a torn chunk), a full
//! `CRPSNAP2` snapshot bootstrap with a fresh resume position.
//!
//! Topology: one primary (durable, accepts writes) and any number of
//! in-memory replicas (`crp serve --replicate-from ADDR`). Replicas
//! answer every read — `Knn`/`TopK`/`ApproxTopK`/`Estimate`/`Stats` —
//! and reject writes with a redirect error until `crp promote` flips
//! them into a standalone primary. Stream loss is survived by
//! reconnecting with jittered exponential backoff and resuming from
//! the last applied position; the primary's checkpoint retention keeps
//! the needed segments alive up to a configurable lag cap (see
//! [`crate::coordinator::durability`]), past which the replica simply
//! re-bootstraps.

use std::collections::HashMap;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant, SystemTime, UNIX_EPOCH};

use crate::coordinator::client::{ReplPull, SketchClient};
use crate::coordinator::durability::{snapshot, wal};
use crate::coordinator::obs::log;
use crate::coordinator::protocol::{CollectionInfo, ReplicationStats};
use crate::coordinator::registry::{
    CollectionOptions, CollectionSpec, Registry, DEFAULT_COLLECTION,
};

/// How a replica reaches its primary and paces the stream.
#[derive(Clone, Debug)]
pub struct ReplicationConfig {
    /// Primary `host:port` (the protocol listener, not `/metrics`).
    pub primary: String,
    /// Sleep between polls once fully caught up.
    pub poll: Duration,
    /// First reconnect delay after stream loss (doubles per failure,
    /// jittered to ±50%).
    pub backoff_min: Duration,
    /// Reconnect delay ceiling.
    pub backoff_max: Duration,
    /// Lag (bytes) past which the replica reports not-ready on
    /// `/readyz` — align with the primary's retention cap.
    pub lag_cap: u64,
}

impl Default for ReplicationConfig {
    fn default() -> Self {
        ReplicationConfig {
            primary: String::new(),
            poll: Duration::from_millis(50),
            backoff_min: Duration::from_millis(100),
            backoff_max: Duration::from_secs(5),
            lag_cap: crate::coordinator::durability::DEFAULT_REPL_LAG_CAP,
        }
    }
}

/// Bounded exponential backoff with multiplicative jitter: each delay
/// is uniform-ish in `[base/2, 3·base/2)` (entropy from the wall
/// clock's nanosecond field — good enough to de-synchronize replicas
/// without an RNG dependency), with `base` doubling per failure up to
/// the ceiling.
pub struct Backoff {
    base: Duration,
    min: Duration,
    max: Duration,
}

impl Backoff {
    pub fn new(min: Duration, max: Duration) -> Backoff {
        let min = min.max(Duration::from_millis(1));
        Backoff {
            base: min,
            min,
            max: max.max(min),
        }
    }

    /// The next delay to sleep; advances the exponential schedule.
    pub fn next_delay(&mut self) -> Duration {
        let base = self.base;
        self.base = (self.base * 2).min(self.max);
        let span = base.as_nanos().max(1) as u64;
        let jitter = SystemTime::now()
            .duration_since(UNIX_EPOCH)
            .unwrap_or_default()
            .subsec_nanos() as u64
            % span;
        base / 2 + Duration::from_nanos(jitter)
    }

    /// Back to the minimum after a successful (re)connection.
    pub fn reset(&mut self) {
        self.base = self.min;
    }
}

/// Live replication posture, shared between the applier thread and the
/// request router (lag gauges for `/metrics` + `StatsDetailed`, the
/// active flag that gates writes, readiness for `/readyz`).
pub struct ReplicaState {
    /// Primary address the applier pulls from.
    pub primary: String,
    /// True until promotion: writes rejected, applier running.
    active: AtomicBool,
    /// Every collection has bootstrapped at least once.
    bootstrapped: AtomicBool,
    lag_bytes: AtomicU64,
    lag_records: AtomicU64,
    bootstraps: AtomicU64,
    reconnects: AtomicU64,
    lag_cap: u64,
    /// Last instant the stream was fully caught up (lag-seconds clock).
    caught_up_at: Mutex<Instant>,
}

impl ReplicaState {
    pub fn new(primary: String, lag_cap: u64) -> Arc<ReplicaState> {
        Arc::new(ReplicaState {
            primary,
            active: AtomicBool::new(true),
            bootstrapped: AtomicBool::new(false),
            lag_bytes: AtomicU64::new(0),
            lag_records: AtomicU64::new(0),
            bootstraps: AtomicU64::new(0),
            reconnects: AtomicU64::new(0),
            lag_cap,
            caught_up_at: Mutex::new(Instant::now()),
        })
    }

    /// Still replicating (false once promoted)?
    pub fn is_active(&self) -> bool {
        self.active.load(Ordering::Relaxed)
    }

    /// Promote: stop replicating, accept writes. Returns whether this
    /// call did the flip (idempotent).
    pub fn promote(&self) -> bool {
        self.active.swap(false, Ordering::Relaxed)
    }

    /// Load-balancer readiness: an active replica is ready once every
    /// collection has bootstrapped and lag sits under the cap; a
    /// promoted one is simply a primary.
    pub fn ready(&self) -> bool {
        !self.is_active()
            || (self.bootstrapped.load(Ordering::Relaxed)
                && self.lag_bytes.load(Ordering::Relaxed) < self.lag_cap)
    }

    pub fn lag_bytes(&self) -> u64 {
        self.lag_bytes.load(Ordering::Relaxed)
    }

    pub fn lag_records(&self) -> u64 {
        self.lag_records.load(Ordering::Relaxed)
    }

    /// Seconds since the stream was last fully caught up (0 when it is
    /// caught up right now).
    pub fn lag_seconds(&self) -> f64 {
        if self.lag_bytes() == 0 && self.bootstrapped.load(Ordering::Relaxed) {
            return 0.0;
        }
        self.caught_up_at.lock().unwrap().elapsed().as_secs_f64()
    }

    pub fn bootstraps(&self) -> u64 {
        self.bootstraps.load(Ordering::Relaxed)
    }

    pub fn reconnects(&self) -> u64 {
        self.reconnects.load(Ordering::Relaxed)
    }

    /// Wire-facing snapshot for the `StatsDetailed` replication tail.
    pub fn stats(&self) -> ReplicationStats {
        ReplicationStats {
            primary: self.primary.clone(),
            active: self.is_active(),
            lag_bytes: self.lag_bytes(),
            lag_records: self.lag_records(),
            lag_seconds: self.lag_seconds(),
            bootstraps: self.bootstraps(),
            reconnects: self.reconnects(),
        }
    }

    fn set_lag(&self, bytes: u64, records: u64) {
        self.lag_bytes.store(bytes, Ordering::Relaxed);
        self.lag_records.store(records, Ordering::Relaxed);
        if bytes == 0 {
            *self.caught_up_at.lock().unwrap() = Instant::now();
        }
    }
}

/// Per-collection stream position, owned by the applier thread.
struct Pos {
    /// Segment the next pull resumes from (0 = needs bootstrap).
    segment: u64,
    offset: u64,
    /// Primary lifetime record count at the last bootstrap — the
    /// subtraction baseline for lag-in-records.
    baseline: u64,
    /// Records applied since that bootstrap.
    applied: u64,
    /// Primary-reported backlog after the last pull.
    behind: u64,
    /// Lag in records after the last pull.
    lag_records: u64,
}

impl Pos {
    fn unbootstrapped() -> Pos {
        Pos {
            segment: 0,
            offset: 0,
            baseline: 0,
            applied: 0,
            behind: 0,
            lag_records: 0,
        }
    }
}

/// The replica-side applier: a background thread that connects to the
/// primary, mirrors its collection set, bootstraps each collection
/// from a snapshot, then tails the WAL stream. Dropping it (or
/// promotion) stops the thread.
pub struct Replicator {
    state: Arc<ReplicaState>,
    stop: Arc<AtomicBool>,
    handle: Option<JoinHandle<()>>,
}

impl Replicator {
    pub fn spawn(registry: Arc<Registry>, cfg: ReplicationConfig) -> crate::Result<Replicator> {
        let state = ReplicaState::new(cfg.primary.clone(), cfg.lag_cap);
        let stop = Arc::new(AtomicBool::new(false));
        let (st, sp) = (state.clone(), stop.clone());
        let handle = std::thread::Builder::new()
            .name("crp-replicator".into())
            .spawn(move || run(registry, st, cfg, sp))?;
        Ok(Replicator {
            state,
            stop,
            handle: Some(handle),
        })
    }

    /// The shared posture (router + metrics hold clones of this).
    pub fn state(&self) -> Arc<ReplicaState> {
        self.state.clone()
    }
}

impl Drop for Replicator {
    fn drop(&mut self) {
        self.stop.store(true, Ordering::Relaxed);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

/// Sleep `d` in small slices so stop/promote never waits a full
/// backoff delay.
fn nap(stop: &AtomicBool, state: &ReplicaState, d: Duration) {
    let deadline = Instant::now() + d;
    while Instant::now() < deadline {
        if stop.load(Ordering::Relaxed) || !state.is_active() {
            return;
        }
        std::thread::sleep(Duration::from_millis(10).min(deadline - Instant::now()));
    }
}

/// Bring the local collection set in line with the primary's: create
/// what is missing (full specs ride `ListCollections`), drop local
/// extras, and refuse a `default` whose spec disagrees with the flags
/// this replica was started with — silently serving estimates under a
/// different coding would corrupt every answer.
fn mirror(registry: &Registry, infos: &[CollectionInfo]) -> crate::Result<()> {
    for info in infos {
        let spec = CollectionSpec {
            scheme: info.scheme,
            w: info.w,
            k: info.k as usize,
            seed: info.seed,
        };
        match registry.get(&info.name) {
            Some(local) => anyhow::ensure!(
                local.spec == spec,
                "collection {:?} on the primary was created with scheme={} w={} k={} \
                 seed={}, but this replica holds scheme={} w={} k={} seed={} — restart \
                 the replica with matching flags",
                info.name,
                spec.scheme.label(),
                spec.w,
                spec.k,
                spec.seed,
                local.spec.scheme.label(),
                local.spec.w,
                local.spec.k,
                local.spec.seed
            ),
            None => {
                registry.create(&info.name, spec, CollectionOptions::for_spec(&spec))?;
            }
        }
    }
    for local in registry.list() {
        if local.name != DEFAULT_COLLECTION && !infos.iter().any(|i| i.name == local.name) {
            let _ = registry.drop_collection(&local.name);
        }
    }
    Ok(())
}

/// Chunk pulls per collection per round — bounds how long one
/// collection can starve the others while catching up.
const PULLS_PER_ROUND: usize = 64;

fn run(registry: Arc<Registry>, state: Arc<ReplicaState>, cfg: ReplicationConfig, stop: Arc<AtomicBool>) {
    // Stable for the process lifetime: the primary keys its retention
    // floor on this, and a restart (which must re-bootstrap anyway)
    // presents a fresh id rather than inheriting a stale floor.
    let replica_id = format!(
        "r-{}-{}",
        std::process::id(),
        SystemTime::now()
            .duration_since(UNIX_EPOCH)
            .unwrap_or_default()
            .subsec_nanos()
    );
    let mut backoff = Backoff::new(cfg.backoff_min, cfg.backoff_max);
    let mut positions: HashMap<String, Pos> = HashMap::new();
    let mut connected_before = false;
    while !stop.load(Ordering::Relaxed) && state.is_active() {
        let mut client = match SketchClient::connect(&cfg.primary) {
            Ok(c) => c,
            Err(e) => {
                if connected_before {
                    state.reconnects.fetch_add(1, Ordering::Relaxed);
                    connected_before = false;
                }
                log::debug(
                    "crp::replication",
                    "primary unreachable; backing off",
                    &[("primary", cfg.primary.clone()), ("error", e.to_string())],
                );
                nap(&stop, &state, backoff.next_delay());
                continue;
            }
        };
        if connected_before {
            // The previous session broke mid-stream and this connect
            // succeeded immediately — still a reconnect.
            state.reconnects.fetch_add(1, Ordering::Relaxed);
        }
        connected_before = true;
        backoff.reset();
        log::info(
            "crp::replication",
            "streaming from primary",
            &[("primary", cfg.primary.clone()), ("replica", replica_id.clone())],
        );
        // One session: pull rounds until the stream breaks.
        if let Err(e) = session(
            &mut client,
            &registry,
            &state,
            &cfg,
            &stop,
            &replica_id,
            &mut positions,
        ) {
            log::debug(
                "crp::replication",
                "stream lost; reconnecting",
                &[("primary", cfg.primary.clone()), ("error", e.to_string())],
            );
            nap(&stop, &state, backoff.next_delay());
        }
    }
}

/// Pull rounds over one live connection; `Err` = stream lost (the
/// caller reconnects with backoff).
fn session(
    client: &mut SketchClient,
    registry: &Registry,
    state: &ReplicaState,
    cfg: &ReplicationConfig,
    stop: &AtomicBool,
    replica_id: &str,
    positions: &mut HashMap<String, Pos>,
) -> crate::Result<()> {
    loop {
        if stop.load(Ordering::Relaxed) || !state.is_active() {
            return Ok(());
        }
        let infos = client.list_collections()?;
        if let Err(e) = mirror(registry, &infos) {
            // Config disagreement (not a transport fault): keep the
            // connection, log loudly, retry after a poll — the
            // operator has to fix the flags.
            log::warn(
                "crp::replication",
                "collection mirror failed",
                &[("error", e.to_string())],
            );
            nap(stop, state, cfg.poll.max(Duration::from_millis(250)));
            continue;
        }
        positions.retain(|name, _| infos.iter().any(|i| i.name == *name));

        let mut progressed = false;
        for info in &infos {
            let Some(c) = registry.get(&info.name) else { continue };
            let pos = positions
                .entry(info.name.clone())
                .or_insert_with(Pos::unbootstrapped);
            for _ in 0..PULLS_PER_ROUND {
                if stop.load(Ordering::Relaxed) || !state.is_active() {
                    return Ok(());
                }
                match client.repl_sync(&info.name, replica_id, pos.segment, pos.offset)? {
                    ReplPull::Bootstrap {
                        segment,
                        offset,
                        primary_records,
                        snapshot: image,
                    } => {
                        // Rebuild empty, restore the image, resume the
                        // stream at the position the primary handed us.
                        let fresh = registry.reset_collection(&info.name)?;
                        let img = snapshot::load_bytes(&image)?;
                        if img.rows() > 0 {
                            snapshot::restore_into(&fresh.store, &img)?;
                        }
                        *pos = Pos {
                            segment,
                            offset,
                            baseline: primary_records,
                            applied: 0,
                            behind: 0,
                            lag_records: 0,
                        };
                        state.bootstraps.fetch_add(1, Ordering::Relaxed);
                        progressed = true;
                        log::info(
                            "crp::replication",
                            "bootstrapped collection",
                            &[
                                ("collection", info.name.clone()),
                                ("rows", fresh.store.len().to_string()),
                                ("resume_segment", segment.to_string()),
                            ],
                        );
                    }
                    ReplPull::Records {
                        segment,
                        next_segment,
                        next_offset,
                        behind_bytes,
                        primary_records,
                        bytes,
                    } => {
                        if segment != pos.segment {
                            // The primary answered for a different
                            // position than we asked — resync from a
                            // snapshot rather than guessing.
                            *pos = Pos::unbootstrapped();
                            continue;
                        }
                        if !bytes.is_empty() {
                            match wal::apply_chunk(&c.store, &bytes) {
                                Ok(n) => {
                                    pos.applied += n;
                                    progressed |= n > 0;
                                }
                                Err(e) => {
                                    // End-to-end CRC caught a torn or
                                    // corrupt chunk. Nothing from it
                                    // was applied; the position may be
                                    // mid-garbage, so fall back to a
                                    // snapshot.
                                    log::warn(
                                        "crp::replication",
                                        "rejected torn chunk; re-bootstrapping",
                                        &[
                                            ("collection", info.name.clone()),
                                            ("error", e.to_string()),
                                        ],
                                    );
                                    *pos = Pos::unbootstrapped();
                                    continue;
                                }
                            }
                        }
                        pos.segment = next_segment;
                        pos.offset = next_offset;
                        pos.behind = behind_bytes;
                        pos.lag_records =
                            primary_records.saturating_sub(pos.baseline + pos.applied);
                        if behind_bytes == 0 {
                            break;
                        }
                    }
                }
            }
        }

        let behind: u64 = positions.values().map(|p| p.behind).sum();
        let lag_records: u64 = positions.values().map(|p| p.lag_records).sum();
        state.set_lag(behind, lag_records);
        if !positions.is_empty() && positions.values().all(|p| p.segment > 0) {
            state.bootstrapped.store(true, Ordering::Relaxed);
        }
        if behind == 0 && !progressed {
            nap(stop, state, cfg.poll);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn backoff_doubles_jitters_and_caps() {
        let mut b = Backoff::new(Duration::from_millis(100), Duration::from_millis(400));
        // Delay k draws from base 100·2^k (capped): always within
        // [base/2, 3·base/2).
        for base_ms in [100u64, 200, 400, 400, 400] {
            let d = b.next_delay().as_millis() as u64;
            assert!(
                d >= base_ms / 2 && d < base_ms + base_ms / 2,
                "delay {d}ms outside [{}..{})",
                base_ms / 2,
                base_ms + base_ms / 2
            );
        }
        b.reset();
        let d = b.next_delay().as_millis() as u64;
        assert!(d < 150, "reset must drop back to the minimum ({d}ms)");
        // Degenerate bounds stay sane.
        let mut tiny = Backoff::new(Duration::ZERO, Duration::ZERO);
        assert!(tiny.next_delay() <= Duration::from_millis(2));
    }

    #[test]
    fn replica_state_tracks_lag_readiness_and_promotion() {
        let s = ReplicaState::new("127.0.0.1:1".into(), 1000);
        assert!(s.is_active());
        assert!(!s.ready(), "not ready before bootstrap");

        s.bootstrapped.store(true, Ordering::Relaxed);
        s.set_lag(10, 2);
        assert!(s.ready(), "under-cap lag is ready");
        assert_eq!(s.lag_bytes(), 10);
        assert_eq!(s.lag_records(), 2);
        assert!(s.lag_seconds() >= 0.0);

        s.set_lag(5000, 100);
        assert!(!s.ready(), "over-cap lag is not ready");

        s.set_lag(0, 0);
        assert!(s.ready());
        assert_eq!(s.lag_seconds(), 0.0, "caught up = zero lag seconds");

        let st = s.stats();
        assert!(st.active);
        assert_eq!(st.primary, "127.0.0.1:1");
        assert_eq!(st.lag_bytes, 0);

        // Promotion is one-shot and flips readiness unconditionally.
        assert!(s.promote(), "first promote reports was_replica");
        assert!(!s.promote(), "second promote is a no-op");
        assert!(!s.is_active());
        assert!(s.ready(), "a promoted replica is a primary");
        assert!(!s.stats().active);
    }
}
