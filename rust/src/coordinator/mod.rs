//! The Layer-3 coordinator: a sketch/similarity service.
//!
//! Clients register raw vectors; the service projects them (dynamic
//! batching onto the fixed AOT artifact shapes), codes them with the
//! configured scheme, and stores only the packed codes — the paper's
//! storage story made operational. Queries then estimate similarities or
//! scan for near neighbors purely over the compact codes.
//!
//! ```text
//!  TCP (length-prefixed JSON)
//!   └── server  — connection loop, frame codec
//!        └── router — request dispatch
//!             ├── batcher — groups projection work into (b_tile)-sized
//!             │             batches with a deadline, executes on the
//!             │             Projector (PJRT artifact or pure Rust)
//!             ├── store   — sharded map: id → PackedCodes, mirrored
//!             │             into an epoch-buffered scan arena
//!             │             (crate::scan) that serves Knn/TopK as
//!             │             sequential sweeps; puts never take the
//!             │             arena write lock
//!             └── metrics — counters + latency histograms
//! ```
//!
//! Python never runs here; the Projector executes AOT artifacts via PJRT.

pub mod protocol;
pub mod store;
pub mod batcher;
pub mod metrics;
pub mod server;
pub mod client;
pub mod persist;

pub use batcher::{BatcherConfig, SketchBatcher};
pub use client::SketchClient;
pub use protocol::{Request, Response};
pub use server::{serve, ServerConfig};
pub use store::SketchStore;
