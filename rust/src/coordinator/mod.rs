//! The Layer-3 coordinator: a sketch/similarity service.
//!
//! Clients register raw vectors; the service projects them (dynamic
//! batching onto the fixed AOT artifact shapes), codes them with the
//! configured scheme, and stores only the packed codes — the paper's
//! storage story made operational. Queries then estimate similarities or
//! scan for near neighbors purely over the compact codes.
//!
//! ```text
//!  TCP (length-prefixed binary frames)
//!   └── server  — connection loop, frame codec
//!        └── router — request dispatch
//!             ├── batcher     — groups projection work into (b_tile)-
//!             │                 sized batches with a deadline, executes
//!             │                 on the Projector (PJRT or pure Rust)
//!             ├── store       — sharded map: id → PackedCodes, mirrored
//!             │                 into an epoch-buffered scan arena
//!             │                 (crate::scan) that serves Knn/TopK as
//!             │                 sequential sweeps; puts never take the
//!             │                 arena write lock
//!             ├── durability  — CRPSNAP2 arena-image snapshots + the
//!             │                 CRPWAL1 epoch WAL; every acknowledged
//!             │                 mutation survives kill -9
//!             ├── maintenance — background thread owning drains,
//!             │                 compaction, and snapshot-then-truncate
//!             │                 checkpoints (writers only notify)
//!             └── metrics     — counters + latency histograms
//! ```
//!
//! Python never runs here; the Projector executes AOT artifacts via PJRT.

pub mod protocol;
pub mod store;
pub mod batcher;
pub mod metrics;
pub mod server;
pub mod client;
pub mod durability;
pub mod maintenance;

pub use batcher::{BatcherConfig, SketchBatcher};
pub use client::SketchClient;
pub use durability::{Durability, DurabilityConfig};
pub use maintenance::{Maintenance, MaintenanceConfig};
pub use protocol::{Request, Response};
pub use server::{serve, ServerConfig};
pub use store::{DrainSignal, SketchStore};
