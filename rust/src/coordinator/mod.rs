//! The Layer-3 coordinator: a multi-collection sketch/similarity
//! service.
//!
//! Clients register raw vectors into named *collections*; the service
//! projects them (dynamic batching onto the fixed AOT artifact shapes),
//! codes them with that collection's scheme, and stores only the packed
//! codes — the paper's storage story made operational, with the coding
//! choice made *per workload*. Sparse inputs skip densification
//! entirely: `RegisterSparse` frames carry CSR batches (validated at
//! every decode boundary) that are projected at O(nnz·k) by the gather
//! kernel in [`crate::projection::sparse`], producing codes
//! byte-identical to the dense path; collections created with a
//! sign-sparse matrix kind drop the Gaussian multiplies too. Queries
//! then estimate similarities or scan for near neighbors purely over
//! the compact codes.
//!
//! ```text
//!  TCP (length-prefixed binary frames)
//!   └── server  — front-end (--server-mode, --max-conns): blocking
//!        │        thread-per-connection loop (the oracle, default) or
//!        │        the sharded epoll reactor (--reactor-threads
//!        │        SO_REUSEPORT loops, 10k+ connections each, pipelined
//!        │        zero-copy framing, Register/RegisterSparse/TopK
//!        │        coalescing, write backpressure, idle sweep, and a
//!        │        --reactor-workers pool running fused bulk work
//!        │        off-loop — see `reactor`); byte-identical responses
//!        │        either way
//!        └── router — request dispatch; legacy frames → "default",
//!             │       Scoped frames → named collection
//!             └── registry — named collections, created/dropped at
//!                  │         runtime; durable layout under one root
//!                  │         (<root>/<name>/{snap,wal} + MANIFEST)
//!                  ├── batcher     — per collection: groups projection
//!                  │                 work into (b_tile)-sized batches
//!                  │                 with a deadline, executes on the
//!                  │                 Projector (PJRT or pure Rust);
//!                  │                 CSR rows take the fused O(nnz·k)
//!                  │                 encode_csr path, byte-identical
//!                  │                 to densify-then-project
//!                  ├── store       — per collection: sharded map
//!                  │                 id → PackedCodes, mirrored into an
//!                  │                 epoch-buffered scan arena
//!                  │                 (crate::scan) that serves Knn/TopK
//!                  │                 exactly and ApproxTopK through the
//!                  │                 banded multi-probe code index
//!                  │                 (crate::lsh::CodeIndex, maintained
//!                  │                 at every drain; per-collection
//!                  │                 IndexConfig in the MANIFEST)
//!                  ├── durability  — per collection: CRPSNAP2 snapshots
//!                  │                 + the CRPWAL1 epoch WAL (fsync
//!                  │                 policy: always|os|group:<ms>)
//!                  ├── maintenance — ONE background thread multiplexing
//!                  │                 drains, compaction, and checkpoints
//!                  │                 across all collections off one
//!                  │                 DrainSignal
//!                  ├── metrics     — counters + latency histograms +
//!                  │                 connection gauge; per-request-kind
//!                  │                 full-path latency
//!                  ├── obs         — structured logs (--log-level),
//!                  │                 slow-query ring + trace lines, and
//!                  │                 the Prometheus-style /metrics +
//!                  │                 /healthz + /readyz endpoint
//!                  │                 (--metrics-addr) rendered straight
//!                  │                 off metrics + registry
//!                  └── replication — WAL-shipping replicas: snapshot
//!                                    bootstrap + chunked log tail over
//!                                    the same frame protocol
//!                                    (--replicate-from, `crp promote`),
//!                                    reconnect with jittered backoff,
//!                                    lag gauges through obs
//! ```
//!
//! Python never runs here; Projectors execute AOT artifacts via PJRT.

pub mod protocol;
pub mod store;
pub mod batcher;
pub mod metrics;
pub mod reactor;
pub mod registry;
pub mod server;
pub mod client;
pub mod durability;
pub mod maintenance;
pub mod obs;
pub mod replication;

pub use batcher::{BatcherConfig, SketchBatcher};
pub use client::SketchClient;
pub use durability::{Durability, DurabilityConfig, FsyncPolicy};
pub use maintenance::{Maintenance, MaintenanceConfig};
pub use protocol::{CollectionInfo, CollectionStats, Request, Response};
pub use registry::{
    Collection, CollectionOptions, CollectionSpec, Registry, RegistryConfig, DEFAULT_COLLECTION,
};
pub use replication::{ReplicaState, ReplicationConfig, Replicator};
pub use server::{serve, ServerConfig, ServerMode};
pub use store::{DrainSignal, SketchStore};
