//! TCP server: thread-per-connection loop + request router.

use std::net::{TcpListener, TcpStream};
use std::sync::{Arc, Mutex};
use std::time::Instant;

use crate::coding::{BatchEncoder, CodingParams, PackedCodes};
use crate::coordinator::batcher::{BatcherConfig, SketchBatcher};
use crate::coordinator::durability::{Durability, DurabilityConfig};
use crate::coordinator::maintenance::{Maintenance, MaintenanceConfig};
use crate::coordinator::metrics::Metrics;
use crate::coordinator::protocol::{self, KnnHit, Request, Response};
use crate::coordinator::store::SketchStore;
use crate::estimator::CollisionEstimator;
use crate::projection::Projector;
use crate::scan::EpochConfig;

/// Server configuration.
#[derive(Clone, Debug)]
pub struct ServerConfig {
    pub addr: String,
    pub coding: CodingParams,
    pub batcher: BatcherConfig,
    /// Ingest-epoch drain/compaction policy for the scan arena.
    pub epoch: EpochConfig,
    /// Snapshot + WAL persistence; `None` runs fully in-memory.
    pub durability: Option<DurabilityConfig>,
    /// Background drain/checkpoint thread cadence.
    pub maintenance: MaintenanceConfig,
}

impl Default for ServerConfig {
    fn default() -> Self {
        ServerConfig {
            addr: "127.0.0.1:7474".to_string(),
            coding: CodingParams::new(crate::coding::Scheme::TwoBit, 0.75),
            batcher: BatcherConfig::default(),
            epoch: EpochConfig::default(),
            durability: None,
            maintenance: MaintenanceConfig::default(),
        }
    }
}

/// Fused bulk-ingest state: one encoder (cached offsets + scratch) and
/// one word buffer, reused across `RegisterBatch` requests.
struct BulkIngest {
    encoder: BatchEncoder,
    words: Vec<u64>,
}

/// Upper bound on the padded projection workspace (`b·d` f32 cells) one
/// `RegisterBatch` may demand. Vectors are padded to the batch's max
/// dimension, so without this cap a frame mixing one huge vector with
/// many tiny ones would force an allocation quadratic in frame size.
const MAX_BULK_CELLS: usize = 1 << 24; // 64 MiB of f32 workspace

/// Shared service state.
pub struct ServiceState {
    pub store: Arc<SketchStore>,
    pub batcher: SketchBatcher,
    pub estimator: CollisionEstimator,
    pub metrics: Arc<Metrics>,
    pub k: usize,
    /// Shared with the batcher worker; `RegisterBatch` projects whole
    /// batches directly (they need no size-or-deadline coalescing).
    projector: Arc<Projector>,
    bulk: Mutex<BulkIngest>,
    /// WAL + snapshot engine (None = in-memory service).
    durability: Option<Arc<Durability>>,
    /// Background drain/checkpoint thread; its `Drop` is the graceful-
    /// shutdown flush.
    _maintenance: Maintenance,
}

impl ServiceState {
    /// In-memory service state (no durability). Panics only if
    /// `cfg.durability` is set and fails to open — use
    /// [`ServiceState::open`] for durable configurations.
    pub fn new(projector: Arc<Projector>, cfg: &ServerConfig) -> Arc<Self> {
        Self::open(projector, cfg).expect("opening service state")
    }

    /// Build the service state: recover the store from `cfg.durability`
    /// (snapshot bulk-restore + WAL replay) when configured, and spawn
    /// the background maintenance thread that owns drains, compaction,
    /// and checkpoints.
    pub fn open(projector: Arc<Projector>, cfg: &ServerConfig) -> crate::Result<Arc<Self>> {
        let metrics = Arc::new(Metrics::default());
        let batcher = SketchBatcher::spawn(
            projector.clone(),
            cfg.coding.clone(),
            cfg.batcher.clone(),
            metrics.clone(),
        );
        let k = batcher.k;
        // Arena-backed: Knn/TopK run as columnar scans, not map walks,
        // and registration is epoch-buffered so it never waits behind
        // them.
        let store = Arc::new(SketchStore::with_arena_config(
            k,
            cfg.coding.bits_per_code(),
            cfg.epoch.clone(),
        ));
        let durability = match &cfg.durability {
            Some(dcfg) => {
                let (d, stats) = Durability::open(dcfg.clone(), &store)?;
                metrics
                    .registered
                    .fetch_add(stats.live, std::sync::atomic::Ordering::Relaxed);
                Some(Arc::new(d))
            }
            None => None,
        };
        let maintenance = Maintenance::spawn(
            store.clone(),
            durability.clone(),
            metrics.clone(),
            cfg.maintenance.clone(),
        );
        Ok(Arc::new(ServiceState {
            estimator: CollisionEstimator::new(cfg.coding.clone()),
            batcher,
            metrics,
            k,
            bulk: Mutex::new(BulkIngest {
                encoder: BatchEncoder::new(cfg.coding.clone(), k),
                words: Vec::new(),
            }),
            projector,
            store,
            durability,
            _maintenance: maintenance,
        }))
    }

    /// As [`ServiceState::new`], seeding the store from a snapshot file
    /// (see [`crate::coordinator::durability::snapshot`]) via one bulk
    /// restore — no per-sketch epoch-buffer trips. The snapshot's
    /// sketch shape must match the projector/coding configuration.
    pub fn with_snapshot(
        projector: Arc<Projector>,
        cfg: &ServerConfig,
        snapshot: &std::path::Path,
    ) -> crate::Result<Arc<Self>> {
        // Legacy one-shot restore: the explicit file is the whole
        // story, so strip any durability config rather than recovering
        // through it first and double-restoring (and double-counting
        // `registered`) on top.
        let cfg = ServerConfig {
            durability: None,
            ..cfg.clone()
        };
        let state = Self::open(projector, &cfg)?;
        if snapshot.is_file() {
            let img = crate::coordinator::durability::snapshot::load(snapshot)?;
            // Stored sketches carry the width-rounded packing bits, so
            // compare against the rounded width, not the raw bit count.
            let want_bits = crate::coding::supported_width(cfg.coding.bits_per_code());
            anyhow::ensure!(
                img.rows() == 0 || (img.k == state.k && img.bits == want_bits),
                "snapshot shape (k={}, bits={}) does not match service (k={}, bits={})",
                img.k,
                img.bits,
                state.k,
                want_bits
            );
            let n = crate::coordinator::durability::snapshot::restore_into(&state.store, &img)?;
            state
                .metrics
                .registered
                .fetch_add(n, std::sync::atomic::Ordering::Relaxed);
        }
        Ok(state)
    }

    fn estimate_response(&self, collisions: usize) -> Response {
        let rho = self.estimator.estimate_from_count(collisions, self.k);
        let v = self
            .estimator
            .params
            .scheme
            .variance_factor(rho.min(0.999), self.estimator.params.w);
        Response::Estimate {
            rho,
            std_err: (v / self.k as f64).sqrt(),
            p_hat: collisions as f64 / self.k as f64,
        }
    }

    /// Map scan results to wire hits (ρ̂ from the collision count).
    fn to_knn_hits(&self, hits: Vec<crate::scan::ScanHit>) -> Vec<KnnHit> {
        hits.into_iter()
            .map(|h| KnnHit {
                id: h.id,
                rho: self.estimator.estimate_from_count(h.collisions, self.k),
            })
            .collect()
    }

    /// Exact top-`n` hits for one query sketch, ranked
    /// `(collisions desc, id asc)`. The service store is always
    /// arena-backed (both constructors build it that way), so the scan
    /// engine is the one authoritative ranking path.
    fn topk_hits(&self, q: &PackedCodes, n: usize) -> Vec<KnnHit> {
        let arena = self.store.arena().expect("service store is arena-backed");
        self.to_knn_hits(arena.scan_topk(q, n, 0))
    }

    /// Store one sketch, WAL-first when durability is on: the record is
    /// flushed before the store mutates, so an acknowledged `Register`
    /// survives `kill -9`. An `Err` means nothing was applied.
    fn durable_put(&self, id: &str, codes: PackedCodes) -> crate::Result<()> {
        match &self.durability {
            Some(d) => d.log_put(id, &codes, || self.store.put(id.to_string(), codes.clone())),
            None => {
                self.store.put(id.to_string(), codes);
                Ok(())
            }
        }
    }

    /// Handle one request (the router).
    pub fn handle(&self, req: Request) -> Response {
        match req {
            Request::Ping => Response::Pong,
            Request::Stats => {
                let mut st = self.metrics.snapshot();
                if let Some(arena) = self.store.arena() {
                    st.pending_rows = arena.pending_rows() as u64;
                    st.drains = arena.drains();
                    st.tombstones = arena.tombstones() as u64;
                    st.kernel = arena.kernel_kind().label().to_string();
                }
                if let Some(d) = &self.durability {
                    st.wal_records = d.wal_records();
                    st.wal_bytes = d.wal_bytes();
                    st.last_checkpoint_rows = d.last_checkpoint_rows();
                }
                Response::Stats(st)
            }
            Request::Register { id, vector } => {
                let t0 = Instant::now();
                match self.batcher.sketch(vector) {
                    Ok(codes) => match self.durable_put(&id, codes) {
                        Ok(()) => {
                            self.metrics
                                .registered
                                .fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                            self.metrics
                                .register_latency
                                .record(t0.elapsed().as_micros() as u64);
                            Response::Registered { id }
                        }
                        Err(e) => Response::Error {
                            message: format!("register failed: {e}"),
                        },
                    },
                    Err(e) => Response::Error {
                        message: format!("sketch failed: {e}"),
                    },
                }
            }
            Request::Remove { id } => {
                let result = match &self.durability {
                    Some(d) => d.log_remove(&id, || self.store.remove(&id)),
                    None => Ok(self.store.remove(&id)),
                };
                match result {
                    Ok(existed) => Response::Removed { existed },
                    Err(e) => Response::Error {
                        message: format!("remove failed: {e}"),
                    },
                }
            }
            Request::Persist => match &self.durability {
                Some(d) => match d.checkpoint(&self.store) {
                    Ok((rows, wal_bytes)) => Response::Persisted { rows, wal_bytes },
                    Err(e) => Response::Error {
                        message: format!("checkpoint failed: {e}"),
                    },
                },
                None => Response::Error {
                    message: "durability is not enabled (serve with --snapshot/--wal-dir)"
                        .to_string(),
                },
            },
            Request::Estimate { a, b } => {
                let (sa, sb) = (self.store.get(&a), self.store.get(&b));
                match (sa, sb) {
                    (Some(sa), Some(sb)) => {
                        self.metrics
                            .estimates
                            .fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                        let collisions = crate::coding::collision_count_packed(&sa, &sb);
                        self.estimate_response(collisions)
                    }
                    (None, _) => Response::Error {
                        message: format!("unknown id {a:?}"),
                    },
                    (_, None) => Response::Error {
                        message: format!("unknown id {b:?}"),
                    },
                }
            }
            Request::EstimateVec { id, vector } => {
                let Some(stored) = self.store.get(&id) else {
                    return Response::Error {
                        message: format!("unknown id {id:?}"),
                    };
                };
                match self.batcher.sketch(vector) {
                    Ok(q) => {
                        self.metrics
                            .estimates
                            .fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                        let collisions = crate::coding::collision_count_packed(&q, &stored);
                        self.estimate_response(collisions)
                    }
                    Err(e) => Response::Error {
                        message: format!("sketch failed: {e}"),
                    },
                }
            }
            Request::Knn { vector, n } => match self.batcher.sketch(vector) {
                Ok(q) => {
                    self.metrics
                        .knn_queries
                        .fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                    Response::Knn {
                        hits: self.topk_hits(&q, n as usize),
                    }
                }
                Err(e) => Response::Error {
                    message: format!("sketch failed: {e}"),
                },
            },
            Request::TopK { vectors, n } => {
                let mut queries = Vec::with_capacity(vectors.len());
                for vector in vectors {
                    match self.batcher.sketch(vector) {
                        Ok(q) => queries.push(q),
                        Err(e) => {
                            return Response::Error {
                                message: format!("sketch failed: {e}"),
                            }
                        }
                    }
                }
                self.metrics
                    .knn_queries
                    .fetch_add(queries.len() as u64, std::sync::atomic::Ordering::Relaxed);
                let arena = self.store.arena().expect("service store is arena-backed");
                let results = arena
                    .scan_topk_batch(&queries, n as usize, 0)
                    .into_iter()
                    .map(|hits| self.to_knn_hits(hits))
                    .collect();
                Response::TopK { results }
            }
            Request::RegisterBatch { ids, vectors } => self.register_batch(ids, vectors),
        }
    }

    /// The fused bulk-ingest path: one batched projection, one
    /// encode+pack pass into a reused word buffer, one bulk arena
    /// insert. Sketches are byte-identical to per-vector `Register`
    /// (same projector, same coding, same packing).
    fn register_batch(&self, ids: Vec<String>, vectors: Vec<Vec<f32>>) -> Response {
        if ids.len() != vectors.len() {
            return Response::Error {
                message: format!(
                    "ids/vectors length mismatch ({} vs {})",
                    ids.len(),
                    vectors.len()
                ),
            };
        }
        if ids.is_empty() {
            return Response::RegisteredBatch { count: 0 };
        }
        let t0 = Instant::now();
        let b = vectors.len();
        let d = vectors.iter().map(|v| v.len()).max().unwrap_or(1).max(1);
        if b.saturating_mul(d) > MAX_BULK_CELLS {
            return Response::Error {
                message: format!(
                    "batch of {b} vectors padded to dim {d} exceeds the bulk \
                     workspace limit of {MAX_BULK_CELLS} cells"
                ),
            };
        }
        let x = self
            .projector
            .project_ragged(vectors.iter().map(|v| v.as_slice()), b);
        let stored = {
            let mut bulk = self.bulk.lock().unwrap();
            let BulkIngest { encoder, words } = &mut *bulk;
            encoder.encode_pack_batch_into(&x, b, words);
            let words: &[u64] = words;
            match &self.durability {
                // One WAL record, one flush, for the whole batch.
                Some(d) => d.log_put_rows(&ids, words, || self.store.put_rows(&ids, words)),
                None => self.store.put_rows(&ids, words),
            }
        };
        match stored {
            Ok(()) => {
                use std::sync::atomic::Ordering::Relaxed;
                self.metrics.registered.fetch_add(b as u64, Relaxed);
                self.metrics.batches_executed.fetch_add(1, Relaxed);
                self.metrics.vectors_projected.fetch_add(b as u64, Relaxed);
                // One amortized sample per vector, so the percentiles
                // weight bulk and per-request registrations equally.
                self.metrics
                    .register_latency
                    .record_n((t0.elapsed().as_micros() as u64 / b as u64).max(1), b as u64);
                Response::RegisteredBatch { count: b as u64 }
            }
            Err(e) => Response::Error {
                message: format!("bulk register failed: {e}"),
            },
        }
    }
}

/// Run the server until the listener errors. Binds, then reports the
/// bound address through `ready` (useful for ephemeral-port tests).
pub fn serve(
    projector: Arc<Projector>,
    cfg: ServerConfig,
    ready: Option<std::sync::mpsc::Sender<std::net::SocketAddr>>,
) -> crate::Result<()> {
    let listener = TcpListener::bind(&cfg.addr)?;
    let addr = listener.local_addr()?;
    if let Some(tx) = ready {
        let _ = tx.send(addr);
    }
    let state = ServiceState::open(projector, &cfg)?;
    if cfg.durability.is_some() {
        eprintln!(
            "durability on: {} sketches recovered from snapshot + WAL",
            state.store.len()
        );
    }
    for stream in listener.incoming() {
        let stream = stream?;
        let state = state.clone();
        std::thread::Builder::new()
            .name("crp-conn".into())
            .spawn(move || {
                let _ = handle_connection(stream, state);
            })?;
    }
    Ok(())
}

fn handle_connection(stream: TcpStream, state: Arc<ServiceState>) -> crate::Result<()> {
    stream.set_nodelay(true)?;
    let mut reader = std::io::BufReader::new(stream.try_clone()?);
    let mut writer = std::io::BufWriter::new(stream);
    loop {
        let frame = match protocol::read_frame(&mut reader) {
            Ok(f) => f,
            Err(_) => return Ok(()), // client closed
        };
        let resp = match Request::decode(&frame) {
            Ok(req) => state.handle(req),
            Err(e) => Response::Error {
                message: format!("bad request: {e}"),
            },
        };
        protocol::write_frame(&mut writer, &resp.encode())?;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::projection::ProjectionConfig;

    fn state(k: usize) -> Arc<ServiceState> {
        let projector = Arc::new(Projector::new_cpu(ProjectionConfig {
            k,
            seed: 7,
            ..Default::default()
        }));
        ServiceState::new(projector, &ServerConfig::default())
    }

    #[test]
    fn register_then_estimate() {
        let s = state(512);
        let (u, v) = crate::data::pairs::unit_pair_with_rho(128, 0.85, 3);
        let r1 = s.handle(Request::Register {
            id: "u".into(),
            vector: u,
        });
        assert!(matches!(r1, Response::Registered { .. }));
        let r2 = s.handle(Request::Register {
            id: "v".into(),
            vector: v,
        });
        assert!(matches!(r2, Response::Registered { .. }));
        match s.handle(Request::Estimate {
            a: "u".into(),
            b: "v".into(),
        }) {
            Response::Estimate { rho, std_err, .. } => {
                assert!(
                    (rho - 0.85).abs() < 4.0 * std_err + 0.05,
                    "rho {rho} err {std_err}"
                );
            }
            other => panic!("unexpected {other:?}"),
        }
    }

    #[test]
    fn unknown_id_errors() {
        let s = state(64);
        match s.handle(Request::Estimate {
            a: "nope".into(),
            b: "nada".into(),
        }) {
            Response::Error { message } => assert!(message.contains("nope")),
            other => panic!("unexpected {other:?}"),
        }
    }

    #[test]
    fn knn_orders_by_similarity() {
        let s = state(512);
        let (base, near) = crate::data::pairs::unit_pair_with_rho(96, 0.95, 11);
        let (_, far) = crate::data::pairs::unit_pair_with_rho(96, 0.1, 12);
        s.handle(Request::Register {
            id: "near".into(),
            vector: near,
        });
        s.handle(Request::Register {
            id: "far".into(),
            vector: far,
        });
        match s.handle(Request::Knn {
            vector: base,
            n: 2,
        }) {
            Response::Knn { hits } => {
                assert_eq!(hits.len(), 2);
                assert_eq!(hits[0].id, "near");
                assert!(hits[0].rho > hits[1].rho);
            }
            other => panic!("unexpected {other:?}"),
        }
    }

    #[test]
    fn knn_scan_is_byte_identical_to_bruteforce() {
        let s = state(256);
        let mut g = crate::mathx::Pcg64::new(77, 0);
        for i in 0..60 {
            let v: Vec<f32> = (0..48).map(|_| g.next_f64() as f32 - 0.5).collect();
            s.handle(Request::Register {
                id: format!("v{i:02}"),
                vector: v,
            });
        }
        let q: Vec<f32> = (0..48).map(|_| g.next_f64() as f32 - 0.5).collect();
        // Register the query too: the batcher is deterministic, so its
        // stored sketch equals the sketch Knn computes internally.
        s.handle(Request::Register {
            id: "query".into(),
            vector: q.clone(),
        });
        let qs = s.store.get("query").unwrap();
        let mut want: Vec<(String, usize)> = Vec::new();
        s.store.for_each(|id, codes| {
            want.push((
                id.to_string(),
                crate::coding::collision_count_packed(&qs, codes),
            ));
        });
        want.sort_by(|a, b| b.1.cmp(&a.1).then_with(|| a.0.cmp(&b.0)));
        want.truncate(10);
        match s.handle(Request::Knn { vector: q, n: 10 }) {
            Response::Knn { hits } => {
                assert_eq!(hits.len(), 10);
                assert_eq!(hits[0].id, "query");
                for (hit, (id, c)) in hits.iter().zip(&want) {
                    assert_eq!(&hit.id, id);
                    assert_eq!(hit.rho, s.estimator.estimate_from_count(*c, s.k));
                }
            }
            other => panic!("unexpected {other:?}"),
        }
    }

    #[test]
    fn topk_batch_matches_per_query_knn() {
        let s = state(128);
        let mut g = crate::mathx::Pcg64::new(5, 5);
        for i in 0..40 {
            let v: Vec<f32> = (0..32).map(|_| g.next_f64() as f32 - 0.5).collect();
            s.handle(Request::Register {
                id: format!("c{i}"),
                vector: v,
            });
        }
        let queries: Vec<Vec<f32>> = (0..5)
            .map(|_| (0..32).map(|_| g.next_f64() as f32 - 0.5).collect())
            .collect();
        let batched = match s.handle(Request::TopK {
            vectors: queries.clone(),
            n: 3,
        }) {
            Response::TopK { results } => results,
            other => panic!("unexpected {other:?}"),
        };
        assert_eq!(batched.len(), queries.len());
        for (q, want) in queries.into_iter().zip(&batched) {
            match s.handle(Request::Knn { vector: q, n: 3 }) {
                Response::Knn { hits } => assert_eq!(&hits, want),
                other => panic!("unexpected {other:?}"),
            }
        }
    }

    #[test]
    fn register_batch_matches_per_vector_register() {
        let s = state(256);
        let mut g = crate::mathx::Pcg64::new(31, 0);
        let vectors: Vec<Vec<f32>> = (0..20)
            .map(|_| (0..40).map(|_| g.next_f64() as f32 - 0.5).collect())
            .collect();
        for (i, v) in vectors.iter().enumerate() {
            s.handle(Request::Register {
                id: format!("single{i}"),
                vector: v.clone(),
            });
        }
        let ids: Vec<String> = (0..20).map(|i| format!("bulk{i}")).collect();
        match s.handle(Request::RegisterBatch {
            ids: ids.clone(),
            vectors: vectors.clone(),
        }) {
            Response::RegisteredBatch { count } => assert_eq!(count, 20),
            other => panic!("unexpected {other:?}"),
        }
        // The fused pipeline must produce byte-identical sketches.
        for i in 0..20 {
            assert_eq!(
                s.store.get(&format!("bulk{i}")),
                s.store.get(&format!("single{i}")),
                "vector {i}"
            );
        }
        match s.handle(Request::RegisterBatch {
            ids,
            vectors: vec![],
        }) {
            Response::Error { .. } => {}
            other => panic!("unexpected {other:?}"),
        }
        match s.handle(Request::Stats) {
            Response::Stats(st) => {
                assert_eq!(st.registered, 40);
                assert!(!st.kernel.is_empty(), "stats must name the scan kernel");
            }
            other => panic!("unexpected {other:?}"),
        }
    }

    #[test]
    fn stats_track_activity() {
        let s = state(64);
        s.handle(Request::Register {
            id: "a".into(),
            vector: vec![1.0; 32],
        });
        match s.handle(Request::Stats) {
            Response::Stats(st) => {
                assert_eq!(st.registered, 1);
                assert!(st.vectors_projected >= 1);
            }
            other => panic!("unexpected {other:?}"),
        }
    }
}
